"""Inject the roofline tables into EXPERIMENTS.md from reports/dryrun/*.json.

    PYTHONPATH=src python -m repro.tools.update_experiments
"""

from __future__ import annotations

import glob
import json
import os
import re

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DRYRUN = os.path.join(REPO, "reports", "dryrun")
EXP = os.path.join(REPO, "EXPERIMENTS.md")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load():
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def is_variant(r):
    return r["tag"].count("__") >= 3


def fmt(r):
    roof = r["roofline"]
    peak = r["memory"].get("peak_bytes_per_device") or 0
    return (
        f"| {r['arch']} | {r['shape']} | {roof['compute_s']*1e3:,.1f} "
        f"| {roof['memory_s']*1e3:,.1f} | {roof['collective_s']*1e3:,.1f} "
        f"| {roof['dominant']} | {roof['useful_ratio']:.2f} "
        f"| {peak/2**30:.2f} |"
    )


def baseline_table(reports):
    head = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant "
        "| useful | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    rows, skips = [], []
    sel = [r for r in reports
           if not is_variant(r) and r.get("mesh") == "16x16"]
    sel.sort(key=lambda r: (r.get("arch", ""), SHAPE_ORDER.get(r.get("shape"), 9)))
    for r in sel:
        if r["status"] == "ok":
            rows.append(fmt(r))
        elif r["status"] == "skipped":
            skips.append(f"| {r['tag'].split('__')[0]} | "
                         f"{r['tag'].split('__')[1]} | — | — | — | skipped | — | — |")
    note = (f"\n*(multi-pod 2×16×16: every non-skipped pair also lowers and "
            f"compiles — JSONs in reports/dryrun/ with the `2x16x16` tag; "
            f"the roofline table is single-pod per the brief.)*")
    return "\n".join(head + rows + skips) + note


def optimized_table(reports):
    head = [
        "| arch | shape | variant | compute ms | memory ms | collective ms "
        "| dominant | useful |",
        "|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    base = {(r["arch"], r["shape"]): r for r in reports
            if not is_variant(r) and r.get("mesh") == "16x16"
            and r["status"] == "ok"}
    sel = [r for r in reports if is_variant(r) and r["status"] == "ok"
           and r["tag"].endswith("__optimized")]
    sel.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    for r in sel:
        roof = r["roofline"]
        b = base.get((r["arch"], r["shape"]))
        delta = ""
        if b:
            br = b["roofline"]
            dom = br["dominant"] + "_s"
            if br[dom] > 0:
                delta = f" ({roof[dom]/br[dom]-1:+.0%} vs baseline dominant)"
        rows.append(
            f"| {r['arch']} | {r['shape']} | optimized "
            f"| {roof['compute_s']*1e3:,.1f} | {roof['memory_s']*1e3:,.1f} "
            f"| {roof['collective_s']*1e3:,.1f} | {roof['dominant']}{delta} "
            f"| {roof['useful_ratio']:.2f} |"
        )
    return "\n".join(head + rows)


def main():
    reports = load()
    with open(EXP) as f:
        text = f.read()
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n\nReading of the table)",
        "<!-- ROOFLINE_TABLE -->\n" + baseline_table(reports),
        text, flags=re.S,
    )
    text = re.sub(
        r"<!-- OPTIMIZED_TABLE -->.*?(?=\n\n## §Bench harness)",
        "<!-- OPTIMIZED_TABLE -->\n" + optimized_table(reports),
        text, flags=re.S,
    )
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
