"""Roofline analysis from the compiled dry-run artifact (deliverable g).

Three terms per (arch, shape, mesh), in seconds:

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are NOT in cost_analysis, so we parse the optimized HLO text and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops. MODEL_FLOPS = 6 N D (dense) or 6 N_active D (MoE)
anchors the "useful compute" ratio.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "bf16[16,1024,128]{2,1,0} all-gather(...)" — capture result type + op
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
# tuple-result collectives: "= (f32[...], f32[...]) all-reduce-start(...)"
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: int) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + nbytes
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of every collective op in the optimized HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not any(c in stripped for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(stripped)
        if m:
            dtype, dims, kind = m.groups()
            stats.add(kind, _shape_bytes(dtype, dims))
            continue
        m = _TUPLE_RE.search(stripped)
        if m:
            inner, kind = m.groups()
            total = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(inner)
            )
            # tuple results hold (operand, result) for -start ops: halve to
            # avoid double counting the aliased input buffer
            stats.add(kind, total // 2 if "-start" in stripped else total)
    return stats


@dataclass
class Roofline:
    flops: float              # whole-program HLO flops (all chips)
    hbm_bytes: float          # whole-program HLO bytes accessed
    collective_bytes: float   # whole-program bytes moved by collectives
    chips: int
    model_flops: float        # 6*N(_active)*D useful flops

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
        }


def model_flops_estimate(cfg, tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D for inference (N = active params)."""
    n_active = cfg.active_params()
    per_token = 6.0 if kind == "train" else 2.0
    return per_token * n_active * tokens


def roofline_from_costs(per_device: dict, cfg, shape_spec, chips: int) -> Roofline:
    """Build a Roofline from per-device cost dict (composite or direct)."""
    tokens = shape_spec.global_batch * (
        shape_spec.seq_len if shape_spec.kind != "decode" else 1
    )
    return Roofline(
        flops=per_device["flops"] * chips,
        hbm_bytes=per_device["bytes"] * chips,
        collective_bytes=per_device["collective_bytes"] * chips,
        chips=chips,
        model_flops=model_flops_estimate(cfg, tokens, shape_spec.kind),
    )


def roofline_from_compiled(compiled, cfg, shape_spec, chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    # jax 0.8: cost_analysis() returns a dict (or list of one dict)
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # cost_analysis reports PER-DEVICE quantities (the compiled module is the
    # per-device SPMD program — calibrated in EXPERIMENTS.md §Dry-run); the
    # roofline terms divide by chips, so scale back to whole-program numbers.
    flops = float(cost.get("flops", 0.0)) * chips
    hbm = float(cost.get("bytes accessed", 0.0)) * chips
    # collective shapes in the partitioned HLO are per-device shards as well:
    # total_bytes is per-device traffic; whole-program = x chips.
    stats = parse_collectives(compiled.as_text())
    tokens = shape_spec.global_batch * (
        shape_spec.seq_len if shape_spec.kind != "decode" else 1
    )
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(stats.total_bytes) * chips,
        chips=chips,
        model_flops=model_flops_estimate(cfg, tokens, shape_spec.kind),
    )
