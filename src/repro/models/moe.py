"""Mixture-of-Experts FFN: top-k routing with ragged (sorted) expert matmuls.

TPU-idiomatic dispatch (DESIGN.md hardware-adaptation table): instead of the
GShard dense one-hot dispatch tensor (O(S^2 * E / capacity) bytes) we sort the
token copies by expert id and run ``jax.lax.ragged_dot`` — grouped matmuls the
TPU executes back-to-back on the MXU (the megablox pattern). FLOPs scale with
*active* params only, which keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio
honest for MoE architectures.

Experts are sharded over the "model" mesh axis on the leading (group) dim of
each expert weight; GSPMD turns the sorted-token exchange into an all-to-all.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import partition


def init_moe(key, cfg, d_model: int, d_ff: int) -> dict:
    E = cfg.moe.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "router": (jax.random.normal(k1, (d_model, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (E, d_model, d_ff)) * s_in).astype(dt),
        "w_up": (jax.random.normal(k3, (E, d_model, d_ff)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k4, (E, d_ff, d_model)) * s_out).astype(dt),
    }


def moe_ffn(p: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    aux_loss is the Switch-style load-balance term
    E * sum_e f_e * p_e (f = dispatch fraction, p = mean router prob).
    """
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    T = B * S
    xf = x.reshape(T, D)

    router_logits = xf.astype(jnp.float32) @ p["router"]       # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                     # (T, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Load-balance auxiliary loss.
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_i, E, dtype=jnp.float32).sum(axis=1), axis=0
    )
    aux = E * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0)) * cfg.moe.aux_loss_weight

    # Token copies sorted by expert: ragged grouped matmuls.
    expert_id = top_i.reshape(T * K)
    order = jnp.argsort(expert_id)
    inv_order = jnp.argsort(order)
    xs = jnp.repeat(xf, K, axis=0)[order]                      # (T*K, D)
    group_sizes = jnp.bincount(expert_id, length=E).astype(jnp.int32)

    dt = x.dtype
    hg = partition.shard_ff(jax.lax.ragged_dot(xs, p["w_gate"].astype(dt), group_sizes))
    hu = partition.shard_ff(jax.lax.ragged_dot(xs, p["w_up"].astype(dt), group_sizes))
    act = jax.nn.silu(hg) * hu
    ys = jax.lax.ragged_dot(act, p["w_down"].astype(dt), group_sizes)  # (T*K, D)

    y = ys[inv_order].reshape(T, K, D)
    out = jnp.sum(y * top_w[..., None].astype(dt), axis=1)
    return partition.shard_tokens(out.reshape(B, S, D)), aux


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_ffn_dense(p: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-padded dense dispatch: (E, C, D) buckets + batched matmuls.

    GSPMD partitions plain batched dot_generals (unlike ragged_dot), so the
    per-device expert FLOPs really are global/chips; tokens over capacity C
    are dropped (standard Switch behaviour, capacity_factor controls slack).
    """
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    T = B * S
    TK = T * K
    xf = x.reshape(T, D)

    router_logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_i, E, dtype=jnp.float32).sum(axis=1), axis=0
    )
    aux = E * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0)) * cfg.moe.aux_loss_weight

    # Rank of each token copy within its expert bucket.
    expert_id = top_i.reshape(TK)
    order = jnp.argsort(expert_id)
    sorted_e = expert_id[order]
    group_sizes = jnp.bincount(expert_id, length=E)
    starts = jnp.cumsum(group_sizes) - group_sizes          # exclusive
    rank_sorted = jnp.arange(TK) - starts[sorted_e]

    C = _round_up(max(1, int(TK / E * cfg.moe.capacity_factor)), 256)
    keep = rank_sorted < C

    token_sorted = (order // K).astype(jnp.int32)
    dt = x.dtype
    xd = jnp.zeros((E, C, D), dt)
    xd = xd.at[sorted_e, jnp.where(keep, rank_sorted, 0)].add(
        jnp.where(keep[:, None], xf[token_sorted], 0)
    )
    xd = partition.constrain(
        xd, lambda axes: _ecd_spec(axes, C, D, hidden=False)
    )

    wg = p["w_gate"].astype(dt)
    wu = p["w_up"].astype(dt)
    wd = p["w_down"].astype(dt)
    h = jnp.einsum("ecd,edf->ecf", xd, wg)
    h = partition.constrain(h, lambda axes: _ecd_spec(axes, C, h.shape[-1], hidden=True))
    u = jnp.einsum("ecd,edf->ecf", xd, wu)
    act = jax.nn.silu(h) * u
    yd = jnp.einsum("ecf,efd->ecd", act, wd)                # (E, C, D)
    yd = partition.constrain(
        yd, lambda axes: _ecd_spec(axes, C, D, hidden=False)
    )

    # Combine back: gather each copy's expert output (dropped copies get 0).
    ys = jnp.where(
        keep[:, None],
        yd[sorted_e, jnp.where(keep, rank_sorted, 0)],
        0,
    )
    inv_order = jnp.argsort(order)
    y = ys[inv_order].reshape(T, K, D)
    out = jnp.sum(y * top_w[..., None].astype(dt), axis=1)
    return partition.shard_tokens(out.reshape(B, S, D)), aux


def _ecd_spec(axes, C, last, hidden):
    """(E, C, last): capacity over the batch axes, last dim over model."""
    from jax.sharding import PartitionSpec as P

    ba = tuple(a for a in ("pod", "data") if a in axes)
    total = 1
    for a in ba:
        total *= axes[a]
    c_ax = ba if (ba and C % total == 0) else None
    m_ax = "model" if ("model" in axes and last % axes["model"] == 0) else None
    if c_ax is None and m_ax is None:
        return None
    return P(None, c_ax, m_ax)


def moe_ffn_dispatch(p: dict, x: jnp.ndarray, cfg):
    """Select implementation by cfg.moe.impl."""
    if cfg.moe.impl == "dense":
        return moe_ffn_dense(p, x, cfg)
    return moe_ffn(p, x, cfg)
