from repro.models import blocks, layers, model, moe, ssm  # noqa: F401
from repro.models.config import (  # noqa: F401
    EncoderConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
)
