"""Block-level composition: init / forward / decode for every block type.

A block is (params, x) -> (x, aux). Pre-norm residual throughout; gemma2 adds
post-norms (cfg.post_norm). Decode variants thread a per-block cache.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_mod, ssm as ssm_mod

ATTN_TYPES = {"attn", "attn_local", "attn_swa", "attn_moe", "enc_attn", "dec_attn"}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_block(key, block_type: str, cfg) -> dict:
    d, hd = cfg.d_model, cfg.hd
    keys = jax.random.split(key, 8)
    p: dict = {}
    if block_type in ATTN_TYPES:
        p["ln_attn"] = layers.init_norm(cfg, d)
        p["attn"] = layers.init_attention(
            keys[0], cfg, d, cfg.n_heads, cfg.n_kv_heads, hd
        )
        if cfg.post_norm:
            p["ln_attn_post"] = layers.init_norm(cfg, d)
        if block_type == "dec_attn":
            p["ln_cross"] = layers.init_norm(cfg, d)
            p["cross"] = layers.init_attention(
                keys[1], cfg, d, cfg.n_heads, cfg.n_heads, hd, cross=True
            )
        p["ln_ffn"] = layers.init_norm(cfg, d)
        if block_type in ("attn_swa", "attn_moe"):
            p["moe"] = moe_mod.init_moe(keys[2], cfg, d, cfg.d_ff)
        else:
            p["ffn"] = layers.init_ffn(keys[2], cfg, d, cfg.d_ff)
        if cfg.post_norm:
            p["ln_ffn_post"] = layers.init_norm(cfg, d)
    elif block_type == "mamba":
        p["ln"] = layers.init_norm(cfg, d)
        p["mamba"] = ssm_mod.init_mamba(keys[0], cfg, d)
    elif block_type == "rwkv":
        p["ln_time"] = layers.init_norm(cfg, d)
        p["time"] = ssm_mod.init_rwkv(keys[0], cfg, d)
        p["ln_chan"] = layers.init_norm(cfg, d)
        p["chan"] = ssm_mod.init_rwkv_channel(keys[1], cfg, d, cfg.d_ff)
    else:
        raise ValueError(f"unknown block type {block_type!r}")
    return p


def init_shared_attn(key, cfg) -> dict:
    """Zamba2's weight-shared attention+FFN block (applied periodically)."""
    d, hd = cfg.d_model, cfg.hd
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": layers.init_norm(cfg, d),
        "attn": layers.init_attention(k1, cfg, d, cfg.n_heads, cfg.n_kv_heads, hd),
        "ln_ffn": layers.init_norm(cfg, d),
        "ffn": layers.init_ffn(k2, cfg, d, cfg.d_ff),
    }


# ---------------------------------------------------------------------------
# Forward (full sequence)
# ---------------------------------------------------------------------------
def _attn_kwargs(block_type: str, cfg) -> dict:
    window = cfg.window if block_type in ("attn_local", "attn_swa") else 0
    return dict(
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
        causal=block_type != "enc_attn",
        window=window,
        attn_softcap=cfg.attn_softcap,
        use_rope=cfg.pos_type == "rope",
    )


def block_forward(
    p: dict, x: jnp.ndarray, block_type: str, cfg,
    enc_out: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    if block_type in ATTN_TYPES:
        h = layers.attention(
            p["attn"], layers.apply_norm(p["ln_attn"], x, cfg), cfg,
            **_attn_kwargs(block_type, cfg),
        )
        if cfg.post_norm:
            h = layers.apply_norm(p["ln_attn_post"], h, cfg)
        x = x + h
        if block_type == "dec_attn":
            h = layers.attention(
                p["cross"], layers.apply_norm(p["ln_cross"], x, cfg), cfg,
                n_heads=cfg.n_heads, n_kv=cfg.n_heads, hd=cfg.hd,
                causal=False, kv_src=enc_out, use_rope=False,
            )
            x = x + h
        z = layers.apply_norm(p["ln_ffn"], x, cfg)
        if block_type in ("attn_swa", "attn_moe"):
            h, aux = moe_mod.moe_ffn_dispatch(p["moe"], z, cfg)
        else:
            h = layers.ffn(p["ffn"], z, cfg)
        if cfg.post_norm:
            h = layers.apply_norm(p["ln_ffn_post"], h, cfg)
        x = x + h
    elif block_type == "mamba":
        x = x + ssm_mod.mamba_forward(
            p["mamba"], layers.apply_norm(p["ln"], x, cfg), cfg, cfg.d_model
        )
    elif block_type == "rwkv":
        x = x + ssm_mod.rwkv_forward(
            p["time"], layers.apply_norm(p["ln_time"], x, cfg), cfg, cfg.d_model
        )
        out, _ = ssm_mod.rwkv_channel_mix(
            p["chan"], layers.apply_norm(p["ln_chan"], x, cfg)
        )
        x = x + out
    else:
        raise ValueError(block_type)
    return x, aux


def shared_attn_forward(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    h = layers.attention(
        p["attn"], layers.apply_norm(p["ln_attn"], x, cfg), cfg,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
        causal=True, use_rope=cfg.pos_type == "rope",
    )
    x = x + h
    x = x + layers.ffn(p["ffn"], layers.apply_norm(p["ln_ffn"], x, cfg), cfg)
    return x


# ---------------------------------------------------------------------------
# Cache init + decode (single token)
# ---------------------------------------------------------------------------
def init_block_cache(block_type: str, cfg, batch: int, seq_len: int) -> dict:
    if block_type in ATTN_TYPES:
        window = cfg.window if block_type in ("attn_local", "attn_swa") else 0
        cache = layers.init_kv_cache(
            cfg, batch, seq_len, cfg.n_kv_heads, cfg.hd, window
        )
        return cache
    if block_type == "mamba":
        return ssm_mod.init_mamba_cache(cfg, batch, cfg.d_model)
    if block_type == "rwkv":
        c = ssm_mod.init_rwkv_cache(cfg, batch, cfg.d_model)
        c["chan_prev"] = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
        return c
    raise ValueError(block_type)


def block_decode(
    p: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
    block_type: str, cfg,
    cross_cache: Optional[dict] = None,
) -> tuple[jnp.ndarray, dict]:
    if block_type in ATTN_TYPES:
        window = cfg.window if block_type in ("attn_local", "attn_swa") else 0
        h, new_cache = layers.attention_decode(
            p["attn"], layers.apply_norm(p["ln_attn"], x, cfg), cache, pos, cfg,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
            window=window, attn_softcap=cfg.attn_softcap,
            use_rope=cfg.pos_type == "rope",
        )
        if cfg.post_norm:
            h = layers.apply_norm(p["ln_attn_post"], h, cfg)
        x = x + h
        if block_type == "dec_attn":
            # cross-attention against precomputed encoder K/V (cross_cache)
            h = _cross_decode(p["cross"], layers.apply_norm(p["ln_cross"], x, cfg),
                              cross_cache, cfg)
            x = x + h
        z = layers.apply_norm(p["ln_ffn"], x, cfg)
        if block_type in ("attn_swa", "attn_moe"):
            h, _ = moe_mod.moe_ffn_dispatch(p["moe"], z, cfg)
        else:
            h = layers.ffn(p["ffn"], z, cfg)
        if cfg.post_norm:
            h = layers.apply_norm(p["ln_ffn_post"], h, cfg)
        return x + h, new_cache
    if block_type == "mamba":
        h, new_cache = ssm_mod.mamba_decode(
            p["mamba"], layers.apply_norm(p["ln"], x, cfg), cache, cfg, cfg.d_model
        )
        return x + h, new_cache
    if block_type == "rwkv":
        h, time_cache = ssm_mod.rwkv_decode(
            p["time"], layers.apply_norm(p["ln_time"], x, cfg),
            {"state": cache["state"], "x_prev": cache["x_prev"]}, cfg, cfg.d_model,
        )
        x = x + h
        z = layers.apply_norm(p["ln_chan"], x, cfg)
        out, _ = ssm_mod.rwkv_channel_mix(
            p["chan"], z, x_prev=cache["chan_prev"].astype(z.dtype)
        )
        new_cache = dict(time_cache, chan_prev=z.astype(jnp.float32))
        return x + out, new_cache
    raise ValueError(block_type)


def _cross_decode(p: dict, x: jnp.ndarray, cross_cache: dict, cfg) -> jnp.ndarray:
    """Cross-attention with K/V precomputed once from encoder output."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, 1, H, hd)
    k, v = cross_cache["k"], cross_cache["v"]     # (B, S_enc, H, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
    probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, 1, H * hd)
    return out @ p["wo"].astype(x.dtype)


def shared_attn_decode(p: dict, x: jnp.ndarray, cache: dict, pos, cfg):
    h, new_cache = layers.attention_decode(
        p["attn"], layers.apply_norm(p["ln_attn"], x, cfg), cache, pos, cfg,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
        use_rope=cfg.pos_type == "rope",
    )
    x = x + h
    x = x + layers.ffn(p["ffn"], layers.apply_norm(p["ln_ffn"], x, cfg), cfg)
    return x, new_cache


def make_cross_cache(p_block: dict, enc_out: jnp.ndarray, cfg) -> dict:
    """Precompute cross-attention K/V from encoder output for one dec layer."""
    B, S_enc, _ = enc_out.shape
    k = (enc_out @ p_block["cross"]["wk"].astype(enc_out.dtype)).reshape(B, S_enc, cfg.n_heads, cfg.hd)
    v = (enc_out @ p_block["cross"]["wv"].astype(enc_out.dtype)).reshape(B, S_enc, cfg.n_heads, cfg.hd)
    return {"k": k, "v": v}
