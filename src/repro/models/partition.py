"""Activation-sharding constraints, mesh-aware and no-op off-mesh.

GSPMD propagates weight shardings through the forward, but without anchors on
activations it can choose replication — the calibration experiment in
EXPERIMENTS.md §Perf showed ~14x redundant per-device FLOPs on smollm before
these constraints existed. Every helper:

  * reads the ambient abstract mesh (jax.set_mesh / jit context),
  * silently no-ops when there is no mesh (CPU smoke tests) or when the dim
    is not divisible by the target axis size (MQA kv=1, batch=1, H=9, ...).

Axis conventions match DESIGN.md §8: batch -> ("pod","data"), feature/head/
expert fan-out -> "model".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh


def _mesh_axes() -> dict:
    mesh = get_abstract_mesh()
    if mesh.empty:
        return {}
    return dict(mesh.shape)


def _batch_axes(axes: dict) -> tuple:
    return tuple(a for a in ("pod", "data") if a in axes)


def _fits(dim: int, names, axes: dict) -> bool:
    if isinstance(names, str):
        names = (names,)
    total = 1
    for n in names:
        if n not in axes:
            return False
        total *= axes[n]
    return dim % total == 0


def constrain(x: jnp.ndarray, spec_builder) -> jnp.ndarray:
    """Apply with_sharding_constraint(spec_builder(axes)) if a mesh is set."""
    axes = _mesh_axes()
    if not axes:
        return x
    spec = spec_builder(axes)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def shard_tokens(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, ...) activations between blocks: batch over (pod, data)."""

    def build(axes):
        ba = _batch_axes(axes)
        if not ba or not _fits(x.shape[0], ba, axes):
            return None
        return P(ba, *([None] * (x.ndim - 1)))

    return constrain(x, build)


def shard_fused_heads(x: jnp.ndarray, n_heads: int | None = None,
                      seq_ok: bool = True) -> jnp.ndarray:
    """(B, S, H*hd) fused-head activations (attention output before w_o).

    When heads divide the model axis, shard the fused dim (w_o's contraction
    reduces locally, reduce-scatter friendly). When they DON'T (gemma2 H=8),
    keep the SEQUENCE sharding the scores carried — constraining the fused
    dim here made XLA reshard by all-gathering the (S, S) f32 probs in the
    backward (EXPERIMENTS.md §Perf, gemma2 iteration 2).
    """

    def build(axes):
        ba = _batch_axes(axes)
        b = ba if (ba and _fits(x.shape[0], ba, axes)) else None
        heads_fit = n_heads is None or _fits(n_heads, "model", axes)
        if not heads_fit and seq_ok and x.shape[1] > 1 and                 _fits(x.shape[1], "model", axes):
            return P(b, "model", None)
        m = "model" if _fits(x.shape[-1], "model", axes) else None
        if b is None and m is None:
            return None
        return P(b, None, m)

    return constrain(x, build)


def shard_heads(x: jnp.ndarray, role: str = "q", seq_ok: bool = True) -> jnp.ndarray:
    """(B, S, H, hd) split heads.

    Preference order (EXPERIMENTS.md §Perf, gemma2 hillclimb):
      1. heads over "model" when H divides — zero-redundancy head parallelism;
      2. for QUERIES: the query-sequence dim over "model" — keeps the (S, S)
         score/prob tensors sharded through fwd AND bwd (the hd fallback made
         XLA all-gather 4 full S^2 f32 tensors per layer in the backward);
      3. head_dim over "model" (legacy fallback, kept for decode's S == 1);
      4. batch only.
    K/V never seq-shard (they are contracted over the full key sequence).
    """

    def build(axes):
        ba = _batch_axes(axes)
        b = ba if (ba and _fits(x.shape[0], ba, axes)) else None
        if _fits(x.shape[2], "model", axes):
            return P(b, None, "model", None)
        if role == "q" and seq_ok and x.shape[1] > 1 and _fits(x.shape[1], "model", axes):
            return P(b, "model", None, None)
        if role != "kv" and _fits(x.shape[3], "model", axes):
            return P(b, None, None, "model")
        return P(b, None, None, None) if b else None

    return constrain(x, build)


def shard_ff(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, F) FFN hidden (or (T, F) for MoE): last dim over model."""

    def build(axes):
        ba = _batch_axes(axes)
        b = ba if (x.ndim >= 3 and ba and _fits(x.shape[0], ba, axes)) else None
        m = "model" if _fits(x.shape[-1], "model", axes) else None
        if b is None and m is None:
            return None
        return P(*([b] + [None] * (x.ndim - 2) + [m]))

    return constrain(x, build)
