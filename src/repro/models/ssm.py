"""State-space and linear-recurrence blocks: Mamba2 (SSD) and RWKV-6 (WKV).

Both are implemented in the *chunked* formulation — quadratic within a small
chunk (MXU matmuls), linear state passing between chunks (a lax.scan over the
chunk axis) — which is the TPU-native shape of these recurrences: the per-step
recurrence that GPU kernels fuse into registers becomes, on TPU, a sequence of
dense (chunk x chunk) and (chunk x state) contractions.

References: SSD / Mamba-2 (Dao & Gu 2024, arXiv:2405.21060); RWKV-6 "Finch"
(Peng et al. 2024, arXiv:2404.05892). Naive per-token scans in
``*_reference`` serve as test oracles and as the decode-step semantics.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import partition


# ===========================================================================
# Mamba2 / SSD
# ===========================================================================
def init_mamba(key, cfg, d_model: int) -> dict:
    s = cfg.ssm
    d_in = s.d_inner(d_model)
    H = s.n_heads(d_model)
    N = s.d_state
    keys = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    scale = 1.0 / math.sqrt(d_model)
    # in_proj emits [z (d_in), x (d_in), B (N), C (N), dt (H)]
    return {
        "in_proj": (jax.random.normal(keys[0], (d_model, 2 * d_in + 2 * N + H))
                    * scale).astype(dt),
        "conv": (jax.random.normal(keys[1], (s.conv_kernel, d_in))
                 * (1.0 / math.sqrt(s.conv_kernel))).astype(dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones(H, jnp.float32),
        "dt_bias": jnp.zeros(H, jnp.float32),
        "norm": jnp.zeros(d_in, jnp.float32),     # gated RMSNorm scale
        "out_proj": (jax.random.normal(keys[2], (d_in, d_model))
                     * (1.0 / math.sqrt(d_in))).astype(dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time. x: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out


def _split_proj(p, u, cfg, d_model):
    s = cfg.ssm
    d_in = s.d_inner(d_model)
    H = s.n_heads(d_model)
    N = s.d_state
    zxbcdt = partition.shard_ff(u @ p["in_proj"].astype(u.dtype))
    z, xs, Bm, Cm, dt_raw = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1
    )
    return z, xs, Bm, Cm, dt_raw, d_in, H, N


def mamba_forward(p: dict, u: jnp.ndarray, cfg, d_model: int) -> jnp.ndarray:
    """Chunked SSD over a full sequence. u: (B, S, D) -> (B, S, D)."""
    s = cfg.ssm
    B_, S, _ = u.shape
    z, xs, Bm, Cm, dt_raw, d_in, H, N = _split_proj(p, u, cfg, d_model)
    xs = jax.nn.silu(_causal_conv(xs, p["conv"].astype(xs.dtype)))

    P = s.head_dim
    L = min(s.chunk, S)
    assert S % L == 0, f"seq {S} must be a multiple of ssm chunk {L}"
    nc = S // L

    xh = xs.reshape(B_, nc, L, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dt = dt.reshape(B_, nc, L, H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    dA = dt * A                                                       # (B,nc,L,H)
    Bc = Bm.reshape(B_, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, L, N).astype(jnp.float32)

    cs = jnp.cumsum(dA, axis=2)                                       # (B,nc,L,H)
    # Intra-chunk: y_i = sum_{j<=i} (C_i . B_j) exp(cs_i - cs_j) dt_j x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                        # (B,nc,L,L)
    tri = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    # Mask the exponent BEFORE exp: the upper triangle holds cs_i - cs_j > 0
    # which overflows, and inf * 0 in the VJP of a post-hoc mask is NaN.
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]                # (B,nc,L,L,H)
    decay = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = cb[..., None] * decay
    y = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dt, xh)

    # Chunk-final states and inter-chunk scan.
    seg = jnp.exp(cs[:, :, -1:, :] - cs)                              # (B,nc,L,H)
    states = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchnp", seg, dt, Bc, xh)
    total = jnp.exp(cs[:, :, -1, :])                                  # (B,nc,H)

    def scan_fn(carry, inp):
        st, tot = inp   # (B,H,N,P), (B,H)
        out = carry
        new = carry * tot[:, :, None, None] + st
        return new, out

    init = jnp.zeros((B_, H, N, P), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )  # (nc, B, H, N, P) — state entering each chunk
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)

    y = y + jnp.einsum(
        "bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cs), prev_states
    )
    y = y + p["D"][None, None, None, :, None] * xh                    # skip
    y = y.reshape(B_, S, d_in).astype(u.dtype)

    from repro.models import layers

    y = y * jax.nn.silu(z)
    y = layers.rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(y.dtype)


def mamba_reference(p: dict, u: jnp.ndarray, cfg, d_model: int) -> jnp.ndarray:
    """Per-token recurrence (oracle + decode semantics)."""
    s = cfg.ssm
    B_, S, _ = u.shape
    z, xs, Bm, Cm, dt_raw, d_in, H, N = _split_proj(p, u, cfg, d_model)
    xs = jax.nn.silu(_causal_conv(xs, p["conv"].astype(xs.dtype)))
    P = s.head_dim
    xh = xs.reshape(B_, S, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    Bc = Bm.astype(jnp.float32)
    Cc = Cm.astype(jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dt_t * A)[..., None, None]       # (B,H,1,1)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t)
        state = state * decay + upd
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y_t

    init = jnp.zeros((B_, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, init,
        (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
         Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2, 3) + p["D"][None, None, :, None] * xh
    y = y.reshape(B_, S, d_in).astype(u.dtype)

    from repro.models import layers

    y = y * jax.nn.silu(z)
    y = layers.rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(y.dtype)


def init_mamba_cache(cfg, batch: int, d_model: int) -> dict:
    s = cfg.ssm
    H = s.n_heads(d_model)
    return {
        "state": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, s.d_inner(d_model)),
                          jnp.float32),
    }


def mamba_decode(p: dict, u: jnp.ndarray, cache: dict, cfg, d_model: int):
    """One-token step. u: (B, 1, D) -> ((B, 1, D), new_cache)."""
    s = cfg.ssm
    B_ = u.shape[0]
    z, xs, Bm, Cm, dt_raw, d_in, H, N = _split_proj(p, u, cfg, d_model)
    # causal conv over [cached K-1 inputs, current]
    conv_in = jnp.concatenate([cache["conv"], xs.astype(jnp.float32)], axis=1)
    w = p["conv"].astype(jnp.float32)
    xt = jnp.einsum("bkc,kc->bc", conv_in, w)[:, None, :]
    xt = jax.nn.silu(xt)
    new_conv = conv_in[:, 1:, :]

    P = s.head_dim
    xh = xt.reshape(B_, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    b_t = Bm[:, 0].astype(jnp.float32)
    c_t = Cm[:, 0].astype(jnp.float32)

    decay = jnp.exp(dt * A)[..., None, None]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, b_t, xh)
    state = cache["state"] * decay + upd
    y = jnp.einsum("bn,bhnp->bhp", c_t, state) + p["D"][None, :, None] * xh
    y = y.reshape(B_, 1, d_in).astype(u.dtype)

    from repro.models import layers

    y = y * jax.nn.silu(z)
    y = layers.rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(y.dtype), {"state": state, "conv": new_conv}


# ===========================================================================
# RWKV-6 (Finch)
# ===========================================================================
def init_rwkv(key, cfg, d_model: int) -> dict:
    r = cfg.rwkv
    H = d_model // r.head_dim
    keys = jax.random.split(key, 10)
    dt = jnp.dtype(cfg.param_dtype)
    s = 1.0 / math.sqrt(d_model)
    lora = r.decay_lora
    return {
        # token-shift interpolation weights (mu) for r, k, v, w, g
        "mu": jnp.full((5, d_model), 0.5, jnp.float32),
        "wr": (jax.random.normal(keys[0], (d_model, d_model)) * s).astype(dt),
        "wk": (jax.random.normal(keys[1], (d_model, d_model)) * s).astype(dt),
        "wv": (jax.random.normal(keys[2], (d_model, d_model)) * s).astype(dt),
        "wg": (jax.random.normal(keys[3], (d_model, d_model)) * s).astype(dt),
        "wo": (jax.random.normal(keys[4], (d_model, d_model)) * s).astype(dt),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x A) B))  [arXiv:2404.05892]
        "w0": jnp.full((d_model,), -1.0, jnp.float32),
        "wA": (jax.random.normal(keys[5], (d_model, lora)) * s).astype(jnp.float32),
        "wB": (jax.random.normal(keys[6], (lora, d_model))
               * (1.0 / math.sqrt(lora))).astype(jnp.float32),
        "u": (jax.random.normal(keys[7], (d_model,)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones(d_model, jnp.float32),   # per-head groupnorm
        "ln_bias": jnp.zeros(d_model, jnp.float32),
    }


def _rwkv_inputs(p, x, cfg, x_prev=None):
    """Token-shifted projections. x: (B, S, D)."""
    if x_prev is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    else:
        shifted = jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(x.dtype)
    mix = lambda i: x + mu[i] * (shifted - x)
    r = partition.shard_ff(mix(0) @ p["wr"].astype(x.dtype))
    k = partition.shard_ff(mix(1) @ p["wk"].astype(x.dtype))
    v = partition.shard_ff(mix(2) @ p["wv"].astype(x.dtype))
    logw = -jnp.exp(
        jnp.clip(
            p["w0"]
            + jnp.tanh(mix(3).astype(jnp.float32) @ p["wA"]) @ p["wB"],
            -8.0, 1.0,
        )
    )  # (B,S,D), in (-e, 0)
    g = jax.nn.silu(mix(4) @ p["wg"].astype(x.dtype))
    return r, k, v, logw, g


def _group_norm(y: jnp.ndarray, scale, bias, H: int, eps: float) -> jnp.ndarray:
    """Per-head LayerNorm (RWKV's GroupNorm over heads)."""
    B_, S, D = y.shape
    yh = y.reshape(B_, S, H, D // H).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B_, S, D) * scale + bias).astype(y.dtype)


def rwkv_forward(p: dict, x: jnp.ndarray, cfg, d_model: int) -> jnp.ndarray:
    """Chunked WKV-6 over a full sequence. x: (B, S, D)."""
    r_cfg = cfg.rwkv
    B_, S, D = x.shape
    H = D // r_cfg.head_dim
    K = r_cfg.head_dim
    L = min(r_cfg.chunk, S)
    assert S % L == 0, f"seq {S} must be a multiple of rwkv chunk {L}"
    nc = S // L

    r, k, v, logw, g = _rwkv_inputs(p, x, cfg)
    shp = (B_, nc, L, H, K)
    rr = r.reshape(shp).astype(jnp.float32)
    kk = k.reshape(shp).astype(jnp.float32)
    vv = v.reshape(shp).astype(jnp.float32)
    lw = logw.reshape(shp)                        # (B,nc,L,H,K), <= 0
    u = p["u"].reshape(H, K)

    # cls_i = sum_{t<=i} logw_t (inclusive); decay j->i uses cls_{i-1} - cls_j.
    cls = jnp.cumsum(lw, axis=2)
    cls_prev = cls - lw                            # exclusive cumsum
    a = rr * jnp.exp(cls_prev)                     # (B,nc,L,H,K)
    b = kk * jnp.exp(-cls)
    scores = jnp.einsum("bclhk,bcmhk->bchlm", a, b)
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)   # strictly lower: j < i
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    y = jnp.einsum("bchlm,bcmhk->bclhk", scores, vv)
    # bonus term at j == i: y_i += (r_i . (u * k_i)) v_i
    bonus = jnp.einsum("bclhk,hk,bclhk->bclh", rr, u, kk)
    y = y + bonus[..., None] * vv

    # Inter-chunk state passing: S (B,H,K,V)
    seg = jnp.exp(cls[:, :, -1:, :, :] - cls)      # decay from j to chunk end
    states = jnp.einsum("bcjhk,bcjhk,bcjhv->bchkv", seg, kk, vv)
    total = jnp.exp(cls[:, :, -1])                 # (B,nc,H,K)

    def scan_fn(carry, inp):
        st, tot = inp
        out = carry
        new = carry * tot[..., None] + st
        return new, out

    init = jnp.zeros((B_, H, K, K), jnp.float32)
    _, prev = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2, 3)),
    )
    prev = prev.transpose(1, 0, 2, 3, 4)           # (B,nc,H,K,V)
    y = y + jnp.einsum("bclhk,bchkv->bclhv", a, prev)

    y = y.reshape(B_, S, D).astype(x.dtype)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], H, cfg.norm_eps)
    return (y * g) @ p["wo"].astype(y.dtype)


def rwkv_reference(p: dict, x: jnp.ndarray, cfg, d_model: int) -> jnp.ndarray:
    """Naive per-token WKV recurrence (oracle + decode semantics)."""
    r_cfg = cfg.rwkv
    B_, S, D = x.shape
    H = D // r_cfg.head_dim
    K = r_cfg.head_dim
    r, k, v, logw, g = _rwkv_inputs(p, x, cfg)
    rr = r.reshape(B_, S, H, K).astype(jnp.float32)
    kk = k.reshape(B_, S, H, K).astype(jnp.float32)
    vv = v.reshape(B_, S, H, K).astype(jnp.float32)
    lw = logw.reshape(B_, S, H, K)
    u = p["u"].reshape(H, K)

    def step(state, inp):
        r_t, k_t, v_t, lw_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv)
        state = state * jnp.exp(lw_t)[..., None] + kv
        return state, y_t

    init = jnp.zeros((B_, H, K, K), jnp.float32)
    _, ys = jax.lax.scan(
        step, init,
        (rr.transpose(1, 0, 2, 3), kk.transpose(1, 0, 2, 3),
         vv.transpose(1, 0, 2, 3), lw.transpose(1, 0, 2, 3)),
    )
    y = ys.transpose(1, 0, 2, 3).reshape(B_, S, D).astype(x.dtype)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], H, cfg.norm_eps)
    return (y * g) @ p["wo"].astype(y.dtype)


def init_rwkv_cache(cfg, batch: int, d_model: int) -> dict:
    K = cfg.rwkv.head_dim
    H = d_model // K
    return {
        "state": jnp.zeros((batch, H, K, K), jnp.float32),
        "x_prev": jnp.zeros((batch, 1, d_model), jnp.float32),
    }


def rwkv_decode(p: dict, x: jnp.ndarray, cache: dict, cfg, d_model: int):
    """One-token step. x: (B, 1, D)."""
    r_cfg = cfg.rwkv
    B_, _, D = x.shape
    H = D // r_cfg.head_dim
    K = r_cfg.head_dim
    r, k, v, logw, g = _rwkv_inputs(p, x, cfg, x_prev=cache["x_prev"].astype(x.dtype))
    r_t = r.reshape(B_, H, K).astype(jnp.float32)
    k_t = k.reshape(B_, H, K).astype(jnp.float32)
    v_t = v.reshape(B_, H, K).astype(jnp.float32)
    lw_t = logw.reshape(B_, H, K)
    u = p["u"].reshape(H, K)

    kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
    y = jnp.einsum("bhk,bhkv->bhv", r_t, cache["state"] + u[None, :, :, None] * kv)
    state = cache["state"] * jnp.exp(lw_t)[..., None] + kv

    y = y.reshape(B_, 1, D).astype(x.dtype)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], H, cfg.norm_eps)
    out = (y * g) @ p["wo"].astype(y.dtype)
    return out, {"state": state, "x_prev": x.astype(jnp.float32)}


def init_rwkv_channel(key, cfg, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    s = 1.0 / math.sqrt(d_model)
    return {
        "mu": jnp.full((2, d_model), 0.5, jnp.float32),
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * s).astype(dt),
        "w_out": (jax.random.normal(k2, (d_ff, d_model))
                  * (1.0 / math.sqrt(d_ff))).astype(dt),
        "w_recept": (jax.random.normal(k3, (d_model, d_model)) * s).astype(dt),
    }


def rwkv_channel_mix(p: dict, x: jnp.ndarray, x_prev=None):
    """RWKV channel mixing (the FFN analogue): relu^2 with receptance gate.
    Returns (out, last_x) so decode can carry the token shift."""
    if x_prev is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    else:
        shifted = jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (shifted - x)
    xr = x + mu[1] * (shifted - x)
    k = jnp.square(jax.nn.relu(partition.shard_ff(xk @ p["w_in"].astype(x.dtype))))
    out = jax.nn.sigmoid(xr @ p["w_recept"].astype(x.dtype)) * (
        k @ p["w_out"].astype(x.dtype))
    return out, x[:, -1:, :]
