"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM stacks via
a repeating *unit pattern* of block types; the stack is ``num_units`` copies
of the pattern, executed under ``lax.scan`` with per-position stacked params
(models/model.py). Supported block types:

  "attn"         causal self-attention (GQA/MQA via n_kv_heads) + FFN
  "attn_local"   sliding-window causal attention + FFN (gemma2 local layers)
  "attn_swa"     sliding-window attention + MoE FFN (mixtral)
  "attn_moe"     full attention + MoE FFN (granite-moe)
  "mamba"        Mamba2 SSD block (zamba2)
  "rwkv"         RWKV-6 time-mix + channel-mix (finch)
  "enc_attn"     bidirectional attention + FFN (whisper encoder)
  "dec_attn"     causal self-attn + cross-attn + FFN (whisper decoder)

``shared_attn_every > 0`` applies a single weight-shared attention block after
every k-th unit (zamba2's shared block).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # Router aux-loss weight (load balance, Switch-style).
    aux_loss_weight: float = 0.01
    # "ragged": jax.lax.ragged_dot grouped matmuls (exact, but GSPMD cannot
    #   partition the ragged contraction -> expert FLOPs replicate across the
    #   model axis; kept as the measurable baseline).
    # "dense": capacity-padded dispatch (E, C, D) + batched dot_general, which
    #   GSPMD shards cleanly (EXPERIMENTS.md §Perf, granite-moe hillclimb).
    impl: str = "ragged"
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    conv_kernel: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128          # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    chunk: int = 64           # WKV chunk length
    decay_lora: int = 64      # low-rank dim of the data-dependent decay


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder consuming stubbed frame embeddings."""

    num_layers: int
    num_frames: int           # fixed source length (1500 for whisper-large)
    d_model: int              # == decoder d_model here


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int                     # total block count (pattern * units)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[str, ...] = ("attn",)
    head_dim: Optional[int] = None      # default d_model // n_heads

    norm_eps: float = 1e-5
    norm_type: str = "rms"              # rms|layer (whisper uses LayerNorm)
    pos_type: str = "rope"              # rope|abs (whisper uses absolute)
    post_norm: bool = False             # gemma2 adds post-block norms
    rope_theta: float = 10_000.0
    window: int = 0                     # sliding-window size (0 = full)
    attn_softcap: float = 0.0           # gemma2 attention logit softcap
    logits_softcap: float = 0.0         # gemma2 final logit softcap
    ffn_type: str = "swiglu"            # swiglu|geglu|gelu
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    shared_attn_every: int = 0
    encoder: Optional[EncoderConfig] = None

    frontend: str = "none"              # none|vision_stub|audio_stub
    num_patches: int = 0                # VLM stub: first N positions are patches

    seq_shard_attn: bool = True         # query-seq sharding fallback when
    # heads don't divide the model axis (see partition.shard_heads); False
    # reproduces the pre-hillclimb baseline.
    param_dtype: str = "float32"        # float32|bfloat16 (big models: bf16)
    compute_dtype: str = "bfloat16"
    remat: bool = True                  # activation checkpoint each unit
    scan_unroll: bool = False           # fully unroll the unit scan. The
    # dry-run sets this True: XLA's cost_analysis counts a while-loop body
    # ONCE, so rolled scans underreport FLOPs/bytes/collectives by ~num_units;
    # unrolling keeps math + sharding identical and makes the roofline exact.
    source: str = ""                    # citation (model card / arXiv)

    # ------------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def num_units(self) -> int:
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: {self.num_layers} layers not a multiple of "
            f"pattern {self.pattern}"
        )
        return self.num_layers // len(self.pattern)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to 256 so logits shard cleanly over the model axis."""
        return _round_up(self.vocab, 256)

    @property
    def is_decoder_only(self) -> bool:
        return self.encoder is None

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: every block is windowed/SSM/linear except
        at most a periodic shared-attention block (decode cost stays O(1) or
        O(window) per token per block)."""
        full_attn = {"attn", "attn_moe", "enc_attn", "dec_attn"}
        return not any(p in full_attn for p in self.pattern)

    def flops_params(self) -> int:
        """Total parameter count (approx, for 6ND roofline accounting)."""
        from repro.models import model as model_mod

        return model_mod.count_params_analytic(self)

    def active_params(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        from repro.models import model as model_mod

        return model_mod.count_params_analytic(self, active_only=True)
