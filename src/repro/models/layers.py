"""Shared neural layers: norms, RoPE, attention (GQA/MQA, sliding window,
softcap, cross-attention, KV cache), and FFN variants. Pure functional JAX —
params are plain dicts, shapes are static, everything jit/scan-friendly.

Sharding note: weights carry NamedSharding via launch/shardings.py; inside
the forward we only add light ``with_sharding_constraint``-free code and let
GSPMD propagate — the dry-run (launch/dryrun.py) verifies the result.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import partition


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def apply_norm(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.norm_type == "layer":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg, d: int) -> dict:
    if cfg.norm_type == "layer":
        return {"scale": jnp.ones(d, jnp.float32), "bias": jnp.zeros(d, jnp.float32)}
    return {"scale": jnp.zeros(d, jnp.float32)}  # rms stored as (1 + scale)


# ---------------------------------------------------------------------------
# Rotary / absolute positions
# ---------------------------------------------------------------------------
def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_at(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal embeddings at arbitrary (possibly traced) positions.

    positions: (..., S) int -> (..., S, d) float32.
    """
    pos = positions.astype(jnp.float32)[..., None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    ang = pos * div
    pe = jnp.zeros(positions.shape + (d,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang))
    return pe


def sinusoidal_positions(length: int, d: int) -> jnp.ndarray:
    return sinusoidal_at(jnp.arange(length), d)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


def init_attention(key, cfg, d_model: int, n_heads: int, n_kv: int, hd: int,
                   cross: bool = False) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d_model, n_kv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d_model, n_kv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (n_heads * hd, d_model))
               * (1.0 / math.sqrt(n_heads * hd))).astype(dt),
    }
    return p


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, K, hd) -> (B, S, K*groups, hd) by head repetition (GQA)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attention(
    p: dict,
    x: jnp.ndarray,
    cfg,
    *,
    n_heads: int,
    n_kv: int,
    hd: int,
    causal: bool = True,
    window: int = 0,
    positions: Optional[jnp.ndarray] = None,
    kv_src: Optional[jnp.ndarray] = None,     # cross-attention source
    attn_softcap: float = 0.0,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Full-sequence attention. x: (B, S, D) -> (B, S, D)."""
    B, S, _ = x.shape
    src = kv_src if kv_src is not None else x
    S_kv = src.shape[1]
    seq_ok = getattr(cfg, "seq_shard_attn", True)
    wq, wk, wv = (p[w].astype(x.dtype) for w in ("wq", "wk", "wv"))
    q = partition.shard_heads((x @ wq).reshape(B, S, n_heads, hd),
                              role="q", seq_ok=seq_ok)
    k = partition.shard_heads((src @ wk).reshape(B, S_kv, n_kv, hd), role="kv")
    v = partition.shard_heads((src @ wv).reshape(B, S_kv, n_kv, hd), role="kv")

    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    k = partition.shard_heads(_expand_kv(k, n_heads // n_kv), role="kv")
    v = partition.shard_heads(_expand_kv(v, n_heads // n_kv), role="kv")

    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    scores = softcap(scores, attn_softcap)

    if kv_src is None:  # self-attention masks
        qi = jnp.arange(S)[:, None]
        ki = jnp.arange(S_kv)[None, :]
        mask = jnp.ones((S, S_kv), bool)
        if causal:
            mask &= ki <= qi
        if window > 0:
            mask &= qi - ki < window
        scores = jnp.where(mask[None, None], scores, -1e30)

    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, S, n_heads * hd)
    out = partition.shard_fused_heads(out, n_heads=n_heads, seq_ok=seq_ok)
    return partition.shard_tokens(out @ p["wo"].astype(x.dtype))


def attention_decode(
    p: dict,
    x: jnp.ndarray,                 # (B, 1, D)
    cache: dict,                    # {"k","v": (B, C, n_kv, hd)}
    pos: jnp.ndarray,               # scalar int32 — absolute position
    cfg,
    *,
    n_heads: int,
    n_kv: int,
    hd: int,
    window: int = 0,
    attn_softcap: float = 0.0,
    use_rope: bool = True,
) -> tuple[jnp.ndarray, dict]:
    """One-token decode against a (ring-buffered when windowed) KV cache."""
    B = x.shape[0]
    C = cache["k"].shape[1]
    wq, wk, wv = (p[w].astype(x.dtype) for w in ("wq", "wk", "wv"))
    q = (x @ wq).reshape(B, 1, n_heads, hd)
    k_new = (x @ wk).reshape(B, 1, n_kv, hd)
    v_new = (x @ wv).reshape(B, 1, n_kv, hd)
    if use_rope:
        pvec = jnp.full((B, 1), pos, jnp.int32)
        q = apply_rope(q, pvec, cfg.rope_theta)
        k_new = apply_rope(k_new, pvec, cfg.rope_theta)

    slot = pos % C  # ring buffer (C == window when windowed, else C == S_max)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    new_cache = {"k": k, "v": v}

    kx = _expand_kv(k, n_heads // n_kv)
    vx = _expand_kv(v, n_heads // n_kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kx) / math.sqrt(hd)
    scores = softcap(scores, attn_softcap)
    valid = jnp.arange(C) <= pos          # unfilled ring slots masked out
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx).reshape(B, 1, n_heads * hd)
    return out @ p["wo"].astype(x.dtype), new_cache


def init_kv_cache(cfg, batch: int, seq_len: int, n_kv: int, hd: int,
                  window: int = 0) -> dict:
    C = min(seq_len, window) if window > 0 else seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros((batch, C, n_kv, hd), dt),
        "v": jnp.zeros((batch, C, n_kv, hd), dt),
    }


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------
def init_ffn(key, cfg, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.ffn_type in ("swiglu", "geglu"):
        return {
            "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dt),
            "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dt),
            "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dt),
        }
    return {
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dt),
        "w_out": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dt),
    }


def ffn(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    w = {k: v.astype(x.dtype) for k, v in p.items()}
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(partition.shard_ff(x @ w["w_gate"])) * (x @ w["w_up"])
    elif cfg.ffn_type == "geglu":
        h = jax.nn.gelu(partition.shard_ff(x @ w["w_gate"]), approximate=True) * (
            x @ w["w_up"])
    else:
        h = jax.nn.gelu(partition.shard_ff(x @ w["w_in"]), approximate=True)
        return partition.shard_tokens(h @ w["w_out"])
    h = partition.shard_ff(h)
    return partition.shard_tokens(h @ w["w_down"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def init_embed(key, cfg) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {
        "tokens": (jax.random.normal(k1, (cfg.vocab_padded, cfg.d_model))
                   * 0.02).astype(dt)
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(k2, (cfg.d_model, cfg.vocab_padded))
            / math.sqrt(cfg.d_model)
        ).astype(dt)
    return p


def embed_tokens(p: dict, tokens: jnp.ndarray, cfg, pos_offset=0) -> jnp.ndarray:
    x = jnp.take(p["tokens"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    x = partition.shard_tokens(x)
    if cfg.pos_type == "abs":  # whisper-style absolute positions
        positions = jnp.arange(tokens.shape[-1]) + pos_offset
        x = x + sinusoidal_at(positions, cfg.d_model).astype(x.dtype)
    return x


def lm_logits(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    w = p["tokens"].T if cfg.tie_embeddings else p["lm_head"]
    logits = partition.shard_ff(x @ w.astype(x.dtype))  # vocab over "model"
    return softcap(logits, cfg.logits_softcap)
