"""Training step factory for the LM substrate (used by smoke tests, the
end-to-end driver, and the dry-run's train shapes)."""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as model_mod
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_lr


class TrainState(NamedTuple):
    params: dict
    opt: object        # AdamWState


def init_train_state(key: jax.Array, cfg: ModelConfig) -> TrainState:
    params = model_mod.init_params(key, cfg)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: dict):
        (loss, parts), grads = jax.value_and_grad(
            model_mod.loss_fn, has_aux=True
        )(state.params, batch, cfg)
        lr = cosine_lr(state.opt.step + 1, peak=peak_lr, warmup=warmup,
                       total=total_steps)
        params, opt = adamw_update(grads, state.opt, state.params, lr)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "lr": lr, "grad_norm": gnorm}
        return TrainState(params=params, opt=opt), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params: dict, batch: dict):
        loss, parts = model_mod.loss_fn(params, batch, cfg)
        return {"loss": loss, **parts}

    return eval_step
