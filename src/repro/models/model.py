"""Model assembly: init, full-sequence forward (training), prefill/decode
(serving), loss — all driven by ModelConfig's unit pattern.

Layer stacking: the stack is ``num_units`` repetitions of ``cfg.pattern``;
parameters are stacked per pattern position (leading axis = num_units) and the
depth loop is a single ``lax.scan`` (keeps HLO size O(pattern), which is what
makes the 80-program dry-run matrix compile in reasonable time). Zamba2's
weight-shared attention block lives outside the scanned pytree and is applied
every ``shared_attn_every`` units under ``lax.cond``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks, layers
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    params: dict = {"embed": layers.init_embed(keys[0], cfg)}

    def stack_init(key, block_type):
        ks = jax.random.split(key, cfg.num_units)
        return jax.vmap(lambda k: blocks.init_block(k, block_type, cfg))(ks)

    unit_keys = jax.random.split(keys[1], len(cfg.pattern))
    params["units"] = [
        stack_init(unit_keys[i], bt) for i, bt in enumerate(cfg.pattern)
    ]
    params["final_norm"] = layers.init_norm(cfg, cfg.d_model)

    if cfg.shared_attn_every > 0:
        params["shared"] = blocks.init_shared_attn(keys[2], cfg)

    if cfg.encoder is not None:
        enc = cfg.encoder
        ks = jax.random.split(keys[3], enc.num_layers)
        params["encoder"] = {
            "layers": jax.vmap(
                lambda k: blocks.init_block(k, "enc_attn", cfg)
            )(ks),
            "final_norm": layers.init_norm(cfg, cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------
def _num_shared_apps(cfg: ModelConfig) -> int:
    if cfg.shared_attn_every <= 0:
        return 0
    return sum(
        1 for u in range(cfg.num_units) if (u + 1) % cfg.shared_attn_every == 0
    )


def _stack_scan(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                enc_out: Optional[jnp.ndarray] = None):
    """Scan the unit stack. Returns (x, total_aux)."""

    def unit_body(carry, unit_params_and_idx):
        x, aux = carry
        unit_params, unit_idx = unit_params_and_idx
        for pos, bt in enumerate(cfg.pattern):
            x, a = blocks.block_forward(unit_params[pos], x, bt, cfg, enc_out)
            aux = aux + a
        if cfg.shared_attn_every > 0:
            x = jax.lax.cond(
                (unit_idx + 1) % cfg.shared_attn_every == 0,
                lambda v: blocks.shared_attn_forward(params["shared"], v, cfg),
                lambda v: v,
                x,
            )
        return (x, aux), None

    body = jax.checkpoint(unit_body) if cfg.remat else unit_body
    (x, aux), _ = jax.lax.scan(
        body,
        (x, jnp.zeros((), jnp.float32)),
        (params["units"], jnp.arange(cfg.num_units)),
        unroll=cfg.num_units if cfg.scan_unroll else 1,
    )
    return x, aux


def encode(params: dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Whisper-style encoder over stubbed frame embeddings (B, F, D)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + layers.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(x, layer_params):
        x, _ = blocks.block_forward(layer_params, x, "enc_attn", cfg)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(
        body, x, params["encoder"]["layers"],
        unroll=cfg.encoder.num_layers if cfg.scan_unroll else 1,
    )
    return layers.apply_norm(params["encoder"]["final_norm"], x, cfg)


def forward(
    params: dict,
    tokens: jnp.ndarray,                      # (B, S) int32
    cfg: ModelConfig,
    patch_embeds: Optional[jnp.ndarray] = None,   # VLM stub (B, P, D)
    frames: Optional[jnp.ndarray] = None,         # audio stub (B, F, D)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward -> (logits (B, S, vocab_padded), aux_loss)."""
    x = layers.embed_tokens(params["embed"], tokens, cfg)
    if cfg.frontend == "vision_stub" and patch_embeds is not None:
        # First num_patches positions carry projected patch embeddings
        # (the ViT+projector is stubbed per the brief; DESIGN.md §7).
        P = patch_embeds.shape[1]
        x = jnp.concatenate(
            [patch_embeds.astype(x.dtype), x[:, P:, :]], axis=1
        )
    enc_out = None
    if cfg.encoder is not None:
        assert frames is not None, "audio arch requires stub frames"
        enc_out = encode(params, frames, cfg)

    x, aux = _stack_scan(params, x, cfg, enc_out)
    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.lm_logits(params["embed"], x, cfg)
    return logits, aux


def loss_fn(
    params: dict, batch: dict, cfg: ModelConfig
) -> tuple[jnp.ndarray, dict]:
    """Next-token cross entropy (+ MoE aux). batch: tokens, labels[, stubs]."""
    logits, aux = forward(
        params, batch["tokens"], cfg,
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"),
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = ce + aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Decode cache for the whole stack, stacked per pattern position."""
    cache: dict = {
        "blocks": [
            jax.tree.map(
                lambda l: jnp.broadcast_to(
                    l, (cfg.num_units,) + l.shape
                ),
                blocks.init_block_cache(bt, cfg, batch, seq_len),
            )
            for bt in cfg.pattern
        ]
    }
    if cfg.shared_attn_every > 0:
        base = blocks.init_block_cache("attn", cfg, batch, seq_len)
        cache["shared"] = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (cfg.num_units,) + l.shape), base
        )
    if cfg.encoder is not None:
        enc = cfg.encoder
        kv_shape = (cfg.num_units, batch, enc.num_frames, cfg.n_heads, cfg.hd)
        dt = jnp.dtype(cfg.compute_dtype)
        cache["cross"] = {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt)}
    return cache


def fill_cross_cache(params: dict, cache: dict, enc_out: jnp.ndarray,
                     cfg: ModelConfig) -> dict:
    """Populate the per-decoder-layer cross K/V from encoder output (prefill)."""
    assert cfg.pattern == ("dec_attn",), "cross cache assumes a dec-only pattern"
    kv = jax.vmap(
        lambda p_layer: blocks.make_cross_cache(p_layer, enc_out, cfg)
    )(params["units"][0])
    return dict(cache, cross=kv)


def decode_step(
    params: dict,
    cache: dict,
    token: jnp.ndarray,     # (B, 1) int32
    pos: jnp.ndarray,       # scalar int32
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, dict]:
    """One decode step -> (logits (B, 1, vocab_padded), new cache)."""
    x = layers.embed_tokens(params["embed"], token, cfg, pos_offset=pos)

    def unit_body(carry, xs):
        x = carry
        unit_params, unit_caches, shared_cache, cross_cache, unit_idx = xs
        new_caches = []
        for p_idx, bt in enumerate(cfg.pattern):
            cc = cross_cache if bt == "dec_attn" else None
            x, nc = blocks.block_decode(
                unit_params[p_idx], x, unit_caches[p_idx], pos, bt, cfg,
                cross_cache=cc,
            )
            new_caches.append(nc)
        if cfg.shared_attn_every > 0:
            def fire(operand):
                xx, sc = operand
                return blocks.shared_attn_decode(params["shared"], xx, sc, pos, cfg)

            x, shared_cache = jax.lax.cond(
                (unit_idx + 1) % cfg.shared_attn_every == 0,
                fire,
                lambda operand: operand,
                (x, shared_cache),
            )
        return x, (new_caches, shared_cache)

    xs = (
        params["units"],
        cache["blocks"],
        cache.get("shared"),
        cache.get("cross"),
        jnp.arange(cfg.num_units),
    )
    x, (new_block_caches, new_shared) = jax.lax.scan(
        unit_body, x, xs, unroll=cfg.num_units if cfg.scan_unroll else 1,
    )
    new_cache = dict(cache, blocks=new_block_caches)
    if cfg.shared_attn_every > 0:
        new_cache["shared"] = new_shared

    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.lm_logits(params["embed"], x, cfg)
    return logits, new_cache


def prefill(
    params: dict, tokens: jnp.ndarray, cfg: ModelConfig, **stubs
) -> jnp.ndarray:
    """Prefill = full forward returning last-position logits (cache filling is
    exercised separately by decode_step; the dry-run prefill shape lowers this
    full-sequence program, which dominates prefill cost)."""
    logits, _ = forward(params, tokens, cfg, **stubs)
    return logits[:, -1:, :]


# ---------------------------------------------------------------------------
# Parameter accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------
def count_params(params: dict) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count via eval_shape (no allocation); MoE active-only
    replaces expert params with the top_k fraction."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        moe_layers = sum(1 for bt in cfg.pattern if bt in ("attn_swa", "attn_moe"))
        moe_layers *= cfg.num_units
        expert_params = cfg.moe.num_experts * 3 * cfg.d_model * cfg.d_ff
        active = cfg.moe.top_k * 3 * cfg.d_model * cfg.d_ff
        total -= moe_layers * (expert_params - active)
    return total
