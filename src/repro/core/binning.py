"""Quantile binning (Alg. 2 step 1).

Each party bins its own feature columns against L quantile points
``S_k = {s_k1, ..., s_kL}``; the binned representation is what histogram
accumulation consumes. Binning is a one-off preprocessing step, so it is
implemented in plain jnp (no kernel needed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantile_bin_edges(x: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Per-feature quantile edges.

    Args:
      x: (n, d) float features.
      num_bins: number of bins B; returns B-1 interior edges per feature.

    Returns:
      (d, num_bins - 1) float32 edges, non-decreasing along axis 1.
    """
    qs = jnp.linspace(0.0, 1.0, num_bins + 1)[1:-1]  # B-1 interior quantiles
    edges = jnp.quantile(x.astype(jnp.float32), qs, axis=0)  # (B-1, d)
    return edges.T  # (d, B-1)


def bin_data(x: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Digitise features into bin ids.

    ``bin = #edges strictly below value`` so bins are in [0, B-1] and the
    split predicate "bin <= t" corresponds to "value <= edges[t]".

    Args:
      x: (n, d) float features.
      edges: (d, B-1) per-feature edges.

    Returns:
      (n, d) int32 bin indices.
    """

    def per_feature(col: jnp.ndarray, e: jnp.ndarray) -> jnp.ndarray:
        return jnp.searchsorted(e, col, side="left").astype(jnp.int32)

    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(
        x.astype(jnp.float32), edges
    )


def fit_bin(x: jnp.ndarray, num_bins: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Convenience: fit edges on x and bin it. Returns (binned, edges)."""
    edges = quantile_bin_edges(x, num_bins)
    return bin_data(x, edges), edges
