"""Quantile binning (Alg. 2 step 1).

Each party bins its own feature columns against L quantile points
``S_k = {s_k1, ..., s_kL}``; the binned representation is what histogram
accumulation consumes. Binning is a one-off preprocessing step, so it is
implemented in plain jnp (no kernel needed).

Missing values: real credit-scoring tables (the paper's datasets) carry
NaNs.  Edges are fit with ``nanquantile`` so missing entries never poison
the quantile grid, and ``bin_data`` routes NaNs to the deterministic
missing-value bin ``NAN_BIN`` (= 0).  Bin 0 satisfies ``bin <= threshold``
for every split threshold, so missing values always route LEFT — a fixed,
platform-independent default direction (XGBoost learns the direction per
split; a fixed one keeps the VFL parties trivially consistent).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NAN_BIN = 0  # deterministic bin for missing values (routes left at any split)


def quantile_bin_edges(x: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Per-feature quantile edges, NaN-safe.

    Args:
      x: (n, d) float features; NaN entries are ignored per feature.
      num_bins: number of bins B; returns B-1 interior edges per feature.

    Returns:
      (d, num_bins - 1) float32 edges, non-decreasing along axis 1, always
      finite: an all-NaN feature column degrades to constant-0 edges (every
      sample then lands in one bin, so the feature is simply unsplittable).
    """
    qs = jnp.linspace(0.0, 1.0, num_bins + 1)[1:-1]  # B-1 interior quantiles
    edges = jnp.nanquantile(x.astype(jnp.float32), qs, axis=0)  # (B-1, d)
    edges = jnp.where(jnp.isnan(edges), 0.0, edges)  # all-NaN column guard
    return edges.T  # (d, B-1)


def bin_data(x: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Digitise features into bin ids.

    ``bin = #edges strictly below value`` so bins are in [0, B-1] and the
    split predicate "bin <= t" corresponds to "value <= edges[t]".  NaN
    values map to ``NAN_BIN`` (missing-values contract in the module
    docstring) instead of the platform-dependent garbage ``searchsorted``
    returns for unordered comparisons.

    Args:
      x: (n, d) float features (NaNs allowed).
      edges: (d, B-1) per-feature edges.

    Returns:
      (n, d) int32 bin indices.
    """

    def per_feature(col: jnp.ndarray, e: jnp.ndarray) -> jnp.ndarray:
        b = jnp.searchsorted(e, col, side="left").astype(jnp.int32)
        return jnp.where(jnp.isnan(col), jnp.int32(NAN_BIN), b)

    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(
        x.astype(jnp.float32), edges
    )


def fit_bin(x: jnp.ndarray, num_bins: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Convenience: fit edges on x and bin it. Returns (binned, edges)."""
    edges = quantile_bin_edges(x, num_bins)
    return bin_data(x, edges), edges
