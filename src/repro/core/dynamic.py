"""Dynamic FedGBF parameter schedules (§3.2.2, eqs. 6-7).

The paper's printed equations have mismatched parentheses and swapped
else-branches (eq. 6 is titled "Dynamic Increasing" but is written with cos
and a V_min tail). We implement the semantics the text and the experiments
unambiguously describe — "the cosine function to reduce the parameter values
round by round and the sine function to increase" with the k=1/k=0.5 worked
example of §3.2.2 — and note the typo here:

  decay     V(b_t) = V_min + (V_max - V_min) * cos( pi (b_t-1) / (2 k (b_T-1)) )
            for b_t in [1, k(b_T-1)+1], then V_min; V_max if b_T == 1.
  increase  V(b_t) = V_min + (V_max - V_min) * sin( pi (b_t-1) / (2 k (b_T-1)) )
            for b_t in [1, k(b_T-1)+1], then V_max; V_max if b_T == 1.

Check against the worked example: decay of tree count 50 -> 15 over b_T = 11
rounds. k=1: cos runs 0..pi/2 across rounds 1..11, so round 1 gives 50 and
round 11 gives 15. k=0.5: the cos phase completes at round 6 (value 15) and
rounds 7..11 hold 15 — exactly the paper's description.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import numpy as np


def dynamic_decay(
    b_t: int, b_total: int, v_min: float, v_max: float, k: float = 1.0
) -> float:
    """Cosine decay from v_max (round 1) to v_min (round k*(b_T-1)+1), then hold."""
    if b_total <= 1:
        return v_max
    horizon = k * (b_total - 1)
    if b_t > horizon + 1:
        return v_min
    phase = math.pi * (b_t - 1) / (2.0 * horizon)
    return v_min + (v_max - v_min) * math.cos(phase)


def dynamic_increase(
    b_t: int, b_total: int, v_min: float, v_max: float, k: float = 1.0
) -> float:
    """Sine increase from v_min (round 1) to v_max (round k*(b_T-1)+1), then hold."""
    if b_total <= 1:
        return v_max
    horizon = k * (b_total - 1)
    if b_t > horizon + 1:
        return v_max
    phase = math.pi * (b_t - 1) / (2.0 * horizon)
    return v_min + (v_max - v_min) * math.sin(phase)


def n_trees_schedule(cfg, round_idx: int) -> int:
    """Trees per round (dynamic decaying; paper: 5 -> 2, k = 1). 1-based round."""
    v = dynamic_decay(
        round_idx, cfg.rounds, float(cfg.n_trees_min), float(cfg.n_trees_max),
        cfg.n_trees_speed,
    )
    return max(1, int(round(v)))


def rho_id_schedule(cfg, round_idx: int) -> float:
    """Sample rate per round (dynamic increasing; paper: 0.1 -> 0.3, k = 1)."""
    return float(
        dynamic_increase(
            round_idx, cfg.rounds, cfg.rho_id_min, cfg.rho_id_max, cfg.rho_id_speed
        )
    )


class ScheduleArrays(NamedTuple):
    """Mask-form schedules (DESIGN.md §4): the whole dynamic schedule as
    static-shape per-round arrays — the schedule flips activity bits in a
    fixed ``(rounds, n_trees_max)`` grid instead of changing shapes.

    All arrays are host numpy (the schedule is config, not data).
    """

    n_trees: np.ndarray      # (M,) int32   — scheduled tree count per round
    rho_id: np.ndarray       # (M,) float32 — scheduled sample rate per round
    tree_active: np.ndarray  # (M, n_trees_max) float32 0/1 activity mask


class FlatSchedule(NamedTuple):
    """The schedule flattened to one entry per *scheduled tree build*
    (DESIGN.md §4).

    Derived from ``ScheduleArrays.tree_active``: entry ``s`` is tree slot
    ``tree_in_round[s]`` of round ``round_of_step[s]`` (0-based), in the
    exact order the legacy loop builds trees.  The scanned training engine
    derives every tree's prefix-stable key and its exact-count masks from
    this enumeration in one batched draw, so it does exactly the scheduled
    work — no masked-slot waste.
    """

    round_of_step: np.ndarray   # (S,) int32 — 0-based round index
    tree_in_round: np.ndarray   # (S,) int32 — tree slot within its round


def schedule_arrays(cfg) -> ScheduleArrays:
    """Materialise the (n_trees, rho_id) schedules for all rounds 1..M.

    ``tree_active[m, t] = 1`` iff tree slot ``t`` participates in round
    ``m + 1`` — the first ``n_trees_schedule(m+1)`` slots, so that with
    prefix-stable per-tree keys (``forest.sample_masks``) the active slots
    draw exactly the masks the legacy per-round loop draws.
    """
    rounds = np.arange(1, cfg.rounds + 1)
    n_trees = np.array([n_trees_schedule(cfg, int(m)) for m in rounds], np.int32)
    rho = np.array([rho_id_schedule(cfg, int(m)) for m in rounds], np.float32)
    active = (
        np.arange(cfg.n_trees_max)[None, :] < n_trees[:, None]
    ).astype(np.float32)
    return ScheduleArrays(n_trees=n_trees, rho_id=rho, tree_active=active)


def flat_schedule(cfg) -> tuple[ScheduleArrays, FlatSchedule]:
    """Flatten the mask-form schedule to per-tree-build scan steps.

    Row-major nonzeros of ``tree_active`` enumerate (round, slot) pairs in
    exactly the order the legacy loop builds them.
    """
    sched = schedule_arrays(cfg)
    round_idx, tree_idx = np.nonzero(sched.tree_active)
    flat = FlatSchedule(
        round_of_step=round_idx.astype(np.int32),
        tree_in_round=tree_idx.astype(np.int32),
    )
    return sched, flat
