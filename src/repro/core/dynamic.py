"""Dynamic FedGBF parameter schedules (§3.2.2, eqs. 6-7).

The paper's printed equations have mismatched parentheses and swapped
else-branches (eq. 6 is titled "Dynamic Increasing" but is written with cos
and a V_min tail). We implement the semantics the text and the experiments
unambiguously describe — "the cosine function to reduce the parameter values
round by round and the sine function to increase" with the k=1/k=0.5 worked
example of §3.2.2 — and note the typo here:

  decay     V(b_t) = V_min + (V_max - V_min) * cos( pi (b_t-1) / (2 k (b_T-1)) )
            for b_t in [1, k(b_T-1)+1], then V_min; V_max if b_T == 1.
  increase  V(b_t) = V_min + (V_max - V_min) * sin( pi (b_t-1) / (2 k (b_T-1)) )
            for b_t in [1, k(b_T-1)+1], then V_max; V_max if b_T == 1.

Check against the worked example: decay of tree count 50 -> 15 over b_T = 11
rounds. k=1: cos runs 0..pi/2 across rounds 1..11, so round 1 gives 50 and
round 11 gives 15. k=0.5: the cos phase completes at round 6 (value 15) and
rounds 7..11 hold 15 — exactly the paper's description.
"""

from __future__ import annotations

import math


def dynamic_decay(
    b_t: int, b_total: int, v_min: float, v_max: float, k: float = 1.0
) -> float:
    """Cosine decay from v_max (round 1) to v_min (round k*(b_T-1)+1), then hold."""
    if b_total <= 1:
        return v_max
    horizon = k * (b_total - 1)
    if b_t > horizon + 1:
        return v_min
    phase = math.pi * (b_t - 1) / (2.0 * horizon)
    return v_min + (v_max - v_min) * math.cos(phase)


def dynamic_increase(
    b_t: int, b_total: int, v_min: float, v_max: float, k: float = 1.0
) -> float:
    """Sine increase from v_min (round 1) to v_max (round k*(b_T-1)+1), then hold."""
    if b_total <= 1:
        return v_max
    horizon = k * (b_total - 1)
    if b_t > horizon + 1:
        return v_max
    phase = math.pi * (b_t - 1) / (2.0 * horizon)
    return v_min + (v_max - v_min) * math.sin(phase)


def n_trees_schedule(cfg, round_idx: int) -> int:
    """Trees per round (dynamic decaying; paper: 5 -> 2, k = 1). 1-based round."""
    v = dynamic_decay(
        round_idx, cfg.rounds, float(cfg.n_trees_min), float(cfg.n_trees_max),
        cfg.n_trees_speed,
    )
    return max(1, int(round(v)))


def rho_id_schedule(cfg, round_idx: int) -> float:
    """Sample rate per round (dynamic increasing; paper: 0.1 -> 0.3, k = 1)."""
    return float(
        dynamic_increase(
            round_idx, cfg.rounds, cfg.rho_id_min, cfg.rho_id_max, cfg.rho_id_speed
        )
    )
