"""Random-forest layer: the bagging base learner of FedGBF (Alg. 1 lines 3-7).

The N trees of a round share (g, h) — all fit the same boosting residual —
and differ only in their sampling masks P_m(j), Q_m(j) (eq. 4). TPU
adaptation: the per-tree parallelism the paper gets from multi-worker FATE
becomes a ``jax.vmap`` over the tree axis — one XLA program builds the whole
layer, and the sampling matrices become boolean masks so shapes stay static.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import tree as tree_mod
from repro.core.types import TreeArrays, TreeConfig


def sample_masks(
    rng: jax.Array, n: int, d: int, n_trees: int, rho_id: float, rho_feat: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact-count subsampling masks per tree.

    The paper samples exactly n_m(j) = n * rho_id rows and d_m(j) = d * rho_feat
    features without replacement (eq. 4); ``random.permutation(n) < k`` places
    exactly k ones uniformly at random.

    Returns:
      sample_mask: (n_trees, n) float32 in {0, 1}
      feature_mask: (n_trees, d) bool
    """
    n_keep = max(1, int(round(n * rho_id)))
    d_keep = max(1, int(round(d * rho_feat)))
    keys = jax.random.split(rng, 2 * n_trees).reshape(n_trees, 2, 2)

    def one(k):
        smask = (jax.random.permutation(k[0], n) < n_keep).astype(jnp.float32)
        fmask = jax.random.permutation(k[1], d) < d_keep
        return smask, fmask

    return jax.vmap(one)(keys)


@partial(jax.jit, static_argnames=("cfg", "backend"))
def build_forest(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    sample_mask: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    backend=None,
) -> tuple[TreeArrays, jnp.ndarray]:
    """Build all trees of one forest layer in parallel (vmap over trees).

    Args:
      binned: (n, d) shared binned features.
      g, h: (n,) shared derivatives (all trees of round m fit y_hat^(m-1)).
      sample_mask: (n_trees, n); feature_mask: (n_trees, d).
      backend: ``core.backend.TreeBackend`` execution providers (hashable,
        rides through jit as one static argument); None = centralized-local.
        Reuse one backend instance across rounds to reuse the jit cache.

    Returns:
      (trees, train_pred): trees is a stacked TreeArrays (leading axis
      n_trees); train_pred (n,) is the bagging-averaged raw output on the
      full training set, ready for the boosting update
      y_hat^(m) = y_hat^(m-1) + lr * train_pred (Alg. 1 line 8).
    """

    def one(smask, fmask):
        tr, assign = tree_mod.build_tree(
            binned, g, h, smask, fmask, cfg, backend=backend,
        )
        return tr, tr.leaf_weight[assign]

    trees, per_tree_pred = jax.vmap(one)(sample_mask, feature_mask)
    train_pred = jnp.mean(per_tree_pred, axis=0)
    return trees, train_pred
