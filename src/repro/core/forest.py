"""Random-forest layer: the bagging base learner of FedGBF (Alg. 1 lines 3-7).

The N trees of a round share (g, h) — all fit the same boosting residual —
and differ only in their sampling masks P_m(j), Q_m(j) (eq. 4). TPU
adaptation: the per-tree parallelism the paper gets from multi-worker FATE
becomes the round-native forest engine (``core.tree.build_round``,
DESIGN.md §9) — one XLA program builds the whole layer with the tree axis
explicit in every provider, and the sampling matrices become boolean masks
so shapes stay static.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import tree as tree_mod
from repro.core.types import TreeArrays, TreeConfig


def sample_masks(
    rng: jax.Array, n: int, d: int, n_trees: int, rho_id, rho_feat: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact-count subsampling masks per tree.

    The paper samples exactly n_m(j) = n * rho_id rows and d_m(j) = d * rho_feat
    features without replacement (eq. 4); ``random.permutation(n) < k`` places
    exactly k ones uniformly at random.

    ``rho_id`` may be a python float (host path) — the keep-count is then
    rounded on the host exactly as the legacy loop always did.

    Returns:
      sample_mask: (n_trees, n) float32 in {0, 1}
      feature_mask: (n_trees, d) bool
    """
    n_keep = max(1, int(round(n * rho_id)))
    return sample_masks_counts(rng, n, d, n_trees, n_keep,
                               feature_keep_count(d, rho_feat))


def feature_keep_count(d: int, rho_feat: float) -> int:
    """The ONE rounding rule for d_m(j) = d * rho_feat (eq. 4).

    Loop/scan mask equivalence depends on every call site sharing this exact
    expression — both engines and the GOSS path resolve d_keep through here.
    """
    return max(1, int(round(d * rho_feat)))


def masks_from_keys(
    keys: jnp.ndarray, n: int, d: int, n_keep, d_keep
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact-count masks from pre-derived per-tree keys (batched).

    ``keys`` is (K, 2) uint32; ``n_keep`` is a scalar or a (K,) vector of
    keep-counts (may be traced).  One batched draw for any number of trees —
    the scanned engine precomputes ALL its steps' masks through this in a
    single vmap (a batched sort is far cheaper than per-step sorts).
    """
    n_keep = jnp.broadcast_to(jnp.asarray(n_keep), keys.shape[:1])

    def one(k, nk):
        ks, kf = jax.random.split(k)
        smask = (jax.random.permutation(ks, n) < nk).astype(jnp.float32)
        fmask = jax.random.permutation(kf, d) < d_keep
        return smask, fmask

    return jax.vmap(one)(keys, n_keep)


def fold_in_keys(rng: jax.Array, indices: jnp.ndarray) -> jnp.ndarray:
    """Per-tree keys via ``random.fold_in(rng, t)`` — *prefix-stable* in the
    tree count (unlike ``random.split(rng, k)``, whose keys depend on k), so
    any subset of tree slots draws exactly the masks a full-round draw
    produces.  The scanned training engine (DESIGN.md §4) relies on this to
    stay mask-for-mask equivalent to the legacy per-round loop."""
    return jax.vmap(lambda t: jax.random.fold_in(rng, t))(indices)


def sample_masks_counts(
    rng: jax.Array, n: int, d: int, n_trees: int, n_keep, d_keep
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``sample_masks`` with explicit keep-counts; counts may be traced."""
    return masks_from_keys(
        fold_in_keys(rng, jnp.arange(n_trees)), n, d, n_keep, d_keep
    )


def goss_counts(n: int, rho_id: float, top_share: float) -> tuple[int, int]:
    """Split the round's rho_id sample budget into GOSS (top, random) counts.

    ``n_keep = round(n * rho_id)`` samples total (the exact host expression
    the uniform path uses), of which ``round(n_keep * top_share)`` are the
    largest-|g| samples and the rest are drawn uniformly from the remainder.
    Clamped so at least one random sample is always drawn (the amplification
    factor divides by it) and the top set never swallows the whole dataset.
    """
    n_keep = max(1, min(n, int(round(n * rho_id))))
    n_top = max(0, min(int(round(n_keep * top_share)), n_keep - 1, n - 1))
    n_rand = max(1, min(n_keep - n_top, n - n_top))
    return n_top, n_rand


def goss_masks_from_keys(
    keys: jnp.ndarray, g: jnp.ndarray, d: int, n_top, n_rand, d_keep: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """GOSS weight masks from prefix-stable per-tree keys (DESIGN.md §5).

    Gradient-based one-side sampling (LightGBM; the subsampling lever
    SecureBoost+ carries into VFL): every tree keeps the ``n_top``
    largest-|g| samples at weight 1 (ties broken toward the lower sample
    index — ``argsort`` is stable), then draws exactly ``n_rand`` of the
    remaining samples uniformly at weight ``(n - n_top) / n_rand``, which
    keeps the histogram (g, h, count) sums unbiased estimates of the
    full-data sums over the small-gradient region.

    The returned ``smask`` is therefore a *weight* vector, not 0/1 — every
    consumer already multiplies stats by the mask (``core/histogram.py``), so
    the tree builders and both training engines run unchanged.  ``keys`` uses
    the same ``fold_in`` per-slot discipline as ``masks_from_keys`` (and the
    same (sample, feature) key split, so the feature masks are identical to
    the uniform path's draw for the same keys); the top-|g| set is
    deterministic in ``g`` and shared by all trees of the round.

    Args:
      keys: (K, 2) uint32 per-tree keys (``fold_in_keys``).
      g: (n,) first-order gradients of the round.
      n_top, n_rand: scalars or (K,) vectors; may be traced.
      d_keep: static feature keep-count.
    """
    n = g.shape[0]
    n_top = jnp.broadcast_to(jnp.asarray(n_top), keys.shape[:1])
    n_rand = jnp.broadcast_to(jnp.asarray(n_rand), keys.shape[:1])
    if g.ndim > 1:
        # K-channel objectives: rank by the per-sample L1 gradient norm
        # (reduces to |g| at K = 1, where the branch below stays bit-exact).
        g = jnp.abs(g).sum(axis=-1)
    order = jnp.argsort(-jnp.abs(g))  # stable: ties toward lower index
    rank = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))

    def one(k, nt, nr):
        ks, kf = jax.random.split(k)
        is_top = rank < nt
        u = jax.random.uniform(ks, (n,))
        u = jnp.where(is_top, 2.0, u)  # sentinel > any uniform: tops excluded
        thr = jnp.sort(u)[jnp.clip(nr - 1, 0, n - 1)]  # nr-th smallest
        is_rand = (~is_top) & (u <= thr)
        amplify = (n - nt).astype(jnp.float32) / jnp.maximum(nr, 1).astype(
            jnp.float32
        )
        smask = is_top.astype(jnp.float32) + is_rand.astype(jnp.float32) * amplify
        fmask = jax.random.permutation(kf, d) < d_keep
        return smask, fmask

    return jax.vmap(one)(keys, n_top, n_rand)


def goss_masks(
    rng: jax.Array, g: jnp.ndarray, d: int, n_trees: int,
    n_top: int, n_rand: int, d_keep: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``goss_masks_from_keys`` over a round key (the legacy-loop entry)."""
    return goss_masks_from_keys(
        fold_in_keys(rng, jnp.arange(n_trees)), g, d, n_top, n_rand, d_keep
    )


def _forest_per_tree(binned, g, h, sample_mask, feature_mask, cfg, backend=None,
                     root_delta_rows=0):
    """Un-jitted core: build the whole round, return per-tree predictions.

    One ``tree.build_round`` call (DESIGN.md §9) — the tree axis is explicit
    in every provider, not closed over by a vmap.  Returns (trees,
    per_tree_pred) with per_tree_pred (n_trees, n) — the raw leaf outputs of
    every tree on the full training set, *before* any bagging combiner, so
    the caller owns the combine.
    """
    trees, assign = tree_mod.build_round(
        binned, g, h, sample_mask, feature_mask, cfg, backend=backend,
        root_delta_rows=root_delta_rows,
    )
    if trees.leaf_weight.ndim == 3:  # K-channel leaf table: (T, L, K)
        per_tree_pred = jnp.take_along_axis(
            trees.leaf_weight, assign[..., None], axis=1
        )  # (T, n, K)
    else:
        per_tree_pred = jnp.take_along_axis(trees.leaf_weight, assign, axis=1)
    return trees, per_tree_pred


@partial(jax.jit, static_argnames=("cfg", "backend", "root_delta_rows"))
def build_forest(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    sample_mask: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    backend=None,
    root_delta_rows: int = 0,
) -> tuple[TreeArrays, jnp.ndarray]:
    """Build all trees of one forest layer as one round (tree axis explicit).

    Args:
      binned: (n, d) shared binned features.
      g, h: (n,) shared derivatives (all trees of round m fit y_hat^(m-1)).
      sample_mask: (n_trees, n); feature_mask: (n_trees, d).
      backend: ``core.backend.TreeBackend`` execution providers (hashable,
        rides through jit as one static argument); None = centralized-local.
        Reuse one backend instance across rounds to reuse the jit cache.
      root_delta_rows: static shared-root delta-buffer width (DESIGN.md §9;
        0 = direct level-0 pass).  The training engines derive it from the
        rho_id schedule when ``cfg.shared_root`` is set.

    Returns:
      (trees, train_pred): trees is a stacked TreeArrays (leading axis
      n_trees); train_pred (n,) is the bagging-averaged raw output on the
      full training set, ready for the boosting update
      y_hat^(m) = y_hat^(m-1) + lr * train_pred (Alg. 1 line 8).
    """
    trees, per_tree_pred = _forest_per_tree(
        binned, g, h, sample_mask, feature_mask, cfg, backend, root_delta_rows
    )
    train_pred = jnp.mean(per_tree_pred, axis=0)
    return trees, train_pred


@partial(jax.jit, static_argnames=("cfg", "backend", "root_delta_rows"))
def build_forest_per_tree(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    sample_mask: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    backend=None,
    root_delta_rows: int = 0,
) -> tuple[TreeArrays, jnp.ndarray]:
    """Like ``build_forest`` but returns *per-tree* predictions (n_trees, n).

    The scanned training engine consumes this: it owns the bagging combine
    (and the validation-set prediction reuses the same tree stack), so the
    builder must not reduce over the tree axis itself.
    """
    return _forest_per_tree(
        binned, g, h, sample_mask, feature_mask, cfg, backend, root_delta_rows
    )
