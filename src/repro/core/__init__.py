"""FedGBF core: the paper's contribution as a composable JAX library.

Public API:

  binning.fit_bin / bin_data          quantile binning (Alg. 2 step 1)
  histogram.compute_histogram         g/h histogram accumulation
  histogram.compute_round_histogram   round-native (T, ...) accumulation (§9)
  split.choose_splits[_round]         gain (eq. 1) + per-node argmax
  tree.build_round                    round-native forest engine (DESIGN.md §9)
  tree.build_tree / predict_tree      level-wise GenerateTree (T = 1 case)
  forest.build_forest                 bagging layer over build_round (Alg. 1)
  boosting.train_fedgbf               (Dynamic) FedGBF training (Algs. 1, 3)
  boosting.secureboost_config         the paper's baseline as a degenerate config
  backend.get_backend / TreeBackend   named execution backends (DESIGN.md §1)
  types.pack_ensemble / PackedEnsemble  packed inference layout (DESIGN.md §3)
  dynamic.*                           cosine/sine schedules (eqs. 6-7)
  runtime_model.*                     eqs. 8-11 analytical runtime model
"""

from repro.core import (  # noqa: F401
    backend,
    binning,
    boosting,
    dynamic,
    forest,
    histogram,
    losses,
    metrics,
    runtime_model,
    split,
    tree,
)
from repro.core.backend import (  # noqa: F401
    BackendDescriptor,
    TreeBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.types import (  # noqa: F401
    EnsembleModel,
    FedGBFConfig,
    PackedEnsemble,
    TreeArrays,
    TreeConfig,
    forest_size,
    pack_ensemble,
    unpack_ensemble,
)
