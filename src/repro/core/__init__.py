"""FedGBF core: the paper's contribution as a composable JAX library.

Public API:

  binning.fit_bin / bin_data          quantile binning (Alg. 2 step 1)
  histogram.compute_histogram         g/h histogram accumulation
  split.choose_splits                 gain (eq. 1) + per-node argmax
  tree.build_tree / predict_tree      level-wise GenerateTree (Alg. 2)
  forest.build_forest                 vmap-parallel bagging layer (Alg. 1)
  boosting.train_fedgbf               (Dynamic) FedGBF training (Algs. 1, 3)
  boosting.secureboost_config         the paper's baseline as a degenerate config
  dynamic.*                           cosine/sine schedules (eqs. 6-7)
  runtime_model.*                     eqs. 8-11 analytical runtime model
"""

from repro.core import (  # noqa: F401
    binning,
    boosting,
    dynamic,
    forest,
    histogram,
    losses,
    metrics,
    runtime_model,
    split,
    tree,
)
from repro.core.types import (  # noqa: F401
    EnsembleModel,
    FedGBFConfig,
    TreeArrays,
    TreeConfig,
    forest_size,
)
