"""TreeBackend: the execution seam of the tree library (DESIGN.md §1).

Historically the histogram/split/route/leaf providers were four loose
callables threaded ad-hoc through ``boosting -> forest -> tree``, and the
federated path bypassed them with a fifth (``forest_fn``).  A ``TreeBackend``
bundles all of them plus an execution descriptor (impl name, party/mesh
configuration) into one hashable object that is threaded as a single jit
static argument.  Named backends come from a registry:

  ``"local"``         centralized execution, segment-sum histograms;
  ``"local-pallas"``  centralized execution, Pallas TPU histogram kernel;
  ``"vfl-histogram"`` shard_map VFL, paper-faithful full-histogram exchange;
  ``"vfl-argmax"``    shard_map VFL, candidate-only exchange (beyond-paper);
  ``"vfl-histogram-q8"`` / ``"-q16"``  histogram exchange quantized to
                      int8/int16 + per-(node, feature, channel) scales
                      (lossy; federation/compress.py, DESIGN.md §5);
  ``"vfl-argmax-topk"`` each party ships its k best candidates per node
                      (lossless for any k >= 1);
  ``"vfl-histogram-async[-q8|-q16]"`` the histogram exchange double-
                      buffered: the per-level collective ships as two
                      overlapping transfers (DESIGN.md §10), bit-identical
                      results, one logical message either way;
  ``"vfl-*-sharded"`` the above with samples additionally sharded over the
                      data axes (rows split ``(n/data_shards, ...)`` per
                      host; histograms/leaf stats psum over the data axes,
                      uneven row counts pad with weight-0 rows inside the
                      backend — the multi-host extension, DESIGN.md §8).

The ``vfl-*`` factories need a device mesh and a ``TreeConfig``
(``get_backend(name, mesh=..., tree=...)``); they are registered lazily by
``federation/vfl.py`` on first request so ``core`` never imports
``federation``.  Later scaling work (async rounds, multi-host execution,
histogram caching) plugs in here by registering new factories.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

_REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class BackendDescriptor:
    """Execution metadata of a TreeBackend (all fields hashable/static).

    ``impl`` is the registry name; ``histogram_impl`` names the histogram
    provider family (``"segment"`` | ``"onehot"`` | ``"pallas"``); the party/
    data fields describe the SPMD decomposition for federated backends and
    stay at their defaults for centralized ones.  ``transport`` names the
    wire format of the per-level party exchange (``"raw"`` | ``"q8"`` |
    ``"q16"`` | ``"topk"``; federation/compress.py) and ``transport_spec``
    carries the full (frozen, hashable) ``compress.TransportSpec`` for
    non-raw formats — the tag alone cannot represent non-default parameters
    (a custom top-k k or quantization seed), and byte accounting must never
    guess them.
    """

    impl: str
    histogram_impl: str = "segment"
    num_parties: int = 1
    party_axis: Optional[str] = None
    data_axes: tuple = ()
    shard_samples: bool = False
    transport: str = "raw"
    transport_spec: Optional[object] = None  # compress.TransportSpec (non-raw)
    # Double-buffered level exchange (DESIGN.md §10): the per-level party
    # all_gather ships as two overlapping transfers instead of one barrier
    # collective.  Payloads and results are bit-identical; only the
    # schedule changes.
    async_exchange: bool = False
    # Chaos transport (DESIGN.md §13): the frozen ``chaos.ChaosSpec`` when
    # the level exchange runs under the fault-injecting wrapper, else None.
    # Carried here for the same reason as ``transport_spec``: byte
    # accounting must replay the exact fault schedule, never guess it.
    chaos: Optional[object] = None

    @property
    def is_federated(self) -> bool:
        return self.party_axis is not None


@dataclasses.dataclass(frozen=True)
class TreeBackend:
    """Bundled execution providers for tree/forest construction.

    The execution unit is the *round* (DESIGN.md §9): ``core.tree.build_round``
    drives round-native providers whose operands carry an explicit leading
    ``(T, ...)`` tree axis.  Per-tree providers remain the compatibility
    seam — when only they are set, ``build_round`` lifts them over the tree
    axis with ``jax.vmap``; a backend overrides the ``round_*`` twin to fuse
    the tree axis into its program (the segment-sum fold, the Pallas
    tree-grid kernel, ONE party collective per level).

    Provider semantics (all optional — None selects the centralized default):

      histogram_fn  signature of ``core.histogram.compute_histogram``;
      round_histogram_fn  round-native twin (``compute_round_histogram``
                    contract): (T, n) weight/assign -> (T, nodes, d, B, 3);
                    must accept the keywords ``level`` (the static tree
                    level — stateful transports key per-level state off it)
                    and, when the backend is used with shared-root caching
                    (§9), ``root_delta_rows``;
      child_histogram_fn / round_child_histogram_fn  child-only histogram
                    providers of the subtraction pipeline (DESIGN.md §6):
                    same signatures, but ``assign`` is the current level's
                    assignment and the frontier argument is the PARENT
                    count — return left-child histograms at half width.
                    None derives them generically via
                    ``histogram.as_child_fn``/``as_round_child_fn``;
                    backends override only to fuse the left-mask/parent-id
                    staging (the Pallas child kernels).  Consulted only when
                    ``TreeConfig.hist_subtraction`` is set;
      choose_fn     (hist, feature_mask) -> SplitDecision;
      round_choose_fn  ((T, nodes, d, B, 3), (T, d)) -> (T, nodes) decision;
      route_fn      (binned, assign, decision) -> new assign;
      round_route_fn  batched twin over (T, n) assignments;
      leaf_fn       signature of ``core.histogram.leaf_stats``
                    ((g, h, weight, assign, num_leaves) -> (num_leaves, 3)),
                    used for the leaf-statistics pass;
      round_leaf_fn  round twin ((T, n) -> (T, num_leaves, 3)); also serves
                    the compaction liveness counts (psum'd when sharded);
      forest_builder  full override of ``core.forest.build_forest`` — the
                    federated path uses this to wrap the whole per-round
                    forest construction in one shard_map program with the
                    other providers baked in.
      forest_builder_per_tree  full override of
                    ``core.forest.build_forest_per_tree`` (same wrapping, but
                    returning per-tree predictions) — consumed by the scanned
                    training engine, which owns the bagging combine.

    Frozen (hashable) so the whole object rides through ``jax.jit`` as one
    static argument; reuse a backend instance across rounds/calls to reuse
    the jit cache.
    """

    descriptor: BackendDescriptor
    histogram_fn: Optional[Callable] = None
    child_histogram_fn: Optional[Callable] = None
    choose_fn: Optional[Callable] = None
    route_fn: Optional[Callable] = None
    leaf_fn: Optional[Callable] = None
    round_histogram_fn: Optional[Callable] = None
    round_child_histogram_fn: Optional[Callable] = None
    round_choose_fn: Optional[Callable] = None
    round_route_fn: Optional[Callable] = None
    round_leaf_fn: Optional[Callable] = None
    forest_builder: Optional[Callable] = None
    forest_builder_per_tree: Optional[Callable] = None

    @property
    def name(self) -> str:
        return self.descriptor.impl

    def build_forest(self, binned, g, h, sample_mask, feature_mask, cfg=None,
                     root_delta_rows=0):
        """Build one forest layer (drop-in for ``core.forest.build_forest``).

        ``cfg`` may be omitted for backends whose ``forest_builder`` bakes
        the tree config into a pre-built program (the shard_map VFL path).
        ``root_delta_rows`` is the static shared-root delta-buffer width
        (``core.tree.build_round``; 0 = direct level-0 pass).
        """
        if self.forest_builder is not None:
            return self.forest_builder(
                binned, g, h, sample_mask, feature_mask, cfg,
                root_delta_rows=root_delta_rows,
            )
        if cfg is None:
            raise ValueError(f"backend {self.name!r} needs an explicit TreeConfig")
        from repro.core import forest as forest_mod  # local to avoid cycle

        return forest_mod.build_forest(
            binned, g, h, sample_mask, feature_mask, cfg, backend=self,
            root_delta_rows=root_delta_rows,
        )

    def build_forest_per_tree(self, binned, g, h, sample_mask, feature_mask,
                              cfg=None, root_delta_rows=0):
        """Build one forest layer, returning (trees, per_tree_pred (T, n)).

        The scanned training engine's entry point (DESIGN.md §4): the caller
        owns the bagging combine so it can mask out inactive tree slots.
        """
        if self.forest_builder_per_tree is not None:
            return self.forest_builder_per_tree(
                binned, g, h, sample_mask, feature_mask, cfg,
                root_delta_rows=root_delta_rows,
            )
        if self.forest_builder is not None:
            raise ValueError(
                f"backend {self.name!r} overrides forest_builder but provides "
                "no forest_builder_per_tree; the scanned engine needs the "
                "per-tree variant (see federation/vfl.py for the template)"
            )
        if cfg is None:
            raise ValueError(f"backend {self.name!r} needs an explicit TreeConfig")
        from repro.core import forest as forest_mod  # local to avoid cycle

        return forest_mod.build_forest_per_tree(
            binned, g, h, sample_mask, feature_mask, cfg, backend=self,
            root_delta_rows=root_delta_rows,
        )

    def build_tree(self, binned, g, h, sample_mask, feature_mask, cfg):
        """Build one tree (drop-in for ``core.tree.build_tree``)."""
        from repro.core import tree as tree_mod  # local to avoid cycle

        return tree_mod.build_tree(
            binned, g, h, sample_mask, feature_mask, cfg, backend=self
        )


def register_backend(name: str, factory: Callable[..., TreeBackend]) -> None:
    """Register a named backend factory: ``factory(**kwargs) -> TreeBackend``."""
    _REGISTRY[name] = factory


def available_backends() -> tuple:
    """Registered backend names (triggers the lazy vfl registration)."""
    _ensure_vfl_registered()
    return tuple(sorted(_REGISTRY))


def get_backend(name: str, **kwargs) -> TreeBackend:
    """Construct a named backend. ``vfl-*`` names need ``mesh=``/``tree=``."""
    if name not in _REGISTRY and name.startswith("vfl"):
        _ensure_vfl_registered()
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        )
    return _REGISTRY[name](**kwargs)


def resolve_backend(backend, **kwargs) -> TreeBackend:
    """Accept None | name | TreeBackend and return a TreeBackend."""
    if backend is None:
        return get_backend("local")
    if isinstance(backend, str):
        return get_backend(backend, **kwargs)
    if isinstance(backend, TreeBackend):
        return backend
    raise TypeError(f"backend must be None, str, or TreeBackend; got {backend!r}")


def _ensure_vfl_registered() -> None:
    try:
        import repro.federation.vfl  # noqa: F401  (registers vfl-* factories)
    except ImportError as e:
        # Only a genuinely absent federation package degrades to local-only;
        # any other ImportError (e.g. a broken transitive dep) must surface
        # rather than masquerade as "unknown backend".
        if e.name and e.name.startswith("repro.federation"):
            return
        raise


def _local_factory(**_kw) -> TreeBackend:
    return TreeBackend(BackendDescriptor(impl="local"))


def _local_pallas_factory(**_kw) -> TreeBackend:
    # The fused training-side kernel: id/stats staging happens inside the
    # kernel (kernels/histogram/train_histogram.py), not in XLA.  The child
    # variant additionally forms the subtraction pipeline's left-mask and
    # parent ids in-kernel, so the half-width pass stays staging-free too.
    # The round variants add the tree-grid axis (DESIGN.md §9): one kernel
    # launch accumulates the whole round's (T, nodes, d, B, 3) histogram.
    from repro.core.histogram import histogram_dispatch

    return TreeBackend(
        BackendDescriptor(impl="local-pallas", histogram_impl="pallas"),
        histogram_fn=histogram_dispatch("pallas-fused"),
        child_histogram_fn=histogram_dispatch("pallas-fused-child"),
        round_histogram_fn=histogram_dispatch("pallas-fused-round"),
        round_child_histogram_fn=histogram_dispatch("pallas-fused-round-child"),
    )


register_backend("local", _local_factory)
register_backend("local-pallas", _local_pallas_factory)
