"""Objective registry: the pluggable loss seam with K gradient channels.

Every layer of the trainer consumes only second-order statistics, so an
objective is a narrow interface (DESIGN.md §11): per-sample gradients and
hessians, a loss value, a prediction-space activation, the leaf closed
form, and the metric set.  The channel contract:

* **K = 1 objectives** (``logistic``, ``squared``, ``quantile[@a]``)
  return ``(n,)`` gradients/hessians and flow through the historical
  3-channel ``(g, h, count)`` histogram layout byte-for-byte unchanged —
  binary logloss through this registry is bit-identical to the
  pre-registry dual-dispatch (`losses.py` is now a thin shim over it).
* **K > 1 objectives** (``softmax{K}`` multiclass) return ``(n, K)`` each
  and widen the histogram channel axis to ``2K + 1`` channels laid out
  ``(g_1..g_K, h_1..h_K, count)``; margins, leaf values and the packed
  leaf table grow a trailing K axis.  The count channel is always LAST,
  so ``hist[..., -1]`` reads it at any K (and ``hist[..., 2]`` still
  works at K = 1).

Objectives are looked up by name.  Two names are parameterized:
``"softmax{K}"`` (e.g. ``"softmax3"``) and ``"quantile[@alpha]"``
(e.g. ``"quantile@0.25"``; bare ``"quantile"`` is the median,
alpha = 0.5).  ``"softmax1"`` degenerates to the binary-logistic
formulas exactly (one-channel softmax IS a sigmoid margin), so the K = 1
special case is bit-exact, not merely equivalent.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import metrics


# ---------------------------------------------------------------------------
# grad/hess + loss formulas (moved verbatim from the old losses.py dispatch)
# ---------------------------------------------------------------------------
def _logistic_grad_hess(y, y_hat):
    """Binary logloss on raw margins: g = p - y, h = p (1 - p)."""
    p = jax.nn.sigmoid(y_hat)
    return p - y, p * (1.0 - p)


def _logistic_loss(y, y_hat):
    # stable logloss on margins
    return jnp.mean(
        jnp.maximum(y_hat, 0) - y_hat * y + jnp.log1p(jnp.exp(-jnp.abs(y_hat)))
    )


def _squared_grad_hess(y, y_hat):
    """0.5 * (y_hat - y)^2: g = y_hat - y, h = 1."""
    return y_hat - y, jnp.ones_like(y_hat)


def _squared_loss(y, y_hat):
    return 0.5 * jnp.mean((y_hat - y) ** 2)


def _quantile_grad_hess(alpha: float):
    def fn(y, y_hat):
        # Pinball loss: L = a (y - m) if y >= m else (1 - a)(m - y);
        # dL/dm = -a below the quantile, (1 - a) above.  The hessian is 0
        # a.e., so we use the standard constant-hessian surrogate h = 1
        # (the Newton leaf becomes a damped mean of pinball gradients).
        g = jnp.where(y > y_hat, -alpha, 1.0 - alpha)
        return g, jnp.ones_like(y_hat)

    return fn


def _quantile_loss(alpha: float):
    def fn(y, y_hat):
        e = y - y_hat
        return jnp.mean(jnp.maximum(alpha * e, (alpha - 1.0) * e))

    return fn


def _softmax_grad_hess(k: int):
    def fn(y, y_hat):
        # y: (n,) integer class labels (float-typed is fine — onehot casts);
        # y_hat: (n, K) raw per-class margins.  Diagonal-hessian multiclass
        # softmax (XGBoost-style): g_k = p_k - 1[y = k], h_k = p_k (1 - p_k).
        p = jax.nn.softmax(y_hat, axis=-1)
        onehot = jax.nn.one_hot(y.astype(jnp.int32), k, dtype=p.dtype)
        return p - onehot, p * (1.0 - p)

    return fn


def _softmax_loss(k: int):
    def fn(y, y_hat):
        logp = jax.nn.log_softmax(y_hat, axis=-1)
        onehot = jax.nn.one_hot(y.astype(jnp.int32), k, dtype=logp.dtype)
        return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

    return fn


# ---------------------------------------------------------------------------
# metric vectors (in-graph) and host-side evaluation, per objective family
# ---------------------------------------------------------------------------
def _logistic_metric_vector(y, margin):
    prob = 1.0 / (1.0 + jnp.exp(-margin))  # as metrics.classification_report
    return jnp.stack([
        metrics.auc(y, margin),
        metrics.accuracy(y, prob),
        metrics.f1_score(y, prob),
        _logistic_loss(y, margin),
    ])


def _regression_metric_vector(loss_fn):
    def fn(y, margin):
        return jnp.stack([
            jnp.sqrt(jnp.mean((margin - y) ** 2)),
            loss_fn(y, margin),
        ])

    return fn


def _softmax_metric_vector(k: int):
    loss_fn = _softmax_loss(k)

    def fn(y, margin):
        pred = jnp.argmax(margin, axis=-1).astype(jnp.float32)
        acc = jnp.mean(pred == y.astype(jnp.float32))
        return jnp.stack([acc, loss_fn(y, margin)])

    return fn


@dataclasses.dataclass(frozen=True)
class Objective:
    """One registered objective.

    ``grad_hess(y, margin) -> (g, h)``: each ``(n,)`` when ``n_classes == 1``
    else ``(n, K)``.  ``loss_value(y, margin) -> scalar``.  ``activation``
    maps raw margins to prediction space (sigmoid / identity / softmax).
    ``metric_keys`` names the entries of ``metric_vector`` in order (the
    scanned engine's in-graph history rows and the loop engine's dicts use
    the same keys).  ``init_margin`` is the margin value training starts
    from before the config's ``base_score`` shift is applied.
    """

    name: str
    n_classes: int
    grad_hess: Callable
    loss_value: Callable
    activation: Callable
    metric_keys: tuple
    metric_vector: Callable
    init_margin: float = 0.0

    def leaf_from_stats(self, g_sum, h_sum, lambda_):
        """Newton leaf closed form w* = -G / (H + lambda), per channel.

        All shipped objectives use this default (``split.leaf_weights`` is
        its vectorized-over-the-histogram twin); a custom objective that
        overrides it must also swap the leaf provider.
        """
        return -g_sum / (h_sum + lambda_)

    def init_raw(self, n: int, base_score: float = 0.0) -> jnp.ndarray:
        """Initial margin carry: (n,) at K = 1, (n, K) otherwise."""
        shape = (n,) if self.n_classes == 1 else (n, self.n_classes)
        return jnp.full(shape, self.init_margin + base_score, jnp.float32)

    def evaluate(self, y, margin) -> dict:
        """Host-side metric dict — same quantities/order as metric_vector."""
        vec = self.metric_vector(y.astype(jnp.float32), margin)
        return dict(zip(self.metric_keys, (float(v) for v in vec)))


_logistic = Objective(
    name="logistic",
    n_classes=1,
    grad_hess=_logistic_grad_hess,
    loss_value=_logistic_loss,
    activation=jax.nn.sigmoid,
    metric_keys=("auc", "acc", "f1", "loss"),
    metric_vector=_logistic_metric_vector,
)

_REGISTRY = {
    "logistic": _logistic,
    "squared": Objective(
        name="squared",
        n_classes=1,
        grad_hess=_squared_grad_hess,
        loss_value=_squared_loss,
        activation=lambda m: m,
        metric_keys=("rmse", "loss"),
        metric_vector=_regression_metric_vector(_squared_loss),
    ),
}


def register(obj: Objective) -> Objective:
    """Add an objective to the registry (name must be unused)."""
    if obj.name in _REGISTRY:
        raise ValueError(f"objective {obj.name!r} already registered")
    _REGISTRY[obj.name] = obj
    return obj


def available_objectives() -> tuple:
    """Registered fixed names (parameterized families add softmax{K} and
    quantile[@alpha] on top)."""
    return tuple(sorted(_REGISTRY)) + ("quantile", "softmax{K}")


@lru_cache(maxsize=None)
def _parameterized(name: str) -> Objective:
    if name.startswith("softmax"):
        try:
            k = int(name[len("softmax"):])
        except ValueError:
            raise ValueError(f"bad softmax objective {name!r}: expected "
                             "'softmax<K>' (e.g. 'softmax3')") from None
        if k < 1:
            raise ValueError(f"softmax needs K >= 1, got {k}")
        if k == 1:
            # One-channel softmax IS the sigmoid margin: alias the binary
            # formulas so K = 1 is bit-exact, not just equivalent.
            return dataclasses.replace(_logistic, name=name)
        return Objective(
            name=name,
            n_classes=k,
            grad_hess=_softmax_grad_hess(k),
            loss_value=_softmax_loss(k),
            activation=lambda m: jax.nn.softmax(m, axis=-1),
            metric_keys=("acc", "loss"),
            metric_vector=_softmax_metric_vector(k),
        )
    if name.startswith("quantile"):
        alpha = 0.5
        if name != "quantile":
            if not name.startswith("quantile@"):
                raise ValueError(f"bad quantile objective {name!r}: expected "
                                 "'quantile' or 'quantile@<alpha>'")
            alpha = float(name[len("quantile@"):])
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"quantile alpha must be in (0, 1), got {alpha}")
        loss_fn = _quantile_loss(alpha)
        return Objective(
            name=name,
            n_classes=1,
            grad_hess=_quantile_grad_hess(alpha),
            loss_value=loss_fn,
            activation=lambda m: m,
            metric_keys=("rmse", "loss"),
            metric_vector=_regression_metric_vector(loss_fn),
        )
    raise ValueError(
        f"unknown objective {name!r}; options: {available_objectives()}"
    )


def get_objective(name: str) -> Objective:
    """Resolve an objective by name (cached — objectives are singletons,
    so configs keep storing plain strings and jit static args stay cheap)."""
    obj = _REGISTRY.get(name)
    if obj is not None:
        return obj
    return _parameterized(name)


def num_stats(n_classes: int) -> int:
    """Histogram channel count for K gradient channels: (g*K, h*K, count)."""
    return 2 * n_classes + 1
