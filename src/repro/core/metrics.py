"""Evaluation metrics reported by the paper: AUC, accuracy, F1 (§4.1)."""

from __future__ import annotations

import jax.numpy as jnp


def auc(y: jnp.ndarray, score: jnp.ndarray) -> jnp.ndarray:
    """Area under ROC via the Mann-Whitney rank statistic (ties averaged)."""
    y = y.astype(jnp.float32)
    n = y.shape[0]
    order = jnp.argsort(score)
    sorted_scores = score[order]
    # average ranks for ties: rank = mean of 1-based positions of equal scores
    ranks_lo = jnp.searchsorted(sorted_scores, score, side="left").astype(jnp.float32)
    ranks_hi = jnp.searchsorted(sorted_scores, score, side="right").astype(jnp.float32)
    ranks = 0.5 * (ranks_lo + ranks_hi + 1.0)  # 1-based average rank
    n_pos = jnp.sum(y)
    n_neg = n - n_pos
    sum_pos_ranks = jnp.sum(ranks * y)
    return (sum_pos_ranks - n_pos * (n_pos + 1.0) / 2.0) / jnp.maximum(n_pos * n_neg, 1.0)


def accuracy(y: jnp.ndarray, prob: jnp.ndarray, threshold: float = 0.5) -> jnp.ndarray:
    pred = (prob >= threshold).astype(jnp.float32)
    return jnp.mean(pred == y.astype(jnp.float32))


def f1_score(y: jnp.ndarray, prob: jnp.ndarray, threshold: float = 0.5) -> jnp.ndarray:
    y = y.astype(jnp.float32)
    pred = (prob >= threshold).astype(jnp.float32)
    tp = jnp.sum(pred * y)
    fp = jnp.sum(pred * (1.0 - y))
    fn = jnp.sum((1.0 - pred) * y)
    return 2.0 * tp / jnp.maximum(2.0 * tp + fp + fn, 1.0)


def classification_report(y: jnp.ndarray, margin: jnp.ndarray) -> dict:
    """All three paper metrics from raw margins."""
    prob = 1.0 / (1.0 + jnp.exp(-margin))
    return {
        "auc": float(auc(y, margin)),
        "acc": float(accuracy(y, prob)),
        "f1": float(f1_score(y, prob)),
    }


def multiclass_report(y: jnp.ndarray, margin: jnp.ndarray) -> dict:
    """Accuracy + macro-F1 from (n, K) margins (argmax decision rule).

    AUC is a binary ranking statistic — it has no single canonical K-class
    form, so the multiclass report drops it rather than invent one.
    """
    k = margin.shape[-1]
    y = y.astype(jnp.int32)
    pred = jnp.argmax(margin, axis=-1).astype(jnp.int32)
    f1s = []
    for c in range(k):
        yc = (y == c).astype(jnp.float32)
        pc = (pred == c).astype(jnp.float32)
        tp = jnp.sum(pc * yc)
        fp = jnp.sum(pc * (1.0 - yc))
        fn = jnp.sum((1.0 - pc) * yc)
        f1s.append(2.0 * tp / jnp.maximum(2.0 * tp + fp + fn, 1.0))
    return {
        "acc": float(jnp.mean((pred == y).astype(jnp.float32))),
        "macro_f1": float(jnp.mean(jnp.stack(f1s))),
    }
