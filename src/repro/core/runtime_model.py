"""The paper's analytical runtime model (eqs. 8-11, appendix A.1-A.2).

T_unit is the measured cost of one full-data, full-feature federated decision
tree; a subsampled tree costs T_single = alpha * beta * T_unit (A.1 shows the
m*n*log n complexity makes this linear for large n). From T_unit:

  T_F^L = T_0 + sum_i alpha_i beta_i T_unit              (eq. 9, ideal parallel)
  T_F^U = T_0 + sum_i N_i alpha_i beta_i T_unit          (eq. 10, fully sequential)
  T_S   = T_0 + sum_i alpha_S beta_S T_unit              (eq. 11, SecureBoost)

The same bracketing generalises to any layer-parallel/step-sequential system,
which is how the LM substrate reuses it (DESIGN.md §7).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core import dynamic
from repro.core.types import FedGBFConfig


@dataclass(frozen=True)
class RuntimeEstimate:
    lower_s: float    # T_F^L — ideal within-layer parallelism
    upper_s: float    # T_F^U — fully sequential
    t0_s: float

    def as_interval(self) -> tuple[float, float]:
        return (self.lower_s, self.upper_s)


def round_schedules(cfg: FedGBFConfig) -> list[tuple[int, float, float]]:
    """Per-round (N_i, alpha_i, beta_i) implied by the dynamic schedules."""
    return [
        (
            dynamic.n_trees_schedule(cfg, m),
            dynamic.rho_id_schedule(cfg, m),
            cfg.rho_feat,
        )
        for m in range(1, cfg.rounds + 1)
    ]


def estimate_fedgbf_runtime(
    cfg: FedGBFConfig, t_unit_s: float, t0_s: float = 0.0
) -> RuntimeEstimate:
    """Eqs. 9-10 applied to a (Dynamic) FedGBF configuration."""
    lower = t0_s
    upper = t0_s
    for n_i, alpha_i, beta_i in round_schedules(cfg):
        single = alpha_i * beta_i * t_unit_s   # eq. 8
        lower += single                        # trees of a layer in parallel
        upper += n_i * single                  # trees of a layer sequential
    return RuntimeEstimate(lower_s=lower, upper_s=upper, t0_s=t0_s)


def estimate_secureboost_runtime(
    rounds: int, t_unit_s: float, t0_s: float = 0.0,
    alpha: float = 1.0, beta: float = 1.0,
) -> float:
    """Eq. 11 (the paper trains the baseline with alpha_S = beta_S = 1)."""
    return t0_s + rounds * alpha * beta * t_unit_s


def error_rate(estimate: float, real: float) -> float:
    """Eq. 14: abs(1 - estimate / real)."""
    return abs(1.0 - estimate / real)


def subsample_time_ratio(alpha: float, n: int) -> float:
    """A.1 eq. 12: T_{alpha n} / T_n = alpha + log2(alpha)/log2(n).

    Used by tests to check our measured tree-build times against the paper's
    linearity assumption (the correction term vanishes for large n).
    """
    import math

    if alpha <= 0:
        raise ValueError("alpha must be positive")
    return alpha + math.log2(alpha) / math.log2(n)
