"""Gradient/hessian histogram accumulation (Alg. 2 steps 6-8).

This is the compute hot-spot of every histogram GBDT (and the quantity the
VFL protocol ships between parties), so it has three implementations:

* ``compute_histogram``      — portable jnp ``segment_sum`` path (default on CPU),
* ``kernels/histogram``      — the Pallas TPU kernel (one-hot matmul on the MXU),
  selected via ``impl="pallas"``,
* ``kernels/histogram/ref.py`` — the oracle the kernel is tested against
  (re-exports this module's function).

Layout: ``hist[node, feature, bin, stat]`` with ``stat = (sum_g, sum_h, count)``
for K = 1 objectives and ``stat = (g_1..g_K, h_1..h_K, count)`` — ``2K + 1``
channels, count LAST — for K-channel objectives (DESIGN.md §11).  Every
provider derives the channel extent from the gradient rank (``(n,)`` vs
``(n, K)``), so the K = 1 path is byte-for-byte the historical 3-channel
one.  Histograms are *additive* in samples, which is what makes both the
data-parallel ``psum`` and the VFL per-party decomposition exact.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NUM_STATS = 3  # sum_g, sum_h, count

#: Optional trace-time recorder of histogram row-passes (the round engine's
#: level-0 accounting; benchmarks/ci_guard.py and tests/test_round_engine.py
#: probe through it).  Like ``compress.MessageMeter``, entries accumulate
#: once per *trace* — set it, ``jax.eval_shape`` exactly one program, read
#: it, reset it.  None (the default) skips recording entirely.
PASS_METER: Optional[list] = None


def _record_pass(tag: str, rows: int, trees: int) -> None:
    if PASS_METER is not None:
        PASS_METER.append({"tag": tag, "rows": int(rows), "trees": int(trees)})


def _stack_stats(g: jnp.ndarray, h: jnp.ndarray, weight: jnp.ndarray):
    """Per-row stat channels: (n, 3) for (n,) gradients — the historical
    K = 1 expression, unchanged — else (n, 2K+1) with the count LAST."""
    if g.ndim == 1:
        return jnp.stack([g * weight, h * weight, weight], axis=-1)  # (n, 3)
    w = weight[:, None]
    return jnp.concatenate([g * w, h * w, w], axis=-1)  # (n, 2K+1)


def _stack_round_stats(g: jnp.ndarray, h: jnp.ndarray, weight: jnp.ndarray):
    """Round-native twin of ``_stack_stats``: (T, n) weights folded flat to
    (T*n, 2K+1) stat rows (K = 1 path byte-identical to the historical)."""
    t, n = weight.shape
    if g.ndim == 1:
        return jnp.stack(
            [g[None] * weight, h[None] * weight, weight], axis=-1
        ).reshape(t * n, NUM_STATS)  # (T*n, 3)
    w = weight[..., None]  # (T, n, 1)
    return jnp.concatenate(
        [g[None] * w, h[None] * w, w], axis=-1
    ).reshape(t * n, 2 * g.shape[-1] + 1)


def compute_histogram(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
) -> jnp.ndarray:
    """Accumulate (sum_g, sum_h, count) per (node, feature, bin).

    Args:
      binned: (n, d) int32 bin indices in [0, num_bins).
      g, h:   (n,) float32 first/second-order derivatives — or (n, K) for
        K-channel objectives, widening the stat axis to 2K+1.
      weight: (n,) float32 0/1 sample-subsampling mask (P_m(j) of eq. 4).
      assign: (n,) int32 node assignment at the current level, in [0, num_nodes).
      num_nodes: static frontier width (2**level).
      num_bins:  static B.

    Returns:
      (num_nodes, d, num_bins, 2K+1) float32 histogram (3 channels at K = 1).
    """
    n, d = binned.shape
    data = _stack_stats(g, h, weight)  # (n, 2K+1)
    ids = assign[None, :] * num_bins + binned.T  # (d, n)

    def per_feature(ids_col: jnp.ndarray) -> jnp.ndarray:
        return jax.ops.segment_sum(data, ids_col, num_segments=num_nodes * num_bins)

    hist = jax.vmap(per_feature)(ids)  # (d, num_nodes * B, 2K+1)
    return hist.reshape(
        d, num_nodes, num_bins, data.shape[-1]
    ).transpose(1, 0, 2, 3)


def compute_histogram_onehot(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
) -> jnp.ndarray:
    """MXU-shaped formulation: histogram as a dense one-hot matmul.

    This is the mathematical statement of the TPU adaptation (DESIGN.md §2):
    ``hist = onehot(node*B + bin)^T @ [g, h, 1]`` per feature. The Pallas
    kernel tiles exactly this contraction; this jnp version exists so the
    algebraic identity itself is testable without Pallas.
    """
    n, d = binned.shape
    data = _stack_stats(g, h, weight)  # (n, 2K+1)
    ids = assign[:, None] * num_bins + binned  # (n, d)
    onehot = jax.nn.one_hot(ids, num_nodes * num_bins, dtype=data.dtype)  # (n, d, NB)
    hist = jnp.einsum("ndk,ns->dks", onehot, data)  # (d, NB, 2K+1)
    return hist.reshape(
        d, num_nodes, num_bins, data.shape[-1]
    ).transpose(1, 0, 2, 3)


# ---------------------------------------------------------------------------
# Round-native providers (DESIGN.md §9): the tree axis is explicit
# ---------------------------------------------------------------------------
def compute_round_histogram(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
    *,
    root_delta_rows: int = 0,
    level: int = 0,
) -> jnp.ndarray:
    """Round-native histogram: all T trees of a round in ONE segment pass.

    The trees of a FedGBF round share ``(binned, g, h)`` and differ only in
    their masks (eq. 4), so the tree axis folds into the segment ids — one
    ``segment_sum`` over ``T·n`` rows replaces T per-tree passes (what the
    per-tree vmap formulation lowers to anyway, stated here as the explicit
    contract every round provider satisfies).

    Args:
      binned: (n, d) int32 shared binned features.
      g, h: (n,) float32 shared derivatives — or (n, K), widening the stat
        axis to 2K+1.
      weight: (T, n) float32 per-tree sample masks/weights.
      assign: (T, n) int32 per-tree node assignment in [0, num_nodes).
      num_nodes: static frontier (slot) width.
      num_bins: static B.
      root_delta_rows: when > 0 (level 0 only, ``num_nodes == 1``), compute
        the roots via shared-root caching: ONE unmasked histogram plus a
        per-tree delta over at most this many masked-out rows
        (``root_histogram_via_delta``).  0 = direct masked accumulation.
      level: static tree level of this pass.  Unused here; part of the
        round-provider contract so stateful transports (the quantized
        exchange's stochastic-rounding keys) can derive per-level state —
        ``num_nodes`` stopped being a level proxy once subtraction and
        compaction made several levels share a width.

    Returns:
      (T, num_nodes, d, num_bins, 2K+1) float32.
    """
    if root_delta_rows:
        return root_histogram_via_delta(
            binned, g, h, weight, num_bins, root_delta_rows
        )
    n, d = binned.shape
    t = weight.shape[0]
    _record_pass("round", n, t)
    data = _stack_round_stats(g, h, weight)  # (T*n, 2K+1)
    # segment id = ((tree * num_nodes) + node) * B + bin, per feature column.
    tree_node = (
        jnp.arange(t, dtype=jnp.int32)[:, None] * num_nodes + assign
    )  # (T, n)
    ids = tree_node.reshape(1, t * n) * num_bins + jnp.tile(
        binned.T, (1, t)
    )  # (d, T*n)

    def per_feature(ids_col: jnp.ndarray) -> jnp.ndarray:
        return jax.ops.segment_sum(
            data, ids_col, num_segments=t * num_nodes * num_bins
        )

    hist = jax.vmap(per_feature)(ids)  # (d, T*nodes*B, 2K+1)
    return hist.reshape(
        d, t, num_nodes, num_bins, data.shape[-1]
    ).transpose(1, 2, 0, 3, 4)


def root_histogram_via_delta(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    num_bins: int,
    n_rows: int,
    base_tree_fn=None,
) -> jnp.ndarray:
    """Shared-root caching (DESIGN.md §9): per-tree root histograms as
    ``shared − delta(masked-out rows)``.

    Histograms are linear in the sample weights, so the root of tree t is
    ``hist(w_t) = hist(1) − hist(1 − w_t)``; the first term is ONE unmasked
    pass shared by the whole round, and the second touches only the rows
    tree t masked out — gathered into a static ``(T, n_rows)`` buffer, so
    the level-0 row volume drops from ``T·n`` to ``n + T·n_rows``.

    The caller guarantees ``n_rows`` covers every tree's masked-out count
    (the engines' rho_id >= 0.5 crossover implies ``n − n_keep <= n // 2``)
    and that weights are 0/1 (uniform sampling; GOSS's amplified weights
    would leave ``1 − w`` nonzero on kept rows outside the buffer, so the
    engines route GOSS rounds through the direct pass).  Surplus buffer
    entries land on kept rows whose delta weight ``1 − w`` is 0 — inert.

    Args:
      weight: (T, n) float32 0/1 per-tree masks.
      n_rows: static delta-buffer width (rows per tree).
      base_tree_fn: per-tree histogram provider used for BOTH the shared
        full-n pass and the gathered per-tree delta rows
        (``compute_histogram`` signature); None = the portable segment-sum
        path.  Routing the dominant shared pass through the same provider
        keeps e.g. local-pallas on its fused kernel for the whole level-0
        derivation.

    Returns:
      (T, 1, d, B, 3) float32 — same contract as the direct level-0 call.
    """
    if base_tree_fn is None:
        base_tree_fn = compute_histogram
    t, n = weight.shape
    n_rows = min(n_rows, n)
    # The shared pass is the one full-n pass the feature makes dominant, so
    # it runs on the SAME provider as the deltas (the fused Pallas kernel
    # for local-pallas, not the portable fallback); recorded explicitly
    # since it bypasses compute_round_histogram's meter hook.
    _record_pass("round", n, 1)
    shared = base_tree_fn(
        binned, g, h, jnp.ones((n,), jnp.float32),
        jnp.zeros((n,), jnp.int32), 1, num_bins,
    )[None]  # (1, 1, d, B, 3)
    _record_pass("root_delta", n_rows, t)
    # Stable sort puts masked-out rows (w == 0) first, ascending row index.
    order = jnp.argsort(weight > 0, axis=1)[:, :n_rows]  # (T, n_rows)
    sub_w = 1.0 - jnp.take_along_axis(weight, order, axis=1)  # (T, n_rows)
    zeros = jnp.zeros((n_rows,), jnp.int32)

    def one_delta(rows, w_t):
        return base_tree_fn(
            binned[rows], g[rows], h[rows], w_t, zeros, 1, num_bins
        )

    delta = jax.vmap(one_delta)(order, sub_w)  # (T, 1, d, B, 3)
    return shared - delta


def as_round_child_fn(round_histogram_fn):
    """Round-native twin of ``as_child_fn``: adapt any (T, ...) histogram
    provider into the subtraction pipeline's left-child-only provider.
    ``assign`` is the current level's (T, n) slot assignment (width
    ``2 * num_parents``); odd slots are weight-masked out and the ids halve
    to parent slots, inside whatever program the provider runs (so federated
    round transports ship the half-width payload)."""

    def fn(binned, g, h, weight, assign, num_parents, num_bins, *, level=0):
        left_w = weight * (1 - (assign % 2)).astype(weight.dtype)
        return round_histogram_fn(binned, g, h, left_w, assign // 2,
                                  num_parents, num_bins, level=level)

    return fn


def round_leaf_stats(
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_leaves: int,
) -> jnp.ndarray:
    """Round-native ``leaf_stats``: (T, n) masks/assignment → (T, leaves, 3)
    in one flat stat-channel ``segment_sum`` (tree folded into segments)."""
    t, n = weight.shape
    data = _stack_round_stats(g, h, weight)
    ids = (
        jnp.arange(t, dtype=jnp.int32)[:, None] * num_leaves + assign
    ).reshape(t * n)
    out = jax.ops.segment_sum(data, ids, num_segments=t * num_leaves)
    return out.reshape(t, num_leaves, data.shape[-1])


# ---------------------------------------------------------------------------
# Sibling-subtraction pipeline (DESIGN.md §6)
# ---------------------------------------------------------------------------
def as_child_fn(histogram_fn):
    """Adapt any histogram provider into the *child-only* provider of the
    subtraction pipeline: accumulate only the samples routed to LEFT
    children, at half-frontier width indexed by parent.

    The child provider keeps the histogram signature except that ``assign``
    is the CURRENT level's assignment (width ``2 * num_parents``) and the
    frontier argument is ``num_parents``: left children have even ``assign``
    (routing is ``assign * 2 + go_right``), so masking odd-assign samples to
    weight 0 and halving the ids yields exactly the left-child histogram of
    each parent.  Because the adaptation happens *inside* whatever program
    ``histogram_fn`` runs (a shard_map collective, a quantized transport…),
    every transport's wire payload shrinks to the half-width frontier for
    free.  The Pallas training kernel has a fused variant instead
    (``kernels/histogram/ops.compute_histogram_pallas_fused_child``) so the
    mask/halve staging never touches HBM.
    """

    def fn(binned, g, h, weight, assign, num_parents, num_bins):
        left_w = weight * (1 - (assign % 2)).astype(weight.dtype)
        return histogram_fn(binned, g, h, left_w, assign // 2,
                            num_parents, num_bins)

    return fn


def derive_sibling(parent_hist: jnp.ndarray, left_hist: jnp.ndarray) -> jnp.ndarray:
    """Sibling-subtraction combiner: ``right = parent − left``, interleaved
    back to the full frontier.

    Args:
      parent_hist: (..., P, d, B, 3) — the previous level's histograms
        (optionally with a leading tree axis — the round engine passes
        (T, P, d, B, 3)); after routing, node ``p``'s samples are exactly
        the union of its children, so additivity gives
        ``parent == left + right`` (bit-exact only in exact arithmetic;
        float reassociation is why the direct pass stays the reference
        oracle).
      left_hist: (..., P, d, B, 3) — left-child histograms indexed by
        parent (``as_child_fn`` / ``as_round_child_fn``).

    Returns:
      (..., 2P, d, B, 3) with node ``2p`` = left child, ``2p + 1`` = derived
      right sibling, matching the routing order ``assign * 2 + go_right``.
    """
    right = parent_hist - left_hist
    *batch, p, d, b, s = left_hist.shape
    return jnp.stack([left_hist, right], axis=-4).reshape(
        *batch, 2 * p, d, b, s
    )


def leaf_stats(
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_leaves: int,
) -> jnp.ndarray:
    """Aggregate (G, H, count) per leaf: the leaf-statistics fast path.

    A direct three-channel ``segment_sum`` over the final assignment —
    bit-identical to (and replacing) the old pseudo-feature
    ``compute_histogram`` call, which built an (n, 1) zeros operand and a
    4-D reshape just to read back ``hist[:, 0, 0, :]``.

    Returns (num_leaves, 2K+1) float32 (3 channels at K = 1).
    """
    data = _stack_stats(g, h, weight)  # (n, 2K+1)
    return jax.ops.segment_sum(data, assign, num_segments=num_leaves)


def histogram_dispatch(impl: str = "segment"):
    """Select a histogram implementation by name.

    ``"pallas"`` is the original kernel behind an XLA staging wrapper;
    ``"pallas-fused"`` is the training-side kernel that fuses the id/stats
    staging into the scatter-accumulate (what ``local-pallas`` runs);
    ``"pallas-fused-child"`` is its child-only variant for the subtraction
    pipeline (left-mask and parent ids formed in-kernel).  The ``round-*``
    family serves the round-native contract (DESIGN.md §9, explicit
    (T, ...) tree axis): ``"round-segment"`` is the portable fold-the-tree-
    into-the-segment-ids path; ``"pallas-fused-round[-child]"`` put the
    tree on the kernel grid (what ``local-pallas``' round providers run).
    """
    if impl == "segment":
        return compute_histogram
    if impl == "onehot":
        return compute_histogram_onehot
    if impl == "pallas":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_histogram_pallas
    if impl == "pallas-fused":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_histogram_pallas_fused
    if impl == "pallas-fused-child":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_histogram_pallas_fused_child
    if impl == "round-segment":
        return compute_round_histogram
    if impl == "pallas-fused-round":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_round_histogram_pallas_fused
    if impl == "pallas-fused-round-child":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_round_histogram_pallas_fused_child
    raise ValueError(f"unknown histogram impl {impl!r}")
