"""Gradient/hessian histogram accumulation (Alg. 2 steps 6-8).

This is the compute hot-spot of every histogram GBDT (and the quantity the
VFL protocol ships between parties), so it has three implementations:

* ``compute_histogram``      — portable jnp ``segment_sum`` path (default on CPU),
* ``kernels/histogram``      — the Pallas TPU kernel (one-hot matmul on the MXU),
  selected via ``impl="pallas"``,
* ``kernels/histogram/ref.py`` — the oracle the kernel is tested against
  (re-exports this module's function).

Layout: ``hist[node, feature, bin, stat]`` with ``stat = (sum_g, sum_h, count)``.
Histograms are *additive* in samples, which is what makes both the data-parallel
``psum`` and the VFL per-party decomposition exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NUM_STATS = 3  # sum_g, sum_h, count


def compute_histogram(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
) -> jnp.ndarray:
    """Accumulate (sum_g, sum_h, count) per (node, feature, bin).

    Args:
      binned: (n, d) int32 bin indices in [0, num_bins).
      g, h:   (n,) float32 first/second-order derivatives.
      weight: (n,) float32 0/1 sample-subsampling mask (P_m(j) of eq. 4).
      assign: (n,) int32 node assignment at the current level, in [0, num_nodes).
      num_nodes: static frontier width (2**level).
      num_bins:  static B.

    Returns:
      (num_nodes, d, num_bins, 3) float32 histogram.
    """
    n, d = binned.shape
    data = jnp.stack([g * weight, h * weight, weight], axis=-1)  # (n, 3)
    ids = assign[None, :] * num_bins + binned.T  # (d, n)

    def per_feature(ids_col: jnp.ndarray) -> jnp.ndarray:
        return jax.ops.segment_sum(data, ids_col, num_segments=num_nodes * num_bins)

    hist = jax.vmap(per_feature)(ids)  # (d, num_nodes * B, 3)
    return hist.reshape(d, num_nodes, num_bins, NUM_STATS).transpose(1, 0, 2, 3)


def compute_histogram_onehot(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
) -> jnp.ndarray:
    """MXU-shaped formulation: histogram as a dense one-hot matmul.

    This is the mathematical statement of the TPU adaptation (DESIGN.md §2):
    ``hist = onehot(node*B + bin)^T @ [g, h, 1]`` per feature. The Pallas
    kernel tiles exactly this contraction; this jnp version exists so the
    algebraic identity itself is testable without Pallas.
    """
    n, d = binned.shape
    data = jnp.stack([g * weight, h * weight, weight], axis=-1)  # (n, 3)
    ids = assign[:, None] * num_bins + binned  # (n, d)
    onehot = jax.nn.one_hot(ids, num_nodes * num_bins, dtype=data.dtype)  # (n, d, NB)
    hist = jnp.einsum("ndk,ns->dks", onehot, data)  # (d, NB, 3)
    return hist.reshape(d, num_nodes, num_bins, NUM_STATS).transpose(1, 0, 2, 3)


# ---------------------------------------------------------------------------
# Sibling-subtraction pipeline (DESIGN.md §8)
# ---------------------------------------------------------------------------
def as_child_fn(histogram_fn):
    """Adapt any histogram provider into the *child-only* provider of the
    subtraction pipeline: accumulate only the samples routed to LEFT
    children, at half-frontier width indexed by parent.

    The child provider keeps the histogram signature except that ``assign``
    is the CURRENT level's assignment (width ``2 * num_parents``) and the
    frontier argument is ``num_parents``: left children have even ``assign``
    (routing is ``assign * 2 + go_right``), so masking odd-assign samples to
    weight 0 and halving the ids yields exactly the left-child histogram of
    each parent.  Because the adaptation happens *inside* whatever program
    ``histogram_fn`` runs (a shard_map collective, a quantized transport…),
    every transport's wire payload shrinks to the half-width frontier for
    free.  The Pallas training kernel has a fused variant instead
    (``kernels/histogram/ops.compute_histogram_pallas_fused_child``) so the
    mask/halve staging never touches HBM.
    """

    def fn(binned, g, h, weight, assign, num_parents, num_bins):
        left_w = weight * (1 - (assign % 2)).astype(weight.dtype)
        return histogram_fn(binned, g, h, left_w, assign // 2,
                            num_parents, num_bins)

    return fn


def derive_sibling(parent_hist: jnp.ndarray, left_hist: jnp.ndarray) -> jnp.ndarray:
    """Sibling-subtraction combiner: ``right = parent − left``, interleaved
    back to the full frontier.

    Args:
      parent_hist: (P, d, B, 3) — the previous level's histograms; after
        routing, node ``p``'s samples are exactly the union of its children,
        so additivity gives ``parent == left + right`` (bit-exact only in
        exact arithmetic; float reassociation is why the direct pass stays
        the reference oracle).
      left_hist: (P, d, B, 3) — left-child histograms indexed by parent
        (``as_child_fn``).

    Returns:
      (2P, d, B, 3) with node ``2p`` = left child, ``2p + 1`` = derived
      right sibling, matching the routing order ``assign * 2 + go_right``.
    """
    right = parent_hist - left_hist
    p, d, b, s = left_hist.shape
    return jnp.stack([left_hist, right], axis=1).reshape(2 * p, d, b, s)


def leaf_stats(
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_leaves: int,
) -> jnp.ndarray:
    """Aggregate (G, H, count) per leaf: the leaf-statistics fast path.

    A direct three-channel ``segment_sum`` over the final assignment —
    bit-identical to (and replacing) the old pseudo-feature
    ``compute_histogram`` call, which built an (n, 1) zeros operand and a
    4-D reshape just to read back ``hist[:, 0, 0, :]``.

    Returns (num_leaves, 3) float32.
    """
    data = jnp.stack([g * weight, h * weight, weight], axis=-1)  # (n, 3)
    return jax.ops.segment_sum(data, assign, num_segments=num_leaves)


def histogram_dispatch(impl: str = "segment"):
    """Select a histogram implementation by name.

    ``"pallas"`` is the original kernel behind an XLA staging wrapper;
    ``"pallas-fused"`` is the training-side kernel that fuses the id/stats
    staging into the scatter-accumulate (what ``local-pallas`` runs);
    ``"pallas-fused-child"`` is its child-only variant for the subtraction
    pipeline (left-mask and parent ids formed in-kernel).
    """
    if impl == "segment":
        return compute_histogram
    if impl == "onehot":
        return compute_histogram_onehot
    if impl == "pallas":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_histogram_pallas
    if impl == "pallas-fused":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_histogram_pallas_fused
    if impl == "pallas-fused-child":
        from repro.kernels.histogram import ops as _ops

        return _ops.compute_histogram_pallas_fused_child
    raise ValueError(f"unknown histogram impl {impl!r}")
