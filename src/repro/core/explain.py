"""Model explainability utilities (the paper's motivating requirement:
"tree models ... meet the user's requirement for model explainability" §1).

Gain-based and split-count feature importances over a trained EnsembleModel,
per-party attribution (which party's features drive the model — the quantity
a VFL consortium actually negotiates over), and a text dump of any tree.
"""

from __future__ import annotations

from typing import Union

import numpy as np

from repro.core.types import EnsembleModel, PackedEnsemble, forest_size
from repro.data.tabular import VerticalPartition


def feature_importance(model: Union[EnsembleModel, PackedEnsemble],
                       num_features: int, kind: str = "gain") -> np.ndarray:
    """Importance per feature. kind: 'gain' (sum of split gains) or 'count'.

    Bagging-aware: each tree's contribution is weighted 1/n_trees of its
    round, mirroring the forest-mean combiner.

    Accepts either ensemble layout: the per-round ``EnsembleModel`` or the
    packed serving layout (``PackedEnsemble``), so checkpoint-loaded models
    (``checkpoint.io.load_ensemble``) are explainable without unpacking.
    The packed path recovers the 1/n_trees round weight from ``tree_scale``
    (= lr / n_trees of the tree's round); both paths agree to float
    tolerance (tests/test_explain_and_misc.py).
    """
    if isinstance(model, PackedEnsemble):
        # per-tree bagging weight recovered from tree_scale = lr / n_trees
        weights = np.asarray(model.tree_scale, np.float64) / model.learning_rate
        per_tree = zip(np.asarray(model.feature), np.asarray(model.gain), weights)
    else:
        per_tree = (
            (f, g, 1.0 / forest_size(trees))
            for trees in model.forests
            for f, g in zip(np.asarray(trees.feature), np.asarray(trees.gain))
        )
    imp = np.zeros(num_features, np.float64)
    for feats, gains, weight in per_tree:     # rows: (num_internal,) per tree
        valid = feats >= 0
        f = feats[valid]
        w = gains[valid] if kind == "gain" else np.ones_like(f, float)
        np.add.at(imp, f, w * weight)
    total = imp.sum()
    return imp / total if total > 0 else imp


def party_importance(model: Union[EnsembleModel, PackedEnsemble],
                     partition: VerticalPartition,
                     kind: str = "gain") -> dict:
    """Share of model importance contributed by each party's feature slice."""
    imp = feature_importance(model, partition.num_features, kind)
    return {
        f"party_{p}": float(imp[partition.columns(p)].sum())
        for p in range(partition.num_parties)
    }


def dump_tree(model: EnsembleModel, round_idx: int, tree_idx: int,
              feature_names=None) -> str:
    """Human-readable text rendering of one tree (bin-threshold splits)."""
    trees = model.forests[round_idx]
    feat = np.asarray(trees.feature[tree_idx])
    thr = np.asarray(trees.threshold[tree_idx])
    gain = np.asarray(trees.gain[tree_idx])
    leaf = np.asarray(trees.leaf_weight[tree_idx])
    edges = np.asarray(model.bin_edges)
    name = (lambda f: feature_names[f]) if feature_names else (lambda f: f"f{f}")

    lines = []

    def rec(level: int, idx: int, indent: str):
        node = 2**level - 1 + idx
        depth = model.max_depth
        if level == depth:
            lines.append(f"{indent}leaf[{idx}] = {leaf[idx]:+.5f}")
            return
        f, t = int(feat[node]), int(thr[node])
        if f < 0:
            lines.append(f"{indent}(pass-through)")
            rec(level + 1, idx * 2, indent + "  ")
            return
        cut = edges[f, t] if t < edges.shape[1] else float("inf")
        lines.append(
            f"{indent}if {name(f)} <= {cut:.4f}  (bin {t}, gain {gain[node]:.3f})"
        )
        rec(level + 1, idx * 2, indent + "  ")
        lines.append(f"{indent}else")
        rec(level + 1, idx * 2 + 1, indent + "  ")

    rec(0, 0, "")
    return "\n".join(lines)
