"""Second-order losses: per-sample gradients g_i and hessians h_i (Alg. 2 step 2).

In the VFL protocol these are the quantities the active party computes,
encrypts and broadcasts; everything downstream consumes only (g, h).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sigmoid(z: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.sigmoid(z)


def logistic_grad_hess(y: jnp.ndarray, y_hat: jnp.ndarray):
    """Binary logloss on raw margins: g = p - y, h = p (1 - p)."""
    p = sigmoid(y_hat)
    return p - y, p * (1.0 - p)


def squared_grad_hess(y: jnp.ndarray, y_hat: jnp.ndarray):
    """0.5 * (y_hat - y)^2: g = y_hat - y, h = 1."""
    return y_hat - y, jnp.ones_like(y_hat)


_LOSSES = {
    "logistic": logistic_grad_hess,
    "squared": squared_grad_hess,
}


def grad_hess(loss: str, y: jnp.ndarray, y_hat: jnp.ndarray):
    try:
        fn = _LOSSES[loss]
    except KeyError as e:  # pragma: no cover - config error
        raise ValueError(f"unknown loss {loss!r}; options: {sorted(_LOSSES)}") from e
    return fn(y.astype(jnp.float32), y_hat.astype(jnp.float32))


def loss_value(loss: str, y: jnp.ndarray, y_hat: jnp.ndarray) -> jnp.ndarray:
    y = y.astype(jnp.float32)
    if loss == "logistic":
        # stable logloss on margins
        return jnp.mean(jnp.maximum(y_hat, 0) - y_hat * y + jnp.log1p(jnp.exp(-jnp.abs(y_hat))))
    if loss == "squared":
        return 0.5 * jnp.mean((y_hat - y) ** 2)
    raise ValueError(f"unknown loss {loss!r}")
