"""Deprecated shim over ``core.objective`` (kept for callers of the old API).

The two-dict dispatch that used to live here (separate name tables for
``grad_hess`` and ``loss_value`` that could drift apart) is collapsed into
the single Objective registry — ``repro.core.objective.get_objective`` is
the one source of truth for gradients, loss values, activations and
metrics.  These wrappers resolve through the registry so the two functions
can never disagree again.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import objective as objective_mod


def sigmoid(z: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.sigmoid(z)


def logistic_grad_hess(y: jnp.ndarray, y_hat: jnp.ndarray):
    """Binary logloss on raw margins: g = p - y, h = p (1 - p)."""
    return objective_mod.get_objective("logistic").grad_hess(y, y_hat)


def squared_grad_hess(y: jnp.ndarray, y_hat: jnp.ndarray):
    """0.5 * (y_hat - y)^2: g = y_hat - y, h = 1."""
    return objective_mod.get_objective("squared").grad_hess(y, y_hat)


def grad_hess(loss: str, y: jnp.ndarray, y_hat: jnp.ndarray):
    """Deprecated: use ``objective.get_objective(loss).grad_hess``."""
    obj = objective_mod.get_objective(loss)
    return obj.grad_hess(y.astype(jnp.float32), y_hat.astype(jnp.float32))


def loss_value(loss: str, y: jnp.ndarray, y_hat: jnp.ndarray) -> jnp.ndarray:
    """Deprecated: use ``objective.get_objective(loss).loss_value``."""
    obj = objective_mod.get_objective(loss)
    return obj.loss_value(y.astype(jnp.float32), y_hat)
