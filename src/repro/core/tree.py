"""Level-wise decision-tree construction (Alg. 2, GenerateTree) — fully jittable.

TPU adaptation (DESIGN.md §2): instead of growing nodes one at a time from a
pending-split queue, we grow the complete tree *level by level* with static
shapes — one histogram pass per level covers the whole frontier, the routing
update is a vectorised gather, and the depth loop is unrolled (max_depth is
static and small, paper uses 3).

The histogram provider is injectable: the centralized path passes
``core.histogram.compute_histogram``; the federated path passes a shard_map
wrapper that computes per-party shard histograms and reassembles them
(federation/aggregator.py). Because histograms are additive and reassembly is
exact, both paths produce *identical* trees — the paper's losslessness claim,
asserted in tests/test_federation.py.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import histogram as hist_mod
from repro.core import split as split_mod
from repro.core.types import PackedEnsemble, TreeArrays, TreeConfig

HistogramFn = Callable[..., jnp.ndarray]


def traverse_level(
    binned: jnp.ndarray,
    idx: jnp.ndarray,
    feature: jnp.ndarray,
    threshold: jnp.ndarray,
) -> jnp.ndarray:
    """The ONE node-traversal gather body: each sample reads its current
    node's (feature, threshold) and goes right iff its bin value is strictly
    above the threshold; unsplit nodes (feature == -1, threshold == B) route
    every sample left.

    Shared by builder routing (``route_local``), tree prediction
    (``predict_tree``), and — via the latter — the ``ensemble_predict``
    kernel oracle, so the routing semantics live in exactly one place.

    Args:
      binned: (n, d) int32.
      idx: (n,) int32 within-level node index.
      feature / threshold: (width,) int32 — the level's nodes only.
    Returns:
      (n,) int32 next-level node index ``idx * 2 + go_right``.
    """
    rows = jnp.arange(binned.shape[0])
    f = feature[idx]    # (n,)
    t = threshold[idx]  # (n,)
    fv = binned[rows, jnp.clip(f, 0, None)]
    go_right = (f >= 0) & (fv > t)
    return idx * 2 + go_right.astype(jnp.int32)


def route_local(binned: jnp.ndarray, assign: jnp.ndarray, decision) -> jnp.ndarray:
    """Centralized routing: one ``traverse_level`` step over the frontier."""
    return traverse_level(binned, assign, decision.feature, decision.threshold)


def build_tree(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    sample_mask: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    backend=None,
    histogram_fn: Optional[HistogramFn] = None,
    choose_fn: Optional[Callable] = None,
    route_fn: Optional[Callable] = None,
    leaf_fn: Optional[Callable] = None,
) -> tuple[TreeArrays, jnp.ndarray]:
    """Build one tree; returns (tree, leaf_assign_for_all_samples).

    Every sample (masked or not) is routed so the caller can update
    y_hat on the full training set; masked-out samples simply do not
    contribute to histograms or leaf weights.

    Args:
      binned: (n, d) int32 binned features (the *local feature shard* on the
        federated path — d is then d_party, not d_global).
      g, h: (n,) float32 derivatives w.r.t. y_hat^(m-1).
      sample_mask: (n,) float32 0/1 — P_m(j) of eq. 4.
      feature_mask: (d,) bool — Q_m(j) of eq. 4 (local slice when federated).
      backend: a ``core.backend.TreeBackend`` bundling the execution
        providers (DESIGN.md §1); None = centralized-local defaults.  The
        federated backends override the providers with the shard_map
        collectives of Alg. 2 ("the passive party returns the divided ID
        space", etc. — see federation/aggregator.py).
      histogram_fn / choose_fn / route_fn / leaf_fn: DEPRECATED per-provider
        overrides, kept as a shim for direct kernel tests; prefer passing a
        backend.  An explicit fn wins over the backend's provider.
    """
    explicit_hist = histogram_fn is not None
    child_fn = None
    if backend is not None:
        histogram_fn = histogram_fn or backend.histogram_fn
        choose_fn = choose_fn or backend.choose_fn
        route_fn = route_fn or backend.route_fn
        leaf_fn = leaf_fn or backend.leaf_fn
        if not explicit_hist:
            child_fn = backend.child_histogram_fn
    if histogram_fn is None:
        histogram_fn = hist_mod.compute_histogram
    if choose_fn is None:
        choose_fn = lambda hist, fmask: split_mod.choose_splits(hist, fmask, cfg)
    if route_fn is None:
        route_fn = route_local
    if cfg.hist_subtraction and child_fn is None:
        # Any histogram provider adapts into the child-only provider (the
        # mask/halve staging runs inside its program, so federated transports
        # ship the half-width payload); backends override only to fuse the
        # staging (local-pallas).
        child_fn = hist_mod.as_child_fn(histogram_fn)

    n, _ = binned.shape
    assign = jnp.zeros(n, dtype=jnp.int32)  # within-level node index

    features, thresholds, gains = [], [], []
    prev_hist = None
    for level in range(cfg.max_depth):
        num_nodes = 2**level
        if cfg.hist_subtraction and level >= 1:
            # Subtraction pipeline (DESIGN.md §8): accumulate only the left
            # children (half-frontier width, indexed by parent) and derive
            # every right sibling from the carried parent histograms —
            # halving histogram compute, memory, and (federated) exchanged
            # bytes at every level past the root.
            left = child_fn(
                binned, g, h, sample_mask, assign, num_nodes // 2, cfg.num_bins
            )
            hist = hist_mod.derive_sibling(prev_hist, left)
        else:
            hist = histogram_fn(
                binned, g, h, sample_mask, assign, num_nodes, cfg.num_bins
            )
        decision = choose_fn(hist, feature_mask)
        features.append(decision.feature)
        thresholds.append(decision.threshold)
        gains.append(jnp.maximum(decision.gain, 0.0))
        assign = route_fn(binned, assign, decision)
        prev_hist = hist

    # Leaf statistics: aggregate (G, H, count) per leaf over masked samples.
    # In the VFL protocol the active party owns g, h and the final routing in
    # plaintext, so leaf weights are computed locally (Alg. 2 step 14);
    # ``leaf_fn`` (signature of ``histogram.leaf_stats``) is only overridden
    # when samples are sharded over the data axis (psum of the additive
    # stats, no party gather).
    if leaf_fn is None:
        leaf_fn = hist_mod.leaf_stats
    leaf_hist = leaf_fn(g, h, sample_mask, assign, cfg.num_leaves)
    weights = split_mod.leaf_weights(leaf_hist, cfg)

    tree = TreeArrays(
        feature=jnp.concatenate(features),
        threshold=jnp.concatenate(thresholds),
        gain=jnp.concatenate(gains),
        leaf_weight=weights,
    )
    return tree, assign


def predict_tree(tree: TreeArrays, binned: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Route samples through one tree and return leaf weights.

    Args:
      tree: TreeArrays (single tree, no leading batch axis).
      binned: (n, d) int32 — binned with the training edges.
      max_depth: static tree depth.
    Returns:
      (n,) float32 raw tree output.
    """
    n = binned.shape[0]
    idx = jnp.zeros(n, dtype=jnp.int32)
    for level in range(max_depth):
        offset = 2**level - 1
        width = 2**level
        idx = traverse_level(
            binned, idx,
            tree.feature[offset:offset + width],
            tree.threshold[offset:offset + width],
        )
    return tree.leaf_weight[idx]


def predict_trees(trees: TreeArrays, binned: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Per-tree margins of a stacked forest: (n_trees, n) float32.

    The single vmapped traversal shared by forest prediction, training-time
    validation, and ``PackedEnsemble`` inference (DESIGN.md §3) — every
    prediction consumer funnels through this one program.
    """
    return jax.vmap(lambda tr: predict_tree(tr, binned, max_depth))(trees)


def predict_forest(trees: TreeArrays, binned: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Mean over a stacked forest (bagging combiner g of Alg. 1 line 7)."""
    return jnp.mean(predict_trees(trees, binned, max_depth), axis=0)


def predict_packed(packed: PackedEnsemble, binned: jnp.ndarray) -> jnp.ndarray:
    """Raw-margin prediction from the packed layout, bit-for-bit equal to the
    legacy per-round loop (asserted in tests/test_packed.py).

    Per-round sums are accumulated segment-by-segment over the *static*
    ``round_offsets`` boundaries: each round's ``(n_trees_r, n)`` per-tree
    block is a transient of that segment only — the full ``(total_trees, n)``
    per-tree matrix of the original one-shot vmapped formulation is never
    materialised.  That matrix is what made the packed path 0.34x the loop
    on CPU (BENCH_predict.json history); the segmented accumulation restores
    loop-parity while keeping the packed layout's uniform storage.  The
    traversal-count trade-off lives in the combiner choice: this path is the
    bit-exact one; ``predict_packed_weighted`` streams all trees through one
    scanned body (O(1) compile cost), and the Pallas ``ensemble_predict``
    kernel fuses the whole ensemble on TPU.
    """
    out = jnp.full((binned.shape[0],), packed.base_score, dtype=jnp.float32)
    for r in range(packed.rounds):
        s, e = packed.round_offsets[r], packed.round_offsets[r + 1]
        seg = TreeArrays(
            feature=packed.feature[s:e], threshold=packed.threshold[s:e],
            gain=packed.gain[s:e], leaf_weight=packed.leaf_weight[s:e],
        )
        per_tree = predict_trees(seg, binned, packed.max_depth)  # (k_r, n)
        out = out + packed.learning_rate * jnp.mean(per_tree, axis=0)
    return out


def predict_packed_weighted(packed: PackedEnsemble, binned: jnp.ndarray) -> jnp.ndarray:
    """Single-pass combiner: ``base + sum_t tree_scale[t] * tree_t(x)``.

    Algebraically identical to ``predict_packed`` (scale = lr / n_trees per
    round) but implemented as a ``lax.scan`` over the packed tree axis with a
    running accumulator: one compiled tree body regardless of ensemble size,
    and the (total_trees, n) per-tree matrix is never materialised — the
    scan's streaming accumulation is the jnp analogue of what the Pallas
    ``ensemble_predict`` kernel does across its tree grid axis.  Prefer this
    for serving; use ``predict_packed`` when bit-exact parity with the
    training-time per-round evaluation matters.
    """
    n = binned.shape[0]

    def body(out, xs):
        feature, threshold, leaf_weight, scale = xs
        tr = TreeArrays(feature=feature, threshold=threshold,
                        gain=jnp.zeros_like(leaf_weight[:0]),
                        leaf_weight=leaf_weight)
        return out + scale * predict_tree(tr, binned, packed.max_depth), None

    out, _ = jax.lax.scan(
        body,
        jnp.full((n,), packed.base_score, dtype=jnp.float32),
        (packed.feature, packed.threshold, packed.leaf_weight,
         packed.tree_scale),
    )
    return out
