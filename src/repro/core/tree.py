"""Level-wise round-native forest construction (Alg. 2 over a whole round).

TPU adaptation (DESIGN.md §2): instead of growing nodes one at a time from a
pending-split queue, we grow complete trees *level by level* with static
shapes — one histogram pass per level covers the whole frontier, the routing
update is a vectorised gather, and the depth loop is unrolled (max_depth is
static and small, paper uses 3).

Round-native engine (DESIGN.md §9): FedGBF's N trees of a round are ONE
parallel unit — they share (g, h) and differ only in their masks (eq. 4) —
so ``build_round`` builds the whole round with the tree axis *explicit* in
every provider (histograms take and return a leading ``(T, ...)`` axis)
instead of closing per-tree builders over a ``jax.vmap``.  That seam is what
enables shared-root caching (one unmasked level-0 histogram + per-tree
deltas), frontier compaction for deep trees (a static ``max_active_nodes``
budget with dead nodes masked out of histograms and the party exchange), and
ONE federated collective per level carrying the ``(T, active, d_party, B,
3)`` payload.  ``build_tree`` is the T = 1 special case.

The providers are injectable via a ``core.backend.TreeBackend``: the
centralized path uses ``core.histogram.compute_round_histogram``; the
federated path passes shard_map wrappers that compute per-party shard
histograms and reassemble them (federation/aggregator.py). Because
histograms are additive and reassembly is exact, both paths produce
*identical* trees — the paper's losslessness claim, asserted in
tests/test_federation.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import histogram as hist_mod
from repro.core import split as split_mod
from repro.core.types import PackedEnsemble, TreeArrays, TreeConfig


def traverse_level(
    binned: jnp.ndarray,
    idx: jnp.ndarray,
    feature: jnp.ndarray,
    threshold: jnp.ndarray,
) -> jnp.ndarray:
    """The ONE node-traversal gather body: each sample reads its current
    node's (feature, threshold) and goes right iff its bin value is strictly
    above the threshold; unsplit nodes (feature == -1, threshold == B) route
    every sample left.

    Shared by builder routing (``route_local``), tree prediction
    (``predict_tree``), and — via the latter — the ``ensemble_predict``
    kernel oracle, so the routing semantics live in exactly one place.

    Args:
      binned: (n, d) int32.
      idx: (n,) int32 within-level node index.
      feature / threshold: (width,) int32 — the level's nodes only.
    Returns:
      (n,) int32 next-level node index ``idx * 2 + go_right``.
    """
    rows = jnp.arange(binned.shape[0])
    f = feature[idx]    # (n,)
    t = threshold[idx]  # (n,)
    fv = binned[rows, jnp.clip(f, 0, None)]
    go_right = (f >= 0) & (fv > t)
    return idx * 2 + go_right.astype(jnp.int32)


def route_local(binned: jnp.ndarray, assign: jnp.ndarray, decision) -> jnp.ndarray:
    """Centralized routing: one ``traverse_level`` step over the frontier."""
    return traverse_level(binned, assign, decision.feature, decision.threshold)


def traverse_level_values(
    x: jnp.ndarray,
    idx: jnp.ndarray,
    feature: jnp.ndarray,
    thr_value: jnp.ndarray,
) -> jnp.ndarray:
    """Raw-float twin of ``traverse_level`` — the fused bin+traverse body.

    ``types.float_thresholds`` rewrites bin-space thresholds into value
    space (``bin(v) <= t  <=>  v <= edges[f, t]``), so serving compares the
    raw feature float directly and the separate binning dispatch disappears.
    NaN features route left (``NaN > thr`` is False) — exactly the reserved
    ``binning.NAN_BIN = 0`` semantics; ±inf compares past every finite edge,
    matching the extreme bins.  Leaf routing is bit-identical to binning
    followed by ``traverse_level``.

    Args:
      x: (n, d) float32 RAW features (not binned).
      idx: (n,) int32 within-level node index.
      feature: (width,) int32; thr_value: (width,) float32 value-space.
    Returns:
      (n,) int32 next-level node index.
    """
    rows = jnp.arange(x.shape[0])
    f = feature[idx]
    t = thr_value[idx]
    fv = x[rows, jnp.clip(f, 0, None)]
    go_right = (f >= 0) & (fv > t)
    return idx * 2 + go_right.astype(jnp.int32)


def traverse_level_round(
    binned: jnp.ndarray,
    idx: jnp.ndarray,
    feature: jnp.ndarray,
    threshold: jnp.ndarray,
) -> jnp.ndarray:
    """Round-native ``traverse_level``: the tree axis is explicit.

    Args:
      binned: (n, d) int32 shared binned features.
      idx: (T, n) int32 per-tree within-level node index.
      feature / threshold: (T, width) int32 — the level's nodes per tree.
    Returns:
      (T, n) int32 next-level node index — the same gather body as
      ``traverse_level``, batched.
    """
    f = jnp.take_along_axis(feature, idx, axis=1)    # (T, n)
    t = jnp.take_along_axis(threshold, idx, axis=1)  # (T, n)
    rows = jnp.arange(binned.shape[0])
    fv = binned[rows[None, :], jnp.clip(f, 0, None)]  # (T, n)
    go_right = (f >= 0) & (fv > t)
    return idx * 2 + go_right.astype(jnp.int32)


def route_local_round(binned, assign, decision) -> jnp.ndarray:
    """Centralized round routing: one batched ``traverse_level`` step."""
    return traverse_level_round(
        binned, assign, decision.feature, decision.threshold
    )


def _derive_round_hist(per_tree_fn):
    """Lift a per-tree histogram provider to the round contract (vmap over
    the (weight, assign) tree axis — the explicit seam stays, only this
    provider's implementation batches implicitly).  Shared-root caching
    (``root_delta_rows``) routes through ``root_histogram_via_delta`` with
    the per-tree provider as the delta accumulator, so ad-hoc per-tree
    backends support the full round contract."""

    def fn(binned, g, h, weight, assign, num_nodes, num_bins,
           root_delta_rows=0, level=0):
        if root_delta_rows:
            return hist_mod.root_histogram_via_delta(
                binned, g, h, weight, num_bins, root_delta_rows,
                base_tree_fn=per_tree_fn,
            )
        return jax.vmap(
            lambda w, a: per_tree_fn(binned, g, h, w, a, num_nodes, num_bins)
        )(weight, assign)

    return fn


def _derive_round_choose(per_tree_fn):
    return lambda hist, fmask: jax.vmap(per_tree_fn)(hist, fmask)


def _derive_round_route(per_tree_fn):
    def fn(binned, assign, decision):
        return jax.vmap(lambda a, d: per_tree_fn(binned, a, d))(assign, decision)

    return fn


def _derive_round_leaf(per_tree_fn):
    def fn(g, h, weight, assign, num_leaves):
        return jax.vmap(
            lambda w, a: per_tree_fn(g, h, w, a, num_leaves)
        )(weight, assign)

    return fn


def _round_providers(cfg: TreeConfig, backend):
    """Resolve the round-native providers: a backend's ``round_*`` provider
    wins; a per-tree provider lifts via vmap; None selects the centralized
    round-native default."""
    hist_fn = choose_fn = route_fn = leaf_fn = child_fn = None
    if backend is not None:
        hist_fn = backend.round_histogram_fn
        if hist_fn is None and backend.histogram_fn is not None:
            hist_fn = _derive_round_hist(backend.histogram_fn)
        choose_fn = backend.round_choose_fn
        if choose_fn is None and backend.choose_fn is not None:
            choose_fn = _derive_round_choose(backend.choose_fn)
        route_fn = backend.round_route_fn
        if route_fn is None and backend.route_fn is not None:
            route_fn = _derive_round_route(backend.route_fn)
        leaf_fn = backend.round_leaf_fn
        if leaf_fn is None and backend.leaf_fn is not None:
            leaf_fn = _derive_round_leaf(backend.leaf_fn)
        child_fn = backend.round_child_histogram_fn
        if child_fn is None and backend.child_histogram_fn is not None:
            child_fn = _derive_round_hist(backend.child_histogram_fn)
    if hist_fn is None:
        hist_fn = hist_mod.compute_round_histogram
    if choose_fn is None:
        choose_fn = lambda hist, fm: split_mod.choose_splits_round(hist, fm, cfg)
    if route_fn is None:
        route_fn = route_local_round
    if leaf_fn is None:
        leaf_fn = hist_mod.round_leaf_stats
    if cfg.hist_subtraction and child_fn is None:
        # Any round histogram provider adapts into the child-only provider
        # (the mask/halve staging runs inside its program, so federated
        # transports ship the half-width payload); backends override only to
        # fuse the staging (local-pallas).
        child_fn = hist_mod.as_round_child_fn(hist_fn)
    return hist_fn, child_fn, choose_fn, route_fn, leaf_fn


def build_round(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    sample_mask: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    backend=None,
    root_delta_rows: int = 0,
) -> tuple[TreeArrays, jnp.ndarray]:
    """Build ALL T trees of one round; returns (stacked trees, (T, n) assign).

    The round-native forest engine (DESIGN.md §9).  Every provider carries
    the tree axis explicitly — histograms take and return ``(T, ...)``
    operands (``histogram.compute_round_histogram`` contract) — so on the
    federated path each level is ONE party collective shipping the whole
    round's ``(T, active, d_party, B, 3)`` payload, and the level-0 pass can
    share work across trees (shared-root caching).

    Every sample (masked or not) is routed in every tree so the caller can
    update y_hat on the full training set; masked-out samples simply do not
    contribute to histograms or leaf weights.

    Args:
      binned: (n, d) int32 binned features (the *local feature shard* on the
        federated path — d is then d_party, not d_global).
      g, h: (n,) float32 derivatives w.r.t. y_hat^(m-1), shared by the round.
      sample_mask: (T, n) float32 per-tree weights — P_m(j) of eq. 4.
      feature_mask: (T, d) bool per-tree masks — Q_m(j) of eq. 4.
      cfg: static tree config.  ``hist_subtraction`` runs the §6 sibling
        pipeline; ``max_active_nodes`` bounds the live frontier per level
        (§9 compaction) for deep trees.
      backend: a ``core.backend.TreeBackend`` (DESIGN.md §1); None =
        centralized-local round-native defaults.
      root_delta_rows: static shared-root delta-buffer width (> 0 enables
        the level-0 ``shared − delta`` derivation; the engines drive it
        from the rho_id schedule — see ``TreeConfig.shared_root``).

    Returns:
      (trees, assign): ``trees`` is a stacked ``TreeArrays`` with leading
      tree axis; ``assign`` (T, n) is every sample's leaf index per tree.
    """
    hist_fn, child_fn, choose_fn, route_fn, leaf_fn = _round_providers(
        cfg, backend
    )
    T, n = sample_mask.shape
    assign = jnp.zeros((T, n), dtype=jnp.int32)  # within-level node index
    t_rows = jnp.arange(T, dtype=jnp.int32)[:, None]

    features, thresholds, gains = [], [], []
    live = None          # (T, width) next-level liveness (compacted levels)
    prev_hist = None     # (T, A_prev, d, B, 3), slot space
    prev_id = prev_w = None
    prev_A = None
    prev_table = None    # (T, width_prev + 1) slot-of-node, None = identity
    for level in range(cfg.max_depth):
        width = 2 ** level
        A = cfg.active_width(level)
        compacted = A < width
        if compacted:
            # Frontier compaction (§9): gather live nodes into dense slots.
            # ``order`` is a stable permutation putting live node ids first
            # (ascending), so slot k < live_count holds the k-th live node;
            # overflow beyond the budget and dead nodes route through the
            # full-width level arrays as unsplit (-1) entries.
            order = jnp.argsort(~live, axis=1)
            slot_node = order[:, :A].astype(jnp.int32)       # (T, A)
            live_count = jnp.sum(live, axis=1).astype(jnp.int32)
            slot_valid = (
                jnp.arange(A, dtype=jnp.int32)[None, :] < live_count[:, None]
            )
            # node -> slot table; dead nodes map to the trash id A (their
            # samples are weight-masked out of the histogram pass), invalid
            # slots scatter into a dummy row that is never read.
            scatter_node = jnp.where(slot_valid, slot_node, width)
            table = jnp.full((T, width + 1), A, jnp.int32)
            table = table.at[t_rows, scatter_node].set(
                jnp.broadcast_to(
                    jnp.arange(A, dtype=jnp.int32)[None, :], (T, A)
                )
            )
            slot_assign = jnp.take_along_axis(table, assign, axis=1)
            w_level = sample_mask * (slot_assign < A).astype(sample_mask.dtype)
            id_level = jnp.minimum(slot_assign, A - 1)
        else:
            slot_node = table = slot_valid = None
            w_level = sample_mask
            id_level = assign

        if cfg.hist_subtraction and level >= 1:
            # Subtraction pipeline (§6): accumulate only the left children
            # at parent-slot width and derive every right sibling from the
            # carried parent histograms; under compaction the interleaved
            # child-slot frontier is then gathered into this level's dense
            # slots (dead children never reach the histogram/exchange).
            side = (assign % 2).astype(jnp.int32)
            cslot = prev_id * 2 + side          # child-slot space, 2*prev_A
            left = child_fn(binned, g, h, prev_w, cslot, prev_A, cfg.num_bins,
                            level=level)
            sib = hist_mod.derive_sibling(prev_hist, left)  # (T, 2*prev_A, ...)
            if compacted:
                # A live slot's parent is itself a valid previous-level slot
                # (liveness requires a split parent); invalid slots gather
                # clipped junk that the decision scatter discards.  The
                # budget is monotone in the level width, so a compacted
                # level's PREVIOUS level may be uncompacted (prev_table is
                # None, parent slot == parent node) but never vice versa.
                pslot = (
                    jnp.take_along_axis(prev_table, slot_node // 2, axis=1)
                    if prev_table is not None else slot_node // 2
                )
                cidx = jnp.clip(pslot * 2 + slot_node % 2, 0, 2 * prev_A - 1)
                hist = jnp.take_along_axis(
                    sib, cidx[:, :, None, None, None], axis=1
                )
            else:
                hist = sib
        else:
            kw = {"level": level}
            if level == 0 and root_delta_rows:
                # Shared-root caching (§9): the provider derives every root
                # as shared − delta inside its own program, so federated
                # transports still ship the standard per-tree payload.
                kw["root_delta_rows"] = root_delta_rows
            hist = hist_fn(binned, g, h, w_level, id_level, A, cfg.num_bins, **kw)

        decision = choose_fn(hist, feature_mask)          # (T, A) fields
        gain_pos = jnp.maximum(decision.gain, 0.0)
        if compacted:
            feat = jnp.where(slot_valid, decision.feature, -1)
            thr = jnp.where(slot_valid, decision.threshold, cfg.num_bins)
            gn = jnp.where(slot_valid, gain_pos, 0.0)
            feature_lvl = (
                jnp.full((T, width), -1, jnp.int32).at[t_rows, slot_node].set(feat)
            )
            threshold_lvl = (
                jnp.full((T, width), cfg.num_bins, jnp.int32)
                .at[t_rows, slot_node].set(thr)
            )
            gain_lvl = (
                jnp.zeros((T, width), jnp.float32).at[t_rows, slot_node].set(gn)
            )
            decision_lvl = split_mod.SplitDecision(
                feature=feature_lvl, threshold=threshold_lvl, gain=gain_lvl
            )
        else:
            feature_lvl, threshold_lvl, gain_lvl = (
                decision.feature, decision.threshold, gain_pos
            )
            decision_lvl = decision
        features.append(feature_lvl)
        thresholds.append(threshold_lvl)
        gains.append(gain_lvl)
        assign = route_fn(binned, assign, decision_lvl)

        next_level = level + 1
        if (next_level < cfg.max_depth
                and cfg.active_width(next_level) < 2 ** next_level):
            # Liveness for the next (compacted) level: a child is live iff
            # its parent split AND it holds weighted samples.  Counts go
            # through the leaf provider so sample-sharded backends psum to
            # the global count (a cheap (n,) pass, no party collective —
            # weights and routing are party-replicated).
            # count is the LAST stat channel at any K (index 2 when K = 1)
            counts = leaf_fn(g, h, sample_mask, assign, 2 ** next_level)[..., -1]
            live = (counts > 0) & jnp.repeat(feature_lvl >= 0, 2, axis=1)
        else:
            live = None
        prev_hist, prev_id, prev_w = hist, id_level, w_level
        prev_A, prev_table = A, table

    # Leaf statistics: aggregate (G, H, count) per leaf over masked samples.
    # In the VFL protocol the active party owns g, h and the final routing
    # in plaintext, so leaf weights are computed locally (Alg. 2 step 14);
    # the leaf provider is only overridden when samples are sharded over the
    # data axis (psum of the additive stats, no party gather).
    leaf_hist = leaf_fn(g, h, sample_mask, assign, cfg.num_leaves)  # (T, L, 2K+1)
    weights = split_mod.leaf_weights(leaf_hist, cfg)           # (T, L[, K])

    trees = TreeArrays(
        feature=jnp.concatenate(features, axis=1),
        threshold=jnp.concatenate(thresholds, axis=1),
        gain=jnp.concatenate(gains, axis=1),
        leaf_weight=weights,
    )
    return trees, assign


def build_tree(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    sample_mask: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    backend=None,
) -> tuple[TreeArrays, jnp.ndarray]:
    """Build one tree — the T = 1 special case of ``build_round``.

    Args:
      sample_mask: (n,) float32 — P_m(j) of eq. 4.
      feature_mask: (d,) bool — Q_m(j) of eq. 4 (local slice when federated).
      backend: a ``core.backend.TreeBackend`` (DESIGN.md §1); None =
        centralized-local defaults.  (The historical per-provider kwargs
        ``histogram_fn``/``choose_fn``/``route_fn``/``leaf_fn`` are gone —
        build an ad-hoc ``TreeBackend`` instead.)

    Returns:
      (tree, leaf_assign_for_all_samples) without the tree axis.
    """
    trees, assign = build_round(
        binned, g, h, sample_mask[None], feature_mask[None], cfg,
        backend=backend,
    )
    return jax.tree_util.tree_map(lambda a: a[0], trees), assign[0]


def predict_tree(tree: TreeArrays, binned: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Route samples through one tree and return leaf weights.

    Args:
      tree: TreeArrays (single tree, no leading batch axis).
      binned: (n, d) int32 — binned with the training edges.
      max_depth: static tree depth.
    Returns:
      (n,) float32 raw tree output — (n, K) when the leaf table carries K
      values per leaf (K-channel objectives).
    """
    n = binned.shape[0]
    idx = jnp.zeros(n, dtype=jnp.int32)
    for level in range(max_depth):
        offset = 2**level - 1
        width = 2**level
        idx = traverse_level(
            binned, idx,
            tree.feature[offset:offset + width],
            tree.threshold[offset:offset + width],
        )
    return tree.leaf_weight[idx]


def predict_trees(trees: TreeArrays, binned: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Per-tree margins of a stacked forest: (n_trees, n) float32.

    The single vmapped traversal shared by forest prediction, training-time
    validation, and ``PackedEnsemble`` inference (DESIGN.md §3) — every
    prediction consumer funnels through this one program.
    """
    return jax.vmap(lambda tr: predict_tree(tr, binned, max_depth))(trees)


def predict_forest(trees: TreeArrays, binned: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Mean over a stacked forest (bagging combiner g of Alg. 1 line 7)."""
    return jnp.mean(predict_trees(trees, binned, max_depth), axis=0)


def _margin_shape(n: int, packed_leaf_weight: jnp.ndarray) -> tuple:
    """Margin accumulator shape from the packed leaf table: (n,) for the
    2-D (trees, leaves) table, (n, K) for the K-channel 3-D one."""
    if packed_leaf_weight.ndim == 2:
        return (n,)
    return (n, packed_leaf_weight.shape[-1])


def predict_packed(packed: PackedEnsemble, binned: jnp.ndarray) -> jnp.ndarray:
    """Raw-margin prediction from the packed layout, bit-for-bit equal to the
    legacy per-round loop (asserted in tests/test_packed.py).

    Per-round sums are accumulated segment-by-segment over the *static*
    ``round_offsets`` boundaries: each round's ``(n_trees_r, n)`` per-tree
    block is a transient of that segment only — the full ``(total_trees, n)``
    per-tree matrix of the original one-shot vmapped formulation is never
    materialised.  That matrix is what made the packed path 0.34x the loop
    on CPU (BENCH_predict.json history); the segmented accumulation restores
    loop-parity while keeping the packed layout's uniform storage.  The
    traversal-count trade-off lives in the combiner choice: this path is the
    bit-exact one; ``predict_packed_weighted`` streams all trees through one
    scanned body (O(1) compile cost), and the Pallas ``ensemble_predict``
    kernel fuses the whole ensemble on TPU.
    """
    out = jnp.full(
        _margin_shape(binned.shape[0], packed.leaf_weight),
        packed.base_score, dtype=jnp.float32,
    )
    for r in range(packed.rounds):
        s, e = packed.round_offsets[r], packed.round_offsets[r + 1]
        seg = TreeArrays(
            feature=packed.feature[s:e], threshold=packed.threshold[s:e],
            gain=packed.gain[s:e], leaf_weight=packed.leaf_weight[s:e],
        )
        per_tree = predict_trees(seg, binned, packed.max_depth)  # (k_r, n)
        out = out + packed.learning_rate * jnp.mean(per_tree, axis=0)
    return out


def predict_packed_weighted(packed: PackedEnsemble, binned: jnp.ndarray) -> jnp.ndarray:
    """Single-pass combiner: ``base + sum_t tree_scale[t] * tree_t(x)``.

    Algebraically identical to ``predict_packed`` (scale = lr / n_trees per
    round) but implemented as a ``lax.scan`` over the packed tree axis with a
    running accumulator: one compiled tree body regardless of ensemble size,
    and the (total_trees, n) per-tree matrix is never materialised — the
    scan's streaming accumulation is the jnp analogue of what the Pallas
    ``ensemble_predict`` kernel does across its tree grid axis.  Prefer this
    for serving; use ``predict_packed`` when bit-exact parity with the
    training-time per-round evaluation matters.
    """
    n = binned.shape[0]

    def body(out, xs):
        feature, threshold, leaf_weight, scale = xs
        tr = TreeArrays(feature=feature, threshold=threshold,
                        gain=jnp.zeros_like(leaf_weight[:0]),
                        leaf_weight=leaf_weight)
        return out + scale * predict_tree(tr, binned, packed.max_depth), None

    out, _ = jax.lax.scan(
        body,
        jnp.full(_margin_shape(n, packed.leaf_weight), packed.base_score,
                 dtype=jnp.float32),
        (packed.feature, packed.threshold, packed.leaf_weight,
         packed.tree_scale),
    )
    return out


def predict_tree_values(
    x: jnp.ndarray,
    feature: jnp.ndarray,
    thr_value: jnp.ndarray,
    leaf: jnp.ndarray,
    max_depth: int,
) -> jnp.ndarray:
    """``predict_tree`` on RAW floats via the value-space threshold table.

    Args:
      x: (n, d) float32 raw features.
      feature: (num_internal,) int32; thr_value: (num_internal,) float32.
      leaf: (num_leaves[, K]) float32.
    Returns:
      (n[, K]) float32 leaf values — leaf-index-identical to binning + the
      bin-space ``predict_tree``.
    """
    n = x.shape[0]
    idx = jnp.zeros(n, dtype=jnp.int32)
    for level in range(max_depth):
        offset = 2**level - 1
        width = 2**level
        idx = traverse_level_values(
            x, idx,
            feature[offset:offset + width],
            thr_value[offset:offset + width],
        )
    return leaf[idx]


def predict_packed_fused(model, x: jnp.ndarray) -> jnp.ndarray:
    """Fused bin+traverse serving margin: ONE program on raw floats.

    The scan structure mirrors ``predict_packed_weighted`` — streaming
    ``base + sum_t tree_scale[t] * tree_t(x)`` accumulation, one compiled
    tree body — but the per-sample binning pass (a ``searchsorted`` over
    every feature column) is gone: thresholds were rewritten into value
    space once at table-build time (``types.serving_tables``).  Accepts a
    ``PackedEnsemble`` or a ``QuantizedEnsemble`` (leaf table dequantized
    in-graph).  Leaf routing, and therefore the margin, is bit-identical to
    ``bin_data`` + ``predict_packed_weighted`` for every input, including
    NaN (routes left, the NAN_BIN semantics) and ±inf rows.
    """
    from repro.core.types import serving_tables

    feature, thr_value, leaf, tree_scale = serving_tables(model)
    n = x.shape[0]

    def body(out, xs):
        f, t, lw, scale = xs
        return out + scale * predict_tree_values(
            x, f, t, lw, model.max_depth
        ), None

    out, _ = jax.lax.scan(
        body,
        jnp.full(_margin_shape(n, leaf), model.base_score, dtype=jnp.float32),
        (feature, thr_value, leaf, tree_scale),
    )
    return out
