"""(Dynamic) FedGBF training loop (Algs. 1 & 3) and the SecureBoost baseline.

The outer boosting loop is a Python loop (M is small, each round's forest
build is one jitted XLA program); the dynamic schedules change n_trees per
round, so XLA caches one program per distinct (n_trees,) shape — with the
paper's 5 -> 2 schedule that is at most 4 programs.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import binning, dynamic, losses, metrics
from repro.core import forest as forest_mod
from repro.core.types import (
    EnsembleModel,
    FedGBFConfig,
    PackedEnsemble,
    forest_size,
    pack_ensemble,
)


@dataclass
class TrainHistory:
    rounds: list = field(default_factory=list)
    train: list = field(default_factory=list)     # dict of metrics per round
    valid: list = field(default_factory=list)
    n_trees: list = field(default_factory=list)
    rho_id: list = field(default_factory=list)
    wall_time_s: list = field(default_factory=list)


def _evaluate(loss: str, y, margin) -> dict:
    if loss == "logistic":
        rep = metrics.classification_report(y, margin)
    else:
        rep = {"rmse": float(jnp.sqrt(jnp.mean((margin - y) ** 2)))}
    rep["loss"] = float(losses.loss_value(loss, y, margin))
    return rep


def train_fedgbf(
    x: jnp.ndarray,
    y: jnp.ndarray,
    cfg: FedGBFConfig,
    rng: jax.Array,
    x_valid: Optional[jnp.ndarray] = None,
    y_valid: Optional[jnp.ndarray] = None,
    backend: Union[str, "backend_mod.TreeBackend", None] = None,
    eval_every: int = 1,
    verbose: bool = False,
) -> tuple[EnsembleModel, TrainHistory]:
    """Train (Dynamic) FedGBF. Set min == max on both schedules for static FedGBF.

    ``backend`` selects the execution layer (DESIGN.md §1): a registry name
    (``"local"``, ``"local-pallas"``; ``"vfl-*"`` names need a constructed
    backend since they bind a mesh) or a ``TreeBackend`` instance from
    ``core.backend.get_backend`` / ``federation.vfl.make_vfl_backend``.
    None means centralized-local execution, which the paper itself argues
    (and SecureBoost's losslessness guarantees) is metric-equivalent (§4.2.1).
    """
    bk = backend_mod.resolve_backend(backend)
    n, d = x.shape
    binned, edges = binning.fit_bin(x, cfg.tree.num_bins)
    y = y.astype(jnp.float32)

    y_hat = jnp.full((n,), cfg.base_score, dtype=jnp.float32)
    y_hat_valid = None
    binned_valid = None
    if x_valid is not None:
        binned_valid = binning.bin_data(x_valid, edges)
        y_hat_valid = jnp.full((x_valid.shape[0],), cfg.base_score, jnp.float32)

    forests = []
    history = TrainHistory()

    from repro.core import tree as tree_mod  # local to avoid cycle at import

    for m in range(1, cfg.rounds + 1):
        t0 = time.perf_counter()
        n_trees = dynamic.n_trees_schedule(cfg, m)
        rho_id = dynamic.rho_id_schedule(cfg, m)

        rng, k_sample = jax.random.split(rng)
        smask, fmask = forest_mod.sample_masks(
            k_sample, n, d, n_trees, rho_id, cfg.rho_feat
        )
        g, h = losses.grad_hess(cfg.loss, y, y_hat)
        trees, train_pred = bk.build_forest(binned, g, h, smask, fmask, cfg.tree)
        y_hat = y_hat + cfg.learning_rate * train_pred
        forests.append(jax.block_until_ready(trees))
        dt = time.perf_counter() - t0

        if x_valid is not None:
            # predict_forest = the shared packed traversal (tree.predict_trees)
            # + per-round mean, applied incrementally to the newest round.
            vpred = tree_mod.predict_forest(trees, binned_valid, cfg.tree.max_depth)
            y_hat_valid = y_hat_valid + cfg.learning_rate * vpred

        if m % eval_every == 0 or m == cfg.rounds:
            tr = _evaluate(cfg.loss, y, y_hat)
            history.rounds.append(m)
            history.train.append(tr)
            history.n_trees.append(n_trees)
            history.rho_id.append(rho_id)
            history.wall_time_s.append(dt)
            if x_valid is not None:
                history.valid.append(_evaluate(cfg.loss, y_valid, y_hat_valid))
            if verbose:
                msg = ", ".join(f"{k}={v:.4f}" for k, v in tr.items())
                print(f"[round {m:3d}] trees={n_trees} rho_id={rho_id:.2f} {msg}")

    model = EnsembleModel(
        forests=tuple(forests),
        learning_rate=cfg.learning_rate,
        base_score=cfg.base_score,
        bin_edges=edges,
        loss=cfg.loss,
        max_depth=cfg.tree.max_depth,
    )
    return model, history


def secureboost_config(rounds: int = 20, **kw) -> FedGBFConfig:
    """SecureBoost = FedGBF degenerated to 1 tree/round, full sampling (§2.3).

    This *is* the paper's baseline: sequential single-tree gradient boosting
    with the same histogram/split machinery (alpha_S = 1, beta_S = 1).
    """
    kw.setdefault("learning_rate", 0.1)
    return FedGBFConfig(
        rounds=rounds,
        n_trees_max=1, n_trees_min=1,
        rho_id_min=1.0, rho_id_max=1.0,
        rho_feat=1.0,
        **kw,
    )


def dynamic_fedgbf_config(rounds: int = 20, **kw) -> FedGBFConfig:
    """The paper's §4.2.2 setting: trees 5 -> 2 (k=1), rho_id 0.1 -> 0.3 (k=1)."""
    kw.setdefault("learning_rate", 0.1)
    return FedGBFConfig(
        rounds=rounds,
        n_trees_max=5, n_trees_min=2, n_trees_speed=1.0,
        rho_id_min=0.1, rho_id_max=0.3, rho_id_speed=1.0,
        rho_feat=1.0,
        **kw,
    )


def federated_forest_config(n_trees: int = 20, rho_id: float = 0.6, **kw) -> FedGBFConfig:
    """Federated Forest baseline (§2.1): pure bagging = one boosting round.

    A single round of N subsampled trees fit to the initial residual is
    exactly a random forest on (g, h) at y_hat = base_score.
    """
    return FedGBFConfig(
        rounds=1,
        learning_rate=1.0,
        n_trees_max=n_trees, n_trees_min=n_trees,
        rho_id_min=rho_id, rho_id_max=rho_id,
        **kw,
    )


_PACK_CACHE: "OrderedDict" = OrderedDict()  # id(model) -> (model, packed)


def _packed_for(model: EnsembleModel) -> PackedEnsemble:
    """Memoized pack_ensemble so repeated predict calls on the same model
    (metric sweeps, eval loops) do not re-concatenate the tree stacks.
    Bounded and identity-keyed (keeps the last few models alive — long-lived
    multi-model callers should pre-pack and pass PackedEnsemble directly)."""
    if isinstance(model.bin_edges, jax.core.Tracer):
        return pack_ensemble(model)  # under jit tracing: never cache tracers
    key = id(model)
    hit = _PACK_CACHE.get(key)
    if hit is not None and hit[0] is model:
        return hit[1]
    packed = pack_ensemble(model)
    _PACK_CACHE[key] = (model, packed)
    while len(_PACK_CACHE) > 4:
        _PACK_CACHE.popitem(last=False)
    return packed


def predict(
    model: Union[EnsembleModel, PackedEnsemble],
    x: jnp.ndarray,
    impl: str = "packed",
) -> jnp.ndarray:
    """Raw-margin prediction F(x) = base + lr * sum_m mean_j T_mj(x) (Alg. 1 l.10).

    Routed through the ``PackedEnsemble`` layout (DESIGN.md §3): one
    traversal of all trees instead of an O(rounds) Python loop.  ``impl``:

      ``"packed"``    single vmapped traversal, exact per-round combiner
                      (bit-for-bit equal to the legacy loop) — the default;
      ``"weighted"``  single-pass tree_scale combiner (serving fast path);
      ``"pallas"``    the fused Pallas ``ensemble_predict`` kernel;
      ``"loop"``      the legacy per-round loop (kept for benchmarks).
    """
    from repro.core import tree as tree_mod

    if impl == "loop":
        return predict_loop(model, x)
    packed = model if isinstance(model, PackedEnsemble) else _packed_for(model)
    binned = binning.bin_data(x, packed.bin_edges)
    if impl == "packed":
        return tree_mod.predict_packed(packed, binned)
    if impl == "weighted":
        return tree_mod.predict_packed_weighted(packed, binned)
    if impl == "pallas":
        from repro.kernels.ensemble_predict.ops import predict_packed_pallas

        return predict_packed_pallas(packed, binned)
    raise ValueError(f"unknown predict impl {impl!r}")


def predict_loop(
    model: Union[EnsembleModel, PackedEnsemble], x: jnp.ndarray
) -> jnp.ndarray:
    """Legacy O(rounds) per-round prediction loop.

    Superseded by the packed path; kept as the reference the packed path is
    asserted bit-for-bit equal to (tests/test_packed.py) and as the baseline
    in benchmarks/predict_bench.py.
    """
    from repro.core import tree as tree_mod
    from repro.core.types import unpack_ensemble

    if isinstance(model, PackedEnsemble):
        model = unpack_ensemble(model)
    binned = binning.bin_data(x, model.bin_edges)
    out = jnp.full((x.shape[0],), model.base_score, dtype=jnp.float32)
    for trees in model.forests:
        out = out + model.learning_rate * tree_mod.predict_forest(
            trees, binned, model.max_depth
        )
    return out


def predict_proba(
    model: Union[EnsembleModel, PackedEnsemble],
    x: jnp.ndarray,
    impl: str = "packed",
) -> jnp.ndarray:
    return jax.nn.sigmoid(predict(model, x, impl=impl))
