"""(Dynamic) FedGBF training (Algs. 1 & 3) and the SecureBoost baseline.

Two training engines share one contract (DESIGN.md §4):

* ``engine="scan"`` (default) — the static-shape scanned engine: the
  Dynamic FedGBF schedule (5 -> 2 trees, rho 0.1 -> 0.3) is factored into
  constant-width segments whose rounds run under ``lax.scan`` inside ONE
  compiled program, so run-time shapes never change — one XLA program
  total, no per-round recompiles, no per-round host sync (metrics are
  evaluated in-graph, gated by ``eval_every``, and fetched once at the end).
* ``engine="loop"`` — the legacy per-round Python loop, kept as the
  reference baseline: XLA caches one program per distinct (n_trees,) shape
  (the paper's 5 -> 2 schedule compiles at least 4) and every round
  host-syncs.  ``tests/test_train_engine.py`` asserts the scanned engine
  reproduces its history metrics to float tolerance.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import binning, dynamic
from repro.core import forest as forest_mod
from repro.core import objective as objective_mod
from repro.core.types import (
    EnsembleModel,
    FedGBFConfig,
    PackedEnsemble,
    forest_size,
    pack_ensemble,
)


@dataclass
class TrainHistory:
    """Per-round training record.

    ``n_trees``, ``rho_id`` and ``wall_time_s`` have one entry for EVERY
    round (length M) regardless of ``eval_every`` — the schedule and the
    spent wall time are facts about training, not about evaluation.  Only
    the metric evals are gated: ``rounds`` lists the (1-based) rounds at
    which metrics were computed and ``train``/``valid`` align with it.
    """

    rounds: list = field(default_factory=list)    # eval rounds (1-based)
    train: list = field(default_factory=list)     # dict of metrics per eval
    valid: list = field(default_factory=list)
    n_trees: list = field(default_factory=list)   # per round, length M
    rho_id: list = field(default_factory=list)    # per round, length M
    wall_time_s: list = field(default_factory=list)  # per round, length M
    engine: str = "loop"

    @property
    def total_wall_time_s(self) -> float:
        return float(sum(self.wall_time_s))


def _evaluate(loss: str, y, margin) -> dict:
    """Host-side metric dict — the objective's metric set (DESIGN.md §11)."""
    return objective_mod.get_objective(loss).evaluate(y, margin)


def train_fedgbf(
    x: jnp.ndarray,
    y: jnp.ndarray,
    cfg: FedGBFConfig,
    rng: jax.Array,
    x_valid: Optional[jnp.ndarray] = None,
    y_valid: Optional[jnp.ndarray] = None,
    backend: Union[str, "backend_mod.TreeBackend", None] = None,
    eval_every: int = 1,
    verbose: bool = False,
    engine: str = "scan",
) -> tuple[EnsembleModel, TrainHistory]:
    """Train (Dynamic) FedGBF. Set min == max on both schedules for static FedGBF.

    ``backend`` selects the execution layer (DESIGN.md §1): a registry name
    (``"local"``, ``"local-pallas"``; ``"vfl-*"`` names need a constructed
    backend since they bind a mesh) or a ``TreeBackend`` instance from
    ``core.backend.get_backend`` / ``federation.vfl.make_vfl_backend``.
    None means centralized-local execution, which the paper itself argues
    (and SecureBoost's losslessness guarantees) is metric-equivalent (§4.2.1).

    ``engine`` selects the training engine (module docstring): ``"scan"``
    (static-shape scanned engine, the default) or ``"loop"`` (legacy
    per-round reference).  Both drive the same ``TreeBackend``.
    """
    if cfg.sampling not in ("uniform", "goss"):
        raise ValueError(
            f"unknown sampling {cfg.sampling!r}; options: 'uniform', 'goss'"
        )
    if engine == "scan":
        return _train_scanned(
            x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose
        )
    if engine == "loop":
        return _train_loop(
            x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose
        )
    raise ValueError(f"unknown engine {engine!r}; options: 'scan', 'loop'")


def _delta_bucket(rows: int, n: int) -> int:
    """Round a delta-buffer width up to the next power of two (capped at n).

    The buffer width is a jit-STATIC shape: under a dynamic rho schedule
    the raw ``n − n_keep`` differs every round, which would compile one
    forest program per round — exactly the recompile churn the engines
    exist to avoid.  Surplus buffer rows land on kept rows whose delta
    weight ``1 − w`` is 0 (inert), so bucketing costs nothing in accuracy
    and caps the distinct programs at O(log n).
    """
    bucket = 1
    while bucket < rows:
        bucket *= 2
    return min(bucket, n)


def _root_delta_rows(cfg: FedGBFConfig, n: int, rho_id: float) -> int:
    """Static shared-root delta-buffer width for one round (DESIGN.md §9).

    The schedule-driven crossover: the ``shared − delta`` derivation wins
    only when most rows are kept — rho_id >= 0.5, i.e. ``n − n_keep <=
    n // 2`` under the exact host rounding the mask draw uses — and only
    for uniform 0/1 masks (GOSS's amplified weights leave ``1 − w`` nonzero
    on kept rows outside the delta buffer).  Returns 0 (direct level-0
    pass) otherwise; a power-of-two buffer width (``_delta_bucket``) when
    the delta path is selected.
    """
    if not cfg.tree.shared_root or cfg.sampling != "uniform":
        return 0
    n_keep = max(1, int(round(n * rho_id)))
    if n - n_keep > n // 2:
        return 0
    return _delta_bucket(max(1, n - n_keep), n)


def _train_loop(
    x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose
) -> tuple[EnsembleModel, TrainHistory]:
    """Legacy per-round training loop (the reference baseline)."""
    bk = backend_mod.resolve_backend(backend)
    obj = objective_mod.get_objective(cfg.loss)
    n, d = x.shape
    binned, edges = binning.fit_bin(x, cfg.tree.num_bins)
    y = y.astype(jnp.float32)

    y_hat = obj.init_raw(n, cfg.base_score)
    y_hat_valid = None
    binned_valid = None
    if x_valid is not None:
        binned_valid = binning.bin_data(x_valid, edges)
        y_hat_valid = obj.init_raw(x_valid.shape[0], cfg.base_score)

    forests = []
    history = TrainHistory(engine="loop")

    from repro.core import tree as tree_mod  # local to avoid cycle at import

    for m in range(1, cfg.rounds + 1):
        t0 = time.perf_counter()
        n_trees = dynamic.n_trees_schedule(cfg, m)
        rho_id = dynamic.rho_id_schedule(cfg, m)

        rng, k_sample = jax.random.split(rng)
        g, h = obj.grad_hess(y, y_hat)
        if cfg.sampling == "goss":
            n_top, n_rand = forest_mod.goss_counts(n, rho_id, cfg.goss_top_share)
            smask, fmask = forest_mod.goss_masks(
                k_sample, g, d, n_trees, n_top, n_rand,
                forest_mod.feature_keep_count(d, cfg.rho_feat)
            )
        else:
            smask, fmask = forest_mod.sample_masks(
                k_sample, n, d, n_trees, rho_id, cfg.rho_feat
            )
        trees, train_pred = bk.build_forest(
            binned, g, h, smask, fmask, cfg.tree,
            root_delta_rows=_root_delta_rows(cfg, n, rho_id),
        )
        y_hat = y_hat + cfg.learning_rate * train_pred
        forests.append(jax.block_until_ready(trees))
        dt = time.perf_counter() - t0

        if x_valid is not None:
            # predict_forest = the shared packed traversal (tree.predict_trees)
            # + per-round mean, applied incrementally to the newest round.
            vpred = tree_mod.predict_forest(trees, binned_valid, cfg.tree.max_depth)
            y_hat_valid = y_hat_valid + cfg.learning_rate * vpred

        # Schedule and timing are recorded for EVERY round; only the metric
        # evals are gated by eval_every.
        history.n_trees.append(n_trees)
        history.rho_id.append(rho_id)
        history.wall_time_s.append(dt)
        if m % eval_every == 0 or m == cfg.rounds:
            tr = _evaluate(cfg.loss, y, y_hat)
            history.rounds.append(m)
            history.train.append(tr)
            if x_valid is not None:
                history.valid.append(_evaluate(cfg.loss, y_valid, y_hat_valid))
            if verbose:
                msg = ", ".join(f"{k}={v:.4f}" for k, v in tr.items())
                print(f"[round {m:3d}] trees={n_trees} rho_id={rho_id:.2f} {msg}")

    model = EnsembleModel(
        forests=tuple(forests),
        learning_rate=cfg.learning_rate,
        base_score=cfg.base_score,
        bin_edges=edges,
        loss=cfg.loss,
        max_depth=cfg.tree.max_depth,
    )
    return model, history


def _schedule_segments(n_trees: "np.ndarray", split_on=None):
    """Factor a per-round tree-count schedule into constant-width segments:
    [(width, first_round, n_rounds), ...].  Monotone schedules (the paper's
    cosine decay) give at most ``n_trees_max - n_trees_min + 1`` segments.

    ``split_on`` (optional, same length) adds extra segment boundaries
    wherever its value changes — the shared-root engine passes the per-round
    crossover eligibility so every round of a segment makes the SAME
    delta-vs-direct choice the loop engine makes for it (both schedules are
    monotone, so this at most doubles the segment count)."""
    segments = []
    start = 0
    for m in range(1, len(n_trees) + 1):
        if (m == len(n_trees) or n_trees[m] != n_trees[start]
                or (split_on is not None and split_on[m] != split_on[start])):
            segments.append((int(n_trees[start]), start, m - start))
            start = m
    return segments


@partial(jax.jit, static_argnames=("cfg", "bk", "eval_every"))
def _scan_train_program(
    binned, y, binned_valid, y_valid, rng, cfg: FedGBFConfig, bk,
    eval_every: int,
):
    """The ONE compiled training program of the scanned engine.

    The mask-form schedule (``dynamic.flat_schedule``) factors the dynamic
    tree-count schedule into constant-width segments
    (``_schedule_segments``); each segment runs its rounds under a
    ``lax.scan`` at the segment's natural width (single-round segments are
    inlined), with the boosting state threaded through all segments.  The
    whole schedule therefore compiles to ONE XLA program whose shapes never
    change at run time — no per-round recompiles, no wasted tree slots, and
    the per-round forest build keeps the vmapped multi-tree batching of the
    legacy loop.

    All sampling masks are drawn up front in one batched vmap; the key
    chain replays the loop's split-per-round / fold_in-per-slot derivation
    exactly, so the scan builds mask-for-mask the legacy loop's trees.
    Metrics are evaluated in-graph (``Objective.metric_vector``) under ``lax.cond``,
    gated to eval rounds — no per-round host sync; the caller fetches the
    whole history in one device->host copy.

    Returns (trees per segment — a tuple of (rounds_seg, width, ...) stacked
    TreeArrays — train metric matrix (M, len(keys)), valid metric matrix or
    None); gated-off rounds hold NaN rows.

    Top-level + jitted so a) it is the unit the compile-count benchmark
    inspects via ``_cache_size()``, and b) identical shapes/configs across
    calls reuse the cache.
    """
    from repro.core import tree as tree_mod  # local to avoid cycle at import

    n, d = binned.shape
    d_keep = forest_mod.feature_keep_count(d, cfg.rho_feat)
    obj = objective_mod.get_objective(cfg.loss)
    lr = cfg.learning_rate
    nan_vec = jnp.full((len(obj.metric_keys),), jnp.nan, jnp.float32)
    has_valid = binned_valid is not None
    y32 = y.astype(jnp.float32)

    sched, flat = dynamic.flat_schedule(cfg)
    use_goss = cfg.sampling == "goss"
    # Per-round keep counts via the exact host expression the legacy loop
    # evaluates (full float64 rho — schedule_arrays' float32 rho_id could
    # round a .5 boundary the other way and break mask equivalence).
    n_keep_round = np.array(
        [max(1, int(round(n * dynamic.rho_id_schedule(cfg, m))))
         for m in range(1, cfg.rounds + 1)],
        np.int32,
    )
    n_keep = n_keep_round[flat.round_of_step]  # (S,)
    if use_goss:
        goss_round = np.array(
            [forest_mod.goss_counts(n, dynamic.rho_id_schedule(cfg, m),
                                    cfg.goss_top_share)
             for m in range(1, cfg.rounds + 1)],
            np.int32,
        )  # (M, 2): per-round (n_top, n_rand), same host arithmetic as loop
    rounds_idx = np.arange(1, cfg.rounds + 1)
    do_eval = (rounds_idx % eval_every == 0) | (rounds_idx == cfg.rounds)

    # -- all mask keys up front ----------------------------------------------
    round_keys = []
    for _ in range(cfg.rounds):  # the loop's exact stream: one split per round
        rng, k_round = jax.random.split(rng)
        round_keys.append(k_round)
    round_keys = jnp.stack(round_keys)  # (M, 2)
    step_keys = jax.vmap(jax.random.fold_in)(
        round_keys[jnp.asarray(flat.round_of_step)],
        jnp.asarray(flat.tree_in_round),
    )  # (S, 2) — prefix-stable per-slot keys, identical to the loop's
    if not use_goss:
        # Uniform masks depend only on the keys: one batched draw up front.
        # GOSS masks depend on the round's gradients, so they are drawn
        # inside round_body from the same per-slot keys instead.
        smask_all, fmask_all = forest_mod.masks_from_keys(
            step_keys, n, d, jnp.asarray(n_keep), d_keep
        )  # (S, n) float32, (S, d) bool

    def round_body(rdr, carry, xs):
        y_hat, y_hat_valid = carry
        g, h = obj.grad_hess(y32, y_hat)
        if use_goss:
            smask, fmask = forest_mod.goss_masks_from_keys(
                xs["keys"], g, d, xs["n_top"], xs["n_rand"], d_keep
            )
        else:
            smask, fmask = xs["smask"], xs["fmask"]
        trees, per_pred = bk.build_forest_per_tree(
            binned, g, h, smask, fmask, cfg.tree, root_delta_rows=rdr
        )
        y_hat = y_hat + lr * jnp.mean(per_pred, axis=0)
        tr_vec = jax.lax.cond(
            xs["do_eval"],
            lambda m: obj.metric_vector(y32, m),
            lambda m: nan_vec,
            y_hat,
        )
        va_vec = nan_vec
        if has_valid:
            vp = tree_mod.predict_trees(trees, binned_valid, cfg.tree.max_depth)
            y_hat_valid = y_hat_valid + lr * jnp.mean(vp, axis=0)
            va_vec = jax.lax.cond(
                xs["do_eval"],
                lambda m: obj.metric_vector(y_valid.astype(jnp.float32), m),
                lambda m: nan_vec,
                y_hat_valid,
            )
        return (y_hat, y_hat_valid), (trees, tr_vec, va_vec)

    y_hat0 = obj.init_raw(n, cfg.base_score)
    y_hat_valid0 = (
        obj.init_raw(binned_valid.shape[0], cfg.base_score)
        if has_valid else None
    )
    carry = (y_hat0, y_hat_valid0)
    offsets = np.concatenate([[0], np.cumsum(sched.n_trees)])
    trees_segs, tr_rows, va_rows = [], [], []
    # Shared-root crossover (DESIGN.md §9): segments additionally split at
    # the rho >= 0.5 eligibility boundary, so every round takes EXACTLY the
    # delta-vs-direct path the loop engine takes for it (host arithmetic
    # identical; engine equivalence must not depend on segment packing).
    # Within an eligible segment the static buffer is the bucketed max of
    # its rounds' deltas — surplus rows are weight-0 inert, so differing
    # buffer widths between the engines cannot change a single bit.
    use_shared_root = cfg.tree.shared_root and not use_goss
    delta_eligible = None
    if use_shared_root:
        delta_eligible = (n - n_keep_round) <= n // 2
    for width, first, n_rounds in _schedule_segments(
        sched.n_trees, split_on=delta_eligible
    ):
        s, e = int(offsets[first]), int(offsets[first + n_rounds])
        xs = {"do_eval": jnp.asarray(do_eval[first:first + n_rounds])}
        if use_goss:
            xs["keys"] = step_keys[s:e].reshape(n_rounds, width, 2)
            xs["n_top"] = jnp.asarray(goss_round[first:first + n_rounds, 0])
            xs["n_rand"] = jnp.asarray(goss_round[first:first + n_rounds, 1])
        else:
            xs["smask"] = smask_all[s:e].reshape(n_rounds, width, n)
            xs["fmask"] = fmask_all[s:e].reshape(n_rounds, width, d)
        rdr = 0
        if use_shared_root and delta_eligible[first]:
            seg_delta = int(n - n_keep_round[first:first + n_rounds].min())
            rdr = _delta_bucket(max(1, seg_delta), n)
        body = partial(round_body, rdr)
        if n_rounds == 1:
            carry, ys = body(
                carry, jax.tree_util.tree_map(lambda a: a[0], xs)
            )
            ys = jax.tree_util.tree_map(lambda a: a[None], ys)
        else:
            carry, ys = jax.lax.scan(body, carry, xs)
        trees_segs.append(ys[0])
        tr_rows.append(ys[1])
        va_rows.append(ys[2])
    tr_mat = jnp.concatenate(tr_rows)  # (M, len(keys))
    va_mat = jnp.concatenate(va_rows) if has_valid else None
    return tuple(trees_segs), tr_mat, va_mat


def _train_scanned(
    x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose
) -> tuple[EnsembleModel, TrainHistory]:
    """Static-shape scanned training engine (DESIGN.md §4).

    Mask-for-mask equivalent to ``_train_loop``: per-tree keys are
    prefix-stable (``forest.fold_in_keys``), so every scan step draws
    exactly the mask the legacy loop draws for that (round, slot); the
    sequential round accumulation reproduces the legacy bagging mean up to
    float reassociation (history metrics agree to ~1e-6, asserted in
    tests/test_train_engine.py).
    """
    bk = backend_mod.resolve_backend(backend)
    binned, edges = binning.fit_bin(x, cfg.tree.num_bins)
    binned_valid = binning.bin_data(x_valid, edges) if x_valid is not None else None

    sched = dynamic.schedule_arrays(cfg)
    rounds_idx = np.arange(1, cfg.rounds + 1)
    do_eval = (rounds_idx % eval_every == 0) | (rounds_idx == cfg.rounds)

    t0 = time.perf_counter()
    trees_segs, tr_mat, va_mat = _scan_train_program(
        binned, y, binned_valid,
        None if y_valid is None else jnp.asarray(y_valid),
        rng, cfg, bk, eval_every,
    )
    jax.block_until_ready(trees_segs)
    # ONE fetch for the whole metric history (the engine's only host sync).
    tr_np = np.asarray(tr_mat)
    va_np = np.asarray(va_mat) if va_mat is not None else None
    wall = time.perf_counter() - t0

    # Unstack each segment's (rounds_seg, width, ...) trees into the ragged
    # per-round forests — structurally identical to the legacy loop's model.
    forests = []
    for seg_trees in trees_segs:
        rounds_seg = seg_trees.feature.shape[0]
        for r in range(rounds_seg):
            forests.append(
                jax.tree_util.tree_map(lambda a: a[r], seg_trees)
            )
    forests = tuple(forests)

    history = TrainHistory(engine="scan")
    history.n_trees = [int(v) for v in sched.n_trees]
    history.rho_id = [dynamic.rho_id_schedule(cfg, m)  # full-precision, as loop
                      for m in range(1, cfg.rounds + 1)]
    # One program ran all rounds: amortise the single wall time uniformly so
    # sum(wall_time_s) stays the true total.
    history.wall_time_s = [wall / cfg.rounds] * cfg.rounds
    keys = objective_mod.get_objective(cfg.loss).metric_keys
    for m in np.nonzero(do_eval)[0]:
        m = int(m)
        history.rounds.append(m + 1)
        tr = dict(zip(keys, (float(v) for v in tr_np[m])))
        history.train.append(tr)
        if va_np is not None:
            history.valid.append(dict(zip(keys, (float(v) for v in va_np[m]))))
        if verbose:
            msg = ", ".join(f"{k}={v:.4f}" for k, v in tr.items())
            print(f"[round {m + 1:3d}] trees={history.n_trees[m]} "
                  f"rho_id={history.rho_id[m]:.2f} {msg}")

    model = EnsembleModel(
        forests=forests,
        learning_rate=cfg.learning_rate,
        base_score=cfg.base_score,
        bin_edges=edges,
        loss=cfg.loss,
        max_depth=cfg.tree.max_depth,
    )
    return model, history


def secureboost_config(rounds: int = 20, **kw) -> FedGBFConfig:
    """SecureBoost = FedGBF degenerated to 1 tree/round, full sampling (§2.3).

    This *is* the paper's baseline: sequential single-tree gradient boosting
    with the same histogram/split machinery (alpha_S = 1, beta_S = 1).
    """
    kw.setdefault("learning_rate", 0.1)
    return FedGBFConfig(
        rounds=rounds,
        n_trees_max=1, n_trees_min=1,
        rho_id_min=1.0, rho_id_max=1.0,
        rho_feat=1.0,
        **kw,
    )


def dynamic_fedgbf_config(rounds: int = 20, **kw) -> FedGBFConfig:
    """The paper's §4.2.2 setting: trees 5 -> 2 (k=1), rho_id 0.1 -> 0.3 (k=1)."""
    kw.setdefault("learning_rate", 0.1)
    return FedGBFConfig(
        rounds=rounds,
        n_trees_max=5, n_trees_min=2, n_trees_speed=1.0,
        rho_id_min=0.1, rho_id_max=0.3, rho_id_speed=1.0,
        rho_feat=1.0,
        **kw,
    )


def federated_forest_config(n_trees: int = 20, rho_id: float = 0.6, **kw) -> FedGBFConfig:
    """Federated Forest baseline (§2.1): pure bagging = one boosting round.

    A single round of N subsampled trees fit to the initial residual is
    exactly a random forest on (g, h) at y_hat = base_score.
    """
    return FedGBFConfig(
        rounds=1,
        learning_rate=1.0,
        n_trees_max=n_trees, n_trees_min=n_trees,
        rho_id_min=rho_id, rho_id_max=rho_id,
        **kw,
    )


_PACK_CACHE: "OrderedDict" = OrderedDict()  # id(model) -> (model, packed)


def _packed_for(model: EnsembleModel) -> PackedEnsemble:
    """Memoized pack_ensemble so repeated predict calls on the same model
    (metric sweeps, eval loops) do not re-concatenate the tree stacks.
    Bounded and identity-keyed (keeps the last few models alive — long-lived
    multi-model callers should pre-pack and pass PackedEnsemble directly)."""
    if isinstance(model.bin_edges, jax.core.Tracer):
        return pack_ensemble(model)  # under jit tracing: never cache tracers
    key = id(model)
    hit = _PACK_CACHE.get(key)
    if hit is not None and hit[0] is model:
        return hit[1]
    packed = pack_ensemble(model)
    _PACK_CACHE[key] = (model, packed)
    while len(_PACK_CACHE) > 4:
        _PACK_CACHE.popitem(last=False)
    return packed


def predict(
    model: Union[EnsembleModel, PackedEnsemble],
    x: jnp.ndarray,
    impl: str = "packed",
) -> jnp.ndarray:
    """Raw-margin prediction F(x) = base + lr * sum_m mean_j T_mj(x) (Alg. 1 l.10).

    Routed through the ``PackedEnsemble`` layout (DESIGN.md §3): one
    traversal of all trees instead of an O(rounds) Python loop.  ``impl``:

      ``"packed"``    single vmapped traversal, exact per-round combiner
                      (bit-for-bit equal to the legacy loop) — the default;
      ``"weighted"``  single-pass tree_scale combiner (serving fast path);
      ``"pallas"``    the fused Pallas ``ensemble_predict`` kernel;
      ``"loop"``      the legacy per-round loop (kept for benchmarks).
    """
    from repro.core import tree as tree_mod

    if impl == "loop":
        return predict_loop(model, x)
    packed = model if isinstance(model, PackedEnsemble) else _packed_for(model)
    binned = binning.bin_data(x, packed.bin_edges)
    if impl == "packed":
        return tree_mod.predict_packed(packed, binned)
    if impl == "weighted":
        return tree_mod.predict_packed_weighted(packed, binned)
    if impl == "pallas":
        from repro.kernels.ensemble_predict.ops import predict_packed_pallas

        return predict_packed_pallas(packed, binned)
    raise ValueError(f"unknown predict impl {impl!r}")


def predict_loop(
    model: Union[EnsembleModel, PackedEnsemble], x: jnp.ndarray
) -> jnp.ndarray:
    """Legacy O(rounds) per-round prediction loop.

    Superseded by the packed path; kept as the reference the packed path is
    asserted bit-for-bit equal to (tests/test_packed.py) and as the baseline
    in benchmarks/predict_bench.py.
    """
    from repro.core import tree as tree_mod
    from repro.core.types import unpack_ensemble

    if isinstance(model, PackedEnsemble):
        model = unpack_ensemble(model)
    binned = binning.bin_data(x, model.bin_edges)
    out = objective_mod.get_objective(model.loss).init_raw(
        x.shape[0], model.base_score
    )
    for trees in model.forests:
        out = out + model.learning_rate * tree_mod.predict_forest(
            trees, binned, model.max_depth
        )
    return out


def predict_proba(
    model: Union[EnsembleModel, PackedEnsemble],
    x: jnp.ndarray,
    impl: str = "packed",
) -> jnp.ndarray:
    """Prediction-space output: the model's objective activation applied to
    the raw margin (sigmoid for logistic, softmax for multiclass, identity
    for regression/quantile) — resolved from the registry, never hard-coded."""
    obj = objective_mod.get_objective(model.loss)
    return obj.activation(predict(model, x, impl=impl))
