"""(Dynamic) FedGBF training (Algs. 1 & 3) and the SecureBoost baseline.

Two training engines share one contract (DESIGN.md §4):

* ``engine="scan"`` (default) — the static-shape scanned engine: the
  Dynamic FedGBF schedule (5 -> 2 trees, rho 0.1 -> 0.3) is factored into
  constant-width segments whose rounds run under ``lax.scan`` inside ONE
  compiled program, so run-time shapes never change — one XLA program
  total, no per-round recompiles, no per-round host sync (metrics are
  evaluated in-graph, gated by ``eval_every``, and fetched once at the end).
* ``engine="loop"`` — the legacy per-round Python loop, kept as the
  reference baseline: XLA caches one program per distinct (n_trees,) shape
  (the paper's 5 -> 2 schedule compiles at least 4) and every round
  host-syncs.  ``tests/test_train_engine.py`` asserts the scanned engine
  reproduces its history metrics to float tolerance.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import binning, dynamic
from repro.core import forest as forest_mod
from repro.core import objective as objective_mod
from repro.obs import trace as trace_mod
from repro.core.types import (
    EnsembleModel,
    FedGBFConfig,
    PackedEnsemble,
    forest_size,
    pack_ensemble,
)


@dataclass
class TrainHistory:
    """Per-round training record.

    ``n_trees``, ``rho_id`` and ``wall_time_s`` have one entry for EVERY
    round (length M) regardless of ``eval_every`` — the schedule and the
    spent wall time are facts about training, not about evaluation.  Only
    the metric evals are gated: ``rounds`` lists the (1-based) rounds at
    which metrics were computed and ``train``/``valid`` align with it.

    ``wall_time_s`` granularity: the loop engine times every round on the
    host, so its entries are per-round exact.  The scan engine runs all
    rounds inside ONE compiled program; it measures true PER-SEGMENT walls
    via in-program host ticks (``jax.debug.callback`` at the segment
    boundaries) and smears each segment's wall uniformly over its rounds —
    per-round resolution inside a segment is fundamentally unavailable
    without a per-round host sync, which the engine exists to avoid.
    ``segments`` records the measured boundaries: one dict per segment
    (``width``, ``first_round`` 0-based, ``rounds``, ``root_delta_rows``,
    ``wall_s``, absolute host-clock ``t0``/``t1``) for the scan engine, one
    single-round entry per round for the loop engine.  ``overhead_s`` is
    the scan call's wall outside the segment ticks (trace + compile +
    dispatch + history fetch) so ``sum(wall_time_s) + overhead_s``
    reconstructs the full call.

    ``telemetry`` (filled when training runs with ``telemetry=True``) holds
    the in-graph per-round stats fetched in the engine's single host sync:
    ``split_nodes_per_level`` ((M, max_depth) — the frontier liveness the
    compaction/shared-root machinery acts on), ``sampled_entries`` (live
    (tree, row) pairs per round) and ``grad_absmean``.
    """

    rounds: list = field(default_factory=list)    # eval rounds (1-based)
    train: list = field(default_factory=list)     # dict of metrics per eval
    valid: list = field(default_factory=list)
    n_trees: list = field(default_factory=list)   # per executed round
    rho_id: list = field(default_factory=list)    # per executed round
    wall_time_s: list = field(default_factory=list)  # per executed round
    engine: str = "loop"
    segments: list = field(default_factory=list)  # measured segment walls
    telemetry: dict = field(default_factory=dict)  # in-graph per-round stats
    overhead_s: float = 0.0                       # scan: wall outside ticks
    #: resume support (DESIGN.md §13): the 0-based round this (possibly
    #: partial) history starts at — per-round lists cover rounds
    #: ``start_round+1 .. start_round+len(n_trees)`` — and the EXACT final
    #: margin carries (float32), which seed ``init_margin`` on resume.
    start_round: int = 0
    final_margin: Optional[np.ndarray] = None
    final_margin_valid: Optional[np.ndarray] = None

    @property
    def total_wall_time_s(self) -> float:
        return float(sum(self.wall_time_s))


def _evaluate(loss: str, y, margin) -> dict:
    """Host-side metric dict — the objective's metric set (DESIGN.md §11)."""
    return objective_mod.get_objective(loss).evaluate(y, margin)


def train_fedgbf(
    x: jnp.ndarray,
    y: jnp.ndarray,
    cfg: FedGBFConfig,
    rng: jax.Array,
    x_valid: Optional[jnp.ndarray] = None,
    y_valid: Optional[jnp.ndarray] = None,
    backend: Union[str, "backend_mod.TreeBackend", None] = None,
    eval_every: int = 1,
    verbose: bool = False,
    engine: str = "scan",
    tracer=None,
    telemetry: bool = False,
    round_feature_mask=None,
    start_round: int = 0,
    stop_round: Optional[int] = None,
    init_margin=None,
    init_margin_valid=None,
) -> tuple[EnsembleModel, TrainHistory]:
    """Train (Dynamic) FedGBF. Set min == max on both schedules for static FedGBF.

    ``backend`` selects the execution layer (DESIGN.md §1): a registry name
    (``"local"``, ``"local-pallas"``; ``"vfl-*"`` names need a constructed
    backend since they bind a mesh) or a ``TreeBackend`` instance from
    ``core.backend.get_backend`` / ``federation.vfl.make_vfl_backend``.
    None means centralized-local execution, which the paper itself argues
    (and SecureBoost's losslessness guarantees) is metric-equivalent (§4.2.1).

    ``engine`` selects the training engine (module docstring): ``"scan"``
    (static-shape scanned engine, the default) or ``"loop"`` (legacy
    per-round reference).  Both drive the same ``TreeBackend``.

    ``tracer`` (an ``obs.trace.Tracer``; None falls back to the process
    global, default disabled) records host-side spans — binning, the
    scan-program call, per-segment/per-round execution.  ``telemetry=True``
    additionally threads the in-graph telemetry block through the training
    program (``TrainHistory.telemetry``); it is a jit-STATIC flag, so the
    default path compiles the exact same program as before (the 1-compile
    property and its cost are untouched — gated by benchmarks/ci_guard.py).

    Fault tolerance (DESIGN.md §13):

    ``round_feature_mask`` — optional (M, d) bool: round m (1-based row
    m-1) restricts the split search to its True columns, composed (AND)
    with the per-tree sampled feature masks.  This is the party-dropout
    degradation hook: a degraded party's columns go False for the rest of
    the round, and the result is bit-identical to a run whose sampled
    masks never contained those candidates.

    ``start_round``/``stop_round`` — train only rounds ``start_round+1 ..
    stop_round`` (0-based window [start, stop)) of the FULL schedule: the
    rng stream, sampling masks, schedule arithmetic and eval gating all
    replay the full-run derivation, so chunked training stitches to a
    byte-identical ensemble.  ``init_margin``/``init_margin_valid`` seed
    the boosting carry (the previous chunk's ``history.final_margin``);
    every history carries its exact final margins for exactly this.
    """
    if cfg.sampling not in ("uniform", "goss"):
        raise ValueError(
            f"unknown sampling {cfg.sampling!r}; options: 'uniform', 'goss'"
        )
    stop = cfg.rounds if stop_round is None else int(stop_round)
    start = int(start_round)
    if not 0 <= start < stop <= cfg.rounds:
        raise ValueError(
            f"round window [{start}, {stop}) invalid for cfg.rounds="
            f"{cfg.rounds}"
        )
    if (init_margin is None) != (start == 0):
        raise ValueError(
            "init_margin must be given exactly when start_round > 0 "
            "(it is the previous chunk's final_margin)"
        )
    if round_feature_mask is not None:
        round_feature_mask = np.asarray(round_feature_mask, bool)
        if round_feature_mask.shape != (cfg.rounds, x.shape[1]):
            raise ValueError(
                f"round_feature_mask shape {round_feature_mask.shape} != "
                f"(rounds, d) = ({cfg.rounds}, {x.shape[1]})"
            )
    if tracer is None:
        tracer = trace_mod.global_tracer()
    if engine == "scan":
        return _train_scanned(
            x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose,
            tracer, telemetry, round_feature_mask, start, stop,
            init_margin, init_margin_valid,
        )
    if engine == "loop":
        return _train_loop(
            x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose,
            tracer, telemetry, round_feature_mask, start, stop,
            init_margin, init_margin_valid,
        )
    raise ValueError(f"unknown engine {engine!r}; options: 'scan', 'loop'")


def _delta_bucket(rows: int, n: int) -> int:
    """Round a delta-buffer width up to the next power of two (capped at n).

    The buffer width is a jit-STATIC shape: under a dynamic rho schedule
    the raw ``n − n_keep`` differs every round, which would compile one
    forest program per round — exactly the recompile churn the engines
    exist to avoid.  Surplus buffer rows land on kept rows whose delta
    weight ``1 − w`` is 0 (inert), so bucketing costs nothing in accuracy
    and caps the distinct programs at O(log n).
    """
    bucket = 1
    while bucket < rows:
        bucket *= 2
    return min(bucket, n)


def _root_delta_rows(cfg: FedGBFConfig, n: int, rho_id: float) -> int:
    """Static shared-root delta-buffer width for one round (DESIGN.md §9).

    The schedule-driven crossover: the ``shared − delta`` derivation wins
    only when most rows are kept — rho_id >= 0.5, i.e. ``n − n_keep <=
    n // 2`` under the exact host rounding the mask draw uses — and only
    for uniform 0/1 masks (GOSS's amplified weights leave ``1 − w`` nonzero
    on kept rows outside the delta buffer).  Returns 0 (direct level-0
    pass) otherwise; a power-of-two buffer width (``_delta_bucket``) when
    the delta path is selected.
    """
    if not cfg.tree.shared_root or cfg.sampling != "uniform":
        return 0
    n_keep = max(1, int(round(n * rho_id)))
    if n - n_keep > n // 2:
        return 0
    return _delta_bucket(max(1, n - n_keep), n)


def _round_telemetry(trees, smask, g, max_depth) -> list:
    """The in-graph telemetry vector for one round's built forest.

    Per-level live split-node counts over the round's T trees (the frontier
    liveness the compaction/shared-root machinery acts on), the live
    (tree, row) sample-mask entries, and the mean |g| — all O(T·nodes)
    reductions over arrays the round already materialized, so the traced
    cost is noise next to one histogram pass (the <=5% ci_guard gate).
    Returns a list of scalar jnp values, length ``max_depth + 2``.
    """
    tele, off = [], 0
    for level in range(max_depth):
        width = 2 ** level
        tele.append(jnp.sum(
            (trees.feature[:, off:off + width] >= 0).astype(jnp.float32)
        ))
        off += width
    tele.append(jnp.sum((smask > 0).astype(jnp.float32)))
    tele.append(jnp.mean(jnp.abs(g)))
    return tele


#: telemetry slots beyond the per-level liveness counts
_TELE_EXTRA = 2


def _telemetry_dict(tele_np: "np.ndarray", max_depth: int) -> dict:
    """Unpack the fetched (M, max_depth + 2) telemetry matrix."""
    return {
        "split_nodes_per_level":
            tele_np[:, :max_depth].astype(np.int64).tolist(),
        "sampled_entries": tele_np[:, max_depth].astype(np.int64).tolist(),
        "grad_absmean": [float(v) for v in tele_np[:, max_depth + 1]],
    }


def _train_loop(
    x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose,
    tracer=trace_mod.NULL_TRACER, telemetry=False,
    round_feature_mask=None, start_round=0, stop_round=None,
    init_margin=None, init_margin_valid=None,
) -> tuple[EnsembleModel, TrainHistory]:
    """Legacy per-round training loop (the reference baseline)."""
    bk = backend_mod.resolve_backend(backend)
    obj = objective_mod.get_objective(cfg.loss)
    n, d = x.shape
    start = int(start_round)
    stop = cfg.rounds if stop_round is None else int(stop_round)
    with tracer.span("binning", cat="train"):
        binned, edges = binning.fit_bin(x, cfg.tree.num_bins)
    y = y.astype(jnp.float32)

    # Resume (DESIGN.md §13): replay the rng stream through the skipped
    # rounds — one split per round, exactly what the loop below draws — so
    # round m's key is identical whether or not rounds before it ran here.
    for _ in range(start):
        rng, _ = jax.random.split(rng)
    y_hat = (obj.init_raw(n, cfg.base_score) if init_margin is None
             else jnp.asarray(init_margin))
    y_hat_valid = None
    binned_valid = None
    if x_valid is not None:
        binned_valid = binning.bin_data(x_valid, edges)
        y_hat_valid = (obj.init_raw(x_valid.shape[0], cfg.base_score)
                       if init_margin_valid is None
                       else jnp.asarray(init_margin_valid))

    forests = []
    history = TrainHistory(engine="loop", start_round=start)

    from repro.core import tree as tree_mod  # local to avoid cycle at import

    for m in range(start + 1, stop + 1):
        t0 = time.perf_counter()
        n_trees = dynamic.n_trees_schedule(cfg, m)
        rho_id = dynamic.rho_id_schedule(cfg, m)

        rng, k_sample = jax.random.split(rng)
        g, h = obj.grad_hess(y, y_hat)
        if cfg.sampling == "goss":
            n_top, n_rand = forest_mod.goss_counts(n, rho_id, cfg.goss_top_share)
            smask, fmask = forest_mod.goss_masks(
                k_sample, g, d, n_trees, n_top, n_rand,
                forest_mod.feature_keep_count(d, cfg.rho_feat)
            )
        else:
            smask, fmask = forest_mod.sample_masks(
                k_sample, n, d, n_trees, rho_id, cfg.rho_feat
            )
        if round_feature_mask is not None:
            # party-dropout degradation: the round's surviving columns,
            # composed with the sampled masks (DESIGN.md §13)
            fmask = fmask & jnp.asarray(round_feature_mask[m - 1])[None, :]
        rdr = _root_delta_rows(cfg, n, rho_id)
        with tracer.span(f"round {m}", cat="train",
                         args={"n_trees": n_trees,
                               "rho_id": round(rho_id, 6)}):
            trees, train_pred = bk.build_forest(
                binned, g, h, smask, fmask, cfg.tree, root_delta_rows=rdr,
            )
            y_hat = y_hat + cfg.learning_rate * train_pred
            forests.append(jax.block_until_ready(trees))
        t1 = time.perf_counter()
        dt = t1 - t0
        history.segments.append({
            "width": n_trees, "first_round": m - 1, "rounds": 1,
            "root_delta_rows": rdr, "wall_s": dt, "t0": t0, "t1": t1,
        })
        if telemetry:
            tele = np.asarray(jnp.stack(
                _round_telemetry(trees, smask, g, cfg.tree.max_depth)
            ))[None]
            for k, v in _telemetry_dict(tele, cfg.tree.max_depth).items():
                history.telemetry.setdefault(k, []).extend(v)

        if x_valid is not None:
            # predict_forest = the shared packed traversal (tree.predict_trees)
            # + per-round mean, applied incrementally to the newest round.
            vpred = tree_mod.predict_forest(trees, binned_valid, cfg.tree.max_depth)
            y_hat_valid = y_hat_valid + cfg.learning_rate * vpred

        # Schedule and timing are recorded for EVERY executed round; only
        # the metric evals are gated by eval_every.  The eval condition is
        # ABSOLUTE (cfg.rounds, not the chunk's stop), so a chunked run
        # evaluates at exactly the rounds the uninterrupted run does.
        history.n_trees.append(n_trees)
        history.rho_id.append(rho_id)
        history.wall_time_s.append(dt)
        if m % eval_every == 0 or m == cfg.rounds:
            tr = _evaluate(cfg.loss, y, y_hat)
            history.rounds.append(m)
            history.train.append(tr)
            if x_valid is not None:
                history.valid.append(_evaluate(cfg.loss, y_valid, y_hat_valid))
            if verbose:
                msg = ", ".join(f"{k}={v:.4f}" for k, v in tr.items())
                print(f"[round {m:3d}] trees={n_trees} rho_id={rho_id:.2f} {msg}")

    history.final_margin = np.asarray(y_hat)
    if y_hat_valid is not None:
        history.final_margin_valid = np.asarray(y_hat_valid)
    model = EnsembleModel(
        forests=tuple(forests),
        learning_rate=cfg.learning_rate,
        base_score=cfg.base_score,
        bin_edges=edges,
        loss=cfg.loss,
        max_depth=cfg.tree.max_depth,
    )
    return model, history


#: host-side segment-boundary timestamps appended by the in-program
#: ``jax.debug.callback`` ticks of the CURRENT scan-engine call: (seg_idx,
#: perf_counter).  Cleared by ``_train_scanned`` before each program call and
#: read back after ``jax.effects_barrier()`` — a probing device like
#: ``MessageMeter``, not re-entrant across concurrent trains in one process.
_SEGMENT_TICKS: list = []


def _segment_tick(seg_idx, _anchor) -> None:
    _SEGMENT_TICKS.append((int(seg_idx), time.perf_counter()))


def _emit_tick(seg_idx: int, anchor) -> None:
    """Stage a host tick anchored on ``anchor`` (a traced array).

    The data dependency on the boosting carry pins the callback to the
    point where the preceding segment's result exists, so the host
    timestamps bracket real segment execution.  Deliberately UNordered:
    ordered effects refuse to run on >1 device, and the vfl backends train
    on a multi-device mesh — sequencing comes from the carry chain instead
    (tick i+1's operand depends on everything tick i's did), and the reader
    dedups per segment index.  One scalar rides per tick — a handful of
    tiny host callbacks per *program execution*, which is why the
    per-segment wall-time fix costs nothing measurable (ci_guard's
    traced-vs-untraced gate).
    """
    jax.debug.callback(_segment_tick, seg_idx, anchor.ravel()[0])


def _schedule_segments(n_trees: "np.ndarray", split_on=None):
    """Factor a per-round tree-count schedule into constant-width segments:
    [(width, first_round, n_rounds), ...].  Monotone schedules (the paper's
    cosine decay) give at most ``n_trees_max - n_trees_min + 1`` segments.

    ``split_on`` (optional, same length) adds extra segment boundaries
    wherever its value changes — the shared-root engine passes the per-round
    crossover eligibility so every round of a segment makes the SAME
    delta-vs-direct choice the loop engine makes for it (both schedules are
    monotone, so this at most doubles the segment count)."""
    segments = []
    start = 0
    for m in range(1, len(n_trees) + 1):
        if (m == len(n_trees) or n_trees[m] != n_trees[start]
                or (split_on is not None and split_on[m] != split_on[start])):
            segments.append((int(n_trees[start]), start, m - start))
            start = m
    return segments


def _keep_counts(cfg: FedGBFConfig, n: int) -> "np.ndarray":
    """Per-round keep counts via the exact host expression the legacy loop
    evaluates (full float64 rho — schedule_arrays' float32 rho_id could
    round a .5 boundary the other way and break mask equivalence)."""
    return np.array(
        [max(1, int(round(n * dynamic.rho_id_schedule(cfg, m))))
         for m in range(1, cfg.rounds + 1)],
        np.int32,
    )


def _plan_segments(cfg: FedGBFConfig, n: int, start_round: int = 0,
                   stop_round: Optional[int] = None) -> list:
    """The scan engine's segment plan: [(width, first_round, n_rounds,
    root_delta_rows), ...] — ONE host-side derivation shared by the compiled
    program and by the history/trace attribution of the segment ticks, so
    the two can never disagree on segment boundaries.

    Shared-root crossover (DESIGN.md §9): segments additionally split at
    the rho >= 0.5 eligibility boundary, so every round takes EXACTLY the
    delta-vs-direct path the loop engine takes for it (host arithmetic
    identical; engine equivalence must not depend on segment packing).
    Within an eligible segment the static buffer is the bucketed max of
    its rounds' deltas — surplus rows are weight-0 inert, so differing
    buffer widths between the engines cannot change a single bit.

    Resume (DESIGN.md §13): ``start_round``/``stop_round`` clip the FULL
    plan to the 0-based round window [start, stop) — segment widths and the
    per-segment ``root_delta_rows`` are derived from the full schedule
    first, so a clipped segment keeps the buffer width the uninterrupted
    run uses (surplus delta-buffer rows are weight-0 inert, so the shared
    width cannot change a bit; see above).
    """
    sched, _ = dynamic.flat_schedule(cfg)
    n_keep_round = _keep_counts(cfg, n)
    use_shared_root = cfg.tree.shared_root and cfg.sampling != "goss"
    delta_eligible = None
    if use_shared_root:
        delta_eligible = (n - n_keep_round) <= n // 2
    plan = []
    for width, first, n_rounds in _schedule_segments(
        sched.n_trees, split_on=delta_eligible
    ):
        rdr = 0
        if use_shared_root and delta_eligible[first]:
            seg_delta = int(n - n_keep_round[first:first + n_rounds].min())
            rdr = _delta_bucket(max(1, seg_delta), n)
        plan.append((width, first, n_rounds, rdr))
    start = int(start_round)
    stop = cfg.rounds if stop_round is None else int(stop_round)
    if start > 0 or stop < cfg.rounds:
        clipped = []
        for width, first, n_rounds, rdr in plan:
            a, b = max(first, start), min(first + n_rounds, stop)
            if b > a:
                clipped.append((width, a, b - a, rdr))
        plan = clipped
    return plan


@partial(jax.jit, static_argnames=("cfg", "bk", "eval_every", "telemetry",
                                   "start_round", "stop_round"))
def _scan_train_program(
    binned, y, binned_valid, y_valid, rng, cfg: FedGBFConfig, bk,
    eval_every: int, telemetry: bool = False, round_mask=None,
    init_margin=None, init_margin_valid=None, start_round: int = 0,
    stop_round: Optional[int] = None,
):
    """The ONE compiled training program of the scanned engine.

    The mask-form schedule (``dynamic.flat_schedule``) factors the dynamic
    tree-count schedule into constant-width segments
    (``_schedule_segments``); each segment runs its rounds under a
    ``lax.scan`` at the segment's natural width (single-round segments are
    inlined), with the boosting state threaded through all segments.  The
    whole schedule therefore compiles to ONE XLA program whose shapes never
    change at run time — no per-round recompiles, no wasted tree slots, and
    the per-round forest build keeps the vmapped multi-tree batching of the
    legacy loop.

    All sampling masks are drawn up front in one batched vmap; the key
    chain replays the loop's split-per-round / fold_in-per-slot derivation
    exactly, so the scan builds mask-for-mask the legacy loop's trees.
    Metrics are evaluated in-graph (``Objective.metric_vector``) under ``lax.cond``,
    gated to eval rounds — no per-round host sync; the caller fetches the
    whole history in one device->host copy.

    Returns (trees per segment — a tuple of (rounds_seg, width, ...) stacked
    TreeArrays — train metric matrix (M, len(keys)), valid metric matrix or
    None, telemetry matrix (M, max_depth + 2) or None); gated-off rounds
    hold NaN metric rows.

    Observability (DESIGN.md §12): an ordered ``jax.debug.callback`` tick
    fires at every segment boundary (anchored on the boosting carry) so the
    caller recovers TRUE per-segment walls from one program execution; with
    the jit-STATIC ``telemetry`` flag the per-round liveness block
    (``_round_telemetry``) rides the scan ``ys`` and is fetched in the same
    single host sync as the metrics — neither path adds a host round-trip
    or a second compile.

    Top-level + jitted so a) it is the unit the compile-count benchmark
    inspects via ``_cache_size()``, and b) identical shapes/configs across
    calls reuse the cache.

    Fault tolerance (DESIGN.md §13): ``round_mask`` ((M, d) bool or None)
    ANDs into every round's sampled feature masks (party-dropout
    degradation); ``start_round``/``stop_round`` (jit-static) clip the
    executed segment plan to a round window while the rng stream, mask
    draws and eval gating replay the FULL schedule, and
    ``init_margin``/``init_margin_valid`` seed the boosting carry — the
    final carry is returned so chunked runs hand margins forward exactly.
    """
    from repro.core import tree as tree_mod  # local to avoid cycle at import

    start = int(start_round)
    stop = cfg.rounds if stop_round is None else int(stop_round)
    n, d = binned.shape
    d_keep = forest_mod.feature_keep_count(d, cfg.rho_feat)
    obj = objective_mod.get_objective(cfg.loss)
    lr = cfg.learning_rate
    nan_vec = jnp.full((len(obj.metric_keys),), jnp.nan, jnp.float32)
    has_valid = binned_valid is not None
    y32 = y.astype(jnp.float32)

    sched, flat = dynamic.flat_schedule(cfg)
    use_goss = cfg.sampling == "goss"
    n_keep_round = _keep_counts(cfg, n)
    n_keep = n_keep_round[flat.round_of_step]  # (S,)
    if use_goss:
        goss_round = np.array(
            [forest_mod.goss_counts(n, dynamic.rho_id_schedule(cfg, m),
                                    cfg.goss_top_share)
             for m in range(1, cfg.rounds + 1)],
            np.int32,
        )  # (M, 2): per-round (n_top, n_rand), same host arithmetic as loop
    rounds_idx = np.arange(1, cfg.rounds + 1)
    do_eval = (rounds_idx % eval_every == 0) | (rounds_idx == cfg.rounds)

    # -- all mask keys up front ----------------------------------------------
    round_keys = []
    for _ in range(cfg.rounds):  # the loop's exact stream: one split per round
        rng, k_round = jax.random.split(rng)
        round_keys.append(k_round)
    round_keys = jnp.stack(round_keys)  # (M, 2)
    step_keys = jax.vmap(jax.random.fold_in)(
        round_keys[jnp.asarray(flat.round_of_step)],
        jnp.asarray(flat.tree_in_round),
    )  # (S, 2) — prefix-stable per-slot keys, identical to the loop's
    if not use_goss:
        # Uniform masks depend only on the keys: one batched draw up front.
        # GOSS masks depend on the round's gradients, so they are drawn
        # inside round_body from the same per-slot keys instead.
        smask_all, fmask_all = forest_mod.masks_from_keys(
            step_keys, n, d, jnp.asarray(n_keep), d_keep
        )  # (S, n) float32, (S, d) bool

    def round_body(rdr, carry, xs):
        y_hat, y_hat_valid = carry
        g, h = obj.grad_hess(y32, y_hat)
        if use_goss:
            smask, fmask = forest_mod.goss_masks_from_keys(
                xs["keys"], g, d, xs["n_top"], xs["n_rand"], d_keep
            )
        else:
            smask, fmask = xs["smask"], xs["fmask"]
        if round_mask is not None:
            # party-dropout degradation (DESIGN.md §13): the round's
            # surviving columns AND into the per-tree sampled masks
            fmask = fmask & xs["rmask"][None, :]
        trees, per_pred = bk.build_forest_per_tree(
            binned, g, h, smask, fmask, cfg.tree, root_delta_rows=rdr
        )
        y_hat = y_hat + lr * jnp.mean(per_pred, axis=0)
        tele_vec = (jnp.stack(_round_telemetry(trees, smask, g,
                                               cfg.tree.max_depth))
                    if telemetry else None)
        tr_vec = jax.lax.cond(
            xs["do_eval"],
            lambda m: obj.metric_vector(y32, m),
            lambda m: nan_vec,
            y_hat,
        )
        va_vec = nan_vec
        if has_valid:
            vp = tree_mod.predict_trees(trees, binned_valid, cfg.tree.max_depth)
            y_hat_valid = y_hat_valid + lr * jnp.mean(vp, axis=0)
            va_vec = jax.lax.cond(
                xs["do_eval"],
                lambda m: obj.metric_vector(y_valid.astype(jnp.float32), m),
                lambda m: nan_vec,
                y_hat_valid,
            )
        ys = ((trees, tr_vec, va_vec, tele_vec) if telemetry
              else (trees, tr_vec, va_vec))
        return (y_hat, y_hat_valid), ys

    y_hat0 = (obj.init_raw(n, cfg.base_score) if init_margin is None
              else init_margin)
    y_hat_valid0 = None
    if has_valid:
        y_hat_valid0 = (
            obj.init_raw(binned_valid.shape[0], cfg.base_score)
            if init_margin_valid is None else init_margin_valid
        )
    carry = (y_hat0, y_hat_valid0)
    offsets = np.concatenate([[0], np.cumsum(sched.n_trees)])
    trees_segs, tr_rows, va_rows, tele_rows = [], [], [], []
    # Segment boundaries + shared-root crossover come from the ONE shared
    # host-side plan (``_plan_segments``) the caller also uses to attribute
    # the segment ticks back to rounds.  Under a resume window the plan is
    # the full schedule's plan clipped to [start, stop) — keys/masks index
    # by ABSOLUTE round, so every executed round replays its full-run draw.
    _emit_tick(0, y_hat0)
    for seg_idx, (width, first, n_rounds, rdr) in enumerate(
        _plan_segments(cfg, n, start, stop)
    ):
        s, e = int(offsets[first]), int(offsets[first + n_rounds])
        xs = {"do_eval": jnp.asarray(do_eval[first:first + n_rounds])}
        if use_goss:
            xs["keys"] = step_keys[s:e].reshape(n_rounds, width, 2)
            xs["n_top"] = jnp.asarray(goss_round[first:first + n_rounds, 0])
            xs["n_rand"] = jnp.asarray(goss_round[first:first + n_rounds, 1])
        else:
            xs["smask"] = smask_all[s:e].reshape(n_rounds, width, n)
            xs["fmask"] = fmask_all[s:e].reshape(n_rounds, width, d)
        if round_mask is not None:
            xs["rmask"] = round_mask[first:first + n_rounds]
        body = partial(round_body, rdr)
        if n_rounds == 1:
            carry, ys = body(
                carry, jax.tree_util.tree_map(lambda a: a[0], xs)
            )
            ys = jax.tree_util.tree_map(lambda a: a[None], ys)
        else:
            carry, ys = jax.lax.scan(body, carry, xs)
        trees_segs.append(ys[0])
        tr_rows.append(ys[1])
        va_rows.append(ys[2])
        if telemetry:
            tele_rows.append(ys[3])
        _emit_tick(seg_idx + 1, carry[0])
    tr_mat = jnp.concatenate(tr_rows)  # (stop - start, len(keys))
    va_mat = jnp.concatenate(va_rows) if has_valid else None
    tele_mat = jnp.concatenate(tele_rows) if telemetry else None
    return tuple(trees_segs), tr_mat, va_mat, tele_mat, carry


def _train_scanned(
    x, y, cfg, rng, x_valid, y_valid, backend, eval_every, verbose,
    tracer=trace_mod.NULL_TRACER, telemetry=False,
    round_feature_mask=None, start_round=0, stop_round=None,
    init_margin=None, init_margin_valid=None,
) -> tuple[EnsembleModel, TrainHistory]:
    """Static-shape scanned training engine (DESIGN.md §4).

    Mask-for-mask equivalent to ``_train_loop``: per-tree keys are
    prefix-stable (``forest.fold_in_keys``), so every scan step draws
    exactly the mask the legacy loop draws for that (round, slot); the
    sequential round accumulation reproduces the legacy bagging mean up to
    float reassociation (history metrics agree to ~1e-6, asserted in
    tests/test_train_engine.py).
    """
    bk = backend_mod.resolve_backend(backend)
    with tracer.span("binning", cat="train"):
        binned, edges = binning.fit_bin(x, cfg.tree.num_bins)
        binned_valid = (binning.bin_data(x_valid, edges)
                        if x_valid is not None else None)

    sched = dynamic.schedule_arrays(cfg)
    start = int(start_round)
    stop = cfg.rounds if stop_round is None else int(stop_round)
    rounds_idx = np.arange(1, cfg.rounds + 1)
    do_eval = (rounds_idx % eval_every == 0) | (rounds_idx == cfg.rounds)

    _SEGMENT_TICKS.clear()
    t0 = time.perf_counter()
    with tracer.span("scan_program", cat="train",
                     args={"rounds": cfg.rounds, "telemetry": telemetry}):
        trees_segs, tr_mat, va_mat, tele_mat, carry = _scan_train_program(
            binned, y, binned_valid,
            None if y_valid is None else jnp.asarray(y_valid),
            rng, cfg, bk, eval_every, telemetry=telemetry,
            round_mask=(None if round_feature_mask is None
                        else jnp.asarray(round_feature_mask)),
            init_margin=(None if init_margin is None
                         else jnp.asarray(init_margin)),
            init_margin_valid=(None if init_margin_valid is None
                               else jnp.asarray(init_margin_valid)),
            start_round=start, stop_round=stop,
        )
        jax.block_until_ready(trees_segs)
    jax.effects_barrier()  # flush the in-program segment ticks
    wall = time.perf_counter() - t0
    with tracer.span("fetch_history", cat="train"):
        # ONE fetch for the whole metric (+ telemetry) history — the
        # engine's only host sync.
        tr_np = np.asarray(tr_mat)
        va_np = np.asarray(va_mat) if va_mat is not None else None
        tele_np = np.asarray(tele_mat) if tele_mat is not None else None

    # Unstack each segment's (rounds_seg, width, ...) trees into the ragged
    # per-round forests — structurally identical to the legacy loop's model.
    forests = []
    for seg_trees in trees_segs:
        rounds_seg = seg_trees.feature.shape[0]
        for r in range(rounds_seg):
            forests.append(
                jax.tree_util.tree_map(lambda a: a[r], seg_trees)
            )
    forests = tuple(forests)

    history = TrainHistory(engine="scan", start_round=start)
    history.n_trees = [int(v) for v in sched.n_trees[start:stop]]
    history.rho_id = [dynamic.rho_id_schedule(cfg, m)  # full-precision, as loop
                      for m in range(start + 1, stop + 1)]
    if tele_np is not None:
        history.telemetry = _telemetry_dict(tele_np, cfg.tree.max_depth)

    # Per-SEGMENT walls from the in-program ticks: tick i and i+1 bracket
    # segment i's execution, so each segment's wall is real, smeared
    # uniformly only over the rounds INSIDE it (see the TrainHistory
    # docstring for the granularity limit).  Everything the call spent
    # outside the ticks — trace + compile + dispatch — lands in
    # ``overhead_s``, so cold and warm calls stay comparable.
    plan = _plan_segments(cfg, binned.shape[0], start, stop)
    # Unordered callbacks fire once per participating device: dedup to the
    # earliest timestamp per segment index, then clamp to monotone (host
    # callback delivery can jitter by microseconds across devices).
    by_idx: dict = {}
    for i, t in _SEGMENT_TICKS:
        by_idx[i] = min(t, by_idx.get(i, t))
    if set(by_idx) == set(range(len(plan) + 1)):
        ticks = [(i, by_idx[i]) for i in range(len(plan) + 1)]
        for k in range(1, len(ticks)):
            ticks[k] = (k, max(ticks[k][1], ticks[k - 1][1]))
        history.wall_time_s = []
        for (width, first, n_rounds, rdr), (_, ta), (_, tb) in zip(
            plan, ticks, ticks[1:]
        ):
            history.wall_time_s.extend([(tb - ta) / n_rounds] * n_rounds)
            history.segments.append({
                "width": width, "first_round": first, "rounds": n_rounds,
                "root_delta_rows": rdr, "wall_s": tb - ta,
                "t0": ta, "t1": tb,
            })
            tracer.add_span(
                f"segment[T={width}]", ta, tb, cat="train", track="train",
                args={"rounds": n_rounds, "first_round": first + 1,
                      "root_delta_rows": rdr},
            )
        history.overhead_s = max(0.0, wall - (ticks[-1][1] - ticks[0][1]))
        tracer.add_span("trace+compile+dispatch", t0, ticks[0][1],
                        cat="train", track="train")
    else:  # ticks unavailable (e.g. a backend without host callbacks):
        # fall back to the uniform smear so the total stays true.
        n_exec = stop - start
        history.wall_time_s = [wall / n_exec] * n_exec
        per = wall / n_exec
        for width, first, n_rounds, rdr in plan:
            history.segments.append({
                "width": width, "first_round": first, "rounds": n_rounds,
                "root_delta_rows": rdr, "wall_s": per * n_rounds,
                "t0": t0 + (first - start) * per,
                "t1": t0 + (first - start + n_rounds) * per,
            })
    keys = objective_mod.get_objective(cfg.loss).metric_keys
    for m in np.nonzero(do_eval)[0]:
        m = int(m)
        if not (start <= m < stop):
            continue
        history.rounds.append(m + 1)
        tr = dict(zip(keys, (float(v) for v in tr_np[m - start])))
        history.train.append(tr)
        if va_np is not None:
            history.valid.append(
                dict(zip(keys, (float(v) for v in va_np[m - start])))
            )
        if verbose:
            msg = ", ".join(f"{k}={v:.4f}" for k, v in tr.items())
            print(f"[round {m + 1:3d}] trees={history.n_trees[m - start]} "
                  f"rho_id={history.rho_id[m - start]:.2f} {msg}")

    history.final_margin = np.asarray(carry[0])
    if carry[1] is not None:
        history.final_margin_valid = np.asarray(carry[1])
    model = EnsembleModel(
        forests=forests,
        learning_rate=cfg.learning_rate,
        base_score=cfg.base_score,
        bin_edges=edges,
        loss=cfg.loss,
        max_depth=cfg.tree.max_depth,
    )
    return model, history


def secureboost_config(rounds: int = 20, **kw) -> FedGBFConfig:
    """SecureBoost = FedGBF degenerated to 1 tree/round, full sampling (§2.3).

    This *is* the paper's baseline: sequential single-tree gradient boosting
    with the same histogram/split machinery (alpha_S = 1, beta_S = 1).
    """
    kw.setdefault("learning_rate", 0.1)
    return FedGBFConfig(
        rounds=rounds,
        n_trees_max=1, n_trees_min=1,
        rho_id_min=1.0, rho_id_max=1.0,
        rho_feat=1.0,
        **kw,
    )


def dynamic_fedgbf_config(rounds: int = 20, **kw) -> FedGBFConfig:
    """The paper's §4.2.2 setting: trees 5 -> 2 (k=1), rho_id 0.1 -> 0.3 (k=1)."""
    kw.setdefault("learning_rate", 0.1)
    return FedGBFConfig(
        rounds=rounds,
        n_trees_max=5, n_trees_min=2, n_trees_speed=1.0,
        rho_id_min=0.1, rho_id_max=0.3, rho_id_speed=1.0,
        rho_feat=1.0,
        **kw,
    )


def federated_forest_config(n_trees: int = 20, rho_id: float = 0.6, **kw) -> FedGBFConfig:
    """Federated Forest baseline (§2.1): pure bagging = one boosting round.

    A single round of N subsampled trees fit to the initial residual is
    exactly a random forest on (g, h) at y_hat = base_score.
    """
    return FedGBFConfig(
        rounds=1,
        learning_rate=1.0,
        n_trees_max=n_trees, n_trees_min=n_trees,
        rho_id_min=rho_id, rho_id_max=rho_id,
        **kw,
    )


_PACK_CACHE: "OrderedDict" = OrderedDict()  # id(model) -> (model, packed)


def _packed_for(model: EnsembleModel) -> PackedEnsemble:
    """Memoized pack_ensemble so repeated predict calls on the same model
    (metric sweeps, eval loops) do not re-concatenate the tree stacks.
    Bounded and identity-keyed (keeps the last few models alive — long-lived
    multi-model callers should pre-pack and pass PackedEnsemble directly)."""
    if isinstance(model.bin_edges, jax.core.Tracer):
        return pack_ensemble(model)  # under jit tracing: never cache tracers
    key = id(model)
    hit = _PACK_CACHE.get(key)
    if hit is not None and hit[0] is model:
        return hit[1]
    packed = pack_ensemble(model)
    _PACK_CACHE[key] = (model, packed)
    while len(_PACK_CACHE) > 4:
        _PACK_CACHE.popitem(last=False)
    return packed


def predict(
    model: Union[EnsembleModel, PackedEnsemble],
    x: jnp.ndarray,
    impl: str = "packed",
) -> jnp.ndarray:
    """Raw-margin prediction F(x) = base + lr * sum_m mean_j T_mj(x) (Alg. 1 l.10).

    Routed through the ``PackedEnsemble`` layout (DESIGN.md §3): one
    traversal of all trees instead of an O(rounds) Python loop.  ``impl``:

      ``"packed"``        single vmapped traversal, exact per-round combiner
                          (bit-for-bit equal to the legacy loop) — default;
      ``"weighted"``      single-pass tree_scale combiner;
      ``"pallas"``        the Pallas ``ensemble_predict`` kernel on binned
                          inputs;
      ``"fused"``         serve-time binning fused INTO the traversal
                          (DESIGN.md §14): raw floats compare against
                          value-space thresholds, no separate binning
                          dispatch — leaf-routing-identical to binning +
                          ``"weighted"``;
      ``"fused-pallas"``  the fused path as one Pallas kernel sweep;
      ``"loop"``          the legacy per-round loop (kept for benchmarks).

    A ``QuantizedEnsemble`` (DESIGN.md §14) serves natively on the fused
    impls (leaf table dequantized in-graph); the binned impls widen it to
    the f32 packed layout first.
    """
    from repro.core import tree as tree_mod
    from repro.core.types import QuantizedEnsemble, dequantize_ensemble

    if impl == "loop":
        if isinstance(model, QuantizedEnsemble):
            model = dequantize_ensemble(model)
        return predict_loop(model, x)
    if isinstance(model, (PackedEnsemble, QuantizedEnsemble)):
        packed = model
    else:
        packed = _packed_for(model)
    if impl == "fused":
        return tree_mod.predict_packed_fused(packed, x)
    if impl == "fused-pallas":
        from repro.kernels.ensemble_predict.ops import (
            predict_packed_fused_pallas,
        )

        return predict_packed_fused_pallas(packed, x)
    if isinstance(packed, QuantizedEnsemble):
        packed = dequantize_ensemble(packed)
    binned = binning.bin_data(x, packed.bin_edges)
    if impl == "packed":
        return tree_mod.predict_packed(packed, binned)
    if impl == "weighted":
        return tree_mod.predict_packed_weighted(packed, binned)
    if impl == "pallas":
        from repro.kernels.ensemble_predict.ops import predict_packed_pallas

        return predict_packed_pallas(packed, binned)
    raise ValueError(f"unknown predict impl {impl!r}")


def predict_loop(
    model: Union[EnsembleModel, PackedEnsemble], x: jnp.ndarray
) -> jnp.ndarray:
    """Legacy O(rounds) per-round prediction loop.

    Superseded by the packed path; kept as the reference the packed path is
    asserted bit-for-bit equal to (tests/test_packed.py) and as the baseline
    in benchmarks/predict_bench.py.
    """
    from repro.core import tree as tree_mod
    from repro.core.types import unpack_ensemble

    if isinstance(model, PackedEnsemble):
        model = unpack_ensemble(model)
    binned = binning.bin_data(x, model.bin_edges)
    out = objective_mod.get_objective(model.loss).init_raw(
        x.shape[0], model.base_score
    )
    for trees in model.forests:
        out = out + model.learning_rate * tree_mod.predict_forest(
            trees, binned, model.max_depth
        )
    return out


def predict_proba(
    model: Union[EnsembleModel, PackedEnsemble],
    x: jnp.ndarray,
    impl: str = "packed",
) -> jnp.ndarray:
    """Prediction-space output: the model's objective activation applied to
    the raw margin (sigmoid for logistic, softmax for multiclass, identity
    for regression/quantile) — resolved from the registry, never hard-coded."""
    obj = objective_mod.get_objective(model.loss)
    return obj.activation(predict(model, x, impl=impl))
