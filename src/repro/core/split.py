"""Split finding: gain evaluation (eq. 1) and global argmax (Alg. 2 step 9).

The active party runs this on decrypted histograms. The same function is used
by the federated path — each party evaluates its feature shard, then the gains
are compared globally (see federation/aggregator.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import TreeConfig

NEG_INF = jnp.float32(-jnp.inf)


class SplitDecision(NamedTuple):
    feature: jnp.ndarray    # (num_nodes,) int32, -1 if no split
    threshold: jnp.ndarray  # (num_nodes,) int32; bin <= threshold goes left
    gain: jnp.ndarray       # (num_nodes,) float32 (NEG_INF/0 when no split)


def split_gains(hist: jnp.ndarray, cfg: TreeConfig) -> jnp.ndarray:
    """Gain of splitting each (node, feature) at each bin threshold.

    Args:
      hist: (num_nodes, d, B, 3) histogram — or (num_nodes, d, B, 2K+1) for
        K-channel objectives (per-class gains summed, diagonal hessian).
      cfg:  tree config (lambda_, gamma, min_child_weight).

    Returns:
      (num_nodes, d, B) float32 gains; invalid candidates are -inf.
      Threshold semantics: left = {bin <= b}.
    """
    num_bins = hist.shape[2]
    cum = jnp.cumsum(hist, axis=2)  # (nodes, d, B, S): left stats at threshold b
    total = cum[:, :, -1, :][:, :, None, :]  # (nodes, d, 1, S)
    lam = cfg.lambda_

    if hist.shape[-1] == 3:  # K = 1: the historical scalar-channel path
        gl, hl = cum[..., 0], cum[..., 1]
        gt, ht = total[..., 0], total[..., 1]
        gr, hr = gt - gl, ht - hl

        gain = 0.5 * (
            gl**2 / (hl + lam) + gr**2 / (hr + lam) - gt**2 / (ht + lam)
        ) - cfg.gamma
        hl_sum, hr_sum = hl, hr
    else:
        k = (hist.shape[-1] - 1) // 2
        gl, hl = cum[..., :k], cum[..., k:2 * k]
        gt, ht = total[..., :k], total[..., k:2 * k]
        gr, hr = gt - gl, ht - hl

        # Diagonal-hessian multiclass gain: per-class Newton gains summed
        # (the K independent leaf values share one structural split).
        gain = 0.5 * jnp.sum(
            gl**2 / (hl + lam) + gr**2 / (hr + lam) - gt**2 / (ht + lam),
            axis=-1,
        ) - cfg.gamma
        hl_sum, hr_sum = hl.sum(axis=-1), hr.sum(axis=-1)

    valid = (
        (hl_sum >= cfg.min_child_weight)
        & (hr_sum >= cfg.min_child_weight)
        # threshold == B-1 sends everything left: not a split
        & (jnp.arange(num_bins)[None, None, :] < num_bins - 1)
    )
    return jnp.where(valid, gain, NEG_INF)


def choose_splits(
    hist: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    feature_offset: int = 0,
) -> SplitDecision:
    """Pick the best (feature, threshold) per node from a histogram.

    Args:
      hist: (num_nodes, d, B, 3).
      feature_mask: (d,) bool — feature subsampling mask (Q_m(j) of eq. 4).
      feature_offset: global index of this histogram's first feature column
        (non-zero on passive parties evaluating a feature shard).

    Returns:
      SplitDecision with *global* feature indices. Nodes whose best gain is
      not positive get feature = -1 and threshold = B (routes all left).
    """
    num_nodes, d, num_bins, _ = hist.shape
    gains = split_gains(hist, cfg)  # (nodes, d, B)
    gains = jnp.where(feature_mask[None, :, None], gains, NEG_INF)

    flat = gains.reshape(num_nodes, d * num_bins)
    best = jnp.argmax(flat, axis=1)
    best_gain = jnp.take_along_axis(flat, best[:, None], axis=1)[:, 0]

    feature = (best // num_bins).astype(jnp.int32) + feature_offset
    threshold = (best % num_bins).astype(jnp.int32)

    has_split = best_gain > 0.0
    feature = jnp.where(has_split, feature, -1)
    threshold = jnp.where(has_split, threshold, num_bins)
    return SplitDecision(feature=feature, threshold=threshold, gain=best_gain)


def choose_splits_round(
    hist: jnp.ndarray,
    feature_mask: jnp.ndarray,
    cfg: TreeConfig,
    feature_offset: int = 0,
) -> SplitDecision:
    """Round-native ``choose_splits``: the tree axis is explicit.

    Args:
      hist: (T, num_nodes, d, B, 3) — one round's histograms.
      feature_mask: (T, d) bool per-tree feature masks.
    Returns:
      SplitDecision with (T, num_nodes) fields — per tree, the same
      per-node argmax ``choose_splits`` computes (vmapped, so tie-breaks
      and gain arithmetic are bit-identical to the per-tree path).
    """
    return jax.vmap(
        lambda ht, fm: choose_splits(ht, fm, cfg, feature_offset)
    )(hist, feature_mask)


def leaf_weights(hist_leaf: jnp.ndarray, cfg: TreeConfig) -> jnp.ndarray:
    """Optimal leaf weights w = -G / (H + lambda) (Alg. 2 step 14).

    Args:
      hist_leaf: (num_leaves, 3) aggregated (G, H, count) per leaf — or
        (num_leaves, 2K+1) for K-channel objectives (K leaf values/node).
    Returns:
      (num_leaves,) float32 — (num_leaves, K) at K > 1; empty leaves get 0.
    """
    if hist_leaf.shape[-1] == 3:  # K = 1: the historical scalar path
        g, h, c = hist_leaf[..., 0], hist_leaf[..., 1], hist_leaf[..., 2]
        w = -g / (h + cfg.lambda_)
        return jnp.where(c > 0, w, 0.0)
    k = (hist_leaf.shape[-1] - 1) // 2
    g, h = hist_leaf[..., :k], hist_leaf[..., k:2 * k]
    c = hist_leaf[..., -1]
    w = -g / (h + cfg.lambda_)
    return jnp.where((c > 0)[..., None], w, 0.0)
