"""Core datatypes for the FedGBF tree-ensemble library.

All tree structures are *fixed-topology complete binary trees* of static depth
``max_depth`` so that every builder/predictor is jittable and vmappable:

* internal nodes are stored level-order: level ``l`` occupies indices
  ``[2**l - 1, 2**(l+1) - 2]``; ``num_internal = 2**max_depth - 1``;
* ``feature == -1`` marks a node that did not split (its threshold is set to
  ``num_bins`` so every sample routes left, landing in the left-most
  descendant leaf, which carries the node's weight);
* leaves are the ``2**max_depth`` slots of the final level.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class TreeArrays(NamedTuple):
    """A single decision tree (or a stack of them when vmapped)."""

    feature: jnp.ndarray      # (num_internal,) int32 — split feature, -1 = leaf-through
    threshold: jnp.ndarray    # (num_internal,) int32 — go left iff bin <= threshold
    gain: jnp.ndarray         # (num_internal,) float32 — split gain (eq. 1)
    leaf_weight: jnp.ndarray  # (2**max_depth,) float32 — XGBoost leaf weights;
    #                           (2**max_depth, K) for K-channel objectives
    #                           (DESIGN.md §11: one leaf value per class)


def forest_size(trees: TreeArrays) -> int:
    """Number of trees in a stacked forest (leading axis of every field)."""
    return int(trees.feature.shape[0])


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static hyper-parameters of a single decision tree (Alg. 2)."""

    max_depth: int = 3
    num_bins: int = 32
    lambda_: float = 1.0          # L2 regulariser on leaf weights
    gamma: float = 0.0            # minimum gain to split (eq. 1's gamma)
    min_child_weight: float = 1e-3

    # Sibling-subtraction histogram pipeline (DESIGN.md §6): at levels >= 1
    # compute only the LEFT-child histograms (half-frontier width) and derive
    # every right sibling as parent - left.  Halves per-level histogram
    # compute/memory and — on the federated path — the dominant VFL message.
    # Default ON (the ROADMAP flip: the tolerance contract held across
    # platforms); False restores the direct full-frontier pass, which stays
    # the reference oracle the subtraction path is tested against
    # (float-reassociation tolerance; the federated-vs-centralized contract
    # stays bit-exact with the switch set the same on both sides).
    hist_subtraction: bool = True

    # Frontier compaction (round engine, DESIGN.md §9): static per-level
    # budget of *live* frontier nodes for max_depth > 3.  0 = uncompacted
    # (the full 2^level frontier).  When a level's width exceeds the budget,
    # live nodes (non-empty AND split-reachable — a parent that did not
    # split determines all its descendants, so they are dead for histogram
    # purposes) are gathered into dense slots; dead nodes are masked out of
    # histograms, the party exchange, and the wire/Paillier cost models.
    # Trees are bit-identical to the uncompacted build whenever the live
    # count fits the budget; overflow drops the highest-node-id live nodes
    # (they fall through as unsplit, routing left).
    max_active_nodes: int = 0

    # Shared-root caching (round engine, DESIGN.md §9): the level-0 pass of
    # a round computes ONE unmasked histogram shared by all T trees and
    # derives each tree's root as ``shared − delta(masked-out rows)``.  The
    # engines enable the delta path per round/segment only when the sampled
    # share is high enough to win (rho_id >= 0.5 crossover, uniform
    # sampling) — see ``boosting``'s ``root_delta_rows`` threading.  A
    # float-reassociation tolerance lever like hist_subtraction (off keeps
    # the round engine bit-identical to the per-tree path).
    shared_root: bool = False

    @property
    def num_internal(self) -> int:
        return 2 ** self.max_depth - 1

    @property
    def num_leaves(self) -> int:
        return 2 ** self.max_depth

    def active_width(self, level: int) -> int:
        """Static live-slot budget of a level: ``min(2**level,
        max_active_nodes)`` (the full frontier when uncompacted)."""
        width = 2 ** level
        if self.max_active_nodes:
            return min(width, self.max_active_nodes)
        return width


@dataclasses.dataclass(frozen=True)
class FedGBFConfig:
    """FedGBF / Dynamic FedGBF training configuration (Algs. 1 & 3).

    ``n_trees_*`` and ``rho_id_*`` describe the dynamic schedules of
    §3.2.2; setting min == max recovers static FedGBF, and
    ``n_trees == 1, rho_id == 1`` recovers SecureBoost exactly.
    """

    rounds: int = 20                  # M, boosting rounds
    learning_rate: float = 0.1
    tree: TreeConfig = dataclasses.field(default_factory=TreeConfig)
    loss: str = "logistic"            # objective registry name (core/objective.py):
    #                                   "logistic" | "squared" | "quantile[@a]"
    #                                   | "softmax{K}"

    # Forest size schedule (dynamic decay, eq. 7): t_max -> t_min at speed t_k.
    n_trees_max: int = 5
    n_trees_min: int = 5
    n_trees_speed: float = 1.0

    # Sample-rate schedule (dynamic increase, eq. 6): S_min -> S_max, speed S_k.
    rho_id_min: float = 1.0
    rho_id_max: float = 1.0
    rho_id_speed: float = 1.0

    rho_feat: float = 1.0             # feature sampling rate (static in the paper)
    base_score: float = 0.0           # initial prediction (paper: y_hat^(0) = 0)

    # Sample-selection policy for the rho_id budget (DESIGN.md §5).
    # "uniform" — the paper's P_m(j) (eq. 4): uniform without replacement;
    # "goss"    — gradient-based one-side sampling (LightGBM / SecureBoost+):
    #             the top-|g| share of the budget is kept deterministically,
    #             the rest is drawn uniformly from the remaining samples and
    #             amplified by (n - n_top) / n_rand so histogram stats stay
    #             unbiased.  Same rho_id schedule, same prefix-stable keys
    #             (core/forest.py: goss_masks_from_keys).
    sampling: str = "uniform"
    goss_top_share: float = 0.5       # fraction of the rho_id budget kept by |g|


class EnsembleModel(NamedTuple):
    """A trained (Dynamic) FedGBF model: one forest per boosting round.

    Rounds may have different tree counts (dynamic schedule), so forests live
    in a Python tuple (of stacked TreeArrays) rather than one array.
    """

    forests: tuple               # tuple[TreeArrays, ...], each with leading tree axis
    learning_rate: float
    base_score: float
    bin_edges: jnp.ndarray       # (d, num_bins - 1) — quantile edges used in training
    loss: str
    max_depth: int

    @property
    def rounds(self) -> int:
        return len(self.forests)

    @property
    def total_trees(self) -> int:
        return sum(forest_size(f) for f in self.forests)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedEnsemble:
    """Inference-optimal ensemble layout (DESIGN.md §3).

    All rounds' stacked ``TreeArrays`` are flattened into one contiguous
    ``(total_trees, ...)`` pytree so prediction is a *single* vmapped (or
    Pallas ``ensemble_predict``) traversal instead of an O(rounds) Python
    loop.  Round structure survives as static metadata:

      * ``round_offsets`` — tree-index boundaries (len rounds+1); round ``r``
        owns trees ``[round_offsets[r], round_offsets[r+1])``.  Static so the
        exact per-round bagging-mean combiner stays shape-static under jit.
      * ``tree_scale`` — per-tree contribution ``lr / n_trees(round)``; the
        weighted single-pass combiner ``margin = base + tree_scale @ per_tree``
        is algebraically identical to the per-round means and is what the
        Pallas kernel accumulates.

    Registered as a pytree: array fields are leaves, everything else is
    static aux data — so a PackedEnsemble can be passed straight through
    ``jax.jit`` (serving) and ``checkpoint.io`` (persistence).
    """

    feature: jnp.ndarray      # (total_trees, num_internal) int32
    threshold: jnp.ndarray    # (total_trees, num_internal) int32
    gain: jnp.ndarray         # (total_trees, num_internal) float32
    leaf_weight: jnp.ndarray  # (total_trees, num_leaves[, K]) float32
    tree_scale: jnp.ndarray   # (total_trees,) float32 = lr / n_trees(round)
    bin_edges: jnp.ndarray    # (d, num_bins - 1) training quantile edges
    round_offsets: tuple      # static: (rounds + 1,) tree-index boundaries
    learning_rate: float
    base_score: float
    loss: str
    max_depth: int

    @property
    def rounds(self) -> int:
        return len(self.round_offsets) - 1

    @property
    def total_trees(self) -> int:
        return int(self.round_offsets[-1])

    def trees(self) -> TreeArrays:
        """The flat (total_trees, ...) stack as a TreeArrays view."""
        return TreeArrays(
            feature=self.feature, threshold=self.threshold,
            gain=self.gain, leaf_weight=self.leaf_weight,
        )

    def round_trees(self, r: int) -> TreeArrays:
        """Round ``r``'s stacked TreeArrays (for explain/debug tooling)."""
        s, e = self.round_offsets[r], self.round_offsets[r + 1]
        return TreeArrays(
            feature=self.feature[s:e], threshold=self.threshold[s:e],
            gain=self.gain[s:e], leaf_weight=self.leaf_weight[s:e],
        )

    # -- pytree protocol: arrays are leaves, the rest is static aux ---------
    def tree_flatten(self):
        leaves = (self.feature, self.threshold, self.gain,
                  self.leaf_weight, self.tree_scale, self.bin_edges)
        aux = (self.round_offsets, self.learning_rate, self.base_score,
               self.loss, self.max_depth)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


def pack_ensemble(model: EnsembleModel) -> PackedEnsemble:
    """Flatten an EnsembleModel into the packed inference layout."""
    offsets = [0]
    for f in model.forests:
        offsets.append(offsets[-1] + forest_size(f))
    scales = jnp.concatenate([
        jnp.full((forest_size(f),), model.learning_rate / forest_size(f),
                 jnp.float32)
        for f in model.forests
    ])
    cat = lambda field: jnp.concatenate([getattr(f, field) for f in model.forests])
    return PackedEnsemble(
        feature=cat("feature"),
        threshold=cat("threshold"),
        gain=cat("gain"),
        leaf_weight=cat("leaf_weight"),
        tree_scale=scales,
        bin_edges=model.bin_edges,
        round_offsets=tuple(offsets),
        learning_rate=model.learning_rate,
        base_score=model.base_score,
        loss=model.loss,
        max_depth=model.max_depth,
    )


def unpack_ensemble(packed: PackedEnsemble) -> EnsembleModel:
    """Inverse of ``pack_ensemble`` (lossless round-trip)."""
    return EnsembleModel(
        forests=tuple(packed.round_trees(r) for r in range(packed.rounds)),
        learning_rate=packed.learning_rate,
        base_score=packed.base_score,
        bin_edges=packed.bin_edges,
        loss=packed.loss,
        max_depth=packed.max_depth,
    )


# ---------------------------------------------------------------------------
# Serving tables: fused bin+traverse + the quantized ensemble variant
# (DESIGN.md §14)
# ---------------------------------------------------------------------------

#: Sentinel threshold for unsplit nodes in the VALUE-space threshold table.
#: Finite (float32 max) rather than +inf: the Pallas traversal reads node
#: params through one-hot contractions, and ``0 * inf = NaN`` would poison
#: the selected lane.  Any real feature value (post-sanitization) compares
#: ``<= FLOAT_MAX``, so the node routes every sample left — the same
#: semantics as the bin-space ``threshold == num_bins`` sentinel.
FLOAT_MAX = float(jnp.finfo(jnp.float32).max)


def float_thresholds(feature: jnp.ndarray, threshold: jnp.ndarray,
                     bin_edges: jnp.ndarray) -> jnp.ndarray:
    """Value-space split thresholds for the fused bin+traverse serving path.

    Training stores bin-space thresholds: go left iff ``bin(v) <= t`` where
    ``bin(v) = searchsorted(edges, v, side="left")`` counts edges strictly
    below ``v``.  That predicate is *exactly* ``v <= edges[f, t]`` (including
    duplicate edges and values landing exactly on an edge), so serving can
    compare raw floats against ``edges[feature, threshold]`` and skip the
    binning pass entirely — one program instead of two, bit-identical leaf
    routing.  Valid split thresholds satisfy ``t <= B - 2`` (``split.py``:
    ``t == B - 1`` sends everything left and is never chosen), so the gather
    is always in range; unsplit nodes (``feature == -1`` / ``t == B``) get
    the ``FLOAT_MAX`` route-left sentinel.

    Args:
      feature / threshold: (T, I) int32 packed node tables.
      bin_edges: (d, B - 1) float32 training quantile edges.
    Returns:
      (T, I) float32 value-space thresholds.
    """
    num_bins = bin_edges.shape[1] + 1
    t = jnp.clip(threshold, 0, num_bins - 2)
    vals = bin_edges[jnp.clip(feature, 0, None), t]
    is_split = (feature >= 0) & (threshold <= num_bins - 2)
    return jnp.where(is_split, vals, FLOAT_MAX).astype(jnp.float32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedEnsemble:
    """int8/int16 serving variant of ``PackedEnsemble`` (DESIGN.md §14).

    The numeric tables shrink the way SecureBoost+ packs its GBDT wire
    payloads: *structure* stays lossless, only *leaf values* are lossy.

      * ``feature`` narrows to int16 and ``threshold`` to int8/int16 —
        LOSSLESS: thresholds are bin ids in ``[0, num_bins]`` and features
        are column ids, both exactly representable (asserted on quantize);
      * ``leaf_q`` is the leaf table stochastically rounded through
        ``federation.compress.quantize_stats`` with one ``leaf_scale`` per
        tree (per channel when the table is K-wide) — the same unbiased
        floor(x/s + u) machinery the VFL histogram wire uses;
      * the gain table is dropped (serving never reads it).

    Because routing is bit-identical to the f32 oracle, the only score error
    is the leaf rounding, which gives the *provable* margin bound of
    ``margin_delta_bound``: ``|margin_q - margin_f32| <=
    sum_t tree_scale[t] * leaf_scale[t]`` (each leaf is off by < 1 quantum).

    Registered as a pytree (arrays = leaves, the rest static aux) so it
    passes straight through ``jax.jit`` serving and ``checkpoint.io``.
    """

    feature: jnp.ndarray      # (total_trees, num_internal) int16
    threshold: jnp.ndarray    # (total_trees, num_internal) int8/int16
    leaf_q: jnp.ndarray       # (total_trees, num_leaves[, K]) int8/int16
    leaf_scale: jnp.ndarray   # (total_trees,[ K]) float32 per-tree quantum
    tree_scale: jnp.ndarray   # (total_trees,) float32 = lr / n_trees(round)
    bin_edges: jnp.ndarray    # (d, num_bins - 1) float32 training edges
    bits: int                 # static: 8 or 16
    round_offsets: tuple
    learning_rate: float
    base_score: float
    loss: str
    max_depth: int

    @property
    def rounds(self) -> int:
        return len(self.round_offsets) - 1

    @property
    def total_trees(self) -> int:
        return int(self.round_offsets[-1])

    def tree_flatten(self):
        leaves = (self.feature, self.threshold, self.leaf_q,
                  self.leaf_scale, self.tree_scale, self.bin_edges)
        aux = (self.bits, self.round_offsets, self.learning_rate,
               self.base_score, self.loss, self.max_depth)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


def quantize_ensemble(packed: PackedEnsemble, bits: int = 8,
                      key=None, stochastic: bool = True) -> QuantizedEnsemble:
    """Quantize a packed ensemble for serving (int8/int16 tables).

    Reuses ``federation.compress.quantize_stats`` for the leaf table
    (stochastic rounding by default; ``key`` defaults to PRNGKey(0) so the
    call is deterministic unless the caller varies it).  Thresholds and
    features round-trip exactly — a narrowing that loses a single id raises
    instead of serving a silently different model.
    """
    from repro.federation import compress  # local: compress imports types

    if bits not in (8, 16):
        raise ValueError(f"bits must be 8 or 16, got {bits}")
    if key is None:
        key = jax.random.PRNGKey(0)
    num_bins = packed.bin_edges.shape[1] + 1
    thr_dtype = jnp.int8 if num_bins <= 126 else jnp.int16
    feature = packed.feature.astype(jnp.int16)
    threshold = packed.threshold.astype(thr_dtype)
    if not bool(jnp.all(feature.astype(jnp.int32) == packed.feature)):
        raise ValueError("feature ids do not fit int16")
    if not bool(jnp.all(threshold.astype(jnp.int32) == packed.threshold)):
        raise ValueError(f"bin thresholds do not fit {thr_dtype.__name__}")
    lw = packed.leaf_weight
    lw3 = lw[..., None] if lw.ndim == 2 else lw  # (T, L, K)
    q, scale = compress.quantize_stats(lw3, bits, key, stochastic=stochastic)
    if lw.ndim == 2:
        q, scale = q[..., 0], scale[..., 0]      # (T, L), (T,)
    return QuantizedEnsemble(
        feature=feature,
        threshold=threshold,
        leaf_q=q,
        leaf_scale=scale,
        tree_scale=packed.tree_scale,
        bin_edges=packed.bin_edges,
        bits=bits,
        round_offsets=packed.round_offsets,
        learning_rate=packed.learning_rate,
        base_score=packed.base_score,
        loss=packed.loss,
        max_depth=packed.max_depth,
    )


def dequantize_leaf(q: QuantizedEnsemble) -> jnp.ndarray:
    """f32 leaf table of a quantized ensemble: ``leaf_q * leaf_scale``
    broadcast per tree (per channel when K-wide)."""
    if q.leaf_q.ndim == 2:
        return q.leaf_q.astype(jnp.float32) * q.leaf_scale[:, None]
    return q.leaf_q.astype(jnp.float32) * q.leaf_scale[:, None, :]


def dequantize_ensemble(q: QuantizedEnsemble) -> PackedEnsemble:
    """Widen a quantized ensemble back to the f32 packed layout.

    Routing tables round-trip exactly; the leaf table carries the rounding
    error bounded by ``margin_delta_bound``.  The gain table (dropped at
    quantize time) comes back as zeros — explain tooling should use the f32
    checkpoint.
    """
    return PackedEnsemble(
        feature=q.feature.astype(jnp.int32),
        threshold=q.threshold.astype(jnp.int32),
        gain=jnp.zeros(q.feature.shape, jnp.float32),
        leaf_weight=dequantize_leaf(q),
        tree_scale=q.tree_scale,
        bin_edges=q.bin_edges,
        round_offsets=q.round_offsets,
        learning_rate=q.learning_rate,
        base_score=q.base_score,
        loss=q.loss,
        max_depth=q.max_depth,
    )


def margin_delta_bound(q: QuantizedEnsemble) -> float:
    """Provable |quantized − f32| margin bound (worst case over any input).

    Every leaf entry is off by < 1 quantum (``leaf_scale[t]``; stochastic
    floor(x/s + u) and round-to-nearest both land within one step, the clip
    at ±qmax only ever moves values back toward the true one), a sample
    reads exactly ONE leaf per tree, and tree contributions are
    ``tree_scale``-weighted sums — so the margin error is at most
    ``sum_t tree_scale[t] * max_k leaf_scale[t, k]``.
    """
    per_tree = q.leaf_scale
    if per_tree.ndim == 2:                      # K-channel: worst channel
        per_tree = jnp.max(per_tree, axis=-1)
    return float(jnp.sum(q.tree_scale * per_tree))


def serving_tables(model) -> tuple:
    """Resolve any ensemble variant into the fused-serving node tables.

    Returns ``(feature i32 (T, I), thr_value f32 (T, I), leaf f32
    (T, L[, K]), tree_scale f32 (T,))`` — value-space thresholds via
    ``float_thresholds`` and, for a ``QuantizedEnsemble``, the leaf table
    dequantized *in-graph* (XLA folds the widening into the traversal, so
    one f32 program serves both variants and the int8 checkpoint stays
    small at rest and on the wire).
    """
    if isinstance(model, QuantizedEnsemble):
        leaf = dequantize_leaf(model)
    else:
        leaf = model.leaf_weight
    feature = model.feature.astype(jnp.int32)
    thr = float_thresholds(feature, model.threshold.astype(jnp.int32),
                           model.bin_edges)
    return feature, thr, leaf.astype(jnp.float32), model.tree_scale
