"""Core datatypes for the FedGBF tree-ensemble library.

All tree structures are *fixed-topology complete binary trees* of static depth
``max_depth`` so that every builder/predictor is jittable and vmappable:

* internal nodes are stored level-order: level ``l`` occupies indices
  ``[2**l - 1, 2**(l+1) - 2]``; ``num_internal = 2**max_depth - 1``;
* ``feature == -1`` marks a node that did not split (its threshold is set to
  ``num_bins`` so every sample routes left, landing in the left-most
  descendant leaf, which carries the node's weight);
* leaves are the ``2**max_depth`` slots of the final level.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp


class TreeArrays(NamedTuple):
    """A single decision tree (or a stack of them when vmapped)."""

    feature: jnp.ndarray      # (num_internal,) int32 — split feature, -1 = leaf-through
    threshold: jnp.ndarray    # (num_internal,) int32 — go left iff bin <= threshold
    gain: jnp.ndarray         # (num_internal,) float32 — split gain (eq. 1)
    leaf_weight: jnp.ndarray  # (2**max_depth,) float32 — XGBoost leaf weights


def forest_size(trees: TreeArrays) -> int:
    """Number of trees in a stacked forest (leading axis of every field)."""
    return int(trees.feature.shape[0])


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static hyper-parameters of a single decision tree (Alg. 2)."""

    max_depth: int = 3
    num_bins: int = 32
    lambda_: float = 1.0          # L2 regulariser on leaf weights
    gamma: float = 0.0            # minimum gain to split (eq. 1's gamma)
    min_child_weight: float = 1e-3

    @property
    def num_internal(self) -> int:
        return 2 ** self.max_depth - 1

    @property
    def num_leaves(self) -> int:
        return 2 ** self.max_depth


@dataclasses.dataclass(frozen=True)
class FedGBFConfig:
    """FedGBF / Dynamic FedGBF training configuration (Algs. 1 & 3).

    ``n_trees_*`` and ``rho_id_*`` describe the dynamic schedules of
    §3.2.2; setting min == max recovers static FedGBF, and
    ``n_trees == 1, rho_id == 1`` recovers SecureBoost exactly.
    """

    rounds: int = 20                  # M, boosting rounds
    learning_rate: float = 0.1
    tree: TreeConfig = dataclasses.field(default_factory=TreeConfig)
    loss: str = "logistic"            # "logistic" | "squared"

    # Forest size schedule (dynamic decay, eq. 7): t_max -> t_min at speed t_k.
    n_trees_max: int = 5
    n_trees_min: int = 5
    n_trees_speed: float = 1.0

    # Sample-rate schedule (dynamic increase, eq. 6): S_min -> S_max, speed S_k.
    rho_id_min: float = 1.0
    rho_id_max: float = 1.0
    rho_id_speed: float = 1.0

    rho_feat: float = 1.0             # feature sampling rate (static in the paper)
    base_score: float = 0.0           # initial prediction (paper: y_hat^(0) = 0)


class EnsembleModel(NamedTuple):
    """A trained (Dynamic) FedGBF model: one forest per boosting round.

    Rounds may have different tree counts (dynamic schedule), so forests live
    in a Python tuple (of stacked TreeArrays) rather than one array.
    """

    forests: tuple               # tuple[TreeArrays, ...], each with leading tree axis
    learning_rate: float
    base_score: float
    bin_edges: jnp.ndarray       # (d, num_bins - 1) — quantile edges used in training
    loss: str
    max_depth: int

    @property
    def rounds(self) -> int:
        return len(self.forests)

    @property
    def total_trees(self) -> int:
        return sum(forest_size(f) for f in self.forests)
