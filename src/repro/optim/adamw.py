"""Functional AdamW + cosine LR schedule (no external optimizer deps).

Moments are stored in the parameter dtype by default so the optimizer-state
footprint is controllable per-architecture (big bf16 models keep bf16 moments
— the trade-off recorded in DESIGN.md hardware-adaptation notes).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p_new = p.astype(jnp.float32) - lr * (update + weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    params_new = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return params_new, AdamWState(step=step, m=m_new, v=v_new)


def cosine_lr(step, *, peak: float, warmup: int, total: int, floor: float = 0.0):
    t = step.astype(jnp.float32)
    warm = peak * t / max(warmup, 1)
    frac = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(math.pi * frac))
    return jnp.where(t < warmup, warm, cos)
