"""Vertical partitioning of tabular data across parties (Table 1 / FATE-style).

In VFL every party holds the same rows (after private-set-intersection
alignment, which we model as an id-sorted join) but a disjoint *column* slice.
The active party (party 0) additionally holds the labels.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np


class VerticalPartition(NamedTuple):
    """Column ownership: party p owns columns [offsets[p], offsets[p+1])."""

    offsets: tuple  # len = num_parties + 1, offsets[0] == 0
    num_features: int

    @property
    def num_parties(self) -> int:
        return len(self.offsets) - 1

    def columns(self, party: int) -> slice:
        return slice(self.offsets[party], self.offsets[party + 1])

    def owner_of(self, feature: int) -> int:
        """Which party owns a global feature index."""
        for p in range(self.num_parties):
            if self.offsets[p] <= feature < self.offsets[p + 1]:
                return p
        raise IndexError(feature)

    def dims(self) -> tuple:
        return tuple(
            self.offsets[p + 1] - self.offsets[p] for p in range(self.num_parties)
        )


def partition_from_dims(dims: Sequence[int]) -> VerticalPartition:
    offsets = [0]
    for d in dims:
        offsets.append(offsets[-1] + int(d))
    return VerticalPartition(offsets=tuple(offsets), num_features=offsets[-1])


def even_partition(num_features: int, num_parties: int) -> VerticalPartition:
    """Equal column shards — the layout the shard_map runtime uses, where the
    party axis is a mesh axis and every shard must have identical width.
    Features are padded (by the caller) when d % parties != 0."""
    if num_features % num_parties != 0:
        raise ValueError(
            f"{num_features} features do not shard evenly over {num_parties} "
            "parties; pad columns first (see pad_features)."
        )
    w = num_features // num_parties
    return partition_from_dims([w] * num_parties)


def pad_features(x: np.ndarray, num_parties: int) -> tuple[np.ndarray, int]:
    """Right-pad with constant columns so d % num_parties == 0.

    Constant columns can never be chosen by split finding (zero gain), so
    padding is semantically inert; returns (padded_x, d_padded).
    """
    n, d = x.shape
    rem = (-d) % num_parties
    if rem == 0:
        return x, d
    pad = np.zeros((n, rem), dtype=x.dtype)
    return np.concatenate([x, pad], axis=1), d + rem


def aligned_intersection(ids_a: np.ndarray, ids_b: np.ndarray) -> np.ndarray:
    """Private-set-intersection stand-in: sorted intersection of sample ids.

    The real protocol (Liang & Chawathe 2004) reveals only the intersection;
    computationally that is exactly np.intersect1d, which is what both sides
    end up ordering their rows by.
    """
    return np.intersect1d(ids_a, ids_b)
