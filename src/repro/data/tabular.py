"""Vertical partitioning of tabular data across parties (Table 1 / FATE-style).

In VFL every party holds the same rows (after private-set-intersection
alignment, which we model as an id-sorted join) but a disjoint *column* slice.
The active party (party 0) additionally holds the labels.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np


class VerticalPartition(NamedTuple):
    """Column ownership: party p owns columns [offsets[p], offsets[p+1])."""

    offsets: tuple  # len = num_parties + 1, offsets[0] == 0
    num_features: int

    @property
    def num_parties(self) -> int:
        return len(self.offsets) - 1

    def columns(self, party: int) -> slice:
        return slice(self.offsets[party], self.offsets[party + 1])

    def owner_of(self, feature: int) -> int:
        """Which party owns a global feature index."""
        for p in range(self.num_parties):
            if self.offsets[p] <= feature < self.offsets[p + 1]:
                return p
        raise IndexError(feature)

    def dims(self) -> tuple:
        return tuple(
            self.offsets[p + 1] - self.offsets[p] for p in range(self.num_parties)
        )


def partition_from_dims(dims: Sequence[int]) -> VerticalPartition:
    offsets = [0]
    for d in dims:
        offsets.append(offsets[-1] + int(d))
    return VerticalPartition(offsets=tuple(offsets), num_features=offsets[-1])


def even_partition(num_features: int, num_parties: int) -> VerticalPartition:
    """Equal column shards — the layout the shard_map runtime uses, where the
    party axis is a mesh axis and every shard must have identical width.
    Features are padded (by the caller) when d % parties != 0."""
    if num_features % num_parties != 0:
        raise ValueError(
            f"{num_features} features do not shard evenly over {num_parties} "
            "parties; pad columns first (see pad_features)."
        )
    w = num_features // num_parties
    return partition_from_dims([w] * num_parties)


def pad_features(x: np.ndarray, num_parties: int) -> tuple[np.ndarray, int]:
    """Right-pad with constant columns so d % num_parties == 0.

    Constant columns can never be chosen by split finding (zero gain), so
    padding is semantically inert; returns (padded_x, d_padded).
    """
    n, d = x.shape
    rem = (-d) % num_parties
    if rem == 0:
        return x, d
    pad = np.zeros((n, rem), dtype=x.dtype)
    return np.concatenate([x, pad], axis=1), d + rem


def load_csv(
    path: str,
    label_col: str | int = -1,
    train_frac: float = 0.7,
    seed: int = 0,
    max_rows: int | None = None,
):
    """Real tabular loader: a labelled CSV → the ``synthetic.Dataset`` shape.

    Grounds the benchmarks' AUC deltas on real data (the synthetic credit
    generator stays the CI default — see ``benchmarks/comm_bench.py
    --dataset``).  numpy-only on purpose: no pandas dependency.

    Args:
      path: CSV file with one header row; numeric feature columns.  Blank /
        non-numeric cells load as NaN (the binning path is NaN-safe:
        nanquantile edges + the dedicated NAN_BIN).
      label_col: header name or column index of the binary/regression
        label (default: the last column).
      train_frac: train share of the 7:3-style shuffled split (paper §4.1).
      seed: shuffle seed.
      max_rows: optional row cap (subsampled after shuffle).

    Returns:
      ``repro.data.synthetic.Dataset`` (x_train, y_train, x_test, y_test,
      name, active_dims) with active_dims = ceil(d / 2) — the Table-1-style
      "active party holds about half the columns" default; callers doing a
      real vertical split re-partition with ``partition_from_dims``.
    """
    from repro.data.synthetic import Dataset  # local: synthetic is numpy-only

    with open(path) as f:
        header = f.readline().strip().split(",")
    raw = np.genfromtxt(path, delimiter=",", skip_header=1, dtype=np.float64)
    if raw.ndim == 1:
        raw = raw[:, None]
    if isinstance(label_col, str):
        if label_col not in header:
            raise ValueError(
                f"label column {label_col!r} not in CSV header {header}"
            )
        label_idx = header.index(label_col)
    else:
        label_idx = label_col % len(header)
    y = raw[:, label_idx].astype(np.float32)
    x = np.delete(raw, label_idx, axis=1).astype(np.float32)
    keep = ~np.isnan(y)
    x, y = x[keep], y[keep]

    rng = np.random.default_rng(seed)
    perm = rng.permutation(x.shape[0])
    if max_rows is not None:
        perm = perm[:max_rows]
    x, y = x[perm], y[perm]
    k = int(train_frac * x.shape[0])
    name = path.rsplit("/", 1)[-1]
    return Dataset(
        x_train=x[:k], y_train=y[:k], x_test=x[k:], y_test=y[k:],
        name=f"csv:{name}", active_dims=(x.shape[1] + 1) // 2,
    )


def aligned_intersection(ids_a: np.ndarray, ids_b: np.ndarray) -> np.ndarray:
    """Private-set-intersection stand-in: sorted intersection of sample ids.

    The real protocol (Liang & Chawathe 2004) reveals only the intersection;
    computationally that is exactly np.intersect1d, which is what both sides
    end up ordering their rows by.
    """
    return np.intersect1d(ids_a, ids_b)
