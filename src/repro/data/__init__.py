from repro.data import synthetic, tabular  # noqa: F401
