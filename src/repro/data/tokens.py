"""Synthetic token pipeline for the LM substrate.

Offline-friendly corpus: a character-level Zipfian Markov source with
long-range copy structure (so the loss actually decreases with context) —
enough signal for the ~100M-model end-to-end driver without external data.
Batches are host-generated numpy, device_put with the activation sharding by
the caller (launch/train.py).
"""

from __future__ import annotations

from typing import Iterator

import numpy as np


class MarkovZipfSource:
    """Order-1 Markov chain with Zipf marginals + periodic copy spans."""

    def __init__(self, vocab: int, seed: int = 0, copy_period: int = 64,
                 copy_len: int = 16):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        k = min(vocab, 512)  # dense transition over the frequent head
        base = 1.0 / (np.arange(1, k + 1) ** 1.1)
        self.head = k
        trans = rng.dirichlet(base * 50, size=k)
        self.trans_cum = np.cumsum(trans, axis=1)
        self.copy_period = copy_period
        self.copy_len = copy_len

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        state = int(rng.integers(0, self.head))
        for i in range(length):
            if self.copy_period and i % self.copy_period == 0 and i >= self.copy_len:
                # copy span: repeat a recent window (gives context signal)
                span = out[i - self.copy_len : i]
                end = min(i + self.copy_len, length)
                out[i:end] = span[: end - i]
                if end == length:
                    break
                state = int(out[end - 1]) % self.head
                continue
            u = rng.random()
            state = int(np.searchsorted(self.trans_cum[state], u))
            state = min(state, self.head - 1)
            out[i] = state
        return out


def batches(
    vocab: int,
    batch_size: int,
    seq_len: int,
    seed: int = 0,
    num_batches: int | None = None,
) -> Iterator[dict]:
    """Yields {tokens (B,S) int32, labels (B,S) int32} next-token pairs."""
    src = MarkovZipfSource(vocab, seed)
    rng = np.random.default_rng(seed + 1)
    i = 0
    while num_batches is None or i < num_batches:
        seq = np.stack(
            [src.sample(rng, seq_len + 1) for _ in range(batch_size)]
        )
        yield {"tokens": seq[:, :-1].astype(np.int32),
               "labels": seq[:, 1:].astype(np.int32)}
        i += 1
