"""Synthetic stand-ins for the paper's benchmark datasets (§4.1).

The Kaggle datasets are not available offline (repro band 2/5 — data gate),
so we generate credit-risk-like data with the *same shape, class imbalance and
signal structure*: a sparse-logit ground truth with feature interactions,
heavy-tailed monetary features and missing-value spikes, which is what makes
tree ensembles the right model family on the real datasets.

  give_me_some_credit : 150 000 x 10, ~6.7 % positive rate
  default_credit_card : 30 000 x 23, ~22 % positive rate

All relative claims (FedGBF vs SecureBoost quality/efficiency) are evaluated
on these; absolute AUCs are reported but not compared against the paper's.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    name: str
    # Vertical split used by the paper (Table 1): active-party feature count.
    active_dims: int


def _credit_like(
    rng: np.random.Generator,
    n: int,
    d: int,
    pos_rate: float,
    interaction_pairs: int,
) -> tuple[np.ndarray, np.ndarray]:
    # Heavy-tailed monetary features + bounded utilisation ratios + counts.
    n_heavy = d // 3
    n_ratio = d // 3
    n_count = d - n_heavy - n_ratio

    heavy = rng.lognormal(mean=0.0, sigma=1.2, size=(n, n_heavy))
    ratio = rng.beta(2.0, 5.0, size=(n, n_ratio))
    count = rng.poisson(lam=3.0, size=(n, n_count)).astype(np.float64)
    x = np.concatenate([heavy, ratio, count], axis=1)

    # Missing-value spikes (credit bureaus): 5% of heavy features clamped to a
    # sentinel, which quantile binning must isolate into its own bin.
    miss = rng.random((n, n_heavy)) < 0.05
    x[:, :n_heavy][miss] = -1.0

    # Sparse logit with pairwise interactions and a non-monotone term.
    z = (x - x.mean(axis=0)) / (x.std(axis=0) + 1e-9)
    w = rng.normal(size=d) * (rng.random(d) < 0.7)
    logit = z @ w * 0.8
    for _ in range(interaction_pairs):
        i, j = rng.integers(0, d, size=2)
        logit += 0.5 * z[:, i] * z[:, j]
    k = rng.integers(0, d)
    logit += 0.6 * np.abs(z[:, k]) - 0.5
    logit += rng.normal(scale=0.8, size=n)

    # Calibrate the intercept to hit the target positive rate.
    logit_sorted = np.sort(logit)
    thresh = logit_sorted[int((1.0 - pos_rate) * n)]
    y = (logit > thresh).astype(np.float32)
    return x.astype(np.float32), y


def _split(x, y, rng, train_frac=0.7):
    """Paper §4.1: train/test divided 7:3."""
    n = x.shape[0]
    perm = rng.permutation(n)
    k = int(train_frac * n)
    tr, te = perm[:k], perm[k:]
    return x[tr], y[tr], x[te], y[te]


def give_me_some_credit(seed: int = 0, n: int = 150_000) -> Dataset:
    """150k x 10, ~6.7% positives, active party holds 5 of 10 dims (Table 1)."""
    rng = np.random.default_rng(seed)
    x, y = _credit_like(rng, n, 10, pos_rate=0.067, interaction_pairs=3)
    xt, yt, xe, ye = _split(x, y, rng)
    return Dataset(xt, yt, xe, ye, "give_me_some_credit", active_dims=5)


def default_credit_card(seed: int = 1, n: int = 30_000) -> Dataset:
    """30k x 23, ~22% positives, active party holds 13 of 23 dims (Table 1)."""
    rng = np.random.default_rng(seed)
    x, y = _credit_like(rng, n, 23, pos_rate=0.22, interaction_pairs=5)
    xt, yt, xe, ye = _split(x, y, rng)
    return Dataset(xt, yt, xe, ye, "default_credit_card", active_dims=13)


def credit_risk_tiers(seed: int = 2, n: int = 20_000) -> Dataset:
    """20k x 12, THREE risk tiers (low/watch/default) — multiclass workload.

    Same credit-like feature generator as the binary datasets; the latent
    logit is cut at its 60th/85th percentiles into ordinal tiers, so the
    class structure is feature-driven (not random labels) and imbalanced
    like real delinquency buckets (~60/25/15).  Labels are float class ids
    {0, 1, 2} for the ``softmax3`` objective (DESIGN.md §11).
    """
    rng = np.random.default_rng(seed)
    d = 12
    x, _ = _credit_like(rng, n, d, pos_rate=0.5, interaction_pairs=4)
    z = (x - x.mean(axis=0)) / (x.std(axis=0) + 1e-9)
    w = rng.normal(size=d) * (rng.random(d) < 0.7)
    logit = z @ w * 0.8
    for _ in range(4):
        i, j = rng.integers(0, d, size=2)
        logit += 0.5 * z[:, i] * z[:, j]
    logit += rng.normal(scale=0.6, size=n)
    lo, hi = np.quantile(logit, [0.60, 0.85])
    y = (logit > lo).astype(np.float32) + (logit > hi).astype(np.float32)
    xt, yt, xe, ye = _split(x, y, rng)
    return Dataset(xt, yt, xe, ye, "credit_risk_tiers", active_dims=6)


DATASETS = {
    "give_me_some_credit": give_me_some_credit,
    "default_credit_card": default_credit_card,
    "credit_risk_tiers": credit_risk_tiers,
}


def load(name: str, seed: int = 0, n: int | None = None) -> Dataset:
    fn = DATASETS[name]
    return fn(seed=seed) if n is None else fn(seed=seed, n=n)
