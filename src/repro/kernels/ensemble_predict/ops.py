"""Jitted wrappers: drop-ins for ``core.tree.predict_forest`` (bagging mean
of one forest layer) and ``core.tree.predict_packed_weighted`` (whole packed
ensemble in one kernel sweep)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import PackedEnsemble, TreeArrays
from repro.kernels.ensemble_predict.ensemble_predict import (
    predict_forest_pallas_call,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("max_depth", "tile_n", "interpret"))
def _scaled_ensemble_pallas(
    feature: jnp.ndarray,    # (n_trees, num_internal)
    threshold: jnp.ndarray,
    leaf: jnp.ndarray,       # (n_trees, num_leaves)
    scale: jnp.ndarray,      # (n_trees,)
    binned: jnp.ndarray,     # (n, d) int32
    max_depth: int,
    tile_n: int,
    interpret: bool,
) -> jnp.ndarray:
    n, _ = binned.shape
    n_pad = ((n + tile_n - 1) // tile_n) * tile_n
    binned_p = jnp.pad(binned, ((0, n_pad - n), (0, 0)))
    out = predict_forest_pallas_call(
        binned_p,
        feature.astype(jnp.int32),
        threshold.astype(jnp.int32),
        leaf.astype(jnp.float32),
        scale.astype(jnp.float32),
        max_depth=max_depth,
        tile_n=tile_n,
        interpret=interpret,
    )
    return out[:n]


def predict_forest_pallas(
    trees: TreeArrays,       # stacked: leading axis n_trees
    binned: jnp.ndarray,     # (n, d) int32
    max_depth: int,
    *,
    tile_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Bagging-mean forest prediction, (n,) float32."""
    if interpret is None:
        interpret = not _on_tpu()
    n_trees = trees.feature.shape[0]
    scale = jnp.full((n_trees,), 1.0 / n_trees, jnp.float32)
    return _scaled_ensemble_pallas(
        trees.feature, trees.threshold, trees.leaf_weight, scale, binned,
        max_depth, tile_n, interpret,
    )


def predict_packed_pallas(
    packed: PackedEnsemble,
    binned: jnp.ndarray,     # (n, d) int32
    *,
    tile_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Whole-ensemble raw margin in ONE kernel sweep, (n,) float32.

    The per-tree ``tree_scale`` (= lr / n_trees of the tree's round) folds
    the boosting learning rate and every round's bagging mean into the
    kernel's accumulation, so all ``total_trees`` trees ride a single grid.
    """
    if interpret is None:
        interpret = not _on_tpu()
    margin = _scaled_ensemble_pallas(
        packed.feature, packed.threshold, packed.leaf_weight,
        packed.tree_scale, binned, packed.max_depth, tile_n, interpret,
    )
    return packed.base_score + margin
