"""Jitted wrapper: drop-in for ``core.tree.predict_forest``."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import TreeArrays
from repro.kernels.ensemble_predict.ensemble_predict import (
    predict_forest_pallas_call,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("max_depth", "tile_n", "interpret"))
def predict_forest_pallas(
    trees: TreeArrays,       # stacked: leading axis n_trees
    binned: jnp.ndarray,     # (n, d) int32
    max_depth: int,
    *,
    tile_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Bagging-mean forest prediction, (n,) float32."""
    if interpret is None:
        interpret = not _on_tpu()
    n, d = binned.shape
    n_pad = ((n + tile_n - 1) // tile_n) * tile_n
    binned_p = jnp.pad(binned, ((0, n_pad - n), (0, 0)))
    out = predict_forest_pallas_call(
        binned_p,
        trees.feature.astype(jnp.int32),
        trees.threshold.astype(jnp.int32),
        trees.leaf_weight.astype(jnp.float32),
        max_depth=max_depth,
        tile_n=tile_n,
        interpret=interpret,
    )
    return out[:n]
