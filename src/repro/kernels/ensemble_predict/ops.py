"""Jitted wrappers: drop-ins for ``core.tree.predict_forest`` (bagging mean
of one forest layer) and ``core.tree.predict_packed_weighted`` (whole packed
ensemble in one kernel sweep)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import PackedEnsemble, TreeArrays, serving_tables
from repro.kernels.ensemble_predict.ensemble_predict import (
    predict_forest_pallas_call,
    predict_forest_raw_pallas_call,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("max_depth", "tile_n", "interpret"))
def _scaled_ensemble_pallas(
    feature: jnp.ndarray,    # (n_trees, num_internal)
    threshold: jnp.ndarray,
    leaf: jnp.ndarray,       # (n_trees, num_leaves)
    scale: jnp.ndarray,      # (n_trees,)
    binned: jnp.ndarray,     # (n, d) int32
    max_depth: int,
    tile_n: int,
    interpret: bool,
) -> jnp.ndarray:
    n, _ = binned.shape
    n_pad = ((n + tile_n - 1) // tile_n) * tile_n
    binned_p = jnp.pad(binned, ((0, n_pad - n), (0, 0)))
    out = predict_forest_pallas_call(
        binned_p,
        feature.astype(jnp.int32),
        threshold.astype(jnp.int32),
        leaf.astype(jnp.float32),
        scale.astype(jnp.float32),
        max_depth=max_depth,
        tile_n=tile_n,
        interpret=interpret,
    )
    return out[:n]


def predict_forest_pallas(
    trees: TreeArrays,       # stacked: leading axis n_trees
    binned: jnp.ndarray,     # (n, d) int32
    max_depth: int,
    *,
    tile_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Bagging-mean forest prediction, (n,) float32."""
    if interpret is None:
        interpret = not _on_tpu()
    n_trees = trees.feature.shape[0]
    scale = jnp.full((n_trees,), 1.0 / n_trees, jnp.float32)
    return _scaled_ensemble_pallas(
        trees.feature, trees.threshold, trees.leaf_weight, scale, binned,
        max_depth, tile_n, interpret,
    )


def predict_packed_pallas(
    packed: PackedEnsemble,
    binned: jnp.ndarray,     # (n, d) int32
    *,
    tile_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Whole-ensemble raw margin in ONE kernel sweep, (n,) float32.

    The per-tree ``tree_scale`` (= lr / n_trees of the tree's round) folds
    the boosting learning rate and every round's bagging mean into the
    kernel's accumulation, so all ``total_trees`` trees ride a single grid.
    """
    if interpret is None:
        interpret = not _on_tpu()
    margin = _scaled_ensemble_pallas(
        packed.feature, packed.threshold, packed.leaf_weight,
        packed.tree_scale, binned, packed.max_depth, tile_n, interpret,
    )
    return packed.base_score + margin


@partial(jax.jit, static_argnames=("max_depth", "tile_n", "interpret"))
def _fused_ensemble_pallas(
    feature: jnp.ndarray,    # (n_trees, num_internal) int32
    thr_value: jnp.ndarray,  # (n_trees, num_internal) float32 value-space
    leaf: jnp.ndarray,       # (n_trees, num_leaves) float32
    scale: jnp.ndarray,      # (n_trees,) float32
    x: jnp.ndarray,          # (n, d) float32 RAW features
    max_depth: int,
    tile_n: int,
    interpret: bool,
) -> jnp.ndarray:
    n, _ = x.shape
    n_pad = ((n + tile_n - 1) // tile_n) * tile_n
    x_p = jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    out = predict_forest_raw_pallas_call(
        x_p,
        feature.astype(jnp.int32),
        thr_value.astype(jnp.float32),
        leaf.astype(jnp.float32),
        scale.astype(jnp.float32),
        max_depth=max_depth,
        tile_n=tile_n,
        interpret=interpret,
    )
    return out[:n]


def predict_packed_fused_pallas(
    model,
    x: jnp.ndarray,          # (n, d) float32 RAW features
    *,
    tile_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused bin+traverse ensemble margin in ONE kernel sweep (DESIGN.md §14).

    Takes RAW floats — no ``bin_data`` dispatch — and accepts either a
    ``PackedEnsemble`` or a ``QuantizedEnsemble`` (``serving_tables``
    rewrites thresholds to value space and dequantizes quantized leaves
    in-graph).  Leaf routing is bit-identical to binning + the bin-space
    kernel for all inputs, including NaN/±inf rows (sanitized in-kernel).
    K-channel leaf tables are not supported here (same limitation as the
    bin-space kernel's 2-D leaf BlockSpec) — use the vmap fused path.
    """
    if interpret is None:
        interpret = not _on_tpu()
    feature, thr_value, leaf, scale = serving_tables(model)
    if leaf.ndim != 2:
        raise ValueError(
            "pallas ensemble_predict serves 2-D (trees, leaves) tables; "
            "K-channel ensembles must use impl='fused'"
        )
    margin = _fused_ensemble_pallas(
        feature, thr_value, leaf, scale, x, model.max_depth, tile_n,
        interpret,
    )
    return model.base_score + margin
