"""Pure-jnp oracle: the level-wise gather traversal used by the library."""

from repro.core.tree import predict_forest as predict_forest_ref  # noqa: F401
from repro.core.tree import predict_tree  # noqa: F401
