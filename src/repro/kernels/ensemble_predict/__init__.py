from repro.kernels.ensemble_predict import ops, ref  # noqa: F401
from repro.kernels.ensemble_predict.ops import predict_forest_pallas  # noqa: F401
