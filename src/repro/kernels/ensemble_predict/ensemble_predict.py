"""Pallas TPU kernel: fused forest inference (bagging combiner, Alg. 1 l.7).

TPU adaptation: tree traversal is pointer-chasing on GPU (per-thread gather
chains); TPUs have no efficient per-lane gather, so every gather becomes a
small dense contraction:

  * node lookup  — one-hot(idx over the level's width) @ (feature|threshold)
  * feature read — row-wise dot of one-hot(f over d) with the binned tile
  * leaf lookup  — one-hot(idx over leaves) @ leaf_weight

The depth loop is unrolled (max_depth static, paper uses 3), the whole tree's
arrays live in VMEM (a depth-3 tree is < 1 KiB), and a per-tree *scale*
accumulates across the tree grid axis (sequential on TPU) — one kernel
evaluates the entire forest without materialising per-tree outputs in HBM.
Scale = 1/num_trees reproduces the bagging mean of a single forest layer;
scale = lr/n_trees(round) evaluates a whole PackedEnsemble — every boosting
round of every forest — in the same single sweep (DESIGN.md §3).

VMEM per step (tile_n=256, d<=64, leaves=8, f32): binned 64 KiB, one-hots
<= 256*64*4 = 64 KiB, tree params ~1 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predict_kernel(binned_ref, feat_ref, thr_ref, leaf_ref, scale_ref, out_ref,
                    *, max_depth: int):
    """Grid step: one sample tile (axis 0) x one tree (axis 1).

    binned_ref: (tile_n, d) int32
    feat_ref/thr_ref: (1, num_internal) int32 — this tree's nodes
    leaf_ref: (1, num_leaves) float32
    scale_ref: (1, 1) float32 — this tree's contribution weight
    out_ref: (tile_n,) float32 — accumulated scale-weighted ensemble margin
    """

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile_n, d = binned_ref.shape
    binned = binned_ref[...].astype(jnp.float32)          # (T, d)
    idx = jnp.zeros((tile_n,), jnp.int32)
    for level in range(max_depth):
        off = 2**level - 1
        width = 2**level
        sel = (idx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tile_n, width), 1)).astype(jnp.float32)
        feats = feat_ref[0, off:off + width].astype(jnp.float32)   # (width,)
        thrs = thr_ref[0, off:off + width].astype(jnp.float32)
        f = sel @ feats                                    # (T,)
        t = sel @ thrs
        f_onehot = (f[:, None] == jax.lax.broadcasted_iota(
            jnp.float32, (tile_n, d), 1)).astype(jnp.float32)
        fv = jnp.sum(binned * f_onehot, axis=1)            # (T,)
        go_right = jnp.logical_and(f >= 0.0, fv > t)
        idx = idx * 2 + go_right.astype(jnp.int32)

    leaves = leaf_ref[0, :]                                # (num_leaves,)
    lsel = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tile_n, leaves.shape[0]), 1)).astype(jnp.float32)
    pred = lsel @ leaves
    out_ref[...] += pred * scale_ref[0, 0]


def _predict_raw_kernel(x_ref, feat_ref, thr_ref, leaf_ref, scale_ref, out_ref,
                        *, max_depth: int):
    """Fused bin+traverse grid step: RAW float features, value-space
    thresholds (DESIGN.md §14) — the binning dispatch is gone entirely.

    Identical structure to ``_predict_kernel`` except the feature read
    compares floats against ``types.float_thresholds`` output instead of
    bins against bin ids.  The tile is sanitized up front: the feature read
    is a one-hot *contraction*, so a NaN or ±inf anywhere in the tile would
    poison every lane of its row (``0 * inf = NaN``).  NaN maps to
    -FLOAT_MAX (compares ``<=`` every threshold → routes left, the NAN_BIN
    semantics) and ±inf clips to ±FLOAT_MAX (still beyond every finite
    edge), so routing stays bit-identical to the binned oracle for ALL
    inputs, finite or not.

    x_ref: (tile_n, d) float32 raw features
    thr_ref: (1, num_internal) float32 value-space thresholds
    (rest as ``_predict_kernel``)
    """

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile_n, d = x_ref.shape
    fmax = jnp.float32(jnp.finfo(jnp.float32).max)
    x = x_ref[...]
    x = jnp.where(jnp.isnan(x), -fmax, jnp.clip(x, -fmax, fmax))
    idx = jnp.zeros((tile_n,), jnp.int32)
    for level in range(max_depth):
        off = 2**level - 1
        width = 2**level
        sel = (idx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tile_n, width), 1)).astype(jnp.float32)
        feats = feat_ref[0, off:off + width].astype(jnp.float32)   # (width,)
        thrs = thr_ref[0, off:off + width]
        f = sel @ feats                                    # (T,)
        t = sel @ thrs
        f_onehot = (f[:, None] == jax.lax.broadcasted_iota(
            jnp.float32, (tile_n, d), 1)).astype(jnp.float32)
        fv = jnp.sum(x * f_onehot, axis=1)                 # (T,)
        go_right = jnp.logical_and(f >= 0.0, fv > t)
        idx = idx * 2 + go_right.astype(jnp.int32)

    leaves = leaf_ref[0, :]                                # (num_leaves,)
    lsel = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tile_n, leaves.shape[0]), 1)).astype(jnp.float32)
    pred = lsel @ leaves
    out_ref[...] += pred * scale_ref[0, 0]


def predict_forest_raw_pallas_call(
    x: jnp.ndarray,          # (n_pad, d) float32 RAW features
    feature: jnp.ndarray,    # (n_trees, num_internal) int32
    thr_value: jnp.ndarray,  # (n_trees, num_internal) float32 value-space
    leaf: jnp.ndarray,       # (n_trees, num_leaves) float32
    scale: jnp.ndarray,      # (n_trees,) float32 per-tree contribution
    *,
    max_depth: int,
    tile_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused bin+traverse+combine over the whole ensemble in one kernel."""
    n_pad, d = x.shape
    n_trees, num_internal = feature.shape
    num_leaves = leaf.shape[1]
    grid = (n_pad // tile_n, n_trees)
    return pl.pallas_call(
        functools.partial(_predict_raw_kernel, max_depth=max_depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, num_internal), lambda i, j: (j, 0)),
            pl.BlockSpec((1, num_internal), lambda i, j: (j, 0)),
            pl.BlockSpec((1, num_leaves), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(x, feature, thr_value, leaf, scale.reshape(n_trees, 1))


def predict_forest_pallas_call(
    binned: jnp.ndarray,     # (n_pad, d) int32
    feature: jnp.ndarray,    # (n_trees, num_internal) int32
    threshold: jnp.ndarray,  # (n_trees, num_internal) int32
    leaf: jnp.ndarray,       # (n_trees, num_leaves) float32
    scale: jnp.ndarray,      # (n_trees,) float32 per-tree contribution
    *,
    max_depth: int,
    tile_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    n_pad, d = binned.shape
    n_trees, num_internal = feature.shape
    num_leaves = leaf.shape[1]
    grid = (n_pad // tile_n, n_trees)
    return pl.pallas_call(
        functools.partial(_predict_kernel, max_depth=max_depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, num_internal), lambda i, j: (j, 0)),
            pl.BlockSpec((1, num_internal), lambda i, j: (j, 0)),
            pl.BlockSpec((1, num_leaves), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(binned, feature, threshold, leaf, scale.reshape(n_trees, 1))
