"""Fused Pallas training-side histogram kernel (DESIGN.md §2, §4).

The original ``histogram.py`` kernel consumes *pre-staged* operands: the
wrapper materialises ``ids = assign * B + binned`` (an (n, d) int32 array the
size of the feature matrix) and ``data = stack([g*w, h*w, w])`` in XLA before
the kernel ever runs — two extra HBM round-trips per level per tree that the
training hot path pays at every histogram build.

This kernel fuses that staging into the scatter-accumulate itself: it reads
the raw level inputs (``binned``, ``assign``, ``g``, ``h``, ``w``) and forms
both the fused node×bin ids and the ``[g*w, h*w, w]`` stats rows in
VMEM/VREGs, so the only HBM traffic is the inputs once and the histogram
out.  The accumulation is the same one-hot MXU contraction

    hist[f, :, :] += onehot(assign * B + binned[:, f])^T @ [g*w, h*w, w, 0...]

tiled over (sample tiles, feature blocks) with the standard sequential-grid
revisiting-accumulator pattern on the output block.

VMEM budget per step (tile_n=512, NB<=1024, feat_block=8, f32): binned
512*8*4 = 16 KiB, per-sample vectors 3 * 512*4 = 6 KiB, onehot 512*1024*4 =
2 MiB, out 8*1024*8*4 = 256 KiB — comfortably inside ~16 MiB/core VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_pad(k: int) -> int:
    """Sublane-aligned stats width for K gradient channels: round_up(2K+1, 8)
    (== STATS_PAD at K = 1, so the binary kernel is byte-identical)."""
    return ((2 * k + 1 + 7) // 8) * 8


def _fused_histogram_kernel(
    binned_ref, assign_ref, g_ref, h_ref, w_ref, out_ref,
    *, nb: int, num_bins: int, feat_block: int, child_mode: bool = False,
):
    """One grid step: accumulate ``feat_block`` features for one sample tile.

    binned_ref: (tile_n, feat_block) int32 raw bin ids (NOT pre-fused);
    assign_ref: (tile_n, 1) int32 node assignment at the current level;
    g_ref/h_ref: (tile_n, K) float32 raw derivatives (K = 1 for scalar
        objectives; K-channel objectives fold their channels into the
        stats axis — the grid is unchanged, DESIGN.md §11);
    w_ref: (tile_n, 1) float32 sample mask — padded rows carry w == 0 so
        they contribute nothing;
    out_ref: (feat_block, nb, stats_pad) float32 accumulated histogram,
        stats_pad = round_up(2K+1, 8) (STATS_PAD = 8 at K = 1).

    ``child_mode`` is the subtraction pipeline's left-child-only variant
    (DESIGN.md §6): samples routed right (odd ``assign``) are weight-masked
    to zero and the node id halves to the parent index — both formed in
    VREGs, like the rest of the staging, so the half-width pass adds no HBM
    traffic.  ``nb`` is then ``num_parents * num_bins`` (half the frontier).
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile_n = binned_ref.shape[0]
    gv = g_ref[...]  # (T, K) — K = 1 for scalar-channel objectives
    hv = h_ref[...]
    wv = w_ref[...]  # (T, 1)
    assign = assign_ref[...]  # (T, 1)
    if child_mode:
        wv = wv * (assign % 2 == 0).astype(jnp.float32)
        assign = assign // 2
    # Fused stats staging: [g*w, h*w, w, 0...] built in registers, never HBM
    # ((T, K) * (T, 1) broadcasts per channel; count stays the LAST live lane).
    pad = out_ref.shape[-1] - (2 * gv.shape[1] + 1)
    data = jnp.concatenate(
        [gv * wv, hv * wv, wv, jnp.zeros((tile_n, pad), jnp.float32)],
        axis=1,
    )  # (T, stats_pad)
    node = assign[:, 0]  # (T,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (tile_n, nb), 1)

    def body(f, carry):
        # Fused id staging: node * B + bin, per feature column, in registers.
        ids_col = node * num_bins + binned_ref[:, f]  # (T,)
        onehot = (ids_col[:, None] == iota).astype(jnp.float32)  # (T, NB)
        acc = jax.lax.dot_general(
            onehot, data,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (NB, STATS_PAD) on the MXU
        out_ref[f, :, :] += acc
        return carry

    jax.lax.fori_loop(0, feat_block, body, 0)


def fused_histogram_pallas_call(
    binned: jnp.ndarray,
    assign: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    w: jnp.ndarray,
    nb: int,
    num_bins: int,
    *,
    tile_n: int = 512,
    feat_block: int = 8,
    interpret: bool = False,
    child_mode: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call. Caller guarantees padding invariants (see ops.py):

    binned (n_pad, d_pad) int32, n_pad % tile_n == 0, d_pad % feat_block == 0,
           values in [0, num_bins); padded entries may hold any in-range bin
           because their weight is 0.
    assign (n_pad, 1) int32 in [0, nb // num_bins) — or, when ``child_mode``,
           the current-level assignment in [0, 2 * nb // num_bins) (the
           kernel halves it to parent ids and masks right-routed samples);
           g/h (n_pad, K) float32 (K = 1 scalar objectives) and w (n_pad, 1)
           float32 with zero rows where padded/masked.

    Returns (d_pad, nb, round_up(2K+1, 8)) float32 (STATS_PAD at K = 1) —
    K-channel objectives widen the stats (lane) axis only; the grid and
    block structure are unchanged.
    """
    n_pad, d_pad = binned.shape
    k = g.shape[1]
    stats_pad = _stats_pad(k)
    grid = (n_pad // tile_n, d_pad // feat_block)
    vec_spec = pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0))
    chan_spec = pl.BlockSpec((tile_n, k), lambda i, j: (i, 0))

    return pl.pallas_call(
        functools.partial(
            _fused_histogram_kernel,
            nb=nb, num_bins=num_bins, feat_block=feat_block,
            child_mode=child_mode,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, feat_block), lambda i, j: (i, j)),
            vec_spec,   # assign
            chan_spec,  # g
            chan_spec,  # h
            vec_spec,   # w
        ],
        out_specs=pl.BlockSpec((feat_block, nb, stats_pad), lambda i, j: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_pad, nb, stats_pad), jnp.float32),
        interpret=interpret,
    )(binned, assign, g, h, w)


def _fused_round_histogram_kernel(
    binned_ref, assign_ref, g_ref, h_ref, w_ref, out_ref,
    *, nb: int, num_bins: int, feat_block: int, child_mode: bool = False,
):
    """One grid step of the ROUND kernel (DESIGN.md §9): accumulate
    ``feat_block`` features of one sample tile for one TREE of the round.

    The tree axis is a grid dimension, not a vmap: ``binned``/``g``/``h``
    blocks are shared across the tree grid (a round's trees differ only in
    their masks, eq. 4), while ``assign``/``w`` (and the output block) index
    by the tree id.  Same fused in-VREG staging as
    ``_fused_histogram_kernel``; ``child_mode`` is the subtraction
    pipeline's left-child variant (left-mask + parent ids in VREGs).

    binned_ref: (tile_n, feat_block) int32 (tree-invariant block);
    assign_ref / w_ref: (1, tile_n, 1) — this tree's slice;
    g_ref / h_ref: (tile_n, K) float32 shared derivatives (K = 1 scalar);
    out_ref: (1, feat_block, nb, stats_pad) — this tree's histogram block,
        stats_pad = round_up(2K+1, 8) (STATS_PAD at K = 1).
    """

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile_n = binned_ref.shape[0]
    gv = g_ref[...]          # (T, K)
    hv = h_ref[...]
    wv = w_ref[0]            # strip the tree block dim -> (T, 1)
    assign = assign_ref[0]
    if child_mode:
        wv = wv * (assign % 2 == 0).astype(jnp.float32)
        assign = assign // 2
    pad = out_ref.shape[-1] - (2 * gv.shape[1] + 1)
    data = jnp.concatenate(
        [gv * wv, hv * wv, wv, jnp.zeros((tile_n, pad), jnp.float32)],
        axis=1,
    )  # (T, stats_pad)
    node = assign[:, 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tile_n, nb), 1)

    def body(f, carry):
        ids_col = node * num_bins + binned_ref[:, f]
        onehot = (ids_col[:, None] == iota).astype(jnp.float32)
        acc = jax.lax.dot_general(
            onehot, data,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        out_ref[0, f, :, :] += acc
        return carry

    jax.lax.fori_loop(0, feat_block, body, 0)


def fused_round_histogram_pallas_call(
    binned: jnp.ndarray,
    assign: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    w: jnp.ndarray,
    nb: int,
    num_bins: int,
    *,
    tile_n: int = 512,
    feat_block: int = 8,
    interpret: bool = False,
    child_mode: bool = False,
) -> jnp.ndarray:
    """Raw round-kernel pallas_call. Caller guarantees padding invariants
    (see ops.py):

    binned (n_pad, d_pad) int32 shared by all trees; assign / w
    (n_trees, n_pad, 1) per-tree; g / h (n_pad, K) float32 shared (K = 1
    scalar objectives).  Grid is (n_trees, sample tiles, feature blocks) —
    for a fixed (tree, feature block) the sample-tile dimension revisits the
    output block with the standard sequential-grid accumulator pattern
    (init at tile 0).  K-channel objectives widen only the stats lanes; the
    grid is unchanged.

    Returns (n_trees, d_pad, nb, round_up(2K+1, 8)) float32.
    """
    n_trees = assign.shape[0]
    n_pad, d_pad = binned.shape
    k = g.shape[1]
    stats_pad = _stats_pad(k)
    grid = (n_trees, n_pad // tile_n, d_pad // feat_block)
    tree_vec_spec = pl.BlockSpec((1, tile_n, 1), lambda t, i, j: (t, i, 0))
    shared_chan_spec = pl.BlockSpec((tile_n, k), lambda t, i, j: (i, 0))

    return pl.pallas_call(
        functools.partial(
            _fused_round_histogram_kernel,
            nb=nb, num_bins=num_bins, feat_block=feat_block,
            child_mode=child_mode,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, feat_block), lambda t, i, j: (i, j)),
            tree_vec_spec,     # assign
            shared_chan_spec,  # g
            shared_chan_spec,  # h
            tree_vec_spec,     # w
        ],
        out_specs=pl.BlockSpec(
            (1, feat_block, nb, stats_pad), lambda t, i, j: (t, j, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n_trees, d_pad, nb, stats_pad), jnp.float32
        ),
        interpret=interpret,
    )(binned, assign, g, h, w)
