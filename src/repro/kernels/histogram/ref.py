"""Pure-jnp oracle for the Pallas histogram kernel.

The reference is the segment-sum implementation used by the portable CPU
path; the kernel must match it exactly (float32 accumulation in both).
"""

from repro.core.histogram import compute_histogram as histogram_ref  # noqa: F401
from repro.core.histogram import compute_histogram_onehot  # noqa: F401
