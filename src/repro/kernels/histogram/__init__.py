from repro.kernels.histogram import ops, ref  # noqa: F401
from repro.kernels.histogram.ops import compute_histogram_pallas  # noqa: F401
