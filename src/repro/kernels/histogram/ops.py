"""Jitted wrapper for the Pallas histogram kernel.

Drop-in replacement for ``core.histogram.compute_histogram`` (selected via
``histogram_dispatch("pallas")``): handles id fusion, padding to tile
boundaries, and un-padding of the result. ``interpret`` defaults to True off
TPU so the same code path validates on CPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.histogram.histogram import (
    STATS,
    STATS_PAD,
    histogram_pallas_call,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@partial(
    jax.jit,
    static_argnames=("num_nodes", "num_bins", "tile_n", "feat_block", "interpret"),
)
def compute_histogram_pallas(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
    *,
    tile_n: int = 512,
    feat_block: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Same contract as ``core.histogram.compute_histogram``.

    Returns (num_nodes, d, num_bins, 3) float32.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = binned.shape
    nb = num_nodes * num_bins
    # MXU lane alignment: pad the one-hot width to 128 (see kernel docstring).
    nb_pad = _round_up(nb, 128)

    ids = assign[:, None] * num_bins + binned  # (n, d)
    data = jnp.stack(
        [g * weight, h * weight, weight], axis=-1
    ).astype(jnp.float32)  # (n, 3)

    n_pad = _round_up(n, tile_n)
    d_pad = _round_up(d, feat_block)
    ids = jnp.pad(ids, ((0, n_pad - n), (0, d_pad - d)))
    data = jnp.pad(data, ((0, n_pad - n), (0, STATS_PAD - STATS)))

    hist = histogram_pallas_call(
        ids, data, nb_pad,
        tile_n=tile_n, feat_block=feat_block, interpret=interpret,
    )  # (d_pad, nb_pad, STATS_PAD)

    hist = hist[:d, :nb, :STATS]
    return hist.reshape(d, num_nodes, num_bins, STATS).transpose(1, 0, 2, 3)
