"""Jitted wrappers for the Pallas histogram kernels.

Drop-in replacements for ``core.histogram.compute_histogram``:

* ``compute_histogram_pallas``        — the original kernel; the wrapper
  stages ``ids = assign * B + binned`` and ``data = stack([g*w, h*w, w])``
  in XLA before the kernel (selected via ``histogram_dispatch("pallas")``);
* ``compute_histogram_pallas_fused``  — the training-side fused kernel
  (``train_histogram.py``): id fusion and stats staging happen *inside* the
  kernel, so neither intermediate ever touches HBM (selected via
  ``histogram_dispatch("pallas-fused")``; what the ``local-pallas`` backend
  runs);
* ``compute_histogram_pallas_fused_child`` — its child-only variant for the
  sibling-subtraction pipeline (DESIGN.md §6): left-mask and parent ids are
  formed in-kernel and the one-hot contraction runs at half-frontier width
  (``histogram_dispatch("pallas-fused-child")``; the ``local-pallas``
  backend's ``child_histogram_fn``);
* ``compute_round_histogram_pallas_fused[_child]`` — the round-native
  variants (DESIGN.md §9): the tree axis is a kernel grid dimension, so ONE
  launch accumulates the whole round's (T, nodes, d, B, 3) histogram with
  the tree-invariant operands (binned, g, h) shared across the tree grid
  (``histogram_dispatch("pallas-fused-round[-child]")``; what the
  ``local-pallas`` backend's ``round_*`` providers run).

Both handle padding to tile boundaries and un-padding of the result.
``interpret`` defaults to True off TPU so the same code paths validate on
CPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.histogram.histogram import histogram_pallas_call
from repro.kernels.histogram.train_histogram import (
    fused_histogram_pallas_call,
    fused_round_histogram_pallas_call,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _num_stats(g: jnp.ndarray) -> int:
    """Stats-lane count for the derivative layout: 3 for scalar (n,) g/h,
    2K+1 for K-channel (n, K) objectives (count stays the last lane)."""
    return 3 if g.ndim == 1 else 2 * g.shape[-1] + 1


def _chan_pad(v: jnp.ndarray, pad_n: int) -> jnp.ndarray:
    """Tile-pad a per-sample vector and give it an explicit channel axis:
    (n,) -> (n_pad, 1); (n, K) -> (n_pad, K)."""
    v = v.astype(jnp.float32)
    if v.ndim == 1:
        v = v[:, None]
    return jnp.pad(v, ((0, pad_n), (0, 0)))


@partial(
    jax.jit,
    static_argnames=("num_nodes", "num_bins", "tile_n", "feat_block", "interpret"),
)
def compute_histogram_pallas(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
    *,
    tile_n: int = 512,
    feat_block: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Same contract as ``core.histogram.compute_histogram``.

    Returns (num_nodes, d, num_bins, 2K+1) float32 (3 for scalar g/h).
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = binned.shape
    nb = num_nodes * num_bins
    # MXU lane alignment: pad the one-hot width to 128 (see kernel docstring).
    nb_pad = _round_up(nb, 128)

    ids = assign[:, None] * num_bins + binned  # (n, d)
    if g.ndim == 1:
        data = jnp.stack(
            [g * weight, h * weight, weight], axis=-1
        ).astype(jnp.float32)  # (n, 3)
    else:
        w = weight[:, None]
        data = jnp.concatenate(
            [g * w, h * w, w], axis=-1
        ).astype(jnp.float32)  # (n, 2K+1)
    stats = data.shape[-1]
    stats_pad = _round_up(stats, 8)

    n_pad = _round_up(n, tile_n)
    d_pad = _round_up(d, feat_block)
    ids = jnp.pad(ids, ((0, n_pad - n), (0, d_pad - d)))
    data = jnp.pad(data, ((0, n_pad - n), (0, stats_pad - stats)))

    hist = histogram_pallas_call(
        ids, data, nb_pad,
        tile_n=tile_n, feat_block=feat_block, interpret=interpret,
    )  # (d_pad, nb_pad, stats_pad)

    hist = hist[:d, :nb, :stats]
    return hist.reshape(d, num_nodes, num_bins, stats).transpose(1, 0, 2, 3)


@partial(
    jax.jit,
    static_argnames=(
        "num_nodes", "num_bins", "tile_n", "feat_block", "interpret", "child",
    ),
)
def compute_histogram_pallas_fused(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
    *,
    tile_n: int = 512,
    feat_block: int = 8,
    interpret: bool | None = None,
    child: bool = False,
) -> jnp.ndarray:
    """Same contract as ``core.histogram.compute_histogram``, served by the
    fused training-side kernel: no (n, d) fused-id array and no (n, 3) stats
    stack are ever materialised — only tile-boundary zero padding happens in
    XLA (padded rows carry weight 0, so they accumulate nothing).

    With ``child=True`` it is the subtraction pipeline's child-only provider
    (``core.histogram.as_child_fn`` semantics): ``assign`` is the current
    level's assignment, ``num_nodes`` the PARENT count, and the left-mask /
    parent-id staging happens in-kernel — the one-hot width (and therefore
    the MXU contraction) shrinks to the half frontier.

    Returns (num_nodes, d, num_bins, 2K+1) float32 (3 for scalar g/h).
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, d = binned.shape
    nb = num_nodes * num_bins
    nb_pad = _round_up(nb, 128)  # MXU lane alignment (see kernel docstring)
    stats = _num_stats(g)

    n_pad = _round_up(n, tile_n)
    d_pad = _round_up(d, feat_block)
    pad_n = n_pad - n
    binned_p = jnp.pad(binned, ((0, pad_n), (0, d_pad - d)))
    assign_p = jnp.pad(assign, (0, pad_n))[:, None]

    hist = fused_histogram_pallas_call(
        binned_p, assign_p, _chan_pad(g, pad_n), _chan_pad(h, pad_n),
        _chan_pad(weight, pad_n), nb_pad, num_bins,
        tile_n=tile_n, feat_block=feat_block, interpret=interpret,
        child_mode=child,
    )  # (d_pad, nb_pad, stats_pad)

    hist = hist[:d, :nb, :stats]
    return hist.reshape(d, num_nodes, num_bins, stats).transpose(1, 0, 2, 3)


def compute_histogram_pallas_fused_child(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_parents: int,
    num_bins: int,
    **kw,
) -> jnp.ndarray:
    """Child-only provider for ``TreeBackend.child_histogram_fn``: left-child
    histograms at half-frontier width, all staging fused in-kernel."""
    return compute_histogram_pallas_fused(
        binned, g, h, weight, assign, num_parents, num_bins, child=True, **kw
    )


@partial(
    jax.jit,
    static_argnames=(
        "num_nodes", "num_bins", "tile_n", "feat_block", "interpret", "child",
        "root_delta_rows", "level",
    ),
)
def compute_round_histogram_pallas_fused(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_nodes: int,
    num_bins: int,
    *,
    tile_n: int = 512,
    feat_block: int = 8,
    interpret: bool | None = None,
    child: bool = False,
    root_delta_rows: int = 0,
    level: int = 0,
) -> jnp.ndarray:
    """Round-native provider (``core.histogram.compute_round_histogram``
    contract) served by the tree-grid fused kernel: ONE kernel launch
    accumulates all T trees' histograms, with ``binned``/``g``/``h`` blocks
    shared across the tree grid axis (the round's trees differ only in
    their (weight, assign) masks).

    With ``child=True`` it is the subtraction pipeline's round child
    provider; with ``root_delta_rows > 0`` (level 0) the shared-root
    derivation routes through ``histogram.root_histogram_via_delta`` with
    the per-tree fused kernel as the delta accumulator.

    Args:
      weight / assign: (T, n).
    Returns:
      (T, num_nodes, d, num_bins, 2K+1) float32 (3 for scalar g/h).
    """
    if root_delta_rows:
        from repro.core.histogram import root_histogram_via_delta

        return root_histogram_via_delta(
            binned, g, h, weight, num_bins, root_delta_rows,
            base_tree_fn=compute_histogram_pallas_fused,
        )
    if interpret is None:
        interpret = not _on_tpu()
    n, d = binned.shape
    t = weight.shape[0]
    nb = num_nodes * num_bins
    nb_pad = _round_up(nb, 128)  # MXU lane alignment (see kernel docstring)
    stats = _num_stats(g)

    n_pad = _round_up(n, tile_n)
    d_pad = _round_up(d, feat_block)
    pad_n = n_pad - n
    binned_p = jnp.pad(binned, ((0, pad_n), (0, d_pad - d)))
    tree_col = lambda v: jnp.pad(v, ((0, 0), (0, pad_n)))[:, :, None]
    assign_p = tree_col(assign)
    w_p = tree_col(weight.astype(jnp.float32))

    hist = fused_round_histogram_pallas_call(
        binned_p, assign_p, _chan_pad(g, pad_n), _chan_pad(h, pad_n), w_p,
        nb_pad, num_bins,
        tile_n=tile_n, feat_block=feat_block, interpret=interpret,
        child_mode=child,
    )  # (T, d_pad, nb_pad, stats_pad)

    hist = hist[:, :d, :nb, :stats]
    return hist.reshape(t, d, num_nodes, num_bins, stats).transpose(
        0, 2, 1, 3, 4
    )


def compute_round_histogram_pallas_fused_child(
    binned: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    weight: jnp.ndarray,
    assign: jnp.ndarray,
    num_parents: int,
    num_bins: int,
    **kw,
) -> jnp.ndarray:
    """Round child provider for ``TreeBackend.round_child_histogram_fn``:
    the whole round's left-child histograms in one tree-grid launch."""
    return compute_round_histogram_pallas_fused(
        binned, g, h, weight, assign, num_parents, num_bins, child=True, **kw
    )
