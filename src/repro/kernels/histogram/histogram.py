"""Pallas TPU kernel: gradient-histogram accumulation as one-hot MXU matmuls.

TPU adaptation (DESIGN.md §2). GPU GBDTs accumulate histograms with atomic
scatter-adds into shared memory; TPUs have neither atomics nor arbitrary
scatter. Instead we express the histogram as a dense contraction

    hist[f, :, :] = onehot(node * B + bin[:, f])^T  @  [g*w, h*w, w]
                    (NB x T)                           (T x 3)

which the MXU executes as an ordinary matmul. Key layout decisions:

* ``NB = num_nodes * num_bins`` is the matmul N dimension; with the paper's
  depth-3 trees and B = 32 the deepest frontier gives NB = 128 — exactly one
  MXU tile. ``ops.py`` pads NB to a multiple of 128 otherwise.
* The sample axis T is the contraction dimension; we tile it with
  ``tile_n`` rows per grid step and accumulate across grid axis 0 (TPU grid
  iterations are sequential, so read-modify-write on the output block is the
  standard revisiting-accumulator pattern, initialised at program_id(0) == 0).
* The stats axis (g, h, count) is padded to ``STATS_PAD = 8`` sublanes; the
  wrapper slices back to 3. The matmul is memory-bound (we stream ids once),
  so the pad costs bandwidth-nothing.
* Features are processed ``feat_block`` per grid step (grid axis 1), looped
  inside the kernel with a fori_loop; each feature's one-hot lives only in
  VMEM/VREGs — the (T x NB) one-hot never touches HBM, which is the entire
  point versus materialising ``jax.nn.one_hot`` in XLA.

VMEM budget per step (tile_n=512, NB<=1024, feat_block=8, f32):
ids 512*8*4 = 16 KiB, data 512*8*4 = 16 KiB, onehot 512*1024*4 = 2 MiB,
out 8*1024*8*4 = 256 KiB — comfortably inside the ~16 MiB/core VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

STATS = 3      # sum_g, sum_h, count
STATS_PAD = 8  # sublane-aligned stats width inside the kernel


def _histogram_kernel(ids_ref, data_ref, out_ref, *, nb: int, feat_block: int):
    """One grid step: accumulate ``feat_block`` features for one sample tile.

    ids_ref:  (tile_n, feat_block) int32 — node * B + bin, -1 for padded rows
    data_ref: (tile_n, STATS_PAD) float32 — [g*w, h*w, w, 0...]
    out_ref:  (feat_block, nb, STATS_PAD) float32 — accumulated histogram
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    data = data_ref[...]  # (T, STATS_PAD)
    iota = jax.lax.broadcasted_iota(jnp.int32, (ids_ref.shape[0], nb), 1)

    def body(f, carry):
        ids_col = ids_ref[:, f]  # (T,)
        onehot = (ids_col[:, None] == iota).astype(jnp.float32)  # (T, NB)
        # (NB, T) @ (T, STATS_PAD) on the MXU.
        acc = jax.lax.dot_general(
            onehot, data,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (NB, STATS_PAD)
        out_ref[f, :, :] += acc
        return carry

    jax.lax.fori_loop(0, feat_block, body, 0)


def histogram_pallas_call(
    ids: jnp.ndarray,
    data: jnp.ndarray,
    nb: int,
    *,
    tile_n: int = 512,
    feat_block: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw pallas_call. Caller guarantees padding invariants (see ops.py):

    ids  (n_pad, d_pad) int32, n_pad % tile_n == 0, d_pad % feat_block == 0,
         values in [0, nb); padded rows may hold any id because their data is 0.
    data (n_pad, stats_pad) float32, zero rows where padded/masked.  The
         stats width is read off the operand — ``STATS_PAD`` (= 8) for K = 1
         objectives, ``round_up(2K+1, 8)`` sublanes for K-channel ones
         (DESIGN.md §11: channels fold into the stats axis, grid unchanged).

    Returns (d_pad, nb, stats_pad) float32.
    """
    n_pad, d_pad = ids.shape
    stats_pad = data.shape[1]
    grid = (n_pad // tile_n, d_pad // feat_block)

    return pl.pallas_call(
        functools.partial(_histogram_kernel, nb=nb, feat_block=feat_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, feat_block), lambda i, j: (i, j)),
            pl.BlockSpec((tile_n, stats_pad), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((feat_block, nb, stats_pad), lambda i, j: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_pad, nb, stats_pad), jnp.float32),
        interpret=interpret,
    )(ids, data)
