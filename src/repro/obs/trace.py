"""Host-side span tracing with a zero-overhead disabled path (DESIGN.md §12).

A ``Tracer`` records closed ``Span`` intervals (absolute ``perf_counter``
seconds, so every producer in the process shares one clock) plus counter
samples.  Spans come in two flavours:

* live ``with tracer.span(...)`` context managers for host work that is
  being timed as it happens (binning, the scan-program call, checkpoint
  I/O);
* derived ``tracer.add_span(name, t0, t1, ...)`` intervals reconstructed
  after the fact from other clocks on the same timebase — the scan engine's
  in-program segment ticks, per-round slices of ``TrainHistory``, the
  ledger's per-round wire bytes.

``track`` groups spans into named rows ("threads" in the Chrome trace
model): the exporter assigns one tid per track, so host spans, round spans
and per-phase wire spans land on separate swim-lanes in Perfetto.

The disabled path is ``NULL_TRACER``: ``span()`` returns one shared no-op
context-manager singleton (no per-call allocation — asserted by
tests/test_obs.py), ``add_span``/``counter`` are no-ops, so instrumented
code pays a method call and nothing else when tracing is off.

``set_global_tracer`` / ``global_tracer`` is the process-wide seam for code
that cannot thread a tracer argument (checkpoint I/O, library internals):
default ``NULL_TRACER``, flipped by ``train_fedgbf --trace`` and friends.
"""

from __future__ import annotations

import time


class Span:
    """One closed interval: [t0, t1] absolute ``perf_counter`` seconds."""

    __slots__ = ("name", "cat", "t0", "t1", "track", "args", "depth")

    def __init__(self, name, cat="host", t0=0.0, t1=0.0, track="host",
                 args=None, depth=0):
        self.name = name
        self.cat = cat
        self.t0 = float(t0)
        self.t1 = float(t1)
        self.track = track
        self.args = args
        self.depth = depth

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Span({self.name!r}, cat={self.cat!r}, "
                f"dur={self.duration_s * 1e3:.3f}ms, track={self.track!r})")


class _ActiveSpan:
    """Live span context manager: times the block, appends on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0", "_depth")

    def __init__(self, tracer, name, cat, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._depth = self._tracer._depth
        self._tracer._depth = self._depth + 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        self._tracer._depth = self._depth
        self._tracer.spans.append(
            Span(self._name, self._cat, self._t0, t1, "host", self._args,
                 self._depth)
        )
        return False


class _NullSpan:
    """Shared no-op context manager — the whole disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every call is a no-op, ``span()`` allocates nothing
    (returns the module-level ``_NULL_SPAN`` singleton)."""

    enabled = False

    def span(self, name, cat="host", args=None):
        return _NULL_SPAN

    def add_span(self, name, t0, t1, cat="host", track="host", args=None):
        pass

    def counter(self, name, values, ts=None):
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer: ``spans`` (list of ``Span``) and ``counters``
    (list of ``(name, ts, values_dict)`` samples)."""

    enabled = True

    def __init__(self) -> None:
        self.spans: list = []
        self.counters: list = []
        self._depth = 0  # live-span nesting depth (host track only)

    def span(self, name, cat="host", args=None):
        """Context manager timing the enclosed block on the host track."""
        return _ActiveSpan(self, name, cat, args)

    def add_span(self, name, t0, t1, cat="host", track="host", args=None):
        """Append a derived interval (same ``perf_counter`` timebase)."""
        self.spans.append(Span(name, cat, t0, t1, track, args))

    def counter(self, name, values, ts=None):
        """Record one counter sample: ``values`` is a {series: number} dict."""
        self.counters.append(
            (name, time.perf_counter() if ts is None else float(ts),
             dict(values))
        )


_GLOBAL_TRACER = NULL_TRACER


def set_global_tracer(tracer) -> None:
    """Install the process-wide tracer (``NULL_TRACER`` to disable)."""
    global _GLOBAL_TRACER
    _GLOBAL_TRACER = tracer if tracer is not None else NULL_TRACER


def global_tracer():
    """The process-wide tracer; ``NULL_TRACER`` unless a driver enabled one."""
    return _GLOBAL_TRACER
