"""Serving metrics: log-bucketed histograms, counters, Prometheus text
exposition (DESIGN.md §12).

The serving path scores unbounded request streams, so nothing here may grow
with the stream: ``LogBucketHistogram`` stores a FIXED array of bucket
counts (no raw samples), and quantiles are derived from the buckets — the
estimate lands on the geometric midpoint of the covering bucket, so the
relative error is bounded by half the bucket growth factor (~4.5% at the
default 2**(1/8) growth), independent of stream length.

``MetricsRegistry.render()`` writes the Prometheus text exposition format
(the de-facto scrape payload), so wiring an HTTP endpoint later is just
serving this string; ``serve_fedgbf --metrics-out`` dumps it to a file.
"""

from __future__ import annotations

import math

import numpy as np


class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v

    def render(self) -> list:
        return [f"{self.name} {_fmt(self.value)}"]


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def render(self) -> list:
        return [f"{self.name} {_fmt(self.value)}"]


class LogBucketHistogram:
    """Fixed-size log-bucketed histogram (bounded memory for any stream).

    Bucket upper edges grow geometrically from ``lo`` by ``growth`` up to
    ``hi``, plus one overflow bucket; values below ``lo`` land in the first
    bucket.  ``quantile(q)`` walks the cumulative counts and returns the
    geometric midpoint of the covering bucket — error ≤ (growth - 1) / 2
    relative, by construction, with no raw-sample storage.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", lo: float = 1e-5,
                 hi: float = 60.0, growth: float = 2 ** 0.125) -> None:
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError("need 0 < lo < hi and growth > 1")
        self.name = name
        self.help = help
        self.growth = growth
        n = int(math.ceil(math.log(hi / lo) / math.log(growth))) + 1
        #: upper bucket edges, seconds; the implicit last bucket is +Inf
        self.bounds = lo * growth ** np.arange(n)
        self.counts = np.zeros(n + 1, np.int64)
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[np.searchsorted(self.bounds, v)] += 1
        self.sum += v

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def quantile(self, q: float) -> float:
        """q-quantile estimate from bucket counts (NaN when empty)."""
        total = self.count
        if total == 0:
            return float("nan")
        rank = max(1, int(math.ceil(q * total)))
        idx = int(np.searchsorted(np.cumsum(self.counts), rank))
        if idx >= len(self.bounds):  # overflow bucket: report the hi edge
            return float(self.bounds[-1])
        upper = self.bounds[idx]
        return float(upper / math.sqrt(self.growth))  # geometric midpoint

    def render(self) -> list:
        """Prometheus histogram series: cumulative ``_bucket`` lines for
        occupied buckets (+ the mandatory +Inf), ``_sum``, ``_count``."""
        lines, cum = [], 0
        for i, c in enumerate(self.counts[:-1]):
            if c:
                cum += int(c)
                lines.append(
                    f'{self.name}_bucket{{le="{_fmt(self.bounds[i])}"}} {cum}'
                )
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {self.count}')
        lines.append(f"{self.name}_sum {_fmt(self.sum)}")
        lines.append(f"{self.name}_count {self.count}")
        return lines


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integral values without the '.0'."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class MetricsRegistry:
    """Orders instruments and renders the text exposition."""

    def __init__(self) -> None:
        self._metrics: list = []
        self._names: set = set()

    def _register(self, metric):
        if metric.name in self._names:
            raise ValueError(f"duplicate metric {metric.name!r}")
        self._names.add(metric.name)
        self._metrics.append(metric)
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge(name, help))

    def histogram(self, name: str, help: str = "", **kw) -> LogBucketHistogram:
        return self._register(LogBucketHistogram(name, help, **kw))

    def render(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        out = []
        for m in self._metrics:
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            out.extend(m.render())
        return "\n".join(out) + "\n"
