"""Serving metrics: log-bucketed histograms, counters, Prometheus text
exposition (DESIGN.md §12).

The serving path scores unbounded request streams, so nothing here may grow
with the stream: ``LogBucketHistogram`` stores a FIXED array of bucket
counts (no raw samples), and quantiles are derived from the buckets — the
estimate lands on the geometric midpoint of the covering bucket, so the
relative error is bounded by half the bucket growth factor (~4.5% at the
default 2**(1/8) growth), independent of stream length.

``MetricsRegistry.render()`` writes the Prometheus text exposition format
(the de-facto scrape payload); ``serve_metrics_http`` serves it over a
localhost HTTP endpoint (``serve_fedgbf --metrics-port``), and
``serve_fedgbf --metrics-out`` still dumps it to a file.

Instruments take an optional ``labels`` dict, rendering standard
``name{k="v"}`` series; several instruments may share a family name with
distinct label sets (the per-batch-size serving latency ladder), and HELP /
TYPE headers are emitted once per family.
"""

from __future__ import annotations

import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np


def _label_str(labels: dict | None) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None) -> None:
        self.name = name
        self.help = help
        self.labels = dict(labels) if labels else {}
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v

    def render(self) -> list:
        return [f"{self.name}{_label_str(self.labels)} {_fmt(self.value)}"]


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: dict | None = None) -> None:
        self.name = name
        self.help = help
        self.labels = dict(labels) if labels else {}
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def render(self) -> list:
        return [f"{self.name}{_label_str(self.labels)} {_fmt(self.value)}"]


class LogBucketHistogram:
    """Fixed-size log-bucketed histogram (bounded memory for any stream).

    Bucket upper edges grow geometrically from ``lo`` by ``growth`` up to
    ``hi``, plus one overflow bucket; values below ``lo`` land in the first
    bucket.  ``quantile(q)`` walks the cumulative counts and returns the
    geometric midpoint of the covering bucket — error ≤ (growth - 1) / 2
    relative, by construction, with no raw-sample storage.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", lo: float = 1e-5,
                 hi: float = 60.0, growth: float = 2 ** 0.125,
                 labels: dict | None = None) -> None:
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError("need 0 < lo < hi and growth > 1")
        self.name = name
        self.help = help
        self.labels = dict(labels) if labels else {}
        self.growth = growth
        n = int(math.ceil(math.log(hi / lo) / math.log(growth))) + 1
        #: upper bucket edges, seconds; the implicit last bucket is +Inf
        self.bounds = lo * growth ** np.arange(n)
        self.counts = np.zeros(n + 1, np.int64)
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[np.searchsorted(self.bounds, v)] += 1
        self.sum += v

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def quantile(self, q: float) -> float:
        """q-quantile estimate from bucket counts (NaN when empty)."""
        total = self.count
        if total == 0:
            return float("nan")
        rank = max(1, int(math.ceil(q * total)))
        idx = int(np.searchsorted(np.cumsum(self.counts), rank))
        if idx >= len(self.bounds):  # overflow bucket: report the hi edge
            return float(self.bounds[-1])
        upper = self.bounds[idx]
        return float(upper / math.sqrt(self.growth))  # geometric midpoint

    def render(self) -> list:
        """Prometheus histogram series: cumulative ``_bucket`` lines for
        occupied buckets (+ the mandatory +Inf), ``_sum``, ``_count``."""
        lab = _label_str(self.labels)
        lines, cum = [], 0
        for i, c in enumerate(self.counts[:-1]):
            if c:
                cum += int(c)
                bucket = dict(self.labels, le=_fmt(self.bounds[i]))
                lines.append(f"{self.name}_bucket{_label_str(bucket)} {cum}")
        inf = dict(self.labels, le="+Inf")
        lines.append(f"{self.name}_bucket{_label_str(inf)} {self.count}")
        lines.append(f"{self.name}_sum{lab} {_fmt(self.sum)}")
        lines.append(f"{self.name}_count{lab} {self.count}")
        return lines


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integral values without the '.0'."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class MetricsRegistry:
    """Orders instruments and renders the text exposition.

    Uniqueness is per SERIES — family name + label set — so a family may
    carry many labeled instruments (e.g. one latency histogram per batch
    rung); HELP/TYPE render once per family, on first appearance.
    """

    def __init__(self) -> None:
        self._metrics: list = []
        self._names: set = set()

    def _register(self, metric):
        key = metric.name + _label_str(metric.labels)
        if key in self._names:
            raise ValueError(f"duplicate metric {key!r}")
        self._names.add(key)
        self._metrics.append(metric)
        return metric

    def counter(self, name: str, help: str = "",
                labels: dict | None = None) -> Counter:
        return self._register(Counter(name, help, labels=labels))

    def gauge(self, name: str, help: str = "",
              labels: dict | None = None) -> Gauge:
        return self._register(Gauge(name, help, labels=labels))

    def histogram(self, name: str, help: str = "", **kw) -> LogBucketHistogram:
        return self._register(LogBucketHistogram(name, help, **kw))

    def render(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        out, seen = [], set()
        for m in self._metrics:
            if m.name not in seen:
                seen.add(m.name)
                if m.help:
                    out.append(f"# HELP {m.name} {m.help}")
                out.append(f"# TYPE {m.name} {m.kind}")
            out.extend(m.render())
        return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# HTTP scrape endpoint (DESIGN.md §14): the registry's exposition, served
# ---------------------------------------------------------------------------
class MetricsHTTPServer:
    """Localhost Prometheus scrape endpoint over a live registry.

    A daemon-threaded ``ThreadingHTTPServer`` whose GET handler renders the
    registry *at scrape time* — no snapshotting, the instruments mutate as
    the serving loop runs and the scraper always sees the current counts.
    ``port=0`` binds an ephemeral port (tests); ``.port`` reports the bound
    one.  ``close()`` shuts the listener down.
    """

    CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1") -> None:
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                body = outer.registry.render().encode()
                self.send_response(200)
                self.send_header("Content-Type", outer.CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # scrapes stay off stderr
                pass

        self.registry = registry
        self._server = ThreadingHTTPServer((host, port), Handler)
        self.host = host
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()


def serve_metrics_http(registry: MetricsRegistry, port: int = 0,
                       host: str = "127.0.0.1") -> MetricsHTTPServer:
    """Start a scrape endpoint for ``registry``; returns the server handle."""
    return MetricsHTTPServer(registry, port=port, host=host)
