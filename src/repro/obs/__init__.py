"""Observability layer: host-side spans, Perfetto trace export, serving
metrics (DESIGN.md §12).

Split by concern so nothing here drags jax into import time:

* ``trace``    — ``Span``/``Tracer`` with a zero-overhead disabled path
                 (``NULL_TRACER``) plus the process-global tracer seam the
                 launchers flip on with ``--trace``;
* ``perfetto`` — Chrome-trace/Perfetto JSON exporter merging host spans,
                 per-round ``TrainHistory`` timing/telemetry, and the
                 ledger's per-round wire bytes into one timeline;
* ``metrics``  — log-bucketed latency histograms, counters/gauges, and a
                 Prometheus text exposition writer for the serving path;
* ``log``      — structured per-round JSON lines (``--log-json``) and their
                 parser (consumed by benchmarks).
"""

from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    Span,
    Tracer,
    global_tracer,
    set_global_tracer,
)
