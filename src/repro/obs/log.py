"""Structured per-round training log (``train_fedgbf --log-json``).

One JSON object per round — schedule, wall time, gated metrics, in-graph
liveness telemetry, and the ledger's per-round wire bytes — replacing the
ad-hoc ``[round NNN] ...`` prints with something machines consume
(``benchmarks/obs_bench.py`` parses these lines back).

The scan engine has no per-round host sync (DESIGN.md §4), so the lines are
rendered AFTER training from the fetched history: this is a structured
record of the run, not a live stream.
"""

from __future__ import annotations

import json


def round_records(history, per_round_bytes=None, faults=None) -> list:
    """One dict per round from a ``TrainHistory`` (+ optional ledger rows).

    ``per_round_bytes`` is ``ProtocolLedger.per_round_measured()`` — the
    same rows the trace exporter uses, so log, trace and ledger agree
    byte-for-byte.  ``faults`` is an optional list (one dict per executed
    round) of fault-runtime counters — ``faults_injected`` / ``retries`` /
    ``degraded_parties`` (DESIGN.md §13) — attached verbatim under
    ``"faults"``.  Round numbers are ABSOLUTE: a resumed segment starting at
    ``history.start_round`` logs rounds ``start_round + 1 ...``, so stitched
    logs from a killed-and-resumed run line up with an uninterrupted one.
    """
    base = int(getattr(history, "start_round", 0) or 0)
    eval_at = {m: i for i, m in enumerate(history.rounds)}
    tele = history.telemetry or {}
    recs = []
    for i in range(len(history.n_trees)):
        rec = {
            "event": "round",
            "round": base + i + 1,
            "n_trees": int(history.n_trees[i]),
            "rho_id": round(float(history.rho_id[i]), 6),
            "wall_s": (round(float(history.wall_time_s[i]), 6)
                       if i < len(history.wall_time_s) else None),
            "metrics": None,
            "valid": None,
        }
        j = eval_at.get(base + i + 1)
        if j is not None:
            rec["metrics"] = {k: float(v) for k, v in history.train[j].items()}
            if j < len(history.valid):
                rec["valid"] = {k: float(v)
                                for k, v in history.valid[j].items()}
        if tele.get("split_nodes_per_level") is not None:
            per_level = tele["split_nodes_per_level"]
            if i < len(per_level):
                rec["liveness"] = {
                    "split_nodes_per_level": [int(v) for v in per_level[i]],
                    "sampled_entries": int(tele["sampled_entries"][i]),
                }
        if per_round_bytes is not None and i < len(per_round_bytes):
            rec["bytes"] = {k: int(v) for k, v in per_round_bytes[i].items()
                            if v}
        if faults is not None and i < len(faults) and faults[i]:
            rec["faults"] = faults[i]
        recs.append(rec)
    return recs


def render_round_lines(history, per_round_bytes=None, faults=None) -> list:
    """The ``--log-json`` lines: compact one-object-per-line JSON."""
    return [json.dumps(r, separators=(",", ":"))
            for r in round_records(history, per_round_bytes, faults)]


def parse_round_log(text: str) -> list:
    """Recover the round records from mixed driver output: non-JSON lines
    (backend banners, ledger prints) are skipped, only ``event == "round"``
    objects survive."""
    recs = []
    for line in text.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            obj = json.loads(line)
        except ValueError:
            continue
        if isinstance(obj, dict) and obj.get("event") == "round":
            recs.append(obj)
    return recs
