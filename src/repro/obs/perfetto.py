"""Chrome-trace/Perfetto JSON export (DESIGN.md §12).

Everything a training run knows about time and bytes merges into ONE
timeline in the Chrome trace event format (Perfetto opens it directly):

* live host spans from a ``trace.Tracer`` → ``"X"`` complete events, one
  tid per span ``track`` (named via ``thread_name`` metadata events);
* ``TrainHistory`` rounds → derived per-round spans on the ``rounds``
  track, positioned from the scan engine's in-program segment ticks
  (``history.segments`` carries absolute host-clock [t0, t1] per segment;
  rounds inside a segment slice it uniformly — the engine's granularity
  limit, see ``TrainHistory.wall_time_s``);
* the ledger's per-round wire bytes (``ProtocolLedger.per_round_measured``)
  → per-phase spans on ``wire/<phase>`` tracks whose ``args.bytes`` sum
  EXACTLY to ``ProtocolLedger.breakdown()["measured"]`` — both sides are
  the same ``protocol.per_round_cost`` arithmetic, so the trace is a view
  of the ledger, not a second accounting;
* in-graph telemetry (live split-node counts) → ``"C"`` counter events.

Timestamps are absolute ``perf_counter`` microseconds; Perfetto normalizes
to the trace minimum on load.
"""

from __future__ import annotations

import json
import os


def to_chrome_trace(tracer, metadata=None) -> dict:
    """Render a ``trace.Tracer`` to a Chrome trace event dict."""
    events: list = []
    tids: dict = {}

    def tid_of(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids)
            events.append({
                "ph": "M", "name": "thread_name", "pid": 0,
                "tid": tids[track], "args": {"name": track},
            })
        return tids[track]

    tid_of("host")  # keep the live-span track first in the UI
    for s in tracer.spans:
        events.append({
            "ph": "X", "name": s.name, "cat": s.cat,
            "ts": s.t0 * 1e6, "dur": max(0.0, s.t1 - s.t0) * 1e6,
            "pid": 0, "tid": tid_of(s.track), "args": s.args or {},
        })
    for name, ts, values in tracer.counters:
        events.append({
            "ph": "C", "name": name, "ts": ts * 1e6, "pid": 0,
            "args": values,
        })
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if metadata:
        doc["metadata"] = dict(metadata)
    return doc


def export_chrome_trace(path: str, tracer, metadata=None) -> int:
    """Write the Perfetto-loadable JSON; returns the event count."""
    doc = to_chrome_trace(tracer, metadata)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])


def round_intervals(history) -> list:
    """Absolute host-clock [t0, t1] per round from ``history.segments``.

    Returns ``[(round_index_0based, t0, t1), ...]`` sorted by round.  Rounds
    inside a segment share its measured wall uniformly (the scan engine's
    per-round granularity limit); the loop engine records one single-round
    segment per round, so its intervals are exact.  Empty when the history
    carries no segment anchors (e.g. hand-built histories).
    """
    out = []
    for seg in history.segments:
        rounds = max(1, int(seg["rounds"]))
        per = (seg["t1"] - seg["t0"]) / rounds
        for r in range(rounds):
            out.append((int(seg["first_round"]) + r,
                        seg["t0"] + r * per, seg["t0"] + (r + 1) * per))
    out.sort()
    return out


def add_training_timeline(tracer, history, per_round_bytes=None,
                          faults=None) -> None:
    """Merge a ``TrainHistory`` (and optionally the ledger's per-round wire
    bytes) into ``tracer`` as derived spans + counters.

    Per-round spans land on the ``rounds`` track carrying schedule, metric
    and liveness args; each wire phase gets its own ``wire/<phase>`` track
    whose span ``args.bytes`` are exactly ``per_round_bytes`` (i.e. the
    ledger's own ``protocol.per_round_cost`` rows).  ``faults`` (optional,
    one dict per executed round — DESIGN.md §13) adds a ``faults`` track:
    one span per round that actually saw injected faults, retries, or party
    degradation, so chaos shows up as a first-class timeline lane.

    Segment anchors carry ABSOLUTE ``first_round``; per-executed-round
    lists (``n_trees`` etc.) are indexed relative to ``history.start_round``
    so resumed segments land at their true round numbers.
    """
    tele = history.telemetry or {}
    per_level = tele.get("split_nodes_per_level")
    eval_at = {m: i for i, m in enumerate(history.rounds)}
    base = int(getattr(history, "start_round", 0) or 0)
    cum: dict = {}
    for i, t0, t1 in round_intervals(history):
        k = i - base  # executed-round index into the history lists
        args = {
            "n_trees": int(history.n_trees[k]),
            "rho_id": round(float(history.rho_id[k]), 6),
        }
        if (i + 1) in eval_at:
            args["metrics"] = history.train[eval_at[i + 1]]
        if per_level is not None and k < len(per_level):
            args["split_nodes_per_level"] = per_level[k]
            tracer.counter("live_split_nodes",
                           {"nodes": int(sum(per_level[k]))}, ts=t1)
        tracer.add_span(f"round {i + 1}", t0, t1, cat="round",
                        track="rounds", args=args)
        if faults is not None and k < len(faults) and faults[k]:
            fa = faults[k]
            if (fa.get("faults_injected") or fa.get("retries")
                    or fa.get("degraded_parties")):
                tracer.add_span(f"faults r{i + 1}", t0, t1, cat="fault",
                                track="faults", args=dict(fa))
        if per_round_bytes is not None and k < len(per_round_bytes):
            for phase, nbytes in per_round_bytes[k].items():
                if not nbytes:
                    continue
                tracer.add_span(phase, t0, t1, cat="wire",
                                track=f"wire/{phase}",
                                args={"bytes": int(nbytes)})
                cum[phase] = cum.get(phase, 0) + int(nbytes)
                tracer.counter(f"wire_bytes/{phase}",
                               {"bytes": cum[phase]}, ts=t1)


def wire_span_phase_totals(tracer) -> dict:
    """Sum the exported wire-span bytes per phase — the quantity the
    acceptance check reconciles against ``ProtocolLedger.breakdown()``."""
    out: dict = {}
    for s in tracer.spans:
        if s.cat == "wire" and s.args:
            out[s.name] = out.get(s.name, 0) + int(s.args.get("bytes", 0))
    return out
