"""Batched serving driver (deliverable b): prefill + decode loop with a KV
cache, greedy/temperature sampling over batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as model_mod


def generate(params, cfg, prompts: jnp.ndarray, gen_len: int,
             temperature: float = 0.0, stubs: dict | None = None):
    """prompts: (B, P) int32 -> (B, P + gen_len)."""
    B, P = prompts.shape
    max_len = P + gen_len
    cache = model_mod.init_cache(cfg, B, max_len)
    if cfg.encoder is not None:
        enc_out = model_mod.encode(params, stubs["frames"], cfg)
        cache = model_mod.fill_cross_cache(params, cache, enc_out, cfg)

    step = jax.jit(
        lambda p, c, t, pos: model_mod.decode_step(p, c, t, pos, cfg)
    )
    key = jax.random.PRNGKey(0)
    out = [prompts]
    tok = None
    # teacher-forced prefill through the decode path (fills every cache)
    for t in range(P):
        logits, cache = step(params, cache, prompts[:, t : t + 1], jnp.int32(t))
    tok = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
    for t in range(P, max_len):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(t))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, 0, : cfg.vocab] / temperature
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    stubs = {}
    if cfg.frontend == "audio_stub":
        stubs["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder.num_frames, cfg.d_model)
        ), jnp.float32)

    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen,
                   temperature=args.temperature, stubs=stubs)
    dt = time.time() - t0
    total_steps = args.prompt_len + args.gen
    print(f"arch={cfg.name} batch={args.batch} "
          f"steps={total_steps} wall={dt:.1f}s "
          f"({args.batch * total_steps / dt:.1f} tok/s incl. compile)")
    print("sample token ids:", np.asarray(out[0, :24]))


if __name__ == "__main__":
    main()
