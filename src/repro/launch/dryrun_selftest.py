"""In-pytest dry-run smoke: lowers train/prefill/decode for smoke configs on
a small forced-device mesh (run in a subprocess, like federation.selftest):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.dryrun_selftest
"""

from __future__ import annotations

import sys

import jax

from repro.compat import use_mesh
from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch import shapes as shapes_mod
from repro.launch.dryrun import build_step
from repro.launch.mesh import make_test_mesh

SMOKE_SPECS = [
    shapes_mod.ShapeSpec("smoke_train", "train", 64, 8),
    shapes_mod.ShapeSpec("smoke_prefill", "prefill", 64, 8),
    shapes_mod.ShapeSpec("smoke_decode", "decode", 64, 8),
]

# smoke subset spanning all families
ARCHS = ["smollm-135m", "gemma2-2b", "zamba2-7b", "rwkv6-7b",
         "granite-moe-3b-a800m", "whisper-large-v3"]


def main() -> int:
    mesh = make_test_mesh()
    failures = 0
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        for spec in SMOKE_SPECS:
            try:
                fn, args, in_sh = build_step(cfg, spec, mesh)
                with use_mesh(mesh):
                    compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
                cost = compiled.cost_analysis()
                if isinstance(cost, (list, tuple)):
                    cost = cost[0]
                print(f"OK {arch} {spec.name} flops/dev={cost.get('flops', 0):.3e}")
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"FAIL {arch} {spec.name}: {type(e).__name__}: "
                      f"{str(e)[:200]}")
    print("DRYRUN SELFTEST " + ("FAILED" if failures else "PASSED"))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
