"""FedGBF training driver — the paper's workload under the real VFL runtime.

Execution is selected by a named ``TreeBackend`` from the registry
(DESIGN.md §1):

    # centralized-local (paper's evaluation mode, §4.2)
    PYTHONPATH=src python -m repro.launch.train_fedgbf --dataset default_credit_card

    # federated on a device mesh (parties = model-axis shards)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train_fedgbf \
        --dataset default_credit_card --backend vfl-argmax --parties 4

Fault-tolerant runtime (DESIGN.md §13):

    # chaos transport: seeded drop/corrupt/dup/delay faults at the level
    # exchange; checksum-verified retransmission keeps the model
    # bit-identical and the retried bytes reconcile in the ledger.
    ... --backend vfl-histogram --parties 2 \
        --chaos-drop 0.05 --chaos-corrupt 0.02 --chaos-seed 13

    # party dropout: parties that exhaust --retry-max degrade the round
    # (their feature candidates are masked from split search);
    # --dropout-fallback gradientless adds party-local trees instead.
    ... --party-dropout 0.3 --dropout-seed 0 --retry-max 3 \
        --dropout-fallback gradientless

    # bit-identical segment resume: checkpoint the boosting carry every
    # N rounds (atomic write + sha256 sidecar), kill anywhere, resume to
    # the same bytes as an uninterrupted run.
    ... --checkpoint ckpt/run --checkpoint-every 2 [--stop-after-round 2]
    ... --checkpoint ckpt/run --checkpoint-every 2 --resume
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as checkpoint_io
from repro.core import backend as backend_mod
from repro.core import boosting, metrics
from repro.core import objective as objective_mod
from repro.core.types import TreeConfig, unpack_ensemble
from repro.data import synthetic, tabular
from repro.federation import chaos as chaos_mod
from repro.federation import runtime as runtime_mod
from repro.federation import vfl  # noqa: F401  (registers vfl-* backends)
from repro.launch import mesh as mesh_mod
from repro.obs import log as obs_log
from repro.obs import perfetto
from repro.obs import trace as obs_trace

# All registered backends are launchable, incl. the compressed-transport
# variants (vfl-histogram-q8/q16, vfl-argmax-topk; DESIGN.md §5) and their
# fault-injecting -chaos twins (DESIGN.md §13).
VFL_BACKENDS = tuple(
    n for n in backend_mod.available_backends() if n.startswith("vfl")
)


def _merge_histories(hists: list) -> "boosting.TrainHistory":
    """Stitch per-chunk ``TrainHistory`` objects (contiguous round windows)
    into one history covering the union — used by the ``--checkpoint-every``
    chunked training loop so the telemetry outputs see a single run."""
    if len(hists) == 1:
        return hists[0]
    out = boosting.TrainHistory(engine=hists[0].engine,
                                start_round=hists[0].start_round)
    for h in hists:
        out.rounds.extend(h.rounds)
        out.train.extend(h.train)
        out.valid.extend(h.valid)
        out.n_trees.extend(h.n_trees)
        out.rho_id.extend(h.rho_id)
        out.wall_time_s.extend(h.wall_time_s)
        out.segments.extend(h.segments)
        out.overhead_s += h.overhead_s
    if all(h.telemetry is not None for h in hists):
        keys = hists[0].telemetry.keys()
        out.telemetry = {
            k: np.concatenate([np.asarray(h.telemetry[k]) for h in hists])
            for k in keys
        }
    out.final_margin = hists[-1].final_margin
    out.final_margin_valid = hists[-1].final_margin_valid
    return out


def _stitch_models(prefix_model, models: list) -> "boosting.EnsembleModel":
    """Concatenate the resumed prefix (if any) and the chunk models into the
    full ensemble; all pieces share the same deterministic bin edges."""
    pieces = ([prefix_model] if prefix_model is not None else []) + models
    head = pieces[0]
    forests = tuple(f for m in pieces for f in m.forests)
    return boosting.EnsembleModel(
        forests=forests, learning_rate=head.learning_rate,
        base_score=head.base_score, bin_edges=head.bin_edges,
        loss=head.loss, max_depth=head.max_depth,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=list(synthetic.DATASETS),
                    default="default_credit_card")
    ap.add_argument("--model", choices=["dynamic_fedgbf", "fedgbf",
                                        "secureboost", "federated_forest"],
                    default="dynamic_fedgbf")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--loss", default="logistic",
                    help="objective registry name (DESIGN.md §11): logistic, "
                         "squared, softmax<K> (e.g. softmax3 for "
                         "--dataset credit_risk_tiers), quantile[@alpha]. "
                         "K-channel objectives widen the histogram stats "
                         "axis to 2K+1 through every backend.")
    ap.add_argument("--n", type=int, default=0, help="subsample dataset")
    ap.add_argument("--max-depth", type=int, default=3)
    ap.add_argument("--backend", default="local",
                    choices=("local", "local-pallas") + VFL_BACKENDS,
                    help="named TreeBackend from the registry")
    ap.add_argument("--parties", type=int, default=2,
                    help="party count for vfl-* backends")
    ap.add_argument("--data-shards", type=int, default=0,
                    help="row shards over the mesh data axis for vfl-*-"
                         "sharded backends (DESIGN.md §8): each host holds "
                         "(n/data_shards, ...) rows and the per-level "
                         "histogram psums over the data axis.  0 = auto "
                         "(spread the remaining devices).  Uneven n pads "
                         "with weight-0 rows inside the backend.")
    ap.add_argument("--engine", default="scan", choices=("scan", "loop"),
                    help="training engine: static-shape scanned (one XLA "
                         "program for all rounds) or the legacy per-round "
                         "loop (DESIGN.md §4)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="evaluate metrics every k rounds (schedule and "
                         "timing are recorded every round regardless)")
    ap.add_argument("--sampling", default="uniform",
                    choices=("uniform", "goss"),
                    help="rho_id sample policy: uniform (paper eq. 4) or "
                         "GOSS (top-|g| + amplified random rest; DESIGN.md §5)")
    ap.add_argument("--hist-subtraction", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="sibling-subtraction histogram pipeline (DESIGN.md "
                         "§6, ON by default): levels >= 1 compute/exchange "
                         "only left-child histograms and derive the siblings "
                         "— halves the per-level histogram work and, on "
                         "vfl-* backends, the dominant wire message (1.75x "
                         "phase cut at depth 3).  --no-hist-subtraction "
                         "restores the direct reference pass.")
    ap.add_argument("--max-active-nodes", type=int, default=0,
                    help="frontier-compaction budget for deep trees "
                         "(DESIGN.md §9): static cap on live frontier nodes "
                         "per level; dead nodes are masked out of histograms "
                         "and the party exchange.  0 = uncompacted (use "
                         "with --max-depth > 3).")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace/Perfetto JSON timeline of "
                         "the run (DESIGN.md §12): host spans (binning, "
                         "compile, per-segment execution), per-round spans "
                         "with metrics + frontier liveness, and — on vfl-* "
                         "backends — per-phase wire-byte spans whose bytes "
                         "reconcile exactly with ProtocolLedger.breakdown()")
    ap.add_argument("--log-json", action="store_true",
                    help="emit one structured JSON line per round (schedule, "
                         "wall time, metrics, liveness, wire bytes) instead "
                         "of the ad-hoc [round NNN] prints; parsed by "
                         "benchmarks/obs_bench.py")
    ap.add_argument("--shared-root", action="store_true",
                    help="shared-root caching (DESIGN.md §9): the level-0 "
                         "pass computes ONE unmasked histogram per round "
                         "and derives each tree's root as shared - delta "
                         "(masked-out rows); engaged per round when the "
                         "rho_id schedule clears the 0.5 crossover "
                         "(uniform sampling only).")
    # --- fault-tolerant federation runtime (DESIGN.md §13) ------------------
    ap.add_argument("--chaos-drop", type=float, default=0.0,
                    help="chaos transport: probability a level-exchange "
                         "transmission attempt is dropped (recovered by "
                         "checksum-verified retransmission, so results stay "
                         "bit-identical; only wire bytes grow)")
    ap.add_argument("--chaos-corrupt", type=float, default=0.0,
                    help="chaos transport: probability an attempt is "
                         "bit-corrupted in flight (detected by payload "
                         "checksum, recovered by retransmission)")
    ap.add_argument("--chaos-dup", type=float, default=0.0,
                    help="chaos transport: probability the final delivery is "
                         "duplicated (idempotent receive; accounting only)")
    ap.add_argument("--chaos-delay", type=float, default=0.0,
                    help="chaos transport: probability the final delivery is "
                         "delayed one poll (accounting only)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed of the deterministic chaos fault plan")
    ap.add_argument("--chaos-max-retries", type=int, default=3,
                    help="in-graph retransmission budget per exchange slot")
    ap.add_argument("--party-dropout", type=float, default=0.0,
                    help="probability a party misses a coordinator poll; a "
                         "party exhausting --retry-max polls is DEGRADED for "
                         "the round (its feature candidates are masked from "
                         "split search — bit-identical to a run that never "
                         "had them)")
    ap.add_argument("--dropout-seed", type=int, default=0,
                    help="seed of the deterministic party-availability draw")
    ap.add_argument("--retry-max", type=int, default=3,
                    help="coordinator re-polls (with exponential backoff) "
                         "before degrading a silent party for the round")
    ap.add_argument("--dropout-fallback", default="none",
                    choices=("none", "gradientless"),
                    help="gradientless: parties degraded in >=1 round also "
                         "train party-local gradient-less trees (DESIGN.md "
                         "§7) whose margins are ADDED at test evaluation")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="train-state checkpoint path (atomic npz + sha256 "
                         "sidecar); segment boundaries write here")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                    help="checkpoint the boosting carry every N rounds "
                         "(0 = only at --stop-after-round / completion)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint: replays the full-run "
                         "RNG schedule so the finished ensemble is "
                         "byte-identical to an uninterrupted run")
    ap.add_argument("--stop-after-round", type=int, default=0, metavar="K",
                    help="stop (and checkpoint) after absolute round K — "
                         "the kill half of the kill-and-resume smoke")
    args = ap.parse_args()

    want_obs = bool(args.trace) or args.log_json
    tracer = obs_trace.Tracer() if args.trace else obs_trace.NULL_TRACER
    obs_trace.set_global_tracer(tracer)  # checkpoint I/O etc. hang off this

    ds = synthetic.load(args.dataset, n=args.n or None)
    tree = TreeConfig(max_depth=args.max_depth, num_bins=32,
                      hist_subtraction=args.hist_subtraction,
                      max_active_nodes=args.max_active_nodes,
                      shared_root=args.shared_root)
    cfg = {
        "dynamic_fedgbf": lambda: boosting.dynamic_fedgbf_config(args.rounds, tree=tree),
        "fedgbf": lambda: boosting.FedGBFConfig(
            rounds=args.rounds, tree=tree, n_trees_max=5, n_trees_min=5,
            rho_id_min=0.3, rho_id_max=0.3),
        "secureboost": lambda: boosting.secureboost_config(args.rounds, tree=tree),
        "federated_forest": lambda: boosting.federated_forest_config(
            n_trees=args.rounds, tree=tree),
    }[args.model]()
    if args.sampling != "uniform":
        cfg = dataclasses.replace(cfg, sampling=args.sampling)
    if args.loss != cfg.loss:
        cfg = dataclasses.replace(cfg, loss=args.loss)
    obj = objective_mod.get_objective(cfg.loss)

    x_train, y_train = ds.x_train, ds.y_train
    # --- chaos transport (DESIGN.md §13): rates > 0 auto-select the -chaos
    # twin of the requested backend; an explicit -chaos name with no rates
    # runs the zero-fault spec (checksums only — bit-identical results).
    backend_name = args.backend
    chaos_rates = (args.chaos_drop, args.chaos_corrupt,
                   args.chaos_dup, args.chaos_delay)
    if any(r > 0 for r in chaos_rates) and not backend_name.endswith("-chaos"):
        backend_name += "-chaos"
    chaos = None
    if backend_name.endswith("-chaos"):
        if backend_name not in VFL_BACKENDS:
            raise SystemExit(
                f"chaos transport needs a vfl-* backend, got {args.backend!r}"
            )
        chaos = chaos_mod.ChaosSpec(
            drop=args.chaos_drop, corrupt=args.chaos_corrupt,
            dup=args.chaos_dup, delay=args.chaos_delay,
            seed=args.chaos_seed, max_retries=args.chaos_max_retries,
        )
        print(f"chaos transport: {chaos.tag} (faults are injected, detected "
              "by checksum and retransmitted — results stay bit-identical)")
    federated = backend_name in VFL_BACKENDS
    if federated:
        aggregation = "argmax" if "argmax" in backend_name else "histogram"
        n_dev = len(jax.devices())
        if n_dev < args.parties:
            raise SystemExit(
                f"need >= {args.parties} devices (set XLA_FLAGS=--xla_force_"
                f"host_platform_device_count=...), got {n_dev}"
            )
        x_train, d_pad = tabular.pad_features(x_train, args.parties)
        mesh = mesh_mod.make_vfl_mesh(args.parties, args.data_shards)
        shards = mesh.shape["data"]
        sharded = "-sharded" in backend_name
        if sharded and x_train.shape[0] % shards:
            # shard_map needs n divisible by the data-axis extent; the
            # backend pads the remainder with weight-0 rows internally
            # (after the subsampling masks are drawn over the real n, so
            # the exact-count sampling semantics are untouched).
            print(f"sharded backend: n={x_train.shape[0]} pads to "
                  f"{-(-x_train.shape[0] // shards) * shards} inside the "
                  f"backend ({shards} sample shards, weight-0 rows)")
        bk_kw = {"chaos": chaos} if chaos is not None else {}
        backend = backend_mod.get_backend(backend_name, mesh=mesh, tree=tree,
                                          **bk_kw)
        print(f"backend={backend.name}: {args.parties} parties x "
              f"{shards} data shards, aggregation={aggregation}, "
              f"transport={backend.descriptor.transport}"
              + (", async exchange" if backend.descriptor.async_exchange
                 else ""))
        # measured wire bytes reconciled against the wire model, plus the
        # paper-world Paillier estimate — one shared entry (DESIGN.md §5)
        from repro.federation import compress

        ledger = compress.reconciled_ledger(
            mesh, tree, cfg, aggregation=aggregation,
            transport=backend.descriptor.transport_spec,
            n_samples=x_train.shape[0], num_features=d_pad,
            shard_samples=sharded,
            async_exchange=backend.descriptor.async_exchange,
            n_channels=obj.n_classes,
            chaos=chaos,
        )
        cost = ledger.predicted_paillier()
        print(f"paillier-model bytes (ledger): {cost.total/1e6:.1f} MB "
              f"{cost.breakdown()}")
        rec = ledger.reconcile()
        print(f"wire bytes: measured={rec['total']['measured']/1e6:.1f} MB "
              f"predicted={rec['total']['predicted']/1e6:.1f} MB "
              f"(match={rec['total']['match']})")
    else:
        backend = backend_mod.get_backend(backend_name)

    # --- party-dropout degradation (DESIGN.md §13) --------------------------
    dropout_sched = None
    round_mask = None
    if args.party_dropout > 0:
        policy = runtime_mod.RetryPolicy(max_retries=args.retry_max)
        dropout_sched = runtime_mod.dropout_schedule(
            args.party_dropout, cfg.rounds, args.parties,
            seed=args.dropout_seed, policy=policy,
        )
        round_mask = runtime_mod.degradation_masks(
            dropout_sched.degraded, x_train.shape[1], args.parties,
        )
        print(f"party-dropout: {dropout_sched.degraded_rounds}/{cfg.rounds} "
              f"degraded rounds, {int(dropout_sched.retries.sum())} retries, "
              f"simulated backoff {dropout_sched.backoff_s:.2f}s")

    # --- segment checkpoints + bit-identical resume (DESIGN.md §13) ---------
    fingerprint = json.dumps({
        "dataset": args.dataset, "model": args.model, "rounds": cfg.rounds,
        "loss": cfg.loss, "backend": backend_name, "parties": args.parties,
        "engine": args.engine, "sampling": cfg.sampling,
        "max_depth": args.max_depth, "n": args.n,
        "party_dropout": args.party_dropout,
        "dropout_seed": args.dropout_seed, "retry_max": args.retry_max,
    }, sort_keys=True)
    start = 0
    margin_carry = None
    prefix_model = None
    if args.resume:
        if not args.checkpoint:
            raise SystemExit("--resume needs --checkpoint PATH")
        state = checkpoint_io.load_train_state(args.checkpoint)
        if state["config_fingerprint"] != fingerprint:
            raise SystemExit(
                "--resume: checkpoint was written by a different training "
                "configuration (fingerprint mismatch)"
            )
        start = int(state["completed_rounds"])
        margin_carry = state["margin"]
        prefix_model = unpack_ensemble(state["packed"])
        print(f"resume: {start} completed rounds restored "
              f"from {args.checkpoint}")
    stop_limit = args.stop_after_round or cfg.rounds
    if not start < stop_limit <= cfg.rounds:
        raise SystemExit(
            f"--stop-after-round must be in ({start}, {cfg.rounds}]"
        )

    chunk = args.checkpoint_every or (stop_limit - start)
    models, hists = [], []
    a = start
    while a < stop_limit:
        b = min(a + chunk, stop_limit)
        model_c, hist_c = boosting.train_fedgbf(
            jnp.asarray(x_train), jnp.asarray(y_train), cfg,
            jax.random.PRNGKey(0),
            backend=backend, verbose=not args.log_json, engine=args.engine,
            eval_every=args.eval_every, tracer=tracer, telemetry=want_obs,
            round_feature_mask=round_mask, start_round=a, stop_round=b,
            init_margin=margin_carry,
        )
        models.append(model_c)
        hists.append(hist_c)
        margin_carry = hist_c.final_margin
        a = b
        if args.checkpoint:
            checkpoint_io.save_train_state(
                args.checkpoint, _stitch_models(prefix_model, models),
                margin=margin_carry, completed_rounds=a,
                fingerprint=fingerprint,
            )
            print(f"checkpoint: {a} rounds -> {args.checkpoint}")
    model = _stitch_models(prefix_model, models)
    hist = _merge_histories(hists)
    print(f"engine={hist.engine}: total train wall {hist.total_wall_time_s:.2f}s "
          f"over {len(hist.n_trees)} rounds")
    if args.stop_after_round:
        print(f"stopped after round {stop_limit} (checkpointed); "
              "re-run with --resume to continue")

    # --- unified telemetry outputs (DESIGN.md §12) --------------------------
    per_round_bytes = None
    if federated:
        # ledger rows are absolute over the full schedule; clip to the
        # executed window so they line up with the (possibly resumed) history
        rows = ledger.per_round_measured()
        per_round_bytes = rows[start:start + len(hist.n_trees)]
    faults = None
    if want_obs and (chaos is not None or dropout_sched is not None):
        faults = [dict() for _ in range(len(hist.n_trees))]
        if chaos is not None:
            plan = chaos_mod.plan_summary(
                chaos,
                chaos_mod.n_slots_per_tree(aggregation, args.max_depth),
            )
            for r in faults:  # the static plan repeats per traced tree/round
                r["faults_injected"] = plan["faults_injected"]
                r["retries"] = plan["retries"]
                r["dropped"] = plan["dropped"]
                r["corrupted"] = plan["corrupted"]
        if dropout_sched is not None:
            for i, r in enumerate(faults):
                s = dropout_sched.round_summary(start + i)
                r["retries"] = r.get("retries", 0) + s["retries"]
                r["degraded_parties"] = s["degraded_parties"]
    if args.log_json:
        for line in obs_log.render_round_lines(hist, per_round_bytes, faults):
            print(line)
    if args.trace:
        perfetto.add_training_timeline(tracer, hist, per_round_bytes, faults)
        n_events = perfetto.export_chrome_trace(
            args.trace, tracer,
            metadata={"dataset": args.dataset, "backend": backend_name,
                      "engine": hist.engine, "rounds": args.rounds},
        )
        print(f"trace: {n_events} events -> {args.trace} "
              f"(open in ui.perfetto.dev)")
        if federated and start == 0 and stop_limit == cfg.rounds:
            # acceptance contract: the trace's histogram-phase span bytes
            # are the ledger's own per-round rows, so they must sum to
            # breakdown()["measured"] exactly
            span_hist = perfetto.wire_span_phase_totals(tracer)
            led_hist = ledger.breakdown()["measured"]
            match = span_hist.get("histograms", 0) == led_hist["histograms"]
            print(f"trace: histogram-phase span bytes "
                  f"{span_hist.get('histograms', 0)} vs ledger "
                  f"{led_hist['histograms']} (match={match})")
            if not match:
                raise SystemExit("trace/ledger histogram bytes diverged")
    x_test = ds.x_test
    if federated:
        x_test, _ = tabular.pad_features(x_test, args.parties)
    margin = boosting.predict(model, jnp.asarray(x_test))
    if args.dropout_fallback == "gradientless" and dropout_sched is not None:
        # party-local gradient-less trees for every party that lost >= 1
        # round: their tree contributions (margin minus base) add onto the
        # main ensemble's test margin (DESIGN.md §7 composition rule)
        from repro.federation import gradientless

        for p in runtime_mod.degraded_parties(dropout_sched):
            sl = runtime_mod.party_column_slice(
                p, x_train.shape[1], args.parties)
            gl_model, gl_info = gradientless.train_gradientless(
                jnp.asarray(np.asarray(x_train)[:, sl]),
                jnp.asarray(y_train), cfg,
                jax.random.PRNGKey(1000 + p), num_parties=1,
            )
            delta = (boosting.predict(gl_model,
                                      jnp.asarray(np.asarray(x_test)[:, sl]))
                     - gl_model.base_score)
            margin = margin + delta
            print(f"gradientless fallback: party {p} "
                  f"({gl_model.total_trees} local trees) added to margin")
    if obj.n_classes > 1:
        rep = metrics.multiclass_report(jnp.asarray(ds.y_test), margin)
        print(f"TEST: acc={rep['acc']:.4f} macro_f1={rep['macro_f1']:.4f} "
              f"(total trees: {model.total_trees}, K={obj.n_classes})")
    else:
        rep = metrics.classification_report(jnp.asarray(ds.y_test), margin)
        print(f"TEST: auc={rep['auc']:.4f} acc={rep['acc']:.4f} "
              f"f1={rep['f1']:.4f} (total trees: {model.total_trees})")


if __name__ == "__main__":
    main()
