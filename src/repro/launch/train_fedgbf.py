"""FedGBF training driver — the paper's workload under the real VFL runtime.

Execution is selected by a named ``TreeBackend`` from the registry
(DESIGN.md §1):

    # centralized-local (paper's evaluation mode, §4.2)
    PYTHONPATH=src python -m repro.launch.train_fedgbf --dataset default_credit_card

    # federated on a device mesh (parties = model-axis shards)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train_fedgbf \
        --dataset default_credit_card --backend vfl-argmax --parties 4
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import boosting, metrics
from repro.core import objective as objective_mod
from repro.core.types import TreeConfig
from repro.data import synthetic, tabular
from repro.federation import vfl  # noqa: F401  (registers vfl-* backends)
from repro.launch import mesh as mesh_mod
from repro.obs import log as obs_log
from repro.obs import perfetto
from repro.obs import trace as obs_trace

# All registered backends are launchable, incl. the compressed-transport
# variants (vfl-histogram-q8/q16, vfl-argmax-topk; DESIGN.md §5).
VFL_BACKENDS = tuple(
    n for n in backend_mod.available_backends() if n.startswith("vfl")
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=list(synthetic.DATASETS),
                    default="default_credit_card")
    ap.add_argument("--model", choices=["dynamic_fedgbf", "fedgbf",
                                        "secureboost", "federated_forest"],
                    default="dynamic_fedgbf")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--loss", default="logistic",
                    help="objective registry name (DESIGN.md §11): logistic, "
                         "squared, softmax<K> (e.g. softmax3 for "
                         "--dataset credit_risk_tiers), quantile[@alpha]. "
                         "K-channel objectives widen the histogram stats "
                         "axis to 2K+1 through every backend.")
    ap.add_argument("--n", type=int, default=0, help="subsample dataset")
    ap.add_argument("--max-depth", type=int, default=3)
    ap.add_argument("--backend", default="local",
                    choices=("local", "local-pallas") + VFL_BACKENDS,
                    help="named TreeBackend from the registry")
    ap.add_argument("--parties", type=int, default=2,
                    help="party count for vfl-* backends")
    ap.add_argument("--data-shards", type=int, default=0,
                    help="row shards over the mesh data axis for vfl-*-"
                         "sharded backends (DESIGN.md §8): each host holds "
                         "(n/data_shards, ...) rows and the per-level "
                         "histogram psums over the data axis.  0 = auto "
                         "(spread the remaining devices).  Uneven n pads "
                         "with weight-0 rows inside the backend.")
    ap.add_argument("--engine", default="scan", choices=("scan", "loop"),
                    help="training engine: static-shape scanned (one XLA "
                         "program for all rounds) or the legacy per-round "
                         "loop (DESIGN.md §4)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="evaluate metrics every k rounds (schedule and "
                         "timing are recorded every round regardless)")
    ap.add_argument("--sampling", default="uniform",
                    choices=("uniform", "goss"),
                    help="rho_id sample policy: uniform (paper eq. 4) or "
                         "GOSS (top-|g| + amplified random rest; DESIGN.md §5)")
    ap.add_argument("--hist-subtraction", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="sibling-subtraction histogram pipeline (DESIGN.md "
                         "§6, ON by default): levels >= 1 compute/exchange "
                         "only left-child histograms and derive the siblings "
                         "— halves the per-level histogram work and, on "
                         "vfl-* backends, the dominant wire message (1.75x "
                         "phase cut at depth 3).  --no-hist-subtraction "
                         "restores the direct reference pass.")
    ap.add_argument("--max-active-nodes", type=int, default=0,
                    help="frontier-compaction budget for deep trees "
                         "(DESIGN.md §9): static cap on live frontier nodes "
                         "per level; dead nodes are masked out of histograms "
                         "and the party exchange.  0 = uncompacted (use "
                         "with --max-depth > 3).")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace/Perfetto JSON timeline of "
                         "the run (DESIGN.md §12): host spans (binning, "
                         "compile, per-segment execution), per-round spans "
                         "with metrics + frontier liveness, and — on vfl-* "
                         "backends — per-phase wire-byte spans whose bytes "
                         "reconcile exactly with ProtocolLedger.breakdown()")
    ap.add_argument("--log-json", action="store_true",
                    help="emit one structured JSON line per round (schedule, "
                         "wall time, metrics, liveness, wire bytes) instead "
                         "of the ad-hoc [round NNN] prints; parsed by "
                         "benchmarks/obs_bench.py")
    ap.add_argument("--shared-root", action="store_true",
                    help="shared-root caching (DESIGN.md §9): the level-0 "
                         "pass computes ONE unmasked histogram per round "
                         "and derives each tree's root as shared - delta "
                         "(masked-out rows); engaged per round when the "
                         "rho_id schedule clears the 0.5 crossover "
                         "(uniform sampling only).")
    args = ap.parse_args()

    want_obs = bool(args.trace) or args.log_json
    tracer = obs_trace.Tracer() if args.trace else obs_trace.NULL_TRACER
    obs_trace.set_global_tracer(tracer)  # checkpoint I/O etc. hang off this

    ds = synthetic.load(args.dataset, n=args.n or None)
    tree = TreeConfig(max_depth=args.max_depth, num_bins=32,
                      hist_subtraction=args.hist_subtraction,
                      max_active_nodes=args.max_active_nodes,
                      shared_root=args.shared_root)
    cfg = {
        "dynamic_fedgbf": lambda: boosting.dynamic_fedgbf_config(args.rounds, tree=tree),
        "fedgbf": lambda: boosting.FedGBFConfig(
            rounds=args.rounds, tree=tree, n_trees_max=5, n_trees_min=5,
            rho_id_min=0.3, rho_id_max=0.3),
        "secureboost": lambda: boosting.secureboost_config(args.rounds, tree=tree),
        "federated_forest": lambda: boosting.federated_forest_config(
            n_trees=args.rounds, tree=tree),
    }[args.model]()
    if args.sampling != "uniform":
        cfg = dataclasses.replace(cfg, sampling=args.sampling)
    if args.loss != cfg.loss:
        cfg = dataclasses.replace(cfg, loss=args.loss)
    obj = objective_mod.get_objective(cfg.loss)

    x_train, y_train = ds.x_train, ds.y_train
    federated = args.backend in VFL_BACKENDS
    if federated:
        aggregation = "argmax" if "argmax" in args.backend else "histogram"
        n_dev = len(jax.devices())
        if n_dev < args.parties:
            raise SystemExit(
                f"need >= {args.parties} devices (set XLA_FLAGS=--xla_force_"
                f"host_platform_device_count=...), got {n_dev}"
            )
        x_train, d_pad = tabular.pad_features(x_train, args.parties)
        mesh = mesh_mod.make_vfl_mesh(args.parties, args.data_shards)
        shards = mesh.shape["data"]
        if args.backend.endswith("-sharded") and x_train.shape[0] % shards:
            # shard_map needs n divisible by the data-axis extent; the
            # backend pads the remainder with weight-0 rows internally
            # (after the subsampling masks are drawn over the real n, so
            # the exact-count sampling semantics are untouched).
            print(f"sharded backend: n={x_train.shape[0]} pads to "
                  f"{-(-x_train.shape[0] // shards) * shards} inside the "
                  f"backend ({shards} sample shards, weight-0 rows)")
        backend = backend_mod.get_backend(args.backend, mesh=mesh, tree=tree)
        print(f"backend={backend.name}: {args.parties} parties x "
              f"{shards} data shards, aggregation={aggregation}, "
              f"transport={backend.descriptor.transport}"
              + (", async exchange" if backend.descriptor.async_exchange
                 else ""))
        # measured wire bytes reconciled against the wire model, plus the
        # paper-world Paillier estimate — one shared entry (DESIGN.md §5)
        from repro.federation import compress

        ledger = compress.reconciled_ledger(
            mesh, tree, cfg, aggregation=aggregation,
            transport=backend.descriptor.transport_spec,
            n_samples=x_train.shape[0], num_features=d_pad,
            shard_samples=args.backend.endswith("-sharded"),
            async_exchange=backend.descriptor.async_exchange,
            n_channels=obj.n_classes,
        )
        cost = ledger.predicted_paillier()
        print(f"paillier-model bytes (ledger): {cost.total/1e6:.1f} MB "
              f"{cost.breakdown()}")
        rec = ledger.reconcile()
        print(f"wire bytes: measured={rec['total']['measured']/1e6:.1f} MB "
              f"predicted={rec['total']['predicted']/1e6:.1f} MB "
              f"(match={rec['total']['match']})")
    else:
        backend = backend_mod.get_backend(args.backend)

    model, hist = boosting.train_fedgbf(
        jnp.asarray(x_train), jnp.asarray(y_train), cfg, jax.random.PRNGKey(0),
        backend=backend, verbose=not args.log_json, engine=args.engine,
        eval_every=args.eval_every, tracer=tracer, telemetry=want_obs,
    )
    print(f"engine={hist.engine}: total train wall {hist.total_wall_time_s:.2f}s "
          f"over {len(hist.n_trees)} rounds")

    # --- unified telemetry outputs (DESIGN.md §12) --------------------------
    per_round_bytes = ledger.per_round_measured() if federated else None
    if args.log_json:
        for line in obs_log.render_round_lines(hist, per_round_bytes):
            print(line)
    if args.trace:
        perfetto.add_training_timeline(tracer, hist, per_round_bytes)
        n_events = perfetto.export_chrome_trace(
            args.trace, tracer,
            metadata={"dataset": args.dataset, "backend": args.backend,
                      "engine": hist.engine, "rounds": args.rounds},
        )
        print(f"trace: {n_events} events -> {args.trace} "
              f"(open in ui.perfetto.dev)")
        if federated:
            # acceptance contract: the trace's histogram-phase span bytes
            # are the ledger's own per-round rows, so they must sum to
            # breakdown()["measured"] exactly
            span_hist = perfetto.wire_span_phase_totals(tracer)
            led_hist = ledger.breakdown()["measured"]
            match = span_hist.get("histograms", 0) == led_hist["histograms"]
            print(f"trace: histogram-phase span bytes "
                  f"{span_hist.get('histograms', 0)} vs ledger "
                  f"{led_hist['histograms']} (match={match})")
            if not match:
                raise SystemExit("trace/ledger histogram bytes diverged")
    x_test = ds.x_test
    if federated:
        x_test, _ = tabular.pad_features(x_test, args.parties)
    margin = boosting.predict(model, jnp.asarray(x_test))
    if obj.n_classes > 1:
        rep = metrics.multiclass_report(jnp.asarray(ds.y_test), margin)
        print(f"TEST: acc={rep['acc']:.4f} macro_f1={rep['macro_f1']:.4f} "
              f"(total trees: {model.total_trees}, K={obj.n_classes})")
    else:
        rep = metrics.classification_report(jnp.asarray(ds.y_test), margin)
        print(f"TEST: auc={rep['auc']:.4f} acc={rep['acc']:.4f} "
              f"f1={rep['f1']:.4f} (total trees: {model.total_trees})")


if __name__ == "__main__":
    main()
