"""Compositional roofline costing for scanned (rolled) programs.

XLA's cost_analysis counts a while-loop body ONCE, so the production program
(layers under lax.scan) underreports FLOPs/bytes/collectives by ~num_units.
Fully unrolling fixes the numbers but costs minutes of compile per program —
infeasible for the 10 x 4 x 2 matrix on one CPU core.

Instead we cost compositionally:

    total = program_rolled + (num_units - 1) * unit_body
            [+ (enc_layers - 1) * enc_body]           (whisper)
            [+ (num_shared_apps - 1) * shared_block]  (zamba2)

where each term is a separate small jit program compiled with the SAME mesh
and shardings. The rolled program still proves the full pipeline lowers and
provides memory_analysis (it IS the deployable artifact); the body programs
provide exact per-layer costs. Validation against a full unroll (smollm
train_4k: composite within a few percent) lives in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import use_mesh
from repro.launch import shapes as shapes_mod
from repro.launch.shardings import batch_spec, cache_spec, param_spec
from repro.models import blocks, model as model_mod
from repro.tools import roofline as roofline_mod


def _per_device_cost(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    stats = roofline_mod.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(stats.total_bytes),
        "collectives": stats,
    }


def _unit_param_shapes(cfg, pos_strip=True):
    """Shapes of ONE unit's params (leading stack axis stripped)."""
    shapes = jax.eval_shape(
        lambda k: model_mod.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    units = shapes["units"]
    strip = lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype)
    return jax.tree.map(strip, units), shapes


def _shard_tree(tree, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        tree,
    )


def _x_spec(cfg, batch, seq):
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                jnp.dtype(cfg.compute_dtype))


def unit_body_cost(cfg, mesh, batch: int, seq: int, kind: str,
                   enc_out_spec=None) -> dict:
    """Per-device cost of one scan unit (fwd for prefill/decode kind='fwd',
    fwd+bwd with remat for kind='train')."""
    unit_shapes, _ = _unit_param_shapes(cfg)
    unit_sh = _shard_tree(unit_shapes, mesh)
    x_spec = _x_spec(cfg, batch, seq)
    x_sh = batch_spec(mesh, 3, batch)

    def fwd(unit_params, x, enc_out=None):
        for pos, bt in enumerate(cfg.pattern):
            x, _ = blocks.block_forward(unit_params[pos], x, bt, cfg, enc_out)
        return x

    if kind == "train":
        body = jax.checkpoint(fwd) if cfg.remat else fwd
        if enc_out_spec is not None:
            fn = jax.grad(
                lambda up, x, eo: jnp.sum(body(up, x, eo).astype(jnp.float32)),
                argnums=(0, 1),
            )
            args = (unit_shapes, x_spec, enc_out_spec)
            shardings = (unit_sh, x_sh, batch_spec(mesh, 3, batch))
        else:
            fn = jax.grad(
                lambda up, x: jnp.sum(body(up, x).astype(jnp.float32)),
                argnums=(0, 1),
            )
            args, shardings = (unit_shapes, x_spec), (unit_sh, x_sh)
    else:
        if enc_out_spec is not None:
            fn = lambda up, x, eo: fwd(up, x, eo)
            args = (unit_shapes, x_spec, enc_out_spec)
            shardings = (unit_sh, x_sh, batch_spec(mesh, 3, batch))
        else:
            fn = fwd
            args, shardings = (unit_shapes, x_spec), (unit_sh, x_sh)

    with use_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
    return _per_device_cost(compiled)


def decode_body_cost(cfg, mesh, batch: int, seq_len: int) -> dict:
    """Per-device cost of one decode-scan unit (1 token vs its cache slice)."""
    unit_shapes, _ = _unit_param_shapes(cfg)
    unit_sh = _shard_tree(unit_shapes, mesh)
    cache_shapes = jax.eval_shape(
        lambda: model_mod.init_cache(cfg, batch, seq_len)
    )
    strip = lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype)
    unit_cache = [jax.tree.map(strip, c) for c in cache_shapes["blocks"]]
    # cache_spec on stripped leaves: batch moves to dim 0
    cache_sh = jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, mesh, batch_dim=0), unit_cache
    )
    cross = cache_shapes.get("cross")
    cross_spec = None
    cross_sh = None
    if cross is not None:
        cross_spec = jax.tree.map(strip, cross)
        cross_sh = jax.tree_util.tree_map_with_path(
            lambda path, leaf: cache_spec(path, leaf, mesh, batch_dim=0),
            cross_spec,
        )

    x_spec = _x_spec(cfg, batch, 1)
    x_sh = batch_spec(mesh, 3, batch)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(unit_params, caches, x, pos, cross_cache=None):
        new = []
        for p_idx, bt in enumerate(cfg.pattern):
            cc = cross_cache if bt == "dec_attn" else None
            x, nc = blocks.block_decode(
                unit_params[p_idx], x, caches[p_idx], pos, bt, cfg,
                cross_cache=cc,
            )
            new.append(nc)
        return x, new

    args = [unit_shapes, unit_cache, x_spec, pos_spec]
    shardings = [unit_sh, cache_sh, x_sh, NamedSharding(mesh, P())]
    if cross_spec is not None:
        args.append(cross_spec)
        shardings.append(cross_sh)
    with use_mesh(mesh):
        compiled = (
            jax.jit(fn, in_shardings=tuple(shardings))
            .lower(*args)
            .compile()
        )
    return _per_device_cost(compiled)


def shared_block_cost(cfg, mesh, batch: int, seq: int, kind: str) -> dict:
    """Per-device cost of zamba2's weight-shared attention block."""
    shapes = jax.eval_shape(
        lambda k: blocks.init_shared_attn(k, cfg), jax.random.PRNGKey(0)
    )
    sh = _shard_tree(shapes, mesh)
    x_spec = _x_spec(cfg, batch, seq)
    x_sh = batch_spec(mesh, 3, batch)

    if kind == "train":
        body = jax.checkpoint(
            lambda p, x: blocks.shared_attn_forward(p, x, cfg)
        )
        fn = jax.grad(
            lambda p, x: jnp.sum(body(p, x).astype(jnp.float32)),
            argnums=(0, 1),
        )
    else:
        fn = lambda p, x: blocks.shared_attn_forward(p, x, cfg)
    with use_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=(sh, x_sh)).lower(
            shapes, x_spec
        ).compile()
    return _per_device_cost(compiled)


def shared_decode_cost(cfg, mesh, batch: int, seq_len: int) -> dict:
    shapes = jax.eval_shape(
        lambda k: blocks.init_shared_attn(k, cfg), jax.random.PRNGKey(0)
    )
    sh = _shard_tree(shapes, mesh)
    cache = jax.eval_shape(
        lambda: blocks.init_block_cache("attn", cfg, batch, seq_len)
    )
    cache_sh = jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, mesh, batch_dim=0), cache
    )
    x_spec = _x_spec(cfg, batch, 1)
    fn = lambda p, c, x, pos: blocks.shared_attn_decode(p, x, c, pos, cfg)
    with use_mesh(mesh):
        compiled = jax.jit(
            fn,
            in_shardings=(sh, cache_sh, batch_spec(mesh, 3, batch),
                          NamedSharding(mesh, P())),
        ).lower(shapes, cache, x_spec,
                jax.ShapeDtypeStruct((), jnp.int32)).compile()
    return _per_device_cost(compiled)


def composite_cost(cfg, mesh, shape_name: str, program_cost: dict) -> dict:
    """total = rolled program + (U-1) * unit body [+ encoder, shared terms]."""
    spec = shapes_mod.SHAPES[shape_name]
    U = cfg.num_units
    total = dict(program_cost)

    def add(term: dict, times: float):
        for k in ("flops", "bytes", "collective_bytes"):
            total[k] = total[k] + times * term[k]

    if spec.kind in ("train", "prefill"):
        kind = "train" if spec.kind == "train" else "fwd"
        if cfg.encoder is not None:
            enc_spec = _x_spec(cfg, spec.global_batch, cfg.encoder.num_frames)
            enc_body = unit_body_cost(
                dataclasses.replace(cfg, pattern=("enc_attn",), encoder=None),
                mesh, spec.global_batch, cfg.encoder.num_frames, kind,
            )
            add(enc_body, cfg.encoder.num_layers - 1)
            body = unit_body_cost(
                cfg, mesh, spec.global_batch, spec.seq_len, kind,
                enc_out_spec=enc_spec,
            )
        else:
            body = unit_body_cost(cfg, mesh, spec.global_batch, spec.seq_len, kind)
        add(body, U - 1)
        if cfg.shared_attn_every > 0:
            apps = model_mod._num_shared_apps(cfg)
            sb = shared_block_cost(cfg, mesh, spec.global_batch, spec.seq_len, kind)
            add(sb, max(apps - 1, 0))
    else:  # decode
        body = decode_body_cost(cfg, mesh, spec.global_batch, spec.seq_len)
        add(body, U - 1)
        if cfg.shared_attn_every > 0:
            apps = model_mod._num_shared_apps(cfg)
            sb = shared_decode_cost(cfg, mesh, spec.global_batch, spec.seq_len)
            add(sb, max(apps - 1, 0))
    return total
