"""Assigned input shapes (deliverable f) and ShapeDtypeStruct input specs.

  train_4k     seq=4096    global_batch=256   lowers train_step
  prefill_32k  seq=32768   global_batch=32    lowers prefill (full forward)
  decode_32k   seq=32768   global_batch=128   lowers serve_step (1 token, KV cache)
  long_500k    seq=524288  global_batch=1     lowers serve_step; sub-quadratic archs only

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every model input — shardable stand-ins, no device allocation.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# long_500k applicability (DESIGN.md §7): SSM/hybrid/linear-attention archs
# plus dense archs with a sliding-window variant.
LONG_OK = {"zamba2-7b", "rwkv6-7b", "gemma2-2b", "mixtral-8x22b"}


def applicable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and arch_id not in LONG_OK:
        return False, "full-attention arch: long_500k skipped (DESIGN.md §7)"
    return True, ""


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def stub_specs(cfg: ModelConfig, batch: int) -> dict:
    out = {}
    if cfg.frontend == "vision_stub":
        out["patch_embeds"] = _sd(
            (batch, cfg.num_patches, cfg.d_model), jnp.float32
        )
    if cfg.frontend == "audio_stub":
        out["frames"] = _sd(
            (batch, cfg.encoder.num_frames, cfg.d_model), jnp.float32
        )
    return out


def train_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    batch = {
        "tokens": _sd((spec.global_batch, spec.seq_len), jnp.int32),
        "labels": _sd((spec.global_batch, spec.seq_len), jnp.int32),
    }
    batch.update(stub_specs(cfg, spec.global_batch))
    return batch


def prefill_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    batch = {"tokens": _sd((spec.global_batch, spec.seq_len), jnp.int32)}
    batch.update(stub_specs(cfg, spec.global_batch))
    return batch


def decode_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    """token + pos + cache (cache shapes via eval_shape of init_cache)."""
    from repro.models import model as model_mod

    cache = jax.eval_shape(
        lambda: model_mod.init_cache(cfg, spec.global_batch, spec.seq_len)
    )
    return {
        "token": _sd((spec.global_batch, 1), jnp.int32),
        "pos": _sd((), jnp.int32),
        "cache": cache,
    }


def input_specs_for(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    if spec.kind == "train":
        return train_input_specs(cfg, spec)
    if spec.kind == "prefill":
        return prefill_input_specs(cfg, spec)
    return decode_input_specs(cfg, spec)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    return input_specs_for(cfg, SHAPES[shape_name])
