import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture x input shape) combination against the
production mesh — (16, 16) single pod and (2, 16, 16) multi-pod — and records
memory_analysis / cost_analysis / collective bytes for the roofline.

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count on first init); do not move it. Do NOT import this module from
tests or benches — they must see the real single device. Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.compat import use_mesh
from repro.configs import ARCH_IDS, get_config
from repro.launch import costmodel
from repro.launch import shapes as shapes_mod
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.shardings import (
    batch_spec,
    cache_shardings,
    param_shardings,
    replicated,
    train_state_shardings,
)
from repro.models import model as model_mod
from repro.models import train as train_mod
from repro.tools import roofline as roofline_mod

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def build_step(cfg, spec, mesh):
    """Returns (fn, example_args (ShapeDtypeStructs), in_shardings).

    ``spec`` is a shapes.ShapeSpec — one of shapes.SHAPES for the assigned
    matrix, or any custom spec (the in-pytest smoke uses a tiny one)."""
    specs = shapes_mod.input_specs_for(cfg, spec)

    if spec.kind == "train":
        state_shapes = jax.eval_shape(
            lambda k: train_mod.init_train_state(k, cfg), jax.random.PRNGKey(0)
        )
        step = train_mod.make_train_step(cfg)
        state_sh = train_state_shardings(cfg, mesh)
        batch_sh = {
            k: batch_spec(mesh, len(v.shape), v.shape[0])
            for k, v in specs.items()
        }
        return step, (state_shapes, specs), (state_sh, batch_sh)

    params_shapes = jax.eval_shape(
        lambda k: model_mod.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    params_sh = param_shardings(cfg, mesh)

    if spec.kind == "prefill":
        def prefill_fn(params, batch):
            return model_mod.prefill(
                params, batch["tokens"], cfg,
                patch_embeds=batch.get("patch_embeds"),
                frames=batch.get("frames"),
            )

        batch_sh = {k: batch_spec(mesh, len(v.shape), v.shape[0])
                    for k, v in specs.items()}
        return prefill_fn, (params_shapes, specs), (params_sh, batch_sh)

    # decode
    def serve_step(params, cache, token, pos):
        return model_mod.decode_step(params, cache, token, pos, cfg)

    cache_sh = cache_shardings(cfg, mesh, spec.global_batch, spec.seq_len)
    tok_sh = batch_spec(mesh, 2, spec.global_batch)
    return (
        serve_step,
        (params_shapes, specs["cache"], specs["token"], specs["pos"]),
        (params_sh, cache_sh, tok_sh, replicated(mesh)),
    )


def run_one(arch: str, shape_name: str, multi_pod: bool,
            save: bool = True, unroll: bool = False,
            variant: str = "") -> dict:
    ok, reason = shapes_mod.applicable(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + (f"__{variant}" if variant else "")
    if not ok:
        report = {"tag": tag, "status": "skipped", "reason": reason}
        _save(report, tag, save)
        print(f"[SKIP] {tag}: {reason}")
        return report

    # Default: ROLLED production program (the deployable artifact) + the
    # compositional cost model (costmodel.py). --unroll switches to a fully
    # unrolled program whose cost_analysis is directly exact (validation).
    cfg = dataclasses.replace(get_config(arch), scan_unroll=unroll)
    moe_impl = os.environ.get("REPRO_MOE_IMPL")
    if moe_impl and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl=moe_impl)
        )
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    spec = shapes_mod.SHAPES[shape_name]

    t0 = time.time()
    try:
        fn, args, in_sh = build_step(cfg, spec, mesh)
        with use_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        coll = roofline_mod.parse_collectives(compiled.as_text())
        program_cost = costmodel._per_device_cost(compiled)
        if unroll:
            total = program_cost
        else:
            t1 = time.time()
            total = costmodel.composite_cost(cfg, mesh, shape_name, program_cost)
            t_bodies = time.time() - t1
        roof = roofline_mod.roofline_from_costs(total, cfg, spec, chips)
        report = {
            "tag": tag,
            "status": "ok",
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_name,
            "chips": chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes_per_device": getattr(
                    mem, "peak_memory_in_bytes",
                    getattr(mem, "temp_size_in_bytes", None),
                ),
            },
            "roofline": roof.as_dict(),
            "costing": "unrolled-exact" if unroll else "composite",
            "collectives_program": {
                "bytes_by_kind": coll.bytes_by_kind,
                "count_by_kind": coll.count_by_kind,
            },
        }
        print(
            f"[OK]  {tag}: compile {t_compile:.0f}s "
            f"flops={roof.flops:.3e} hbm={roof.hbm_bytes:.3e} "
            f"coll={roof.collective_bytes:.3e} dominant={roof.dominant} "
            f"useful={roof.useful_ratio:.2f}"
        )
    except Exception as e:  # noqa: BLE001 — failures ARE the test output
        report = {
            "tag": tag,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}")
    _save(report, tag, save)
    return report


def _save(report: dict, tag: str, save: bool) -> None:
    if not save:
        return
    os.makedirs(REPORT_DIR, exist_ok=True)
    with open(os.path.join(REPORT_DIR, tag + ".json"), "w") as f:
        json.dump(report, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(shapes_mod.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="full 10x4 matrix")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll scans (exact but slow; validation)")
    ap.add_argument("--variant", default="", help="report filename suffix")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shape_names = (
        list(shapes_mod.SHAPES) if (args.all or not args.shape) else [args.shape]
    )
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shape_names:
                report = run_one(arch, shape_name, multi_pod,
                                 unroll=args.unroll, variant=args.variant)
                if report["status"] == "error":
                    failures += 1
    print(f"\ndone; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
