"""Parameter / activation / cache PartitionSpecs for the LM substrate.

Rules (DESIGN.md §8): weight matrices shard their contraction structure as
(FSDP over "data", tensor-parallel over "model") —

  up-projections   (..., D_in, D_out):  P(..., "data", "model")
  down-projections (..., D_in, D_out):  P(..., "model", "data")
  expert weights   (U, E, D, F):        same on the trailing two dims
  vectors / norms / small tables:       replicated

An axis is dropped whenever the dim is not divisible by the mesh axis size —
divisibility is checked per-leaf, so MQA (kv=1) K/V projections replicate on
"model" automatically while the 48-head Q shards. Caches shard batch over
(pod, data) and the cache-length (or head) dim over "model" when divisible.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

# Param leaves whose LAST TWO dims shard ("model", "data") instead of
# ("data", "model") — the down/output projections.
_REVERSED = {"w_down", "w_out", "wo", "out_proj"}
# Leaves that stay replicated regardless of shape.
_REPLICATED = {"scale", "bias", "mu", "u", "w0", "A_log", "D", "dt_bias",
               "norm", "ln_scale", "ln_bias", "router"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _divides(dim: int, axis: str, mesh: Mesh) -> bool:
    return axis in mesh.shape and dim % mesh.shape[axis] == 0


def _in_moe(path) -> bool:
    return any(getattr(e, "key", None) == "moe" for e in path)


def param_spec(path, leaf, mesh: Mesh) -> P:
    name = _leaf_name(path)
    shape = leaf.shape
    if name in _REPLICATED or len(shape) < 2:
        return P()
    d_in, d_out = shape[-2], shape[-1]
    lead = (None,) * (len(shape) - 2)
    if _in_moe(path) and len(shape) >= 3:
        # Expert weights (U, E, D, F) / (U, E, F, D): keep the up-projection
        # contraction dim (D) REPLICATED so 'ecd,edf' needs no all-reduce;
        # shard F over "model" (one modest psum on the down-projection).
        # EXPERIMENTS.md §Perf granite-moe iteration 2.
        if name in _REVERSED:  # w_down (E, F, D)
            a_in = "model" if _divides(d_in, "model", mesh) else None
            return P(*lead, a_in, None)
        a_out = "model" if _divides(d_out, "model", mesh) else None
        return P(*lead, None, a_out)
    if name in _REVERSED:
        a_in = "model" if _divides(d_in, "model", mesh) else None
        a_out = "data" if _divides(d_out, "data", mesh) else None
    else:
        a_in = "data" if _divides(d_in, "data", mesh) else None
        a_out = "model" if _divides(d_out, "model", mesh) else None
    return P(*lead, a_in, a_out)


def param_shardings(cfg, mesh: Mesh):
    """NamedSharding pytree matching init_params(cfg) (via eval_shape)."""
    from repro.models import model as model_mod

    shapes = jax.eval_shape(
        lambda k: model_mod.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        shapes,
    )


def train_state_shardings(cfg, mesh: Mesh):
    """Shardings for TrainState(params, AdamWState(step, m, v)) — the AdamW
    moments mirror the parameter shardings exactly."""
    from repro.models import train as train_mod
    from repro.optim.adamw import AdamWState

    ps = param_shardings(cfg, mesh)
    return train_mod.TrainState(
        params=ps,
        opt=AdamWState(step=replicated(mesh), m=ps, v=ps),
    )


def batch_spec(mesh: Mesh, ndim: int, batch_size: int | None = None) -> NamedSharding:
    """Token/label batches: batch dim over (pod, data) when divisible;
    falls back through (data,) alone, then replication (batch == 1)."""
    for ba in (batch_axes(mesh), ("data",) if "data" in mesh.shape else ()):
        if not ba:
            continue
        total = 1
        for a in ba:
            total *= mesh.shape[a]
        if batch_size is None or batch_size % total == 0:
            return NamedSharding(mesh, P(ba, *([None] * (ndim - 1))))
    return NamedSharding(mesh, P(*([None] * ndim)))


def cache_spec(path, leaf, mesh: Mesh, batch_dim: int = 1) -> NamedSharding:
    """Decode caches: leaf shapes (U, B, ...). Shard B over (pod, data) when
    divisible; shard the largest trailing dim over "model" — PLUS any batch
    axes the batch dim could not use (long_500k's B=1 left 'data' idle and
    the zamba2 shared cache peaked at 23.7 GiB/dev; folding the idle axes
    into the cache-length dim cuts it below the 16 GiB HBM line)."""
    shape = leaf.shape
    ba = batch_axes(mesh)
    total_batch_shards = 1
    for a in ba:
        total_batch_shards *= mesh.shape[a]
    spec = [None] * len(shape)
    batch_sharded = (
        len(shape) > batch_dim and shape[batch_dim] % total_batch_shards == 0
    )
    if batch_sharded:
        spec[batch_dim] = ba
    trail_axes = ("model",) if batch_sharded else tuple(ba) + ("model",)
    # trailing dims: pick the largest divisible dim after batch
    for axes in (trail_axes, ("model",)):
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        best = None
        for i in range(batch_dim + 1, len(shape)):
            if shape[i] % total == 0 and (best is None or shape[i] > shape[best]):
                best = i
        if best is not None:
            spec[best] = axes if len(axes) > 1 else axes[0]
            break
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cfg, mesh: Mesh, batch: int, seq_len: int):
    from repro.models import model as model_mod

    shapes = jax.eval_shape(
        lambda: model_mod.init_cache(cfg, batch, seq_len)
    )
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, mesh), shapes
    )


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
