"""End-to-end LM training driver (deliverable b).

Trains any assigned architecture (reduced or full config) on the synthetic
token pipeline. On this CPU container use the smoke configs::

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 200 --batch 8 --seq 256

On real hardware, drop --smoke and pass --mesh to shard over the production
mesh (same code path the dry-run proves out).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import tokens as tokens_mod
from repro.models import train as train_mod


def add_stubs(batch: dict, cfg, rng: np.random.Generator) -> dict:
    B = batch["tokens"].shape[0]
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = rng.normal(
            size=(B, cfg.num_patches, cfg.d_model)
        ).astype(np.float32)
    if cfg.frontend == "audio_stub":
        batch["frames"] = rng.normal(
            size=(B, cfg.encoder.num_frames, cfg.d_model)
        ).astype(np.float32)
    return batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} layers={cfg.num_layers} d_model={cfg.d_model} "
          f"params={cfg.flops_params()/1e6:.1f}M")

    state = train_mod.init_train_state(jax.random.PRNGKey(0), cfg)
    step_fn = jax.jit(train_mod.make_train_step(
        cfg, peak_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
        total_steps=args.steps,
    ))

    rng = np.random.default_rng(0)
    stream = tokens_mod.batches(cfg.vocab, args.batch, args.seq,
                                num_batches=args.steps)
    t0 = time.time()
    losses = []
    for step, raw in enumerate(stream, start=1):
        batch = {k: jnp.asarray(v) for k, v in
                 add_stubs(dict(raw), cfg, rng).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["ce"]))
        if step % args.log_every == 0 or step == args.steps:
            dt = (time.time() - t0) / step
            tok_s = args.batch * args.seq / dt
            print(f"step {step:5d} ce={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"{tok_s:,.0f} tok/s")
    print(f"first-10 mean ce={np.mean(losses[:10]):.4f} -> "
          f"last-10 mean ce={np.mean(losses[-10:]):.4f}")
    if args.ckpt:
        checkpoint.save_pytree(args.ckpt, state.params)
        print(f"saved params to {args.ckpt}")


if __name__ == "__main__":
    main()
