"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state; callers (dryrun.py) force the placeholder device count via
XLA_FLAGS *before* any jax import.

Mesh roles (shared with the tabular VFL runtime, federation/mesh_roles.py):
  single pod   (16, 16)      -> ("data", "model")       256 chips
  multi-pod    (2, 16, 16)   -> ("pod", "data", "model") 512 chips
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(num_devices: int | None = None):
    """Small mesh for in-pytest dry-run smoke (8 forced host devices)."""
    n = num_devices or len(jax.devices())
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_vfl_mesh(parties: int, data_shards: int = 0):
    """2-D (data × party) training mesh for the vfl-* backends (DESIGN.md §8).

    ``parties`` is the model-axis extent (the VFL party decomposition);
    ``data_shards`` the data-axis extent rows shard over (``vfl-*-sharded``
    backends).  0 = auto: spread the remaining devices over the data axis.
    Raises if the device pool cannot host the requested grid.
    """
    n_dev = len(jax.devices())
    if data_shards <= 0:
        data_shards = max(1, n_dev // parties)
    need = parties * data_shards
    if n_dev < need:
        raise ValueError(
            f"mesh ({data_shards} data x {parties} model) needs {need} "
            f"devices, got {n_dev} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need})"
        )
    return jax.make_mesh((data_shards, parties), ("data", "model"),
                         devices=jax.devices()[:need])


def batch_axes(mesh: jax.sharding.Mesh) -> tuple:
    """Axes the global batch shards over (pod folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# TPU v5e hardware constants used by the roofline (tools/roofline.py).
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per direction)
HBM_BYTES = 16 * 2**30       # 16 GiB per chip
