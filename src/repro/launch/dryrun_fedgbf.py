import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the paper's own workload on the production mesh: one FedGBF
forest round (5 depth-3 trees, Give-Me-Some-Credit scale) built by the
federated shard_map runtime with parties = the 16-way model axis and samples
sharded over the 16-way data axis.

This is hillclimb pair #3 (most representative of the paper's technique):
the before/after is the aggregation mode — "histogram" (paper-faithful full
per-party histogram exchange, Alg. 2 step 7) vs "argmax" (beyond-paper
candidate-only exchange) — measured in compiled collective bytes.

    PYTHONPATH=src python -m repro.launch.dryrun_fedgbf
"""

import json
import sys

import jax
import jax.numpy as jnp

from repro.compat import use_mesh
from repro.core import forest as forest_mod
from repro.core.types import TreeConfig
from repro.federation import vfl
from repro.launch.mesh import make_production_mesh
from repro.obs import perfetto
from repro.obs import trace as obs_trace
from repro.tools import roofline as roofline_mod
from repro.launch.dryrun import REPORT_DIR


def run(aggregation: str, n=150_000, d=16, n_trees=5, multi_pod=False,
        hist_subtraction=False, max_depth=3, max_active_nodes=0,
        data_shards=0, async_exchange=False) -> dict:
    if data_shards:
        # explicit row-shard grid (--data-shards): data_shards x 16 parties
        mesh = jax.make_mesh((data_shards, 16), ("data", "model"),
                             devices=jax.devices()[:data_shards * 16])
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    # round the sample count up to the data-sharding granularity (padded
    # rows carry zero sample-mask weight, semantically inert — the backend
    # pads internally either way; pre-rounding keeps the report's n exact)
    shards = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            shards *= mesh.shape[a]
    n = ((n + shards - 1) // shards) * shards
    cfg = TreeConfig(max_depth=max_depth, num_bins=32,
                     hist_subtraction=hist_subtraction,
                     max_active_nodes=max_active_nodes)
    backend = vfl.make_vfl_backend(
        mesh, cfg, aggregation=aggregation, shard_samples=True,
        async_exchange=async_exchange,
    )

    binned = jax.ShapeDtypeStruct((n, d), jnp.int32)
    g = jax.ShapeDtypeStruct((n,), jnp.float32)
    h = jax.ShapeDtypeStruct((n,), jnp.float32)
    smask = jax.ShapeDtypeStruct((n_trees, n), jnp.float32)
    fmask = jax.ShapeDtypeStruct((n_trees, d), bool)

    tracer = obs_trace.global_tracer()
    with use_mesh(mesh):
        # the backend's forest_builder wraps a jit; lower via a fresh jit
        with tracer.span(f"lower[{aggregation}]", cat="dryrun",
                         args={"chips": chips, "n": n, "d": d}):
            lowered = jax.jit(
                lambda b, gg, hh, sm, fm: backend.build_forest(b, gg, hh, sm, fm)
            ).lower(binned, g, h, smask, fmask)
        with tracer.span(f"compile[{aggregation}]", cat="dryrun",
                         args={"chips": chips}):
            compiled = lowered.compile()

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    stats = roofline_mod.parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    grid = (f"{data_shards}x16" if data_shards
            else ("2x16x16" if multi_pod else "16x16"))
    report = {
        "tag": f"fedgbf__forest_round__{grid}"
               f"__{aggregation}{'__sub' if hist_subtraction else ''}"
               + ("__async" if async_exchange else "")
               + (f"__d{max_depth}" if max_depth != 3 else "")
               + (f"__a{max_active_nodes}" if max_active_nodes else ""),
        "status": "ok",
        "aggregation": aggregation,
        "hist_subtraction": hist_subtraction,
        "async_exchange": async_exchange,
        "data_shards": data_shards or shards,
        "max_depth": max_depth,
        "max_active_nodes": max_active_nodes,
        "chips": chips,
        "n": n, "d": d, "n_trees": n_trees,
        "flops_per_dev": float(cost.get("flops", 0.0)),
        "bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_dev": float(stats.total_bytes),
        "collectives_by_kind": stats.bytes_by_kind,
        "peak_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "compute_s": float(cost.get("flops", 0.0)) / 197e12,
        "memory_s": float(cost.get("bytes accessed", 0.0)) / 819e9,
        "collective_s": float(stats.total_bytes) / 50e9,
    }
    tracer.counter("dryrun_collective_bytes_per_dev",
                   {report["tag"]: report["collective_bytes_per_dev"]})
    os.makedirs(REPORT_DIR, exist_ok=True)
    with open(os.path.join(REPORT_DIR, report["tag"] + ".json"), "w") as f:
        json.dump(report, f, indent=1)
    print(f"[OK] {report['tag']}: flops/dev={report['flops_per_dev']:.3e} "
          f"bytes/dev={report['bytes_per_dev']:.3e} "
          f"coll/dev={report['collective_bytes_per_dev']:.3e} "
          f"(compute {report['compute_s']*1e3:.3f}ms, "
          f"memory {report['memory_s']*1e3:.3f}ms, "
          f"coll {report['collective_s']*1e3:.3f}ms)")
    return report


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--data-shards", type=int, default=0,
                    help="also dry-run an explicit (data_shards x 16) row-"
                         "sharded grid (DESIGN.md §8) in addition to the "
                         "production meshes")
    ap.add_argument("--trace", nargs="?", const=os.path.join(
                        REPORT_DIR, "dryrun_trace.json"),
                    default=None, metavar="OUT.json",
                    help="export per-phase lower/compile spans of the sweep "
                         "as a Perfetto-loadable Chrome trace (default "
                         "reports/dryrun_trace.json)")
    args = ap.parse_args()

    if args.trace:
        obs_trace.set_global_tracer(obs_trace.Tracer())

    base = None
    for multi_pod in (False, True):
        for agg in ("histogram", "argmax"):
            report = run(agg, multi_pod=multi_pod)
            if agg == "histogram" and not multi_pod:
                base = report
    # Async double-buffered exchange (DESIGN.md §10): same logical payload,
    # two overlapping transfers — collective bytes must NOT grow.
    async_r = run("histogram", multi_pod=False, async_exchange=True)
    if base["collective_bytes_per_dev"]:
        ratio = (async_r["collective_bytes_per_dev"]
                 / base["collective_bytes_per_dev"])
        print(f"[OK] async exchange collective-bytes ratio vs sync: "
              f"{ratio:.3f}x (must stay ~1.0)")
    if args.data_shards:
        run("histogram", data_shards=args.data_shards)
        run("histogram", data_shards=args.data_shards, async_exchange=True)
    # Sibling-subtraction pipeline (DESIGN.md §6) on the paper-faithful
    # histogram exchange: the before/after is the compiled collective-bytes
    # cut of shipping only the left children at levels >= 1.
    sub = run("histogram", multi_pod=False, hist_subtraction=True)
    if sub["collective_bytes_per_dev"]:
        cut = base["collective_bytes_per_dev"] / sub["collective_bytes_per_dev"]
        print(f"[OK] subtraction collective-bytes cut (histogram mode): "
              f"{cut:.2f}x")
    # Round engine (DESIGN.md §9): deep-tree frontier compaction — the
    # before/after is the compiled collective-bytes cut of shipping only the
    # static live-slot budget at depth 5 instead of the 2^L frontier.
    deep = run("histogram", multi_pod=False, hist_subtraction=True,
               max_depth=5)
    comp = run("histogram", multi_pod=False, hist_subtraction=True,
               max_depth=5, max_active_nodes=4)
    if comp["collective_bytes_per_dev"]:
        cut = deep["collective_bytes_per_dev"] / comp["collective_bytes_per_dev"]
        print(f"[OK] depth-5 frontier-compaction collective-bytes cut: "
              f"{cut:.2f}x")
    if args.trace:
        n_events = perfetto.export_chrome_trace(
            args.trace, obs_trace.global_tracer(),
            metadata={"entry": "dryrun_fedgbf"},
        )
        print(f"[OK] dryrun trace: {n_events} events -> {args.trace}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
