"""Batched FedGBF scoring service — the millions-of-users serving scenario.

The production serving tier (DESIGN.md §14) stacks four layers:

* **Fused bin+traverse** — checkpoints ship their bin edges, requests
  arrive as raw floats, and ONE compiled program does bin + traverse +
  combine (``--impl fused`` vmap scan or ``fused-pallas`` kernel): the
  separate binning dispatch of the two-program serving path is gone.
* **Quantized ensembles** — ``--quantize 8|16`` serves an int8/int16
  ``QuantizedEnsemble`` (stochastically-rounded leaf tables via
  ``federation/compress.py``); routing stays bit-identical to f32 and the
  margin error is bounded by ``types.margin_delta_bound``.
* **Admission control + latency-aware micro-batching** — a pre-compiled
  ``BatchLadder`` of power-of-two batch shapes; each iteration admits the
  largest rung whose observed p99 (read live from the per-rung log-bucket
  histograms) fits ``--p99-budget-ms``, capped at the queue depth so short
  queues never pay full-batch padding.  Adaptation never recompiles: every
  rung was warmed at startup and on every successful hot-swap.
* **Mid-traffic hot-swap** — ``ModelSlot.try_reload`` validates a
  candidate checkpoint (sha256, probe scores, rung pre-compile) and swaps
  it in BETWEEN microbatches (``--reload-at-batch``), timing the swap into
  ``fedgbf_serve_swap_seconds``; a refused candidate leaves the serving
  stream untouched.

Observability (DESIGN.md §12): the stream records into a ``StreamMetrics``
bundle — log-bucketed latency histograms (overall + per rung, p50/p90/p99
from bucket counts so memory stays constant under unbounded streams),
rows/batches/padded-rows/swap counters, occupancy + throughput gauges
segmented per model generation.  ``--metrics-out`` writes the Prometheus
text exposition to a file; ``--metrics-port`` serves it over a localhost
HTTP scrape endpoint.

    # train a small model, save the packed checkpoint, score a request stream
    PYTHONPATH=src python -m repro.launch.serve_fedgbf \
        --dataset default_credit_card --rounds 10 --save /tmp/fedgbf_ckpt

    # serve a checkpoint fused + int8-quantized with a 5 ms p99 budget and
    # a live scrape endpoint
    PYTHONPATH=src python -m repro.launch.serve_fedgbf \
        --checkpoint /tmp/fedgbf_ckpt --impl fused --quantize 8 \
        --requests 200000 --p99-budget-ms 5 --metrics-port 9109

    # hot-swap a retrained checkpoint mid-stream, between microbatches
    PYTHONPATH=src python -m repro.launch.serve_fedgbf \
        --checkpoint /tmp/fedgbf_ckpt --reload /tmp/fedgbf_ckpt_v2 \
        --reload-at-batch 8
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import boosting
from repro.core import objective as objective_mod
from repro.core.types import PackedEnsemble
from repro.data import synthetic
from repro.obs import metrics as obs_metrics


@partial(jax.jit, static_argnames=("impl",))
def _score_batch(packed, x: jnp.ndarray, impl: str) -> jnp.ndarray:
    """One compiled program per (microbatch shape, impl): bin + traverse,
    via the same dispatch boosting.predict exposes.  ``impl="fused"`` /
    ``"fused-pallas"`` skip the binning pass entirely — raw floats compare
    against value-space thresholds (DESIGN.md §14) — and accept a
    ``QuantizedEnsemble`` natively.

    The activation comes from the objective registry keyed by the
    checkpoint's stored loss name (DESIGN.md §11) — sigmoid for logistic,
    softmax rows for softmax{K}, identity for the regression objectives —
    instead of a hard-coded sigmoid, so a squared- or quantile-loss
    checkpoint serves raw margins and a multiclass one serves (n, K)
    probability rows."""
    margin = boosting.predict(packed, x, impl=impl)
    return objective_mod.get_objective(packed.loss).activation(margin)


class StreamMetrics:
    """Serving instruments for one scoring stream (bounded memory).

    Latency lives ONLY in log-bucketed histograms — the overall
    ``fedgbf_serve_batch_latency_seconds`` plus one
    ``fedgbf_serve_rung_latency_seconds{batch_size="..."}`` series per
    admitted batch rung (the admission controller reads rung p99s live) —
    so p50/p90/p99 come from bucket counts with a ~4.5% relative error
    bound, never from a raw list that grows with the stream.

    Occupancy (real rows / admitted capacity) accumulates PER MODEL
    SEGMENT: ``begin_model_segment()`` (called on every successful
    hot-swap) resets the accumulators and bumps
    ``fedgbf_serve_model_generation``, so a swap never blends two models'
    padding behavior into one gauge.
    """

    def __init__(self, batch_size: int) -> None:
        r = obs_metrics.MetricsRegistry()
        self.registry = r
        self.latency = r.histogram(
            "fedgbf_serve_batch_latency_seconds",
            "Per-microbatch scoring latency (bin + traverse + combine).",
            lo=1e-6, hi=60.0,
        )
        self.rows = r.counter("fedgbf_serve_rows_total",
                              "Real (non-padding) rows scored.")
        self.batches = r.counter("fedgbf_serve_batches_total",
                                 "Microbatches dispatched.")
        self.padded_rows = r.counter(
            "fedgbf_serve_padded_rows_total",
            "Zero-padding rows scored to keep microbatch shapes static.")
        self.batch_size = r.gauge("fedgbf_serve_batch_size",
                                  "Capacity of the last admitted microbatch.")
        self.occupancy = r.gauge(
            "fedgbf_serve_batch_occupancy",
            "Mean real-row fraction per microbatch (1 = no padding), "
            "accumulated over the current model segment only.")
        self.rows_per_s = r.gauge("fedgbf_serve_rows_per_second",
                                  "Stream throughput over the last run.")
        self.rows_rejected = r.counter(
            "fedgbf_serve_rows_rejected_total",
            "Rows rejected for non-finite (inf) features: scored as NaN, "
            "never fed to the ensemble (DESIGN.md §13).")
        self.reloads = r.counter(
            "fedgbf_serve_reloads_total",
            "Hot model reloads that passed validation and were swapped in.")
        self.reload_failures = r.counter(
            "fedgbf_serve_reload_failures_total",
            "Hot reloads refused (corrupt checkpoint / failed probe); the "
            "previous ensemble keeps serving.")
        self.swap_latency = r.histogram(
            "fedgbf_serve_swap_seconds",
            "Validate-before-swap hot reload latency (load + sha256 + probe "
            "+ rung warm), successful swaps only.",
            lo=1e-4, hi=600.0,
        )
        self.model_generation = r.gauge(
            "fedgbf_serve_model_generation",
            "Model segment counter: bumped on every successful hot-swap; "
            "per-segment gauges reset at each bump.")
        self.batch_size.set(batch_size)
        self._capacity = batch_size
        self._rung_hists: dict = {}
        self._seg_rows = 0
        self._seg_slots = 0

    def rung_latency(self, capacity: int) -> obs_metrics.LogBucketHistogram:
        """The labeled per-rung latency histogram (registered lazily)."""
        h = self._rung_hists.get(capacity)
        if h is None:
            h = self.registry.histogram(
                "fedgbf_serve_rung_latency_seconds",
                "Per-microbatch latency by admitted batch capacity; the "
                "admission controller reads each rung's p99 live.",
                lo=1e-6, hi=60.0, labels={"batch_size": str(capacity)},
            )
            self._rung_hists[capacity] = h
        return h

    def observe_batch(self, latency_s: float, real_rows: int,
                      capacity: int | None = None) -> None:
        cap = self._capacity if capacity is None else capacity
        self.latency.observe(latency_s)
        self.rung_latency(cap).observe(latency_s)
        self.rows.inc(real_rows)
        self.batches.inc()
        self.padded_rows.inc(cap - real_rows)
        self.batch_size.set(cap)
        self._seg_rows += real_rows
        self._seg_slots += cap
        self.occupancy.set(
            self._seg_rows / self._seg_slots if self._seg_slots else 0.0)

    def begin_model_segment(self) -> None:
        """Reset per-model gauges at a hot-swap boundary: occupancy starts
        a fresh accumulation and the generation gauge bumps, so the gauges
        never blend two models' serving behavior."""
        self._seg_rows = 0
        self._seg_slots = 0
        self.occupancy.set(0.0)
        self.model_generation.set(self.model_generation.value + 1)

    def finalize(self, wall_s: float) -> None:
        if wall_s > 0:
            self.rows_per_s.set(self.rows.value / wall_s)

    def quantiles_ms(self, qs=(0.5, 0.9, 0.99)) -> dict:
        return {q: self.latency.quantile(q) * 1e3 for q in qs}

    def render(self) -> str:
        """Prometheus text exposition of the whole bundle."""
        return self.registry.render()


def ladder_sizes(max_size: int, min_size: int = 256) -> list:
    """Power-of-two batch rungs up to ``max_size`` (always included)."""
    min_size = max(1, min(min_size, max_size))
    sizes, s = [], 1
    while s < max_size:
        if s >= min_size:
            sizes.append(s)
        s *= 2
    sizes.append(max_size)
    return sizes


class BatchLadder:
    """Pre-compiled ladder of static batch shapes + the admission policy.

    Every rung is compiled once up front (``warm``; ``ModelSlot`` re-warms
    on hot-swap), so ``pick`` may move between rungs every single batch
    without ever triggering a recompile — the no-recompile property is
    asserted via ``_score_batch._cache_size()`` in tests.

    ``pick`` implements the admission policy: cap at the smallest rung
    covering the queue (a larger one only buys padding), then take the
    largest capped rung whose OBSERVED p99 — read live from the per-rung
    log-bucket histogram — fits the latency budget.  Rungs with fewer than
    ``min_obs`` observations are admitted optimistically (they were warmed,
    and a broken budget walks the ladder down within a batch or two); with
    no budget the queue cap alone decides (max throughput).
    """

    def __init__(self, sizes) -> None:
        self.sizes = sorted(set(int(s) for s in sizes))
        if not self.sizes or self.sizes[0] < 1:
            raise ValueError(f"need positive rung sizes, got {sizes!r}")
        self.max_size = self.sizes[-1]

    def warm(self, model, d: int, impl: str) -> None:
        """Compile every (rung, model-structure) serving program."""
        for s in self.sizes:
            jax.block_until_ready(
                _score_batch(model, jnp.zeros((s, d), jnp.float32), impl))

    def pick(self, queued: int, budget_s: float | None,
             metrics: StreamMetrics, min_obs: int = 8) -> int:
        cap = self.max_size
        for s in self.sizes:
            if s >= queued:
                cap = s
                break
        if budget_s is None:
            return cap
        for s in reversed(self.sizes):
            if s > cap:
                continue
            h = metrics.rung_latency(s)
            if h.count < min_obs or h.quantile(0.99) <= budget_s:
                return s
        return self.sizes[0]


class ModelSlot:
    """Hot-reloadable model holder with validate-before-swap (DESIGN.md §13).

    ``try_reload`` loads a candidate checkpoint (sha256-verified by
    ``checkpoint.io``), scores a zero probe batch through the serving
    program, pre-compiles every warm rung shape for the candidate, and only
    THEN swaps it in — so the swap is legal BETWEEN MICROBATCHES of a live
    stream and the first post-swap batch hits a warm program.  Any failure
    — missing file, corrupt/truncated npz, checksum mismatch, non-finite
    probe scores — leaves the previous ensemble serving and increments
    ``fedgbf_serve_reload_failures_total`` without touching any other
    serving metric; a successful swap increments
    ``fedgbf_serve_reloads_total``, records the swap wall into
    ``fedgbf_serve_swap_seconds`` and starts a fresh model segment
    (``StreamMetrics.begin_model_segment``).
    """

    def __init__(self, packed, impl: str = "packed",
                 metrics: StreamMetrics = None, warm_sizes=()) -> None:
        self.packed = packed
        self.impl = impl
        self.metrics = metrics
        self.warm_sizes = tuple(int(s) for s in warm_sizes)

    def _validate(self, packed) -> None:
        d = packed.bin_edges.shape[0]
        probe = jnp.zeros((4, d), jnp.float32)
        scores = np.asarray(_score_batch(packed, probe, self.impl))
        if not np.isfinite(scores).all():
            raise ValueError("probe batch produced non-finite scores")
        for s in self.warm_sizes:
            jax.block_until_ready(
                _score_batch(packed, jnp.zeros((s, d), jnp.float32),
                             self.impl))

    def try_reload(self, path: str) -> bool:
        t0 = time.perf_counter()
        try:
            candidate = ckpt_io.load_ensemble(path)
            self._validate(candidate)
        except (ValueError, OSError) as e:
            if self.metrics is not None:
                self.metrics.reload_failures.inc()
            print(f"reload REFUSED ({path}): {e} — keeping previous model")
            return False
        self.packed = candidate
        if self.metrics is not None:
            self.metrics.reloads.inc()
            self.metrics.swap_latency.observe(time.perf_counter() - t0)
            self.metrics.begin_model_segment()
        print(f"reload OK ({path}): {candidate.total_trees} trees / "
              f"{candidate.rounds} rounds")
        return True


def serve_stream(
    slot: ModelSlot,
    x: np.ndarray,
    *,
    ladder: BatchLadder,
    metrics: StreamMetrics = None,
    p99_budget_s: float | None = None,
    swap_plan: dict | None = None,
) -> tuple[np.ndarray, StreamMetrics]:
    """The production serving loop: admission, scoring, mid-stream swaps.

    Each iteration (1) applies any hot-swap scheduled for this batch index
    (``swap_plan``: batch_idx -> checkpoint path — swaps land BETWEEN
    microbatches, never inside one), (2) asks the ladder for a capacity
    given the queue depth and p99 budget, (3) scores one microbatch on the
    slot's current model.

    Host-copy discipline: a full clean batch goes straight from the caller's
    array into the device transfer — NO host-side staging copy.  A copy is
    made only when the batch needs mutation (inf rows zeroed before the
    compiled program; their scores return NaN and land on
    ``fedgbf_serve_rows_rejected_total``) or zero-padding to the admitted
    capacity.  Plain NaN features are NOT rejected: the fused traversal
    routes them left, the same reserved-NAN_BIN semantics training used.
    """
    n = x.shape[0]
    out = None  # allocated after the first batch: (n,) or (n, K) scores
    if metrics is None:
        metrics = StreamMetrics(ladder.max_size)
    pos = 0
    batch_idx = 0
    while pos < n:
        if swap_plan and batch_idx in swap_plan:
            slot.try_reload(swap_plan[batch_idx])
        queued = n - pos
        cap = ladder.pick(queued, p99_budget_s, metrics)
        real = min(cap, queued)
        view = x[pos:pos + real]
        bad = np.isinf(view).any(axis=1)
        nbad = int(bad.sum())
        if nbad or real < cap:
            batch = np.zeros((cap,) + x.shape[1:], x.dtype)
            batch[:real] = view
            if nbad:
                batch[:real][bad] = 0.0
            metrics.rows_rejected.inc(nbad)
        else:
            batch = view
        t0 = time.perf_counter()
        scores = jax.block_until_ready(
            _score_batch(slot.packed, jnp.asarray(batch), slot.impl)
        )
        metrics.observe_batch(time.perf_counter() - t0, real, capacity=cap)
        if out is None:
            out = np.empty((n,) + scores.shape[1:], np.float32)
        block = np.asarray(scores[:real])
        if nbad:
            block = block.copy()
            block[bad] = np.nan
        out[pos:pos + real] = block
        pos += real
        batch_idx += 1
    return out, metrics


def score_stream(
    packed,
    x: np.ndarray,
    batch_size: int = 8192,
    impl: str = "packed",
    metrics: StreamMetrics = None,
) -> tuple[np.ndarray, StreamMetrics]:
    """Score ``x`` in fixed-shape microbatches; returns (scores, metrics).

    The single-rung special case of ``serve_stream`` (kept as the simple
    API): the last partial batch is zero-padded to ``batch_size`` so every
    step hits the same compiled program.  Per-batch latency and occupancy
    land in ``metrics`` (a fresh ``StreamMetrics`` unless one is passed in
    to accumulate across calls) — fixed-size state, so an unbounded stream
    cannot grow it.
    """
    slot = ModelSlot(packed, impl)
    return serve_stream(slot, x, ladder=BatchLadder([batch_size]),
                        metrics=metrics if metrics is not None
                        else StreamMetrics(batch_size))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", default=None,
                    help="packed checkpoint path (checkpoint.io.save_ensemble)")
    ap.add_argument("--save", default=None,
                    help="save the (freshly trained) packed model here")
    ap.add_argument("--dataset", choices=list(synthetic.DATASETS),
                    default="default_credit_card")
    ap.add_argument("--rounds", type=int, default=10,
                    help="training rounds when no checkpoint is given")
    ap.add_argument("--requests", type=int, default=100_000,
                    help="size of the synthetic request stream")
    ap.add_argument("--batch-size", type=int, default=8192,
                    help="microbatch capacity (the ladder's top rung)")
    ap.add_argument("--impl",
                    choices=["fused", "fused-pallas", "packed", "weighted",
                             "pallas"],
                    default="fused",
                    help="serving traversal: 'fused'/'fused-pallas' run "
                         "bin+traverse+combine as ONE program on raw floats "
                         "(DESIGN.md §14); the rest bin in a separate "
                         "dispatch first")
    ap.add_argument("--quantize", type=int, choices=[8, 16], default=None,
                    metavar="BITS",
                    help="serve an int8/int16 QuantizedEnsemble (stochastic "
                         "leaf rounding; margin error provably bounded, "
                         "printed at startup)")
    ap.add_argument("--p99-budget-ms", type=float, default=None,
                    help="latency budget: each batch admits the largest "
                         "ladder rung whose observed p99 fits (implies "
                         "--adaptive)")
    ap.add_argument("--adaptive", action="store_true",
                    help="enable the power-of-two batch ladder even without "
                         "a p99 budget (short queues admit smaller rungs "
                         "instead of padding to --batch-size)")
    ap.add_argument("--ladder-min", type=int, default=256,
                    help="smallest ladder rung (adaptive mode)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition of the "
                         "stream metrics here ('-' for stdout)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve the exposition on a localhost HTTP scrape "
                         "endpoint (0 = ephemeral port) for the stream's "
                         "duration")
    ap.add_argument("--reload", default=None, metavar="PATH",
                    help="hot-reload this checkpoint (validate-before-swap: "
                         "a corrupt or non-finite candidate is refused and "
                         "the current model keeps serving)")
    ap.add_argument("--reload-at-batch", type=int, default=None, metavar="N",
                    help="apply --reload between microbatches N-1 and N of "
                         "the live stream (default: before the stream)")
    args = ap.parse_args()

    ds = synthetic.load(args.dataset)
    if args.checkpoint:
        packed = ckpt_io.load_ensemble(args.checkpoint)
        print(f"loaded {args.checkpoint}: {packed.total_trees} trees / "
              f"{packed.rounds} rounds, depth {packed.max_depth}")
    else:
        cfg = boosting.dynamic_fedgbf_config(rounds=args.rounds)
        model, _ = boosting.train_fedgbf(
            jnp.asarray(ds.x_train), jnp.asarray(ds.y_train), cfg,
            jax.random.PRNGKey(0),
        )
        from repro.core.types import pack_ensemble

        packed = pack_ensemble(model)
        print(f"trained {packed.total_trees} trees / {packed.rounds} rounds")
    if args.save:
        ckpt_io.save_ensemble(args.save, packed)
        print(f"saved packed checkpoint to {args.save}")

    if args.quantize:
        from repro.core.types import margin_delta_bound, quantize_ensemble

        if isinstance(packed, PackedEnsemble):
            packed = quantize_ensemble(packed, bits=args.quantize,
                                       key=jax.random.PRNGKey(0))
        print(f"serving int{args.quantize} quantized tables: margin error "
              f"bound {margin_delta_bound(packed):.3e}")

    # Synthetic request stream: resample test rows up to --requests users.
    rng = np.random.default_rng(0)
    idx = rng.integers(0, ds.x_test.shape[0], args.requests)
    requests = np.asarray(ds.x_test)[idx]

    # A stream smaller than one microbatch would otherwise pad (and score)
    # mostly zeros — and the warm-up below would already score the whole
    # stream.  Cap the microbatch at the stream size instead.
    batch_size = min(args.batch_size, args.requests)
    if batch_size != args.batch_size:
        print(f"requests < batch-size: shrinking microbatch "
              f"{args.batch_size} -> {batch_size}")

    adaptive = args.adaptive or args.p99_budget_ms is not None
    ladder = BatchLadder(ladder_sizes(batch_size, args.ladder_min)
                         if adaptive else [batch_size])

    sm = StreamMetrics(batch_size)
    server = None
    if args.metrics_port is not None:
        server = obs_metrics.serve_metrics_http(sm.registry,
                                                port=args.metrics_port)
        print(f"metrics scrape endpoint: {server.url}")
    slot = ModelSlot(packed, args.impl, metrics=sm,
                     warm_sizes=ladder.sizes)
    swap_plan = {}
    if args.reload:
        if args.reload_at_batch is not None:
            swap_plan[args.reload_at_batch] = args.reload
        else:
            slot.try_reload(args.reload)

    # Warm-up compiles every ladder rung for the current model (swaps warm
    # their own candidate inside ``try_reload``), so the admission
    # controller can move between rungs with ZERO mid-stream recompiles;
    # warm batches are zero probes and never touch the stream metrics.
    d = slot.packed.bin_edges.shape[0]
    ladder.warm(slot.packed, d, args.impl)

    budget_s = (args.p99_budget_ms * 1e-3
                if args.p99_budget_ms is not None else None)
    t0 = time.perf_counter()
    scores, sm = serve_stream(slot, requests, ladder=ladder, metrics=sm,
                              p99_budget_s=budget_s, swap_plan=swap_plan)
    sm.finalize(time.perf_counter() - t0)
    # Quantiles from the log-bucket counts (geometric-midpoint estimate,
    # error bounded by half the bucket growth) — the raw latency list is
    # gone on purpose: it grew with the stream.
    q = sm.quantiles_ms()
    print(f"impl={args.impl} batch<= {batch_size} "
          f"requests={args.requests}: {sm.rows_per_s.value:,.0f} rows/s, "
          f"batch latency p50={q[0.5]:.2f}ms p90={q[0.9]:.2f}ms "
          f"p99={q[0.99]:.2f}ms "
          f"({int(sm.batches.value)} batches, "
          f"occupancy={sm.occupancy.value:.3f}, "
          f"swaps={int(sm.reloads.value)})")
    if args.metrics_out:
        text = sm.render()
        if args.metrics_out == "-":
            print(text, end="")
        else:
            with open(args.metrics_out, "w") as f:
                f.write(text)
            print(f"metrics exposition -> {args.metrics_out}")
    if server is not None:
        # one self-scrape proves the endpoint served the live registry
        from urllib.request import urlopen

        with urlopen(server.url) as resp:
            lines = resp.read().decode().count("\n")
        print(f"self-scrape {server.url}: {lines} exposition lines")
        server.close()
    print(f"score head: {scores[:5]}")


if __name__ == "__main__":
    main()
