"""Batched FedGBF scoring service — the millions-of-users serving scenario.

The model is held in the ``PackedEnsemble`` layout (DESIGN.md §3), so every
request batch costs ONE ensemble traversal: binning + all-trees vmap (or the
fused Pallas ``ensemble_predict`` kernel) + the scale combiner, compiled once
for a fixed microbatch shape.  Requests are padded to the microbatch size so
the whole serving loop replays a single XLA program.

Observability (DESIGN.md §12): the stream records into a ``StreamMetrics``
bundle — a log-bucketed latency histogram (p50/p90/p99 derived from bucket
counts, NOT from a raw per-batch list, so memory stays constant under
unbounded streams) plus rows/batches/padded-rows counters and occupancy /
rows-per-second gauges.  ``--metrics-out`` writes the whole bundle in the
Prometheus text exposition format — the scrape payload a metrics endpoint
serves verbatim.

    # train a small model, save the packed checkpoint, score a request stream
    PYTHONPATH=src python -m repro.launch.serve_fedgbf \
        --dataset default_credit_card --rounds 10 --save /tmp/fedgbf_ckpt

    # serve an existing packed checkpoint with the Pallas kernel
    PYTHONPATH=src python -m repro.launch.serve_fedgbf \
        --checkpoint /tmp/fedgbf_ckpt --impl pallas --requests 200000 \
        --metrics-out /tmp/fedgbf_metrics.prom
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import boosting
from repro.core import objective as objective_mod
from repro.core.types import PackedEnsemble
from repro.data import synthetic
from repro.obs import metrics as obs_metrics


@partial(jax.jit, static_argnames=("impl",))
def _score_batch(packed: PackedEnsemble, x: jnp.ndarray, impl: str) -> jnp.ndarray:
    """One compiled program per (microbatch shape, impl): bin + traverse,
    via the same dispatch boosting.predict exposes.

    The activation comes from the objective registry keyed by the
    checkpoint's stored loss name (DESIGN.md §11) — sigmoid for logistic,
    softmax rows for softmax{K}, identity for the regression objectives —
    instead of a hard-coded sigmoid, so a squared- or quantile-loss
    checkpoint serves raw margins and a multiclass one serves (n, K)
    probability rows."""
    margin = boosting.predict(packed, x, impl=impl)
    return objective_mod.get_objective(packed.loss).activation(margin)


class StreamMetrics:
    """Serving instruments for one scoring stream (bounded memory).

    Latency lives ONLY in the log-bucketed histogram — p50/p90/p99 come
    from ``latency.quantile`` with a bucket-width error bound (~4.5%
    relative at the default growth), never from a raw list that grows with
    the stream.  Batch occupancy = real rows / microbatch capacity, so
    ``1 - occupancy`` is the fraction of traversal work spent on padding.
    """

    def __init__(self, batch_size: int) -> None:
        r = obs_metrics.MetricsRegistry()
        self.registry = r
        self.latency = r.histogram(
            "fedgbf_serve_batch_latency_seconds",
            "Per-microbatch scoring latency (bin + traverse + combine).",
            lo=1e-6, hi=60.0,
        )
        self.rows = r.counter("fedgbf_serve_rows_total",
                              "Real (non-padding) rows scored.")
        self.batches = r.counter("fedgbf_serve_batches_total",
                                 "Microbatches dispatched.")
        self.padded_rows = r.counter(
            "fedgbf_serve_padded_rows_total",
            "Zero-padding rows scored to keep the microbatch shape static.")
        self.batch_size = r.gauge("fedgbf_serve_batch_size",
                                  "Static microbatch capacity.")
        self.occupancy = r.gauge(
            "fedgbf_serve_batch_occupancy",
            "Mean real-row fraction per microbatch (1 = no padding).")
        self.rows_per_s = r.gauge("fedgbf_serve_rows_per_second",
                                  "Stream throughput over the last run.")
        self.rows_rejected = r.counter(
            "fedgbf_serve_rows_rejected_total",
            "Rows rejected for non-finite (inf) features: scored as NaN, "
            "never fed to the ensemble (DESIGN.md §13).")
        self.reloads = r.counter(
            "fedgbf_serve_reloads_total",
            "Hot model reloads that passed validation and were swapped in.")
        self.reload_failures = r.counter(
            "fedgbf_serve_reload_failures_total",
            "Hot reloads refused (corrupt checkpoint / failed probe); the "
            "previous ensemble keeps serving.")
        self.batch_size.set(batch_size)
        self._capacity = batch_size

    def observe_batch(self, latency_s: float, real_rows: int) -> None:
        self.latency.observe(latency_s)
        self.rows.inc(real_rows)
        self.batches.inc()
        self.padded_rows.inc(self._capacity - real_rows)
        total = self._capacity * self.batches.value
        self.occupancy.set(self.rows.value / total if total else 0.0)

    def finalize(self, wall_s: float) -> None:
        if wall_s > 0:
            self.rows_per_s.set(self.rows.value / wall_s)

    def quantiles_ms(self, qs=(0.5, 0.9, 0.99)) -> dict:
        return {q: self.latency.quantile(q) * 1e3 for q in qs}

    def render(self) -> str:
        """Prometheus text exposition of the whole bundle."""
        return self.registry.render()


def score_stream(
    packed: PackedEnsemble,
    x: np.ndarray,
    batch_size: int = 8192,
    impl: str = "packed",
    metrics: StreamMetrics = None,
) -> tuple[np.ndarray, StreamMetrics]:
    """Score ``x`` in fixed-shape microbatches; returns (scores, metrics).

    The last partial batch is zero-padded to ``batch_size`` (scores of the
    padding are dropped) so every step hits the same compiled program.
    Per-batch latency and occupancy land in ``metrics`` (a fresh
    ``StreamMetrics`` unless one is passed in to accumulate across calls) —
    fixed-size state, so an unbounded stream cannot grow it.
    """
    n = x.shape[0]
    out = None  # allocated after the first batch: (n,) or (n, K) scores
    if metrics is None:
        metrics = StreamMetrics(batch_size)
    for start in range(0, n, batch_size):
        chunk = np.array(x[start:start + batch_size], copy=True)
        real = chunk.shape[0]
        pad = batch_size - real
        # Input hardening (DESIGN.md §13): rows carrying inf would silently
        # bin to the extreme buckets and score as if legitimate — reject
        # them instead.  They are zeroed before the compiled program (shape
        # stays static), their scores come back as NaN, and the rejection
        # lands on ``fedgbf_serve_rows_rejected_total``.  Plain NaN features
        # are NOT rejected: binning routes them to the reserved missing-value
        # bin (NAN_BIN), the same semantics training used.
        bad = np.isinf(chunk).any(axis=1)
        if bad.any():
            chunk[bad] = 0.0
            metrics.rows_rejected.inc(int(bad.sum()))
        if pad:
            chunk = np.concatenate([chunk, np.zeros((pad,) + chunk.shape[1:],
                                                    chunk.dtype)])
        t0 = time.perf_counter()
        scores = jax.block_until_ready(
            _score_batch(packed, jnp.asarray(chunk), impl)
        )
        metrics.observe_batch(time.perf_counter() - t0, real)
        if out is None:
            out = np.empty((n,) + scores.shape[1:], np.float32)
        block = np.asarray(scores[:real])
        if bad.any():
            block = block.copy()
            block[bad] = np.nan
        out[start:start + real] = block
    return out, metrics


class ModelSlot:
    """Hot-reloadable model holder with validate-before-swap (DESIGN.md §13).

    ``try_reload`` loads a candidate checkpoint (sha256-verified by
    ``checkpoint.io``), scores a zero probe batch through the serving
    program, and only THEN swaps it in.  Any failure — missing file,
    corrupt/truncated npz, checksum mismatch, non-finite probe scores —
    leaves the previous ensemble serving and increments
    ``fedgbf_serve_reload_failures_total``; a successful swap increments
    ``fedgbf_serve_reloads_total``.
    """

    def __init__(self, packed: PackedEnsemble, impl: str = "packed",
                 metrics: StreamMetrics = None) -> None:
        self.packed = packed
        self.impl = impl
        self.metrics = metrics

    def _validate(self, packed: PackedEnsemble) -> None:
        d = packed.bin_edges.shape[0]
        probe = jnp.zeros((4, d), jnp.float32)
        scores = np.asarray(_score_batch(packed, probe, self.impl))
        if not np.isfinite(scores).all():
            raise ValueError("probe batch produced non-finite scores")

    def try_reload(self, path: str) -> bool:
        try:
            candidate = ckpt_io.load_ensemble(path)
            self._validate(candidate)
        except (ValueError, OSError) as e:
            if self.metrics is not None:
                self.metrics.reload_failures.inc()
            print(f"reload REFUSED ({path}): {e} — keeping previous model")
            return False
        self.packed = candidate
        if self.metrics is not None:
            self.metrics.reloads.inc()
        print(f"reload OK ({path}): {candidate.total_trees} trees / "
              f"{candidate.rounds} rounds")
        return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", default=None,
                    help="packed checkpoint path (checkpoint.io.save_ensemble)")
    ap.add_argument("--save", default=None,
                    help="save the (freshly trained) packed model here")
    ap.add_argument("--dataset", choices=list(synthetic.DATASETS),
                    default="default_credit_card")
    ap.add_argument("--rounds", type=int, default=10,
                    help="training rounds when no checkpoint is given")
    ap.add_argument("--requests", type=int, default=100_000,
                    help="size of the synthetic request stream")
    ap.add_argument("--batch-size", type=int, default=8192)
    ap.add_argument("--impl", choices=["packed", "weighted", "pallas"],
                    default="packed")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition of the "
                         "stream metrics here ('-' for stdout)")
    ap.add_argument("--reload", default=None, metavar="PATH",
                    help="hot-reload this checkpoint before scoring the "
                         "stream (validate-before-swap: a corrupt or "
                         "non-finite candidate is refused and the current "
                         "model keeps serving)")
    args = ap.parse_args()

    ds = synthetic.load(args.dataset)
    if args.checkpoint:
        packed = ckpt_io.load_ensemble(args.checkpoint)
        print(f"loaded {args.checkpoint}: {packed.total_trees} trees / "
              f"{packed.rounds} rounds, depth {packed.max_depth}")
    else:
        cfg = boosting.dynamic_fedgbf_config(rounds=args.rounds)
        model, _ = boosting.train_fedgbf(
            jnp.asarray(ds.x_train), jnp.asarray(ds.y_train), cfg,
            jax.random.PRNGKey(0),
        )
        from repro.core.types import pack_ensemble

        packed = pack_ensemble(model)
        print(f"trained {packed.total_trees} trees / {packed.rounds} rounds")
    if args.save:
        ckpt_io.save_ensemble(args.save, packed)
        print(f"saved packed checkpoint to {args.save}")

    # Synthetic request stream: resample test rows up to --requests users.
    rng = np.random.default_rng(0)
    idx = rng.integers(0, ds.x_test.shape[0], args.requests)
    requests = np.asarray(ds.x_test)[idx]

    # A stream smaller than one microbatch would otherwise pad (and score)
    # mostly zeros — and the warm-up below would already score the whole
    # stream.  Cap the microbatch at the stream size instead.
    batch_size = min(args.batch_size, args.requests)
    if batch_size != args.batch_size:
        print(f"requests < batch-size: shrinking microbatch "
              f"{args.batch_size} -> {batch_size}")

    sm = StreamMetrics(batch_size)
    slot = ModelSlot(packed, args.impl, metrics=sm)
    if args.reload:
        slot.try_reload(args.reload)

    # Warm-up compiles the single microbatch program (ONE batch, not the
    # whole stream); its metrics are thrown away so the reported histogram
    # covers only steady-state batches.
    score_stream(slot.packed, requests[:batch_size], batch_size, args.impl)
    t0 = time.perf_counter()
    scores, sm = score_stream(slot.packed, requests, batch_size, args.impl,
                              metrics=sm)
    sm.finalize(time.perf_counter() - t0)
    # Quantiles from the log-bucket counts (geometric-midpoint estimate,
    # error bounded by half the bucket growth) — the raw latency list is
    # gone on purpose: it grew with the stream.
    q = sm.quantiles_ms()
    print(f"impl={args.impl} batch={batch_size} "
          f"requests={args.requests}: {sm.rows_per_s.value:,.0f} rows/s, "
          f"batch latency p50={q[0.5]:.2f}ms p90={q[0.9]:.2f}ms "
          f"p99={q[0.99]:.2f}ms "
          f"({int(sm.batches.value)} batches, "
          f"occupancy={sm.occupancy.value:.3f})")
    if args.metrics_out:
        text = sm.render()
        if args.metrics_out == "-":
            print(text, end="")
        else:
            with open(args.metrics_out, "w") as f:
                f.write(text)
            print(f"metrics exposition -> {args.metrics_out}")
    print(f"score head: {scores[:5]}")


if __name__ == "__main__":
    main()
