"""Batched FedGBF scoring service — the millions-of-users serving scenario.

The model is held in the ``PackedEnsemble`` layout (DESIGN.md §3), so every
request batch costs ONE ensemble traversal: binning + all-trees vmap (or the
fused Pallas ``ensemble_predict`` kernel) + the scale combiner, compiled once
for a fixed microbatch shape.  Requests are padded to the microbatch size so
the whole serving loop replays a single XLA program.

    # train a small model, save the packed checkpoint, score a request stream
    PYTHONPATH=src python -m repro.launch.serve_fedgbf \
        --dataset default_credit_card --rounds 10 --save /tmp/fedgbf_ckpt

    # serve an existing packed checkpoint with the Pallas kernel
    PYTHONPATH=src python -m repro.launch.serve_fedgbf \
        --checkpoint /tmp/fedgbf_ckpt --impl pallas --requests 200000
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import boosting
from repro.core import objective as objective_mod
from repro.core.types import PackedEnsemble
from repro.data import synthetic


@partial(jax.jit, static_argnames=("impl",))
def _score_batch(packed: PackedEnsemble, x: jnp.ndarray, impl: str) -> jnp.ndarray:
    """One compiled program per (microbatch shape, impl): bin + traverse,
    via the same dispatch boosting.predict exposes.

    The activation comes from the objective registry keyed by the
    checkpoint's stored loss name (DESIGN.md §11) — sigmoid for logistic,
    softmax rows for softmax{K}, identity for the regression objectives —
    instead of a hard-coded sigmoid, so a squared- or quantile-loss
    checkpoint serves raw margins and a multiclass one serves (n, K)
    probability rows."""
    margin = boosting.predict(packed, x, impl=impl)
    return objective_mod.get_objective(packed.loss).activation(margin)


def score_stream(
    packed: PackedEnsemble,
    x: np.ndarray,
    batch_size: int = 8192,
    impl: str = "packed",
) -> tuple[np.ndarray, list]:
    """Score ``x`` in fixed-shape microbatches; returns (scores, latencies_s).

    The last partial batch is zero-padded to ``batch_size`` (scores of the
    padding are dropped) so every step hits the same compiled program.
    """
    n = x.shape[0]
    out = None  # allocated after the first batch: (n,) or (n, K) scores
    lat = []
    for start in range(0, n, batch_size):
        chunk = x[start:start + batch_size]
        pad = batch_size - chunk.shape[0]
        if pad:
            chunk = np.concatenate([chunk, np.zeros((pad,) + chunk.shape[1:],
                                                    chunk.dtype)])
        t0 = time.perf_counter()
        scores = jax.block_until_ready(
            _score_batch(packed, jnp.asarray(chunk), impl)
        )
        lat.append(time.perf_counter() - t0)
        if out is None:
            out = np.empty((n,) + scores.shape[1:], np.float32)
        out[start:start + batch_size - pad] = np.asarray(
            scores[:batch_size - pad]
        )
    return out, lat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--checkpoint", default=None,
                    help="packed checkpoint path (checkpoint.io.save_ensemble)")
    ap.add_argument("--save", default=None,
                    help="save the (freshly trained) packed model here")
    ap.add_argument("--dataset", choices=list(synthetic.DATASETS),
                    default="default_credit_card")
    ap.add_argument("--rounds", type=int, default=10,
                    help="training rounds when no checkpoint is given")
    ap.add_argument("--requests", type=int, default=100_000,
                    help="size of the synthetic request stream")
    ap.add_argument("--batch-size", type=int, default=8192)
    ap.add_argument("--impl", choices=["packed", "weighted", "pallas"],
                    default="packed")
    args = ap.parse_args()

    ds = synthetic.load(args.dataset)
    if args.checkpoint:
        packed = ckpt_io.load_ensemble(args.checkpoint)
        print(f"loaded {args.checkpoint}: {packed.total_trees} trees / "
              f"{packed.rounds} rounds, depth {packed.max_depth}")
    else:
        cfg = boosting.dynamic_fedgbf_config(rounds=args.rounds)
        model, _ = boosting.train_fedgbf(
            jnp.asarray(ds.x_train), jnp.asarray(ds.y_train), cfg,
            jax.random.PRNGKey(0),
        )
        from repro.core.types import pack_ensemble

        packed = pack_ensemble(model)
        print(f"trained {packed.total_trees} trees / {packed.rounds} rounds")
    if args.save:
        ckpt_io.save_ensemble(args.save, packed)
        print(f"saved packed checkpoint to {args.save}")

    # Synthetic request stream: resample test rows up to --requests users.
    rng = np.random.default_rng(0)
    idx = rng.integers(0, ds.x_test.shape[0], args.requests)
    requests = np.asarray(ds.x_test)[idx]

    # A stream smaller than one microbatch would otherwise pad (and score)
    # mostly zeros — and the warm-up below would already score the whole
    # stream.  Cap the microbatch at the stream size instead.
    batch_size = min(args.batch_size, args.requests)
    if batch_size != args.batch_size:
        print(f"requests < batch-size: shrinking microbatch "
              f"{args.batch_size} -> {batch_size}")

    # Warm-up compiles the single microbatch program (ONE batch, not the
    # whole stream).
    score_stream(packed, requests[:batch_size], batch_size, args.impl)
    t0 = time.perf_counter()
    scores, lat = score_stream(packed, requests, batch_size, args.impl)
    wall = time.perf_counter() - t0
    # np.percentile interpolates between order statistics — correct for
    # small / even-length latency streams, where hand-indexing the sorted
    # list is biased (e.g. the "p50" of [1, 2] must be 1.5, not 2).
    lat_ms = np.asarray(lat) * 1e3
    p50 = float(np.percentile(lat_ms, 50))
    p99 = float(np.percentile(lat_ms, 99))
    print(f"impl={args.impl} batch={batch_size} "
          f"requests={args.requests}: {args.requests / wall:,.0f} rows/s, "
          f"batch latency p50={p50:.2f}ms p99={p99:.2f}ms")
    print(f"score head: {scores[:5]}")


if __name__ == "__main__":
    main()
