"""Dependency-free pytree checkpointing (npz + json treedef).

Flattens any pytree of arrays to an .npz plus a json structure descriptor;
round-trips dtypes (incl. bfloat16 via a uint16 view) and python scalars.
Used for both LM TrainStates and FedGBF EnsembleModels.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def save_pytree(path: str, tree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    meta = {"treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        entry = {"dtype": str(arr.dtype)}
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            entry["dtype"] = _BF16
        arrays[f"leaf_{i}"] = arr
        meta["leaves"].append(entry)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path + ".npz" if not path.endswith(".npz") else path, **arrays)
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def load_pytree(path: str, like) -> object:
    """Load into the structure of ``like`` (an example pytree)."""
    npz = np.load(path + ".npz" if not path.endswith(".npz") else path)
    with open(_meta_path(path)) as f:
        meta = json.load(f)
    leaves = []
    for i, entry in enumerate(meta["leaves"]):
        arr = npz[f"leaf_{i}"]
        if entry["dtype"] == _BF16:
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    _, treedef = jax.tree.flatten(like)
    return jax.tree.unflatten(treedef, leaves)


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"
