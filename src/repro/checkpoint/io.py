"""Dependency-free pytree checkpointing (npz + json treedef).

Flattens any pytree of arrays to an .npz plus a json structure descriptor;
round-trips dtypes (incl. bfloat16 via a uint16 view) and python scalars.
Used for both LM TrainStates and FedGBF ensembles.

FedGBF models persist in the *packed* layout (``save_ensemble`` /
``load_ensemble``): the static metadata (round offsets, learning rate, loss)
goes into the json sidecar, so loading needs no example pytree and the
serving entrypoint can mmap a checkpoint straight into the packed predictor
(DESIGN.md §3).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as trace_mod

_BF16 = "bfloat16"


def save_pytree(path: str, tree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    meta = {"treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        entry = {"dtype": str(arr.dtype)}
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            entry["dtype"] = _BF16
        arrays[f"leaf_{i}"] = arr
        meta["leaves"].append(entry)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path + ".npz" if not path.endswith(".npz") else path, **arrays)
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def _load_leaves(path: str, meta: dict) -> list:
    """Load the npz leaves with dtype restoration (incl. the bf16 view)."""
    npz = np.load(path + ".npz" if not path.endswith(".npz") else path)
    leaves = []
    for i, entry in enumerate(meta["leaves"]):
        arr = npz[f"leaf_{i}"]
        if entry["dtype"] == _BF16:
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    return leaves


def load_pytree(path: str, like) -> object:
    """Load into the structure of ``like`` (an example pytree)."""
    with open(_meta_path(path)) as f:
        meta = json.load(f)
    leaves = _load_leaves(path, meta)
    _, treedef = jax.tree.flatten(like)
    return jax.tree.unflatten(treedef, leaves)


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def save_ensemble(path: str, model) -> None:
    """Persist a FedGBF model (EnsembleModel or PackedEnsemble) packed.

    Array leaves go to the npz; the pytree's static aux data (round offsets,
    learning rate, base score, loss, max_depth) goes into the json sidecar
    under ``"packed_ensemble"`` so ``load_ensemble`` is self-describing.
    """
    from repro.core.types import EnsembleModel, PackedEnsemble, pack_ensemble

    # spans on the process-global tracer: checkpoint I/O sits below the
    # drivers, so it cannot be handed a tracer argument (DESIGN.md §12)
    with trace_mod.global_tracer().span("checkpoint.save", cat="io",
                                        args={"path": path}):
        if isinstance(model, EnsembleModel):
            model = pack_ensemble(model)
        if not isinstance(model, PackedEnsemble):
            raise TypeError(
                f"expected EnsembleModel or PackedEnsemble, got {model!r}"
            )
        leaves, aux = model.tree_flatten()
        save_pytree(path, list(leaves))
        round_offsets, lr, base, loss, max_depth = aux
        meta_path = _meta_path(path)
        with open(meta_path) as f:
            meta = json.load(f)
        meta["packed_ensemble"] = {
            "round_offsets": list(round_offsets),
            "learning_rate": lr,
            "base_score": base,
            "loss": loss,
            "max_depth": max_depth,
        }
        with open(meta_path, "w") as f:
            json.dump(meta, f)


def load_ensemble(path: str):
    """Load a packed FedGBF checkpoint; returns a PackedEnsemble."""
    from repro.core.types import PackedEnsemble

    with trace_mod.global_tracer().span("checkpoint.load", cat="io",
                                        args={"path": path}):
        with open(_meta_path(path)) as f:
            meta = json.load(f)
        if "packed_ensemble" not in meta:
            raise ValueError(
                f"{path} is not a packed-ensemble checkpoint (missing "
                "'packed_ensemble' metadata); use load_pytree with an "
                "example tree"
            )
        pe = meta["packed_ensemble"]
        leaves = _load_leaves(path, meta)
        aux = (tuple(pe["round_offsets"]), pe["learning_rate"],
               pe["base_score"], pe["loss"], pe["max_depth"])
        return PackedEnsemble.tree_unflatten(aux, tuple(leaves))
