"""Dependency-free pytree checkpointing (npz + json treedef).

Flattens any pytree of arrays to an .npz plus a json structure descriptor;
round-trips dtypes (incl. bfloat16 via a uint16 view) and python scalars.
Used for both LM TrainStates and FedGBF ensembles.

FedGBF models persist in the *packed* layout (``save_ensemble`` /
``load_ensemble``): the static metadata (round offsets, learning rate, loss)
goes into the json sidecar, so loading needs no example pytree and the
serving entrypoint can mmap a checkpoint straight into the packed predictor
(DESIGN.md §3).

Durability contract (DESIGN.md §13): every write lands via temp file +
``os.replace`` — npz first, sidecar second — so a kill at any instant leaves
either the previous complete checkpoint or the new complete one, never a
torn pair.  The sidecar records a sha256 of the npz payload; every load path
re-hashes the npz and refuses a mismatched or truncated file with a clear
``ValueError`` instead of deserializing garbage.

``save_train_state`` / ``load_train_state`` persist the boosting resume
carrier: the packed-ensemble prefix of the completed rounds, the exact
float32 margin carry (train and optional valid), the RNG key state, and the
completed-round count + config fingerprint that ``--resume`` validates.
"""

from __future__ import annotations

import hashlib
import io as io_mod
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as trace_mod

_BF16 = "bfloat16"


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def _atomic_write_bytes(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` via temp file + rename (same directory, so
    the replace is atomic on POSIX)."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(prefix=os.path.basename(path) + ".", dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def save_pytree(path: str, tree, extra_meta: dict | None = None) -> None:
    """Persist a pytree atomically; ``extra_meta`` merges into the sidecar
    (written in the SAME json dump, so there is never a second read-modify-
    rewrite window on the metadata)."""
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    meta = {"treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        entry = {"dtype": str(arr.dtype)}
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            entry["dtype"] = _BF16
        arrays[f"leaf_{i}"] = arr
        meta["leaves"].append(entry)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    buf = io_mod.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    meta["npz_sha256"] = hashlib.sha256(payload).hexdigest()
    if extra_meta:
        meta.update(extra_meta)
    # npz first, sidecar second: a kill between the two leaves a new npz
    # beside the OLD sidecar, whose stale sha256 makes the load refuse the
    # pair loudly instead of mixing generations.
    _atomic_write_bytes(_npz_path(path), payload)
    _atomic_write_bytes(_meta_path(path), json.dumps(meta).encode())


def _load_leaves(path: str, meta: dict) -> list:
    """Load the npz leaves with dtype restoration (incl. the bf16 view),
    verifying the sidecar's sha256 before touching the zip structure."""
    npz_path = _npz_path(path)
    with open(npz_path, "rb") as f:
        payload = f.read()
    want = meta.get("npz_sha256")
    if want is not None:
        got = hashlib.sha256(payload).hexdigest()
        if got != want:
            raise ValueError(
                f"checkpoint {npz_path} is corrupt or truncated: npz sha256 "
                f"{got[:12]}… does not match sidecar {want[:12]}… "
                f"(file may be from a torn write; re-save the checkpoint)"
            )
    try:
        npz = np.load(io_mod.BytesIO(payload))
        leaves = []
        for i, entry in enumerate(meta["leaves"]):
            arr = npz[f"leaf_{i}"]
            if entry["dtype"] == _BF16:
                arr = arr.view(jnp.bfloat16)
            leaves.append(jnp.asarray(arr))
    except ValueError:
        raise
    except Exception as e:  # zipfile/format errors from a truncated payload
        raise ValueError(
            f"checkpoint {npz_path} failed to deserialize ({e!r}); the file "
            "is corrupt or truncated"
        ) from e
    return leaves


def load_pytree(path: str, like) -> object:
    """Load into the structure of ``like`` (an example pytree)."""
    with open(_meta_path(path)) as f:
        meta = json.load(f)
    leaves = _load_leaves(path, meta)
    _, treedef = jax.tree.flatten(like)
    return jax.tree.unflatten(treedef, leaves)


def _packed_meta(aux) -> dict:
    round_offsets, lr, base, loss, max_depth = aux
    return {
        "round_offsets": list(round_offsets),
        "learning_rate": lr,
        "base_score": base,
        "loss": loss,
        "max_depth": max_depth,
    }


def _packed_aux(pe: dict) -> tuple:
    return (tuple(pe["round_offsets"]), pe["learning_rate"],
            pe["base_score"], pe["loss"], pe["max_depth"])


def _as_packed(model):
    from repro.core.types import EnsembleModel, PackedEnsemble, pack_ensemble

    if isinstance(model, EnsembleModel):
        model = pack_ensemble(model)
    if not isinstance(model, PackedEnsemble):
        raise TypeError(
            f"expected EnsembleModel or PackedEnsemble, got {model!r}"
        )
    return model


def save_ensemble(path: str, model) -> None:
    """Persist a FedGBF model packed (or quantized, DESIGN.md §14).

    Array leaves go to the npz; the pytree's static aux data (round offsets,
    learning rate, base score, loss, max_depth) goes into the json sidecar
    under ``"packed_ensemble"`` so ``load_ensemble`` is self-describing.
    A ``QuantizedEnsemble`` persists its int8/int16 tables verbatim under a
    ``"quantized_ensemble"`` sidecar instead — the checkpoint at rest is as
    small as the serving tables, and ``load_ensemble`` hands back the same
    type it was given.
    """
    from repro.core.types import QuantizedEnsemble

    # spans on the process-global tracer: checkpoint I/O sits below the
    # drivers, so it cannot be handed a tracer argument (DESIGN.md §12)
    with trace_mod.global_tracer().span("checkpoint.save", cat="io",
                                        args={"path": path}):
        if isinstance(model, QuantizedEnsemble):
            leaves, aux = model.tree_flatten()
            meta = _packed_meta(aux[1:])
            meta["bits"] = int(aux[0])
            save_pytree(path, list(leaves),
                        extra_meta={"quantized_ensemble": meta})
            return
        model = _as_packed(model)
        leaves, aux = model.tree_flatten()
        save_pytree(path, list(leaves),
                    extra_meta={"packed_ensemble": _packed_meta(aux)})


def load_ensemble(path: str):
    """Load an ensemble checkpoint; returns a ``PackedEnsemble`` or — for a
    ``"quantized_ensemble"`` sidecar — a ``QuantizedEnsemble``."""
    from repro.core.types import PackedEnsemble, QuantizedEnsemble

    with trace_mod.global_tracer().span("checkpoint.load", cat="io",
                                        args={"path": path}):
        with open(_meta_path(path)) as f:
            meta = json.load(f)
        if "quantized_ensemble" in meta:
            qe = meta["quantized_ensemble"]
            leaves = _load_leaves(path, meta)
            return QuantizedEnsemble.tree_unflatten(
                (int(qe["bits"]),) + _packed_aux(qe), tuple(leaves))
        if "packed_ensemble" not in meta:
            raise ValueError(
                f"{path} is not a packed-ensemble checkpoint (missing "
                "'packed_ensemble' metadata); use load_pytree with an "
                "example tree"
            )
        leaves = _load_leaves(path, meta)
        return PackedEnsemble.tree_unflatten(
            _packed_aux(meta["packed_ensemble"]), tuple(leaves))


def save_train_state(path: str, model, margin, completed_rounds: int,
                     fingerprint: str, rng_key=None, margin_valid=None,
                     history: dict | None = None) -> None:
    """Persist the boosting resume carrier at a segment boundary.

    ``model`` is the ensemble prefix of the completed rounds (packed on
    write); ``margin``/``margin_valid`` are the exact float32 score carries;
    ``rng_key`` is the raw PRNG key state; ``fingerprint`` pins the training
    config so ``--resume`` refuses to continue a different run; ``history``
    is an optional JSON-serializable dict of the per-round metrics so far
    (so a resumed process can stitch a full TrainHistory).
    """
    with trace_mod.global_tracer().span("checkpoint.save_state", cat="io",
                                        args={"path": path,
                                              "rounds": completed_rounds}):
        model = _as_packed(model)
        leaves, aux = model.tree_flatten()
        arrays = list(leaves) + [np.asarray(margin)]
        if margin_valid is not None:
            arrays.append(np.asarray(margin_valid))
        if rng_key is not None:
            arrays.append(np.asarray(rng_key))
        state = {
            "completed_rounds": int(completed_rounds),
            "config_fingerprint": fingerprint,
            "n_ensemble_leaves": len(leaves),
            "has_margin_valid": margin_valid is not None,
            "has_rng_key": rng_key is not None,
        }
        if history is not None:
            state["history"] = history
        save_pytree(path, arrays,
                    extra_meta={"packed_ensemble": _packed_meta(aux),
                                "train_state": state})


def load_train_state(path: str) -> dict:
    """Load a resume carrier saved by ``save_train_state``.

    Returns ``{"packed", "margin", "margin_valid", "rng_key",
    "completed_rounds", "config_fingerprint", "history"}``.
    """
    from repro.core.types import PackedEnsemble

    with trace_mod.global_tracer().span("checkpoint.load_state", cat="io",
                                        args={"path": path}):
        with open(_meta_path(path)) as f:
            meta = json.load(f)
        if "train_state" not in meta:
            raise ValueError(
                f"{path} is not a train-state checkpoint (missing "
                "'train_state' metadata)"
            )
        state = meta["train_state"]
        leaves = _load_leaves(path, meta)
        ne = state["n_ensemble_leaves"]
        packed = PackedEnsemble.tree_unflatten(
            _packed_aux(meta["packed_ensemble"]), tuple(leaves[:ne]))
        rest = [np.asarray(a) for a in leaves[ne:]]
        margin = rest.pop(0)
        margin_valid = rest.pop(0) if state["has_margin_valid"] else None
        rng_key = rest.pop(0) if state["has_rng_key"] else None
        return {
            "packed": packed,
            "margin": margin,
            "margin_valid": margin_valid,
            "rng_key": rng_key,
            "completed_rounds": state["completed_rounds"],
            "config_fingerprint": state["config_fingerprint"],
            "history": state.get("history"),
        }
