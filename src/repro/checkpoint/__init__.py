from repro.checkpoint.io import (  # noqa: F401
    load_ensemble,
    load_pytree,
    save_ensemble,
    save_pytree,
)
