"""RWKV-6 7B "Finch" — attention-free, data-dependent decay.
[arXiv:2404.05892]

Every block is a WKV-6 time-mix + channel-mix; O(1) decode state per layer
qualifies this arch for long_500k (DESIGN.md §7). n_heads/n_kv_heads are
nominal (d_model / rwkv.head_dim WKV heads are what matter)."""

from repro.models.config import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        arch_type="ssm",
        num_layers=32,
        d_model=4096,
        n_heads=64,            # 4096 / 64 WKV heads
        n_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab=65536,
        pattern=("rwkv",),
        rwkv=RWKVConfig(head_dim=64, chunk=16, decay_lora=64),
        param_dtype="bfloat16",
        source="arXiv:2404.05892",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        arch_type="ssm",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("rwkv",),
        rwkv=RWKVConfig(head_dim=64, chunk=16, decay_lora=16),
        remat=False,
        source="arXiv:2404.05892 (reduced)",
    )
