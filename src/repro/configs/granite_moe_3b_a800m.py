"""Granite-MoE 3B-a800m — fine-grained MoE, 40 experts top-8, per-expert
d_ff=512. [hf:ibm-granite/granite-3.0-1b-a400m-base]

Note: the assignment line reads "MoE 40e top-8" in the config field and
"32 experts top-8" in the free-text bracket; we implement the explicit config
field (40 experts). Vocab 49155 is not 256-aligned; logits shard via
``vocab_padded`` = 49408 (models/config.py)."""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        arch_type="moe",
        num_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,          # GQA kv=8
        head_dim=64,
        d_ff=512,              # per-expert
        vocab=49155,
        pattern=("attn_moe",),
        moe=MoEConfig(num_experts=40, top_k=8),
        ffn_type="swiglu",
        rope_theta=10_000.0,
        param_dtype="float32",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=128,
        vocab=512,
        pattern=("attn_moe",),
        moe=MoEConfig(num_experts=4, top_k=2),
        ffn_type="swiglu",
        remat=False,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base (reduced)",
    )
