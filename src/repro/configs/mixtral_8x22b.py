"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088]

SWA (window 4096) on every layer makes decode cost O(window) per token per
layer — this arch runs the long_500k shape (DESIGN.md §7). bf16 params:
~141B total / ~39B active; f32 storage would not fit the 16 GB/chip v5e HBM
budget at 512 chips (hardware-adaptation note)."""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        arch_type="moe",
        num_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,          # GQA kv=8
        head_dim=128,
        d_ff=16384,            # per-expert
        vocab=32768,
        pattern=("attn_swa",),
        window=4096,
        moe=MoEConfig(num_experts=8, top_k=2),
        ffn_type="swiglu",
        rope_theta=1_000_000.0,
        param_dtype="bfloat16",
        source="arXiv:2401.04088",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=256,
        vocab=512,
        pattern=("attn_swa",),
        window=16,
        moe=MoEConfig(num_experts=4, top_k=2),
        ffn_type="swiglu",
        remat=False,
        source="arXiv:2401.04088 (reduced)",
    )
