"""SmolLM-135M — llama-architecture small dense LM.
[hf:HuggingFaceTB/SmolLM-135M]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        arch_type="dense",
        num_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,          # GQA kv=3
        head_dim=64,
        d_ff=1536,
        vocab=49152,
        pattern=("attn",),
        ffn_type="swiglu",
        tie_embeddings=True,
        rope_theta=10_000.0,
        param_dtype="float32",
        source="hf:HuggingFaceTB/SmolLM-135M",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=192,
        n_heads=3,
        n_kv_heads=1,
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("attn",),
        ffn_type="swiglu",
        tie_embeddings=True,
        remat=False,
        source="hf:HuggingFaceTB/SmolLM-135M (reduced)",
    )
