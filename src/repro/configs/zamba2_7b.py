"""Zamba2-7B — hybrid: Mamba2 backbone + periodic weight-SHARED attention
block. [arXiv:2411.15242]

81 Mamba2 layers organised as 27 scan units of 3; the single shared
attention+FFN block fires after every 2nd unit (i.e. every 6 Mamba layers,
13 applications) with its own KV cache per application but one set of
weights — Zamba2's signature parameter sharing. Mamba state is O(1) per
token, the shared block is periodic, so long_500k runs (DESIGN.md §7)."""

from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        arch_type="hybrid",
        num_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,         # shared block is MHA (kv=32)
        head_dim=112,
        d_ff=14336,            # shared block FFN
        vocab=32000,
        pattern=("mamba", "mamba", "mamba"),   # 27 units x 3 = 81 layers
        shared_attn_every=2,                   # after units 2,4,... -> 13 fires
        ssm=SSMConfig(d_state=64, conv_kernel=4, expand=2, head_dim=64,
                      chunk=128),
        ffn_type="swiglu",
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        arch_type="hybrid",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("mamba", "mamba"),
        shared_attn_every=1,
        ssm=SSMConfig(d_state=16, conv_kernel=4, expand=2, head_dim=64,
                      chunk=16),
        ffn_type="swiglu",
        remat=False,
        source="arXiv:2411.15242 (reduced)",
    )
