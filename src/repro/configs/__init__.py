"""Architecture registry: one module per assigned architecture (plus the
paper's own tabular configs). Each module exports ``config()`` (the exact
assigned full-scale configuration, citation in ``source``) and
``smoke_config()`` (reduced same-family variant: <=3 layers, d_model <= 512,
<=4 experts — runnable on CPU)."""

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
