"""Phi-4-mini 3.8B — dense decoder, RoPE + SwiGLU + GQA.
[arXiv:2412.08905]

Simplification note: phi-4-mini's partial-rotary/LongRoPE scaling is replaced
by full-head RoPE (theta 10k); recorded here because it changes no shape and
no sharding, only the rotary fraction."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        arch_type="dense",
        num_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,          # GQA kv=8
        head_dim=128,
        d_ff=8192,
        vocab=200_064,
        pattern=("attn",),
        ffn_type="swiglu",
        tie_embeddings=True,
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        source="arXiv:2412.08905",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("attn",),
        ffn_type="swiglu",
        tie_embeddings=True,
        remat=False,
        source="arXiv:2412.08905 (reduced)",
    )
