"""Gemma-2 2B — local(4096-window)/global alternating attention, GeGLU,
attention & final-logit softcaps, post-norms. [arXiv:2408.00118]

The alternating pattern makes the unit = (local, global) pair; 26 layers =
13 units. Half the layers being windowed is what qualifies gemma2-2b for the
long_500k decode shape (each local layer caches only its 4096-token window;
the global layers hold the full cache — DESIGN.md §7)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        arch_type="dense",
        num_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,          # GQA kv=4
        head_dim=256,
        d_ff=9216,
        vocab=256_000,
        pattern=("attn_local", "attn"),
        window=4096,
        attn_softcap=50.0,
        logits_softcap=30.0,
        post_norm=True,
        ffn_type="geglu",
        tie_embeddings=True,
        rope_theta=10_000.0,
        param_dtype="float32",
        source="arXiv:2408.00118",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke",
        arch_type="dense",
        num_layers=2,          # one (local, global) unit
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("attn_local", "attn"),
        window=16,
        attn_softcap=50.0,
        logits_softcap=30.0,
        post_norm=True,
        ffn_type="geglu",
        tie_embeddings=True,
        remat=False,
        source="arXiv:2408.00118 (reduced)",
    )
