"""Pixtral-12B — VLM: Pixtral-ViT frontend + Mistral-Nemo-style decoder.
[hf:mistralai/Pixtral-12B-2409]

Per the brief, the vision encoder + projector is a STUB: ``input_specs``
supplies pre-projected patch embeddings (B, num_patches, d_model) that occupy
the first ``num_patches`` sequence positions; this module implements the
language decoder that consumes them. Nemo-style: head_dim 128 (attn width
4096 != d_model 5120), large rope theta."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        arch_type="vlm",
        num_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,          # GQA kv=8
        head_dim=128,
        d_ff=14336,
        vocab=131_072,
        pattern=("attn",),
        ffn_type="swiglu",
        rope_theta=1_000_000.0,
        frontend="vision_stub",
        num_patches=256,       # one 1024x1024 image at 16x16 patches, pooled
        param_dtype="bfloat16",
        source="hf:mistralai/Pixtral-12B-2409",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke",
        arch_type="vlm",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("attn",),
        ffn_type="swiglu",
        frontend="vision_stub",
        num_patches=8,
        remat=False,
        source="hf:mistralai/Pixtral-12B-2409 (reduced)",
    )
