"""Lookup of assigned architectures by CLI id (``--arch <id>``)."""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "pixtral-12b",
    "smollm-135m",
    "zamba2-7b",
    "rwkv6-7b",
    "phi4-mini-3.8b",
    "gemma2-2b",
    "granite-20b",
    "granite-moe-3b-a800m",
    "whisper-large-v3",
    "mixtral-8x22b",
)

_MODULES = {
    "pixtral-12b": "pixtral_12b",
    "smollm-135m": "smollm_135m",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-7b": "rwkv6_7b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "gemma2-2b": "gemma2_2b",
    "granite-20b": "granite_20b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "whisper-large-v3": "whisper_large_v3",
    "mixtral-8x22b": "mixtral_8x22b",
}


def _module(arch_id: str):
    try:
        name = _MODULES[arch_id]
    except KeyError as e:
        raise ValueError(
            f"unknown arch {arch_id!r}; options: {', '.join(ARCH_IDS)}"
        ) from e
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str):
    return _module(arch_id).config()


def get_smoke_config(arch_id: str):
    return _module(arch_id).smoke_config()
