"""Whisper-large-v3 — encoder-decoder with conv/mel frontend (stubbed).
[arXiv:2212.04356]

Per the brief, the mel-spectrogram + conv feature extractor is a STUB:
``input_specs`` supplies frame embeddings (B, 1500, d_model) consumed by the
transformer encoder; this module implements encoder + decoder. Whisper uses
LayerNorm + absolute positions + plain-GELU FFN (norm_type/pos_type/ffn_type).

Shape notes (DESIGN.md §7): decode_32k exercises a mechanical 32k-token
decoder self-attention cache (whisper's real decode ceiling is 448 tokens);
long_500k is skipped — full attention, not sub-quadratic."""

from repro.models.config import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        arch_type="audio",
        num_layers=32,         # decoder layers
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,         # MHA (kv=20)
        head_dim=64,
        d_ff=5120,
        vocab=51_866,
        pattern=("dec_attn",),
        encoder=EncoderConfig(num_layers=32, num_frames=1500, d_model=1280),
        norm_type="layer",
        pos_type="abs",
        ffn_type="gelu",
        frontend="audio_stub",
        param_dtype="float32",
        source="arXiv:2212.04356",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        arch_type="audio",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("dec_attn",),
        encoder=EncoderConfig(num_layers=2, num_frames=64, d_model=256),
        norm_type="layer",
        pos_type="abs",
        ffn_type="gelu",
        frontend="audio_stub",
        remat=False,
        source="arXiv:2212.04356 (reduced)",
    )
