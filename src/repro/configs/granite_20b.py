"""Granite-20B (code) — dense decoder with MQA (kv=1). [arXiv:2405.04324]

Per the assignment note ("llama-arch, code") this uses RoPE + SwiGLU with the
assigned dims; kv=1 means K/V projections are replicated across the model
axis rather than head-sharded (launch/shardings.py)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        arch_type="dense",
        num_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,          # MQA
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        pattern=("attn",),
        ffn_type="swiglu",
        rope_theta=10_000.0,
        param_dtype="bfloat16",
        source="arXiv:2405.04324",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=1,          # keep the MQA trait
        head_dim=64,
        d_ff=512,
        vocab=512,
        pattern=("attn",),
        ffn_type="swiglu",
        remat=False,
        source="arXiv:2405.04324 (reduced)",
    )
