"""JAX version-compatibility shims (DESIGN.md §0).

The codebase targets the current JAX APIs (``jax.shard_map``,
``jax.sharding.get_abstract_mesh``, ``jax.set_mesh``); the pinned container
image ships an older release where those names live elsewhere or do not
exist.  Every module that needs one of these imports it from here so the
fallback logic exists exactly once:

  * ``shard_map``         — ``jax.shard_map`` or ``jax.experimental.shard_map``
                            (mapping the ``check_vma`` kwarg to ``check_rep``);
  * ``get_abstract_mesh`` — public API when present, else the ambient physical
                            mesh from the thread-resource environment (which is
                            what the ``use_mesh`` fallback below populates);
  * ``use_mesh``          — ``jax.set_mesh`` context when present, else the
                            legacy ``with mesh:`` resource-env context manager.
"""

from __future__ import annotations

import contextlib

import jax

try:  # current API (jax >= 0.6)
    from jax import shard_map as _shard_map_new

    def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map_new(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )

except ImportError:  # legacy experimental API (jax 0.4.x)
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map_old(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )


try:  # current API
    from jax.sharding import get_abstract_mesh  # noqa: F401
except ImportError:  # legacy: the ambient mesh of the resource environment.
    from jax._src import mesh as _mesh_lib

    def get_abstract_mesh():
        """Ambient mesh (``Mesh``/``AbstractMesh`` both expose .empty/.shape)."""
        return _mesh_lib.thread_resources.env.physical_mesh


if hasattr(jax, "set_mesh"):
    use_mesh = jax.set_mesh
else:

    @contextlib.contextmanager
    def use_mesh(mesh):
        """Legacy resource-env context: ``with mesh:`` sets the ambient mesh
        that both ``with_sharding_constraint(x, PartitionSpec(...))`` and the
        ``get_abstract_mesh`` fallback above read."""
        with mesh:
            yield mesh
