"""Message ledger: exact per-round communication volume of the VFL protocol.

The paper motivates FedGBF by SecureBoost's "high interactive communication
costs" but never quantifies them; this module does, from first principles, so
the communication claim becomes measurable (benchmarks/communication.py) and
so the dry-run's collective-roofline term for the tabular workload has a
ground truth to compare against.

Message inventory per *tree* (Alg. 2), with n = samples, d_p = party p's
features, B = bins, L = levels (= max_depth), P = passive parties:

  1. grad broadcast     active -> each passive: n ciphertext pairs (g, h)
                        [once per boosting round, shared by the round's trees
                        when sample masks are communicated as id lists]
  2. histograms         each passive -> active, per level:
                        nodes(l) * d_p * B * 2 ciphertexts  ("histogram" mode)
                        or nodes(l) * (1 gain + 1 feat + 1 thr) plaintexts
                        ("argmax" mode — the beyond-paper variant)
  3. split notify       active -> owner party: nodes(l) small tuples
  4. id partition       owner -> active: n-bit bitmap per level

Ciphertext size: Paillier with ``key_bits`` modulus has 2*key_bits-bit
ciphertexts (mod N^2); FATE's default key is 1024 bits -> 256 B each.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import dynamic
from repro.core.types import FedGBFConfig


@dataclass(frozen=True)
class ProtocolCosts:
    """Per-phase byte counts for one full training run."""

    grad_broadcast: int
    histograms: int
    split_notify: int
    id_partition: int

    @property
    def total(self) -> int:
        return (
            self.grad_broadcast + self.histograms
            + self.split_notify + self.id_partition
        )

    def breakdown(self) -> dict:
        return {
            "grad_broadcast": self.grad_broadcast,
            "histograms": self.histograms,
            "split_notify": self.split_notify,
            "id_partition": self.id_partition,
            "total": self.total,
        }


@dataclass(frozen=True)
class ProtocolSpec:
    n_samples: int
    party_dims: tuple          # features per passive+active party (active first)
    num_bins: int = 32
    max_depth: int = 3
    key_bits: int = 1024       # Paillier modulus
    aggregation: str = "histogram"   # or "argmax"

    @property
    def ciphertext_bytes(self) -> int:
        return 2 * self.key_bits // 8

    @property
    def passive_parties(self) -> int:
        return len(self.party_dims) - 1


def tree_cost(spec: ProtocolSpec, rho_id: float, rho_feat: float) -> ProtocolCosts:
    """Bytes exchanged to build ONE tree (grad broadcast excluded; it is
    per-round, see run_cost)."""
    n = int(round(spec.n_samples * rho_id))
    ct = spec.ciphertext_bytes
    hist_bytes = 0
    notify_bytes = 0
    partition_bytes = 0
    for level in range(spec.max_depth):
        nodes = 2**level
        for d_p in spec.party_dims[1:]:  # passive parties only send histograms
            d_eff = max(1, int(round(d_p * rho_feat)))
            if spec.aggregation == "histogram":
                hist_bytes += nodes * d_eff * spec.num_bins * 2 * ct
            else:  # argmax: gain (f32) + feature (i32) + threshold (i32)
                hist_bytes += nodes * 12
        notify_bytes += nodes * 12
        partition_bytes += (n + 7) // 8  # one n-bit bitmap per level
    return ProtocolCosts(
        grad_broadcast=0,
        histograms=hist_bytes,
        split_notify=notify_bytes,
        id_partition=partition_bytes,
    )


def run_cost(spec: ProtocolSpec, cfg: FedGBFConfig) -> ProtocolCosts:
    """Total bytes for a full (Dynamic) FedGBF training run under ``cfg``."""
    ct = spec.ciphertext_bytes
    grad = hist = notify = part = 0
    for m in range(1, cfg.rounds + 1):
        n_trees = dynamic.n_trees_schedule(cfg, m)
        rho_id = dynamic.rho_id_schedule(cfg, m)
        n_eff = int(round(spec.n_samples * rho_id))
        # one encrypted (g, h) broadcast per round, to each passive party,
        # restricted to the union of sampled ids (bounded by n_eff * trees)
        grad += spec.passive_parties * min(
            spec.n_samples, n_eff * n_trees
        ) * 2 * ct
        for _ in range(n_trees):
            c = tree_cost(spec, rho_id, cfg.rho_feat)
            hist += c.histograms
            notify += c.split_notify
            part += c.id_partition
    return ProtocolCosts(grad, hist, notify, part)


@dataclass
class Ledger:
    """Mutable run-time ledger for drivers that want live accounting."""

    entries: list = field(default_factory=list)

    def record(self, phase: str, nbytes: int, round_idx: int) -> None:
        self.entries.append({"phase": phase, "bytes": int(nbytes), "round": round_idx})

    def total(self) -> int:
        return sum(e["bytes"] for e in self.entries)

    def by_phase(self) -> dict:
        out: dict = {}
        for e in self.entries:
            out[e["phase"]] = out.get(e["phase"], 0) + e["bytes"]
        return out
