"""Message ledger: exact per-round communication volume of the VFL protocol.

The paper motivates FedGBF by SecureBoost's "high interactive communication
costs" but never quantifies them; this module does, from first principles, so
the communication claim becomes measurable (benchmarks/comm_bench.py ->
BENCH_comm.json) and so the dry-run's collective-roofline term for the
tabular workload has a ground truth to compare against.

Two cost models live here (DESIGN.md §5):

* the **Paillier protocol model** (``tree_cost`` / ``run_cost``) — the
  paper-world prediction: histogram entries priced as ciphertexts, id
  partitions as bitmaps, sampling rates shrinking the messages;
* the **wire model** (``wire_party_tree_cost`` / ``wire_run_cost``) — the
  predicted *actual* payload of the SPMD implementation (plaintext float32/
  int payloads, full shard width, the feature mask as its own message),
  per transport format (raw / quantized / top-k).

``ProtocolLedger`` reconciles the wire model against *measured* bytes — the
payload sizes every collective in federation/{aggregator,compress,vfl}.py
reports (``compress.MessageMeter`` / ``probe_tree_cost``).  For the lossless
transports measured must equal predicted exactly; a mismatch means the
implementation and the cost model drifted apart.

Message inventory per *tree* (Alg. 2), with n = samples, d_p = party p's
features, B = bins, L = levels (= max_depth), P = passive parties:

  1. grad broadcast     active -> each passive: n ciphertext pairs (g, h)
                        [once per boosting round, shared by the round's trees
                        when sample masks are communicated as id lists]
  2. histograms         each passive -> active, per level:
                        nodes(l) * d_p * B * 2 ciphertexts  ("histogram" mode)
                        or nodes(l) * (1 gain + 1 feat + 1 thr) plaintexts
                        ("argmax" mode — the beyond-paper variant)
  3. split notify       active -> owner party: nodes(l) small tuples
  4. id partition       owner -> active: n-bit bitmap per level

Ciphertext size: Paillier with ``key_bits`` modulus has 2*key_bits-bit
ciphertexts (mod N^2); FATE's default key is 1024 bits -> 256 B each.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import dynamic
from repro.core.types import FedGBFConfig


@dataclass(frozen=True)
class ProtocolCosts:
    """Per-phase byte counts for one full training run."""

    grad_broadcast: int
    histograms: int
    split_notify: int
    id_partition: int

    @property
    def total(self) -> int:
        return (
            self.grad_broadcast + self.histograms
            + self.split_notify + self.id_partition
        )

    def breakdown(self) -> dict:
        return {
            "grad_broadcast": self.grad_broadcast,
            "histograms": self.histograms,
            "split_notify": self.split_notify,
            "id_partition": self.id_partition,
            "total": self.total,
        }


@dataclass(frozen=True)
class ProtocolSpec:
    n_samples: int
    party_dims: tuple          # features per passive+active party (active first)
    num_bins: int = 32
    max_depth: int = 3
    key_bits: int = 1024       # Paillier modulus
    aggregation: str = "histogram"   # or "argmax"
    # Sibling-subtraction pipeline (DESIGN.md §6): levels >= 1 exchange only
    # the left-child histograms (half the frontier); the right siblings are
    # derived locally by the receiver.  Must mirror the implementation's
    # ``TreeConfig.hist_subtraction``.
    hist_subtraction: bool = False
    # Frontier compaction (round engine, DESIGN.md §9): per-level exchanged
    # node count is the static live-slot budget min(2^level,
    # max_active_nodes), not the 2^level frontier.  0 = uncompacted.  Must
    # mirror ``TreeConfig.max_active_nodes``.
    max_active_nodes: int = 0
    # Row sharding (DESIGN.md §8): number of sample shards the rows are
    # distributed over (the mesh's data×pod extent under ``shard_samples``).
    # Only the id_partition bitmap depends on it: each shard ships its own
    # ``ceil(ceil(n/shards)/8)``-byte bitmap per level (rows pad to the
    # shard granularity with weight-0 entries), so the per-shard byte
    # rounding is visible in the wire total.  1 = single host.
    data_shards: int = 1
    # Gradient channels K of the objective (DESIGN.md §11): scalar
    # objectives (logistic, squared, quantile) have K = 1; softmax{K} ships
    # K per-class (g, h) pairs.  Scales the grad broadcast (2K values/row),
    # the histogram payloads (2K wire channels + the local count) and the
    # Paillier ciphertext counts (2K ciphertexts per bin).  Must mirror
    # ``objective.get_objective(cfg.loss).n_classes``.
    n_channels: int = 1

    @property
    def ciphertext_bytes(self) -> int:
        return 2 * self.key_bits // 8

    @property
    def passive_parties(self) -> int:
        return len(self.party_dims) - 1

    def active_nodes(self, level: int) -> int:
        """Static exchanged-slot width of a level (compaction-aware)."""
        return _active_nodes(level, self.max_active_nodes)


def _active_nodes(level: int, max_active_nodes: int) -> int:
    width = 2 ** level
    return min(width, max_active_nodes) if max_active_nodes else width


def _nodes_sent(level: int, hist_subtraction: bool,
                max_active_nodes: int) -> int:
    """Histogram-mode node-histograms one party ships at ``level``: the
    active slot width — under subtraction, levels >= 1 ship only the left
    children, i.e. the PARENT level's active width (the §6 halving and the
    §9 compaction compose in this one expression)."""
    if level == 0 or not hist_subtraction:
        return _active_nodes(level, max_active_nodes)
    return _active_nodes(level - 1, max_active_nodes)


def tree_cost(spec: ProtocolSpec, rho_id: float, rho_feat: float) -> ProtocolCosts:
    """Bytes exchanged to build ONE tree (grad broadcast excluded; it is
    per-round, see run_cost)."""
    n = int(round(spec.n_samples * rho_id))
    ct = spec.ciphertext_bytes
    hist_bytes = 0
    notify_bytes = 0
    partition_bytes = 0
    for level in range(spec.max_depth):
        # subtraction halves and compaction caps the exchanged node count —
        # the same ``_nodes_sent`` expression in both cost models.
        nodes = spec.active_nodes(level)
        nodes_sent = _nodes_sent(
            level, spec.hist_subtraction, spec.max_active_nodes
        )
        for d_p in spec.party_dims[1:]:  # passive parties only send histograms
            d_eff = max(1, int(round(d_p * rho_feat)))
            if spec.aggregation == "histogram":
                # 2K ciphertexts per bin: one (g, h) pair per channel.
                hist_bytes += (nodes_sent * d_eff * spec.num_bins
                               * 2 * spec.n_channels * ct)
            else:  # argmax: gain (f32) + feature (i32) + threshold (i32)
                hist_bytes += nodes * 12
        notify_bytes += nodes * 12
        partition_bytes += (n + 7) // 8  # one n-bit bitmap per level
    return ProtocolCosts(
        grad_broadcast=0,
        histograms=hist_bytes,
        split_notify=notify_bytes,
        id_partition=partition_bytes,
    )


def run_cost(spec: ProtocolSpec, cfg: FedGBFConfig) -> ProtocolCosts:
    """Total bytes for a full (Dynamic) FedGBF training run under ``cfg``."""
    ct = spec.ciphertext_bytes
    grad = hist = notify = part = 0
    for m in range(1, cfg.rounds + 1):
        n_trees = dynamic.n_trees_schedule(cfg, m)
        rho_id = dynamic.rho_id_schedule(cfg, m)
        n_eff = int(round(spec.n_samples * rho_id))
        # one encrypted (g, h) broadcast per round, to each passive party,
        # restricted to the union of sampled ids (bounded by n_eff * trees);
        # 2K ciphertexts per sampled row under a K-channel objective.
        grad += spec.passive_parties * min(
            spec.n_samples, n_eff * n_trees
        ) * 2 * spec.n_channels * ct
        for _ in range(n_trees):
            c = tree_cost(spec, rho_id, cfg.rho_feat)
            hist += c.histograms
            notify += c.split_notify
            part += c.id_partition
    return ProtocolCosts(grad, hist, notify, part)


@dataclass
class Ledger:
    """Mutable run-time ledger for drivers that want live accounting."""

    entries: list = field(default_factory=list)

    def record(self, phase: str, nbytes: int, round_idx: int) -> None:
        self.entries.append({"phase": phase, "bytes": int(nbytes), "round": round_idx})

    def total(self) -> int:
        return sum(e["bytes"] for e in self.entries)

    def by_phase(self) -> dict:
        out: dict = {}
        for e in self.entries:
            out[e["phase"]] = out.get(e["phase"], 0) + e["bytes"]
        return out


# ---------------------------------------------------------------------------
# Wire model: predicted ACTUAL payloads of the SPMD implementation
# ---------------------------------------------------------------------------

#: phases whose recorded payload is per *sending party* — the measured run
#: cost multiplies them by the passive-party count (the active party's own
#: contribution never traverses the wire).  ``id_partition`` is counted once
#: per level: protocol-wise it is the owning party's single message (the
#: other parties' psum contributions are structurally zero).
PER_PASSIVE_PHASES = ("grad_broadcast", "histograms", "feature_mask",
                      "split_candidates", "retries")

#: ``retries`` is the chaos transport's integrity + retransmission channel
#: (DESIGN.md §13): 4 checksum bytes per transmission plus the full payload
#: for every transmission after the first.  Zero when no chaos wrapper is
#: active; at zero fault rate it is exactly 4 bytes per exchange slot.
WIRE_PHASES = ("grad_broadcast", "histograms", "feature_mask",
               "split_candidates", "id_partition", "retries")


def wire_party_tree_cost(
    n_samples: int,
    d_party: int,
    num_bins: int,
    max_depth: int,
    aggregation: str = "histogram",
    transport=None,
    hist_subtraction: bool = False,
    max_active_nodes: int = 0,
    data_shards: int = 1,
    n_channels: int = 1,
    chaos=None,
) -> dict:
    """Predicted actual bytes ONE party ships to build ONE tree, mirroring
    the shard_map implementation payload-for-payload (the quantity
    ``compress.probe_tree_cost`` measures from the traced program):

      histogram mode   per level: the full local float32 (g, h, count)
                       histogram ``nodes * d_party * B * (2K+1) * 4`` — or,
                       when quantized, ``nodes * d_party * (B * 2K * bits/8
                       + 2K * 4)`` (int payload for the 2K g/h wire
                       channels + one float32 scale per (node, feature,
                       channel); the count channel stays local) — plus
                       the bool feature-mask slice (``d_party`` bytes; the
                       mask rides the wire, it does not shrink the
                       histogram, unlike the Paillier model's ``rho_feat``).
                       K = ``n_channels`` is 1 for scalar objectives;
      argmax mode      per level: ``nodes * k * 12`` candidate bytes
                       (gain f32 + feature i32 + threshold i32), k = 1 raw
                       or ``transport.k`` for top-k;
      id_partition     per level: the BIT-PACKED routing bitmap — 1 bit per
                       sample, ``ceil(n_shard/8)`` uint8 bytes per data
                       shard with ``n_shard = ceil(n/data_shards)`` (rows
                       pad to the shard granularity with weight-0 entries;
                       each shard ships its own byte-rounded slice).  The
                       SPMD psum operand covers every sample, masked or not
                       (counted once, not per party).

    ``transport`` is a ``compress.TransportSpec`` or None (raw).
    ``hist_subtraction`` halves the histogram-mode payload node count at
    levels >= 1 (only the left children ship; DESIGN.md §6) — at depth 3 the
    per-tree histogram phase drops from 7 to 4 node-histograms, a 1.75× cut.
    ``max_active_nodes`` caps every level's exchanged node count at the
    round engine's static live-slot budget (frontier compaction, DESIGN.md
    §9) — the T-axis round collective ships exactly ``active(level)`` slots
    per tree regardless of the 2^level frontier.
    """
    kind = "raw" if transport is None else transport.kind
    phases = dict.fromkeys(WIRE_PHASES, 0)
    hist_levels = wire_hist_level_bytes(
        d_party, num_bins, max_depth, transport, hist_subtraction,
        max_active_nodes, n_channels,
    )
    n_shard = -(-n_samples // data_shards)  # rows pad to shard granularity
    id_bytes = data_shards * ((n_shard + 7) // 8)
    for level in range(max_depth):
        nodes = _active_nodes(level, max_active_nodes)
        if aggregation == "histogram":
            phases["histograms"] += hist_levels[level]
            phases["feature_mask"] += d_party
        else:  # argmax
            k = transport.k if kind == "topk" else 1
            k = min(k, d_party * num_bins)
            phases["split_candidates"] += nodes * k * (4 + 4 + 4)
        phases["id_partition"] += id_bytes
    if chaos is not None:
        phases["retries"] = wire_retry_bytes(
            chaos, d_party, num_bins, max_depth, aggregation, transport,
            hist_subtraction, max_active_nodes, n_channels,
        )
    return phases


def _chaos_slot_bytes(
    d_party: int,
    num_bins: int,
    max_depth: int,
    aggregation: str = "histogram",
    transport=None,
    hist_subtraction: bool = False,
    max_active_nodes: int = 0,
    n_channels: int = 1,
) -> list:
    """Per-SLOT payload bytes of the chaos-wrapped exchange, in the exact
    order the traced program enumerates its gathers: one histogram gather
    per level (the quantized int payload only — the scale gather is outside
    the chaos seam), or three candidate-stack gathers (gain, feature,
    threshold) per level under argmax/top-k."""
    kind = "raw" if transport is None else transport.kind
    gh = 2 * n_channels
    slots = []
    if aggregation == "histogram":
        per_node = (num_bins * gh * transport.bits // 8
                    if kind == "quantized" else num_bins * (gh + 1) * 4)
        for level in range(max_depth):
            nodes = _nodes_sent(level, hist_subtraction, max_active_nodes)
            slots.append(nodes * d_party * per_node)
    else:  # argmax: three stacked (nodes, k) gathers of 4-byte lanes
        k = transport.k if kind == "topk" else 1
        k = min(k, d_party * num_bins)
        for level in range(max_depth):
            nodes = _active_nodes(level, max_active_nodes)
            slots.extend([nodes * k * 4] * 3)
    return slots


def wire_retry_bytes(
    chaos,
    d_party: int,
    num_bins: int,
    max_depth: int,
    aggregation: str = "histogram",
    transport=None,
    hist_subtraction: bool = False,
    max_active_nodes: int = 0,
    n_channels: int = 1,
) -> int:
    """Predicted per-tree ``retries`` bytes under a ``chaos.ChaosSpec``:
    replay the pure fault plan slot-by-slot and charge 4 checksum bytes per
    transmission plus the slot payload for every retransmission.  This is
    the predicted twin of what ``ChaoticGather`` meters, so the ledger's
    reconciliation stays exact under injected faults (DESIGN.md §13)."""
    from repro.federation.chaos import CHECKSUM_BYTES, plan_for_slot

    slots = _chaos_slot_bytes(d_party, num_bins, max_depth, aggregation,
                              transport, hist_subtraction, max_active_nodes,
                              n_channels)
    total = 0
    for s, payload in enumerate(slots):
        fails, final = plan_for_slot(chaos, s)
        tx = len(fails) + 1 + (1 if final == "dup" else 0)
        total += tx * CHECKSUM_BYTES + (tx - 1) * payload
    return total


def wire_hist_level_bytes(
    d_party: int,
    num_bins: int,
    max_depth: int,
    transport=None,
    hist_subtraction: bool = False,
    max_active_nodes: int = 0,
    n_channels: int = 1,
) -> list:
    """Per-LEVEL histogram-phase bytes one party ships for one tree
    (histogram aggregation) — the level profile benchmarks record so the
    subtraction pipeline's shape (full root, half everywhere below) and the
    compaction cap (active width, not 2^level) are visible, not just the
    per-tree total.  ``n_channels`` (K) widens the stats lanes only: raw
    payloads carry 2K+1 float32 channels, quantized ones 2K int channels +
    2K float32 scales (count stays local)."""
    kind = "raw" if transport is None else transport.kind
    gh = 2 * n_channels
    per_node = (
        num_bins * gh * transport.bits // 8 + gh * 4 if kind == "quantized"
        else num_bins * (gh + 1) * 4
    )
    return [
        _nodes_sent(level, hist_subtraction, max_active_nodes)
        * d_party * per_node
        for level in range(max_depth)
    ]


def wire_run_cost(spec: ProtocolSpec, cfg: FedGBFConfig, transport=None,
                  chaos=None) -> dict:
    """Predicted actual bytes for a full training run under ``cfg``.

    Per-passive-party phases scale by the passive count; ``party_dims`` must
    be the *even shard* dims the implementation runs with (``d_global /
    parties`` after ``data.tabular.pad_features``).  The (g, h) broadcast is
    ``n * 2 * 4`` bytes per passive party per round — the arrays enter the
    program replicated and full-length regardless of the sampling schedule
    (the Paillier model's id-list shrinkage has no wire counterpart here).
    """
    d_party = spec.party_dims[-1]
    per_tree = wire_party_tree_cost(
        spec.n_samples, d_party, spec.num_bins, spec.max_depth,
        spec.aggregation, transport, spec.hist_subtraction,
        spec.max_active_nodes, spec.data_shards, spec.n_channels,
        chaos=chaos,
    )
    grad_per_round = spec.n_samples * 2 * spec.n_channels * 4
    return _assemble_run_cost(per_tree, grad_per_round,
                              spec.passive_parties, cfg)


def measured_run_cost(
    per_tree: dict, grad_per_round: int, passive_parties: int,
    cfg: FedGBFConfig,
) -> dict:
    """Scale ``compress.probe_tree_cost`` measurements up to a full run with
    the exact schedule arithmetic of ``wire_run_cost`` — the two dicts must
    match key-for-key for lossless AND quantized transports (payload sizes
    are shape-determined either way).

    Scope of the reconciliation: the *per-tree payloads* are the genuinely
    independent cross-check (traced operands vs hand-derived formulas); the
    schedule/passive-party scaling is deliberately shared between both
    sides (``_assemble_run_cost``), so drift in that arithmetic moves
    measured and predicted together and is covered by the protocol-model
    tests instead, not by ``ProtocolLedger.matches()``."""
    return _assemble_run_cost(per_tree, grad_per_round, passive_parties, cfg)


def per_round_cost(per_tree, grad_per_round, passive_parties, cfg) -> list:
    """Per-ROUND wire bytes under the schedule: one {phase: bytes} dict per
    round, m = 1..cfg.rounds.

    This is the single schedule/passive-party scaling ``_assemble_run_cost``
    sums — exported so the trace/log join (DESIGN.md §12) emits EXACTLY the
    ledger's numbers per round: summing these rows reproduces
    ``measured_run_cost``/``wire_run_cost`` phase-for-phase by construction,
    which is what makes the Perfetto wire spans reconcile exactly with
    ``ProtocolLedger.breakdown()``.
    """
    rows = []
    for m in range(1, cfg.rounds + 1):
        n_trees = dynamic.n_trees_schedule(cfg, m)
        row = dict.fromkeys(WIRE_PHASES, 0)
        row["grad_broadcast"] += passive_parties * grad_per_round
        for phase, nbytes in per_tree.items():
            mult = passive_parties if phase in PER_PASSIVE_PHASES else 1
            row[phase] = row.get(phase, 0) + mult * n_trees * nbytes
        rows.append(row)
    return rows


def _assemble_run_cost(per_tree, grad_per_round, passive_parties, cfg) -> dict:
    out = dict.fromkeys(WIRE_PHASES, 0)
    for row in per_round_cost(per_tree, grad_per_round, passive_parties, cfg):
        for phase, nbytes in row.items():
            out[phase] = out.get(phase, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class ProtocolLedger:
    """Measured-vs-predicted accounting for one training run (DESIGN.md §5).

    ``spec``/``cfg``/``transport`` fix the predicted wire model;
    ``record_measured`` accumulates the measured side (from
    ``compress.probe_tree_cost`` scaled by the schedule, or any driver
    recording live).  ``reconcile`` diffs the two per phase — exact equality
    is the contract for every transport (payload sizes are shape-determined
    even when the *values* are lossy), asserted by ``federation/selftest.py``
    and reported in BENCH_comm.json.
    """

    spec: ProtocolSpec
    cfg: FedGBFConfig
    transport: object = None     # compress.TransportSpec or None (raw)
    chaos: object = None         # chaos.ChaosSpec or None (no fault wrapper)
    measured: dict = field(default_factory=dict)
    #: the last ``record_run`` probe, kept so per-round views
    #: (``per_round_measured``) are derivable from the ledger alone
    probe: dict = field(default_factory=dict)

    def record_measured(self, phase: str, nbytes: int) -> None:
        self.measured[phase] = self.measured.get(phase, 0) + int(nbytes)

    def record_run(self, per_tree: dict, grad_per_round: int) -> None:
        """Accumulate a whole run's measured bytes from a per-tree probe."""
        self.probe = {"per_tree": dict(per_tree),
                      "grad_per_round": int(grad_per_round)}
        run = measured_run_cost(
            per_tree, grad_per_round, self.spec.passive_parties, self.cfg
        )
        for phase, nbytes in run.items():
            if phase != "total":
                self.record_measured(phase, nbytes)

    def per_round_measured(self) -> list:
        """Measured bytes per round (``per_round_cost`` over the stored
        probe) — the rows the trace exporter and ``--log-json`` consume;
        their per-phase sums equal ``self.measured`` exactly.  Empty when
        no ``record_run`` probe was taken."""
        if not self.probe:
            return []
        return per_round_cost(
            self.probe["per_tree"], self.probe["grad_per_round"],
            self.spec.passive_parties, self.cfg,
        )

    def predicted(self) -> dict:
        """Wire-model prediction (actual plaintext payloads)."""
        return wire_run_cost(self.spec, self.cfg, self.transport,
                             chaos=self.chaos)

    def predicted_paillier(self) -> ProtocolCosts:
        """Paper-world protocol prediction (Paillier ciphertext rates)."""
        return run_cost(self.spec, self.cfg)

    def measured_total(self) -> int:
        return sum(self.measured.values())

    def reconcile(self) -> dict:
        """Per-phase {predicted, measured, delta, match}; 'match' is exact."""
        pred = self.predicted()
        phases = [p for p in pred if p != "total"]
        out = {}
        for phase in phases:
            p, m = pred[phase], self.measured.get(phase, 0)
            out[phase] = {"predicted": p, "measured": m,
                          "delta": m - p, "match": m == p}
        out["total"] = {
            "predicted": pred["total"], "measured": self.measured_total(),
            "delta": self.measured_total() - pred["total"],
            "match": self.measured_total() == pred["total"],
        }
        return out

    def matches(self) -> bool:
        return all(v["match"] for v in self.reconcile().values())

    def breakdown(self) -> dict:
        """Per-phase measured/predicted totals plus per-*mode* wire totals
        (histogram vs argmax under this spec/cfg, raw transport, each with
        and without sibling subtraction), so benchmarks diff the modes
        without re-deriving the schedule math.  ``hist_phase_by_mode``
        carries the histogram-phase bytes alone — the quantity the
        subtraction pipeline halves (7 → 4 node-histograms per depth-3 tree,
        a 1.75× phase cut, visible as histogram vs histogram+sub)."""
        from dataclasses import replace

        modes, hist_phase = {}, {}
        for name, agg, sub in (
            ("histogram", "histogram", False),
            ("histogram+sub", "histogram", True),
            ("argmax", "argmax", False),
        ):
            run = wire_run_cost(
                replace(self.spec, aggregation=agg, hist_subtraction=sub),
                self.cfg,
            )
            modes[name] = run["total"]
            hist_phase[name] = run["histograms"]
        return {
            "measured": dict(self.measured),
            "measured_total": self.measured_total(),
            "predicted": self.predicted(),
            "predicted_paillier": self.predicted_paillier().breakdown(),
            "modes": modes,
            "hist_phase_by_mode": hist_phase,
        }
