"""Secure-aggregation simulation (information-flow model, not cryptography).

TPU inapplicability note (DESIGN.md §2): Paillier is modular big-integer
arithmetic with no TPU analogue — forcing it through the MXU would be a
degenerate port. What the *system* needs from the crypto layer is its
algebra: passive parties can SUM encrypted values they cannot READ. We model
that with pairwise additive masking over float32 (the SecAgg construction of
Bonawitz et al., adapted to VFL): party p adds PRF(seed_pq)-derived masks
that cancel in the aggregate. The active party sees only the sum, passive
parties see only masked values — the same visibility set as Paillier, minus
semantic security of individual messages (which we do not claim).

Used by examples/vfl_credit_scoring.py to demonstrate the protocol flow; the
shard_map hot path exchanges plaintext aggregates (the quantities that are
decrypted in the real protocol anyway) and charges the Paillier byte cost via
protocol.ProtocolSpec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_masks(
    seed: int, num_parties: int, shape: tuple, dtype=jnp.float32
) -> jnp.ndarray:
    """masks[p] for each party, with sum_p masks[p] == 0 exactly.

    mask_p = sum_{q>p} PRF(p,q) - sum_{q<p} PRF(q,p): every PRF term appears
    once with each sign, so the sum telescopes to zero (exact in float because
    the identical bit patterns cancel pairwise).
    """
    masks = [jnp.zeros(shape, dtype) for _ in range(num_parties)]
    for p in range(num_parties):
        for q in range(p + 1, num_parties):
            prf = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(seed), p * num_parties + q),
                shape, dtype,
            )
            masks[p] = masks[p] + prf
            masks[q] = masks[q] - prf
    return jnp.stack(masks)


def mask(values: jnp.ndarray, masks: jnp.ndarray) -> jnp.ndarray:
    """Each party's masked contribution: values[p] + masks[p]."""
    return values + masks


def aggregate(masked: jnp.ndarray) -> jnp.ndarray:
    """Active-party aggregation: sum over parties; masks cancel exactly."""
    return jnp.sum(masked, axis=0)
