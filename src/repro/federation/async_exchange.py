"""Double-buffered async level exchange (DESIGN.md §10).

The round engine's per-level party exchange (DESIGN.md §9) is ONE logical
collective: the whole round's ``(T, active, d_party, B, ...)`` histogram
payload all-gathered over the party axis.  Synchronously that collective is
a barrier — every party waits for the full payload before the dequantize /
sibling-derive / split-search chain can start.

The async backends split the *transfer* without splitting the *message*:
the payload is cut into two buffers along the bin axis and shipped as two
independent all_gathers.  XLA lowers independent collectives to
asynchronous start/done pairs, so the second buffer's transfer is in
flight while the first buffer's downstream consumers (dequantize, the
concat feeding sibling subtraction and split search) already run —
the classic double-buffering overlap, expressed entirely inside the SPMD
program.  Because the split is along a non-gathered axis, the concatenated
result is elementwise identical to the single-gather payload: the async
backends are bit-identical to their synchronous twins.

Accounting contract: the ``MessageMeter`` records the payload ONCE, before
the split — double-buffering is a scheduling detail of the transport, not
an extra protocol message — so ``probe_round_collectives`` still counts
one logical collective per level (two records under quantization: int
payload + scales, same as the synchronous q8/q16 path) and the wire-model
reconciliation (``protocol.ProtocolLedger``) stays exact byte-for-byte.

Composition: the seam is the ``gather`` argument of the histogram
providers (``aggregator.federated_round_histogram_fn``,
``compress.quantized_round_histogram_fn``), which the sibling-subtraction
adaptation (§6) and frontier compaction (§9) wrap *outside* of — so the
double-buffered exchange automatically carries subtraction-halved and
compacted payloads, and composes with q8/q16 (the int payload is split;
the tiny scale vector ships whole).  The argmax/top-k candidate exchange
already ships three small independent gathers (gain/feature/threshold)
and needs no buffering — async is a histogram-aggregation lever.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.federation import aggregator, compress, mesh_roles


def double_buffered_gather(x, party_axis: str, axis: int, split_axis: int = -2):
    """All-gather ``x`` over ``party_axis`` as TWO independent transfers.

    ``x`` is split at the midpoint of ``split_axis`` (the bin axis of a
    histogram payload, by default) and each half rides its own tiled
    all_gather; the halves concatenate back on the same axis.  Since
    ``split_axis != axis`` the result is elementwise identical to the
    single-gather exchange — the split only exposes transfer/compute
    overlap to the scheduler.  Degenerate payloads (extent < 2 on the
    split axis) fall back to the plain gather.
    """
    extent = x.shape[split_axis]
    if extent < 2:
        return aggregator.plain_gather(x, party_axis, axis)
    lo, hi = jnp.split(x, [extent // 2], axis=split_axis)
    g_lo = jax.lax.all_gather(lo, party_axis, axis=axis, tiled=True)
    g_hi = jax.lax.all_gather(hi, party_axis, axis=axis, tiled=True)
    return jnp.concatenate([g_lo, g_hi], axis=split_axis)


def async_round_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    transport: Optional[compress.TransportSpec] = None,
    meter=None,
):
    """Histogram-mode round provider with the double-buffered exchange.

    Raw transport: ``federated_round_histogram_fn`` with the buffered
    gather.  Quantized (q8/q16): the int payload is double-buffered; the
    scales ship whole.  Everything else (data-axis psums, metering, the
    count-channel contract) is inherited from the synchronous providers —
    this module only swaps the gather.
    """
    if transport is None:
        transport = compress.RAW
    gather = partial(double_buffered_gather, split_axis=-2)
    if transport.kind == "quantized":
        return compress.quantized_round_histogram_fn(
            party_axis, data_axes, transport, meter=meter, gather=gather
        )
    if transport.kind == "raw":
        return aggregator.federated_round_histogram_fn(
            party_axis, data_axes, meter=meter, gather=gather
        )
    raise ValueError(
        f"transport {transport.kind!r} does not apply to the async "
        "histogram exchange (use 'raw' or 'quantized')"
    )
