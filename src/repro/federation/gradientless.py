"""Gradient-less party-local training with learnable per-tree rates.

The no-gradient-sharing privacy point of the objective layer (DESIGN.md
§11), after Ma et al.'s "Gradient-less Federated GBT with Learnable
Learning Rates" (PAPERS.md): FedGBF's protocol ships per-sample (g, h)
to every passive party and per-level histograms back — both are the
attack surface SecureBoost encrypts.  This mode removes the messages
instead of encrypting them:

* **Per-party local trees.**  Every party runs ordinary (centralized)
  FedGBF boosting on its OWN feature slice; gradients and histograms
  exist only inside the party and never traverse the wire.  The trees a
  party contributes reference only its local features (offset to global
  column ids when the ensemble is assembled, so the packed model predicts
  on the full feature matrix like any other checkpoint).

* **Learnable per-tree rates.**  The collaboration happens at the
  *margin* level: each party ships its trees' raw per-tree margin columns
  on the training set — (T_p, n[, K]) floats, data-independent of the
  feature values — and the active party fits one scalar rate per tree by
  gradient descent on the global objective loss.  The learned rates land
  in ``PackedEnsemble.tree_scale``, whose weighted combiner
  (``margin = base + tree_scale @ per_tree``) is exactly the model this
  mode trains — serving and checkpointing reuse the packed layout
  verbatim.

* **Ledger semantics.**  The wire inventory is per-party margins in and
  rates back out; the histogram, grad-broadcast and id-partition phases
  are identically ZERO — ``wire_cost`` prices them as such and the
  selftest reconciles the measured payloads (the actual margin/rate
  arrays, recorded by a ``compress.MessageMeter``) against that model
  exactly, at any channel count K.

The trade: no per-split cross-party feature interaction (a tree never
mixes two parties' columns), so accuracy trails protocol FedGBF on
feature-split-correlated data — the price of the privacy point, not a
bug.  The rate fit recovers the cross-party *additive* structure.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import binning, boosting
from repro.core import objective as objective_mod
from repro.core import tree as tree_mod
from repro.core.types import FedGBFConfig, PackedEnsemble, pack_ensemble
from repro.federation import compress


def _party_slices(d: int, num_parties: int) -> list:
    if d % num_parties:
        raise ValueError(
            f"d={d} must shard evenly over {num_parties} parties; "
            "pad columns with data.tabular.pad_features"
        )
    d_party = d // num_parties
    return [slice(p * d_party, (p + 1) * d_party) for p in range(num_parties)]


@partial(jax.jit, static_argnames=("objective_name", "steps"))
def fit_tree_scales(
    margins: jnp.ndarray,
    y: jnp.ndarray,
    init_scale: jnp.ndarray,
    objective_name: str,
    base_score: float = 0.0,
    steps: int = 300,
    lr: float = 0.05,
) -> jnp.ndarray:
    """Learn one rate per tree by Adam on the global objective loss.

    ``margins`` is the stacked per-tree raw output on the training set —
    (T, n) for scalar objectives, (T, n, K) for K-channel ones — and the
    model is the packed combiner itself:
    ``loss(w) = objective.loss_value(y, base + einsum('t,tn...->n...', w, m))``.
    Starting from the per-party packed scales (lr / n_trees) makes step 0
    the plain concatenation of the local models, so the fit can only
    improve on it (up to optimizer noise).
    """
    obj = objective_mod.get_objective(objective_name)

    def loss_fn(w):
        margin = jnp.einsum("t,tn...->n...", w, margins) + base_score
        return obj.loss_value(y, margin)

    grad_fn = jax.grad(loss_fn)

    def step(_, state):
        w, m, v, t = state
        g = grad_fn(w)
        t = t + 1.0
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        m_hat = m / (1.0 - 0.9 ** t)
        v_hat = v / (1.0 - 0.999 ** t)
        w = w - lr * m_hat / (jnp.sqrt(v_hat) + 1e-8)
        return w, m, v, t

    zeros = jnp.zeros_like(init_scale)
    w, _, _, _ = jax.lax.fori_loop(
        0, steps, step, (init_scale, zeros, zeros, 0.0)
    )
    return w


def train_gradientless(
    x: jnp.ndarray,
    y: jnp.ndarray,
    cfg: FedGBFConfig,
    rng: jax.Array,
    num_parties: int,
    scale_steps: int = 300,
    scale_lr: float = 0.05,
    meter: Optional[compress.MessageMeter] = None,
    engine: str = "scan",
) -> tuple[PackedEnsemble, dict]:
    """Train the gradient-less party-local ensemble (module docstring).

    Per party: centralized boosting on the party's feature slice (its own
    rng stream via ``fold_in`` so parties stay independent); globally: one
    learned rate per tree (``fit_tree_scales``).  ``meter`` records the
    two payloads that DO traverse the wire — each passive party's margin
    block in, the rate vector back out to each passive party — and nothing
    else: there is no histogram, gradient or routing message to record.

    Returns (packed, info): ``packed`` is a standard ``PackedEnsemble``
    (global feature ids, learned ``tree_scale``, one logical round) and
    ``info`` carries the before/after training loss and per-party tree
    counts.
    """
    n, d = x.shape
    slices = _party_slices(d, num_parties)
    obj = objective_mod.get_objective(cfg.loss)

    party_packed, party_margins, tree_counts = [], [], []
    for p, sl in enumerate(slices):
        x_p = x[:, sl]
        model_p, _ = boosting.train_fedgbf(
            x_p, y, cfg, jax.random.fold_in(rng, p), engine=engine
        )
        packed_p = pack_ensemble(model_p)
        binned_p = binning.bin_data(x_p, packed_p.bin_edges)
        margins_p = tree_mod.predict_trees(
            packed_p.trees(), binned_p, packed_p.max_depth
        )  # (T_p, n[, K])
        if meter is not None and p > 0:
            # the one inbound message of the protocol: a passive party's
            # per-tree margin block (the active party's own stays local).
            meter.record("tree_margins", margins_p)
        party_packed.append(packed_p)
        party_margins.append(margins_p)
        tree_counts.append(packed_p.total_trees)

    margins = jnp.concatenate(party_margins, axis=0)
    init_scale = jnp.concatenate([pk.tree_scale for pk in party_packed])
    base = float(cfg.base_score) + obj.init_margin
    loss_before = float(obj.loss_value(
        y, jnp.einsum("t,tn...->n...", init_scale, margins) + base
    ))
    scales = fit_tree_scales(
        margins, y, init_scale, cfg.loss, base_score=base,
        steps=scale_steps, lr=scale_lr,
    )
    if meter is not None:
        # the one outbound message: the learned rate vector, to each
        # passive party (so it can serve its own slice of the ensemble).
        for _ in range(num_parties - 1):
            meter.record("tree_scales", scales)
    loss_after = float(obj.loss_value(
        y, jnp.einsum("t,tn...->n...", scales, margins) + base
    ))

    # Assemble the global packed model: party p's features shift to global
    # column ids (leaf-through nodes stay -1); bin edges concatenate
    # feature-wise (per-column quantiles are slice-invariant).
    d_party = d // num_parties
    features = jnp.concatenate([
        jnp.where(pk.feature >= 0, pk.feature + p * d_party, pk.feature)
        for p, pk in enumerate(party_packed)
    ])
    packed = PackedEnsemble(
        feature=features,
        threshold=jnp.concatenate([pk.threshold for pk in party_packed]),
        gain=jnp.concatenate([pk.gain for pk in party_packed]),
        leaf_weight=jnp.concatenate([pk.leaf_weight for pk in party_packed]),
        tree_scale=scales,
        bin_edges=jnp.concatenate([pk.bin_edges for pk in party_packed]),
        round_offsets=(0, int(sum(tree_counts))),
        learning_rate=cfg.learning_rate,
        base_score=base,
        loss=cfg.loss,
        max_depth=cfg.tree.max_depth,
    )
    info = {
        "loss_before": loss_before,
        "loss_after": loss_after,
        "tree_counts": tree_counts,
        "n_channels": obj.n_classes,
    }
    return packed, info


def wire_cost(
    n_samples: int,
    tree_counts: list,
    n_channels: int = 1,
) -> dict:
    """Predicted wire bytes of one gradient-less training run.

    Phase inventory (module docstring): each PASSIVE party ships its
    margin block once (``T_p * n * K * 4`` bytes; the active party — by
    convention party 0 — keeps its own local) and receives the learned
    rate vector (``T_total * 4`` bytes).  Every protocol phase of the
    gradient-sharing mode is identically zero — the mode's ledger
    contract, reconciled in ``federation/selftest.py``.
    """
    total_trees = int(sum(tree_counts))
    passive = len(tree_counts) - 1
    margins = sum(
        int(t) * n_samples * n_channels * 4 for t in tree_counts[1:]
    )
    out = {
        "tree_margins": margins,
        "tree_scales": passive * total_trees * 4,
        "histograms": 0,
        "grad_broadcast": 0,
        "id_partition": 0,
        "feature_mask": 0,
        "split_candidates": 0,
    }
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out
