"""The vertically-federated forest builder: Alg. 1/2 under shard_map.

The entire per-round forest construction runs as one SPMD program in which
the party axis of the mesh *is* the party decomposition of the VFL protocol:
every mesh shard holds one party's feature columns, executes the per-party
steps of Alg. 2 locally, and the protocol's messages become jax.lax
collectives (see aggregator.py for the exact correspondence).

Losslessness: both aggregation modes produce trees identical to the
centralized builder (tests/test_federation.py asserts this bit-for-bit),
which is the SecureBoost property the paper's §4.2.1 relies on to evaluate
federated models locally.
"""

from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import forest as forest_mod
from repro.core.backend import BackendDescriptor, TreeBackend, register_backend
from repro.core.types import TreeConfig
from repro.federation import aggregator, compress, mesh_roles
from repro.federation import async_exchange as async_mod
from repro.federation import chaos as chaos_mod


def make_vfl_backend(
    mesh: Mesh,
    tree: TreeConfig,
    aggregation: str = "histogram",
    party_axis: str = mesh_roles.PARTY_AXIS,
    shard_samples: bool = False,
    transport=None,
    meter=None,
    async_exchange: bool = False,
    chaos=None,
) -> TreeBackend:
    """Construct the vertically-federated TreeBackend (DESIGN.md §1).

    The per-party providers (federated histogram / choose / route / leaf
    collectives from aggregator.py) form an *inner* backend that runs inside
    the shard_map body; the returned backend's ``forest_builder`` wraps the
    whole per-round forest construction in that one SPMD program, so the
    boosting loop threads a single object either way.

    Args:
      mesh: mesh containing ``party_axis`` (and optionally data axes).
      tree: static tree config baked into the shard_map program.
      aggregation: "histogram" (paper-faithful full-histogram exchange) or
        "argmax" (beyond-paper candidate-only exchange; see aggregator.py).
      shard_samples: also shard the sample axis over the data axes (the
        multi-worker extension; histograms/leaf stats psum over those axes).
      transport: ``compress.TransportSpec`` selecting the wire format of the
        per-level exchange (DESIGN.md §5): None/"raw" = full-precision
        float32; "quantized" (histogram mode) = int8/int16 payloads +
        per-(node, feature, channel) scales; "topk" (argmax mode) = k
        candidates per node per party.
      meter: ``compress.MessageMeter`` — when given, every party-axis
        collective records its actual payload size at trace time (use via
        ``compress.probe_tree_cost``; see MessageMeter for semantics).
      async_exchange: double-buffer the per-level histogram exchange
        (DESIGN.md §10): the payload ships as two overlapping transfers
        instead of one barrier all_gather.  Bit-identical results, one
        logical metered message per level either way.  Histogram
        aggregation only — the argmax/top-k candidate exchange already
        ships small independent gathers.
      chaos: ``chaos.ChaosSpec`` — wrap the level exchange (whatever base
        gather the flags above select) in the fault-injecting, checksum-
        verified chaos transport (DESIGN.md §13).  The recovered result is
        bit-identical to the wrapped transport even under injected faults;
        the meter gains a ``"retries"`` phase for the integrity channel +
        retransmissions.
    """
    cfg = tree
    num_parties = mesh.shape[party_axis]
    data_axes = mesh_roles.data_axes(mesh) if shard_samples else ()
    if transport is None:
        transport = compress.RAW
    if async_exchange and aggregation != "histogram":
        raise ValueError(
            "async_exchange applies to the histogram aggregation only "
            "(the argmax candidate exchange is already multi-buffered)"
        )

    # Chaos transport (DESIGN.md §13): ONE stateful wrapper per backend,
    # composed over whatever base gather the other flags select.  The
    # forest builders reset its trace-time slot counter at every entry so
    # each traced program enumerates fault slots 0..L-1 deterministically.
    chaos_gather = None
    if chaos is not None:
        base_gather = (partial(async_mod.double_buffered_gather,
                               split_axis=-2)
                       if async_exchange else aggregator.plain_gather)
        chaos_gather = chaos_mod.ChaoticGather(
            chaos, base_gather, num_parties, meter=meter
        )

    # Round-native providers (DESIGN.md §9): the tree axis is explicit, so
    # each level's party exchange is ONE collective carrying the whole
    # round's (T, active, d_party, B, ...) payload.
    if aggregation == "histogram":
        if chaos_gather is not None:
            # same provider lattice, with the chaos gather at the seam
            if transport.kind == "quantized":
                histogram_fn = compress.quantized_round_histogram_fn(
                    party_axis, data_axes, transport, meter=meter,
                    gather=chaos_gather,
                )
            elif transport.kind == "raw":
                histogram_fn = aggregator.federated_round_histogram_fn(
                    party_axis, data_axes, meter=meter, gather=chaos_gather
                )
            else:
                raise ValueError(
                    f"transport {transport.kind!r} does not apply to the "
                    "histogram aggregation (use 'raw' or 'quantized')"
                )
        elif async_exchange:
            histogram_fn = async_mod.async_round_histogram_fn(
                party_axis, data_axes, transport, meter=meter
            )
        elif transport.kind == "quantized":
            histogram_fn = compress.quantized_round_histogram_fn(
                party_axis, data_axes, transport, meter=meter
            )
        elif transport.kind == "raw":
            histogram_fn = aggregator.federated_round_histogram_fn(
                party_axis, data_axes, meter=meter
            )
        else:
            raise ValueError(
                f"transport {transport.kind!r} does not apply to the "
                "histogram aggregation (use 'raw' or 'quantized')"
            )
        choose_fn = aggregator.centralized_round_choose_fn(
            cfg, party_axis, meter=meter
        )
    elif aggregation == "argmax":
        histogram_fn = aggregator.local_round_histogram_fn(party_axis, data_axes)
        if transport.kind == "topk":
            choose_fn = compress.topk_round_choose_fn(
                cfg, transport.k, party_axis, meter=meter,
                gather=chaos_gather,
            )
        elif transport.kind == "raw":
            choose_fn = compress.topk_round_choose_fn(
                cfg, 1, party_axis, meter=meter, gather=chaos_gather
            )
        else:
            raise ValueError(
                f"transport {transport.kind!r} does not apply to the "
                "argmax aggregation (use 'raw' or 'topk')"
            )
    else:
        raise ValueError(f"unknown aggregation {aggregation!r}")
    route_fn = aggregator.federated_round_route_fn(party_axis, meter=meter)
    leaf_fn = aggregator.local_round_leaf_fn(data_axes=data_axes)
    # Subtraction pipeline (DESIGN.md §6): no dedicated provider needed —
    # ``build_round`` derives ``as_round_child_fn(histogram_fn)`` from the
    # transport above, so the left-mask/halve staging runs inside the
    # shard_map body and the party all_gather (raw or quantized, metered
    # either way) ships the half-frontier payload; every party derives the
    # right siblings locally after the merge.

    impl = f"vfl-{aggregation}"
    if async_exchange:
        impl += "-async"
    if transport.kind != "raw":
        impl += f"-{transport.tag}"
    if shard_samples:
        impl += "-sharded"
    if chaos is not None:
        impl += "-chaos"
    descriptor = BackendDescriptor(
        impl=impl,
        num_parties=num_parties,
        party_axis=party_axis,
        data_axes=data_axes,
        shard_samples=shard_samples,
        transport=transport.tag,
        transport_spec=None if transport.kind == "raw" else transport,
        async_exchange=async_exchange,
        chaos=chaos,
    )
    inner = TreeBackend(
        descriptor=descriptor,
        round_histogram_fn=histogram_fn,
        round_choose_fn=choose_fn,
        round_route_fn=route_fn,
        round_leaf_fn=leaf_fn,
    )

    sample_spec = P(data_axes) if data_axes else P()
    in_specs = (
        P(sample_spec[0] if data_axes else None, party_axis),  # binned (n, d)
        sample_spec,                                           # g (n,)
        sample_spec,                                           # h (n,)
        P(None, sample_spec[0] if data_axes else None),        # smask (T, n)
        P(None, party_axis),                                   # fmask (T, d)
    )

    # The shard_map bodies close over the static shared-root buffer width
    # (``root_delta_rows``, DESIGN.md §9) — a local compute transformation
    # inside each party's histogram program, so the collective payloads are
    # unchanged.  One wrapped program per distinct width, cached.
    @lru_cache(maxsize=None)
    def _sharded(rdr: int):
        def _forest_body(binned_shard, g, h, smask, fmask_shard):
            return forest_mod.build_forest.__wrapped__(  # un-jitted inner
                binned_shard, g, h, smask, fmask_shard, cfg, backend=inner,
                root_delta_rows=rdr,
            )

        return shard_map(
            _forest_body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), sample_spec),  # (trees replicated, train_pred)
            check_vma=False,
        )

    # Per-tree variant: predictions keep the tree axis (T, n) — replicated on
    # the party axis (each party computes the full routing via the psum'd
    # bitmaps), sharded like the samples on the data axes.
    @lru_cache(maxsize=None)
    def _sharded_per_tree(rdr: int):
        def _forest_body_per_tree(binned_shard, g, h, smask, fmask_shard):
            return forest_mod._forest_per_tree(  # un-jitted per-tree inner
                binned_shard, g, h, smask, fmask_shard, cfg, backend=inner,
                root_delta_rows=rdr,
            )

        return shard_map(
            _forest_body_per_tree,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P(None, sample_spec[0] if data_axes else None)),
            check_vma=False,
        )

    @partial(jax.jit, static_argnames=("rdr",))
    def _run(binned, g, h, sample_mask, feature_mask, rdr=0):
        return _sharded(rdr)(binned, g, h, sample_mask, feature_mask)

    @partial(jax.jit, static_argnames=("rdr",))
    def _run_per_tree(binned, g, h, sample_mask, feature_mask, rdr=0):
        return _sharded_per_tree(rdr)(binned, g, h, sample_mask, feature_mask)

    # Row padding for uneven shards (DESIGN.md §8): shard_map needs n
    # divisible by the data-axis extent, but callers hand arbitrary n.  The
    # pad happens HERE — inside the backend, *after* the boosting engine
    # drew its exact-count subsampling masks over the real n rows — so the
    # sampling semantics are untouched: padded rows enter with sample-mask
    # weight 0 (histograms, leaf stats, liveness counts and shared-root
    # deltas all weight by the mask, so they are inert) and the returned
    # predictions slice back to the caller's n.
    shard_count = 1
    for _ax in data_axes:
        shard_count *= mesh.shape[_ax]

    def _pad_rows(binned, g, h, sample_mask):
        n = binned.shape[0]
        n_pad = -(-n // shard_count) * shard_count
        if n_pad == n:
            return binned, g, h, sample_mask, n
        pad = n_pad - n
        # g/h are (n,) for scalar objectives, (n, K) for K-channel ones —
        # either way only the sample axis pads.
        row_pad = lambda v: jnp.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1))
        return (
            jnp.pad(binned, ((0, pad), (0, 0))),
            row_pad(g),
            row_pad(h),
            jnp.pad(sample_mask, ((0, 0), (0, pad))),
            n,
        )

    def _check(binned, _cfg):
        """The tree config is baked into the shard_map program, so a
        caller-passed cfg must match ``tree`` (a silent mismatch would build
        trees at one depth and traverse at another)."""
        if _cfg is not None and _cfg != cfg:
            raise ValueError(
                f"backend {descriptor.impl!r} was built with {cfg}, but the "
                f"caller passed {_cfg}; construct the backend with the same "
                "TreeConfig as FedGBFConfig.tree"
            )
        d = binned.shape[1]
        if d % num_parties != 0:
            raise ValueError(
                f"d={d} must shard evenly over {num_parties} parties; "
                "pad columns with data.tabular.pad_features"
            )

    def forest_builder(binned, g, h, sample_mask, feature_mask, _cfg=None,
                       root_delta_rows=0):
        _check(binned, _cfg)
        if chaos_gather is not None:
            chaos_gather.begin_trace()
        if meter is not None:
            # The per-round (g, h) broadcast active -> each passive party.
            # Not a collective here (the derivatives enter replicated), so
            # it is metered at the program boundary from the actual arrays
            # — the REAL n rows, before any shard padding.
            meter.record("grad_broadcast", g)
            meter.record("grad_broadcast", h)
        binned, g, h, sample_mask, n = _pad_rows(
            binned, g, h, sample_mask.astype(jnp.float32)
        )
        trees, pred = _run(binned, g, h, sample_mask, feature_mask,
                           rdr=root_delta_rows)
        return trees, pred[:n]

    def forest_builder_per_tree(binned, g, h, sample_mask, feature_mask,
                                _cfg=None, root_delta_rows=0):
        _check(binned, _cfg)
        if chaos_gather is not None:
            chaos_gather.begin_trace()
        if meter is not None:
            meter.record("grad_broadcast", g)
            meter.record("grad_broadcast", h)
        binned, g, h, sample_mask, n = _pad_rows(
            binned, g, h, sample_mask.astype(jnp.float32)
        )
        trees, per_tree = _run_per_tree(
            binned, g, h, sample_mask, feature_mask, rdr=root_delta_rows
        )
        return trees, per_tree[:, :n]

    # The per-node collectives live only on the INNER backend consumed inside
    # the shard_map body; exposing them here would invite generic callers
    # (forest.build_forest(backend=...), backend.build_tree) to run them
    # outside shard_map, where the axis names are unbound.  The public
    # surface of a VFL backend is build_forest -> forest_builder (and the
    # per-tree variant the scanned training engine consumes).
    return TreeBackend(
        descriptor=descriptor,
        forest_builder=forest_builder,
        forest_builder_per_tree=forest_builder_per_tree,
    )


def make_federated_forest_fn(
    mesh: Mesh,
    cfg: TreeConfig,
    aggregation: str = "histogram",
    party_axis: str = mesh_roles.PARTY_AXIS,
    shard_samples: bool = False,
):
    """DEPRECATED shim: returns ``make_vfl_backend(...).build_forest`` with
    the legacy hook kwargs (histogram_fn= etc.) absorbed for drop-in use.

    Prefer passing the backend object itself to ``boosting.train_fedgbf``.
    """
    backend = make_vfl_backend(
        mesh, cfg, aggregation=aggregation, party_axis=party_axis,
        shard_samples=shard_samples,
    )

    def forest_fn(binned, g, h, sample_mask, feature_mask, _cfg=None, **_ignored):
        return backend.build_forest(binned, g, h, sample_mask, feature_mask, _cfg)

    return forest_fn


# Registry entries: vfl backends bind a mesh + tree config at construction,
# e.g. ``get_backend("vfl-argmax", mesh=mesh, tree=TreeConfig(...))``.
# Compressed-transport variants (DESIGN.md §5) are distinct registry names,
# not kwargs, so scaling work stays registry factories per DESIGN.md §1.
def _vfl_factory(aggregation: str, shard_samples: bool, transport=None,
                 async_exchange: bool = False, chaos_enabled: bool = False):
    def factory(mesh=None, tree=None, **kw):
        if mesh is None or tree is None:
            raise ValueError(
                "vfl backends need mesh= and tree= (a TreeConfig), e.g. "
                "get_backend('vfl-histogram', mesh=mesh, tree=TreeConfig())"
            )
        explicit = kw.pop("transport", None)
        if (transport is not None and explicit is not None
                and explicit != transport):
            # The registry name encodes the transport (DESIGN.md §1/§5); a
            # conflicting explicit spec would silently ship a different wire
            # format than the name promises.
            raise ValueError(
                f"backend name encodes transport {transport.tag!r} but "
                f"transport= {explicit!r} was passed; drop the kwarg or use "
                "the matching registry name"
            )
        chaos = kw.pop("chaos", None)
        if chaos_enabled:
            # "-chaos" names default to the zero-fault spec: the wrapper
            # (checksum channel + selection fold) is live, faults are not.
            chaos = chaos if chaos is not None else chaos_mod.ChaosSpec()
        elif chaos is not None:
            raise ValueError(
                "chaos= was passed to a non-chaos backend name; use the "
                "matching '-chaos' registry name (DESIGN.md §13)"
            )
        return make_vfl_backend(
            mesh, tree, aggregation=aggregation, shard_samples=shard_samples,
            transport=transport if transport is not None else explicit,
            async_exchange=async_exchange, chaos=chaos, **kw
        )

    return factory


# The async double-buffered exchange (DESIGN.md §10) is a histogram-mode
# lever, so only the histogram family grows "-async" names.  Every name in
# the lattice also grows a "-chaos" twin (DESIGN.md §13): the fault-
# injecting transport composes over any of them.
_TRANSPORTS = {
    "histogram": (("", None), ("-q8", compress.Q8), ("-q16", compress.Q16)),
    "argmax": (("", None), ("-topk", compress.TOPK)),
}
for _agg, _variants in _TRANSPORTS.items():
    for _suffix, _transport in _variants:
        _asyncs = (False, True) if _agg == "histogram" else (False,)
        for _async in _asyncs:
            _name = f"vfl-{_agg}" + ("-async" if _async else "") + _suffix
            for _shard, _sname in ((False, _name), (True, _name + "-sharded")):
                register_backend(
                    _sname,
                    _vfl_factory(_agg, shard_samples=_shard,
                                 transport=_transport, async_exchange=_async),
                )
                register_backend(
                    _sname + "-chaos",
                    _vfl_factory(_agg, shard_samples=_shard,
                                 transport=_transport, async_exchange=_async,
                                 chaos_enabled=True),
                )


def party_shardings(mesh: Mesh, party_axis: str = mesh_roles.PARTY_AXIS):
    """NamedShardings for placing the global arrays party-wise up front so the
    shard_map incurs no re-layout: binned (n, d) sharded on columns."""
    return {
        "binned": NamedSharding(mesh, P(None, party_axis)),
        "vector": NamedSharding(mesh, P()),
    }
