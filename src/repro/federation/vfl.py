"""The vertically-federated forest builder: Alg. 1/2 under shard_map.

The entire per-round forest construction runs as one SPMD program in which
the party axis of the mesh *is* the party decomposition of the VFL protocol:
every mesh shard holds one party's feature columns, executes the per-party
steps of Alg. 2 locally, and the protocol's messages become jax.lax
collectives (see aggregator.py for the exact correspondence).

Losslessness: both aggregation modes produce trees identical to the
centralized builder (tests/test_federation.py asserts this bit-for-bit),
which is the SecureBoost property the paper's §4.2.1 relies on to evaluate
federated models locally.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core import forest as forest_mod
from repro.core.types import TreeConfig
from repro.federation import aggregator, mesh_roles


def make_federated_forest_fn(
    mesh: Mesh,
    cfg: TreeConfig,
    aggregation: str = "histogram",
    party_axis: str = mesh_roles.PARTY_AXIS,
    shard_samples: bool = False,
):
    """Build a drop-in replacement for ``core.forest.build_forest``.

    Args:
      mesh: mesh containing ``party_axis`` (and optionally data axes).
      aggregation: "histogram" (paper-faithful full-histogram exchange) or
        "argmax" (beyond-paper candidate-only exchange; see aggregator.py).
      shard_samples: also shard the sample axis over the data axes (the
        multi-worker extension; histograms/leaf stats psum over those axes).

    Returns:
      forest_fn(binned, g, h, sample_mask, feature_mask, cfg, **_) matching
      the ``boosting.train_fedgbf(forest_fn=...)`` hook. Inputs are global
      (unsharded) arrays; sharding is applied via shard_map specs.
    """
    num_parties = mesh.shape[party_axis]
    data_axes = mesh_roles.data_axes(mesh) if shard_samples else ()

    if aggregation == "histogram":
        histogram_fn = aggregator.federated_histogram_fn(party_axis, data_axes)
        choose_fn = aggregator.centralized_choose_fn(cfg, party_axis)
    elif aggregation == "argmax":
        histogram_fn = aggregator.local_histogram_fn(party_axis, data_axes)
        choose_fn = aggregator.federated_choose_fn(cfg, party_axis)
    else:
        raise ValueError(f"unknown aggregation {aggregation!r}")
    route_fn = aggregator.federated_route_fn(party_axis)
    leaf_fn = aggregator.local_histogram_fn(party_axis="", data_axes=data_axes)

    sample_spec = P(data_axes) if data_axes else P()

    def _forest_body(binned_shard, g, h, smask, fmask_shard):
        return forest_mod.build_forest.__wrapped__(  # un-jitted inner
            binned_shard, g, h, smask, fmask_shard, cfg,
            histogram_fn=histogram_fn,
            choose_fn=choose_fn,
            route_fn=route_fn,
            leaf_fn=leaf_fn,
        )

    sharded = shard_map(
        _forest_body,
        mesh=mesh,
        in_specs=(
            P(sample_spec[0] if data_axes else None, party_axis),  # binned (n, d)
            sample_spec,                                           # g (n,)
            sample_spec,                                           # h (n,)
            P(None, sample_spec[0] if data_axes else None),        # smask (T, n)
            P(None, party_axis),                                   # fmask (T, d)
        ),
        out_specs=(P(), sample_spec),  # (trees replicated, train_pred (n,))
        check_vma=False,
    )

    @jax.jit
    def _run(binned, g, h, sample_mask, feature_mask):
        return sharded(binned, g, h, sample_mask, feature_mask)

    def forest_fn(binned, g, h, sample_mask, feature_mask, _cfg=None, **_ignored):
        """Drop-in for core.forest.build_forest (extra kwargs absorbed —
        the federated providers are baked in at construction)."""
        d = binned.shape[1]
        if d % num_parties != 0:
            raise ValueError(
                f"d={d} must shard evenly over {num_parties} parties; "
                "pad columns with data.tabular.pad_features"
            )
        return _run(binned, g, h, sample_mask.astype(jnp.float32), feature_mask)

    return forest_fn


def party_shardings(mesh: Mesh, party_axis: str = mesh_roles.PARTY_AXIS):
    """NamedShardings for placing the global arrays party-wise up front so the
    shard_map incurs no re-layout: binned (n, d) sharded on columns."""
    return {
        "binned": NamedSharding(mesh, P(None, party_axis)),
        "vector": NamedSharding(mesh, P()),
    }
