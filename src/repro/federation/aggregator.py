"""Per-party collectives of the VFL protocol, as jax.lax primitives.

Two aggregation modes (DESIGN.md §2, EXPERIMENTS.md §Perf):

* ``"histogram"`` — paper-faithful: every party ships its full per-shard
  histogram to the active party (Alg. 2 step 7). In SPMD this is an
  ``all_gather`` over the party axis; bytes = nodes * d_party * B * 3 per
  party per level.
* ``"argmax"`` — beyond-paper collective optimisation: each party evaluates
  its local best split and only the (gain, feature, threshold) candidates are
  exchanged; bytes = nodes * 3 per party per level, a ~d_party*B/1
  reduction of the dominant protocol message. Lossless: the global argmax of
  per-party argmaxes equals the argmax of the union (ties broken towards the
  lower party id, matching jnp.argmax's first-occurrence rule on the
  concatenated axis).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import histogram as hist_mod
from repro.core import split as split_mod
from repro.core.split import SplitDecision
from repro.core.types import TreeConfig
from repro.federation import mesh_roles


def federated_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    base_fn: Callable = hist_mod.compute_histogram,
):
    """Histogram provider running *inside* shard_map.

    Computes the local-shard histogram, psums over sample shards (the
    beyond-FATE multi-worker extension — histograms are additive), then
    all-gathers over parties so split selection sees the global histogram,
    mirroring "send summed ciphertext bins to the active party".
    """

    def fn(binned_shard, g, h, weight, assign, num_nodes, num_bins):
        local = base_fn(binned_shard, g, h, weight, assign, num_nodes, num_bins)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        return jax.lax.all_gather(local, party_axis, axis=1, tiled=True)

    return fn


def local_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    base_fn: Callable = hist_mod.compute_histogram,
):
    """Like federated_histogram_fn but WITHOUT the party all-gather — used by
    the argmax aggregation mode, where histograms stay party-local."""

    def fn(binned_shard, g, h, weight, assign, num_nodes, num_bins):
        local = base_fn(binned_shard, g, h, weight, assign, num_nodes, num_bins)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        return local

    return fn


def federated_choose_fn(cfg: TreeConfig, party_axis: str = mesh_roles.PARTY_AXIS):
    """Split chooser for the ``argmax`` mode: local best, then global argmax.

    Receives the *party-local* histogram (nodes, d_party, B, 3); returns a
    SplitDecision with global feature ids, identical on every party.
    """

    def fn(hist_local, feature_mask_local):
        d_party = hist_local.shape[1]
        p = jax.lax.axis_index(party_axis)
        local = split_mod.choose_splits(
            hist_local, feature_mask_local, cfg,
            feature_offset=p * d_party,
        )
        # Exchange only the candidate tuples (the small message).
        gains = jax.lax.all_gather(local.gain, party_axis)       # (P, nodes)
        feats = jax.lax.all_gather(local.feature, party_axis)    # (P, nodes)
        thrs = jax.lax.all_gather(local.threshold, party_axis)   # (P, nodes)
        best_party = jnp.argmax(gains, axis=0)                   # (nodes,)
        take = lambda a: jnp.take_along_axis(a, best_party[None, :], axis=0)[0]
        return SplitDecision(
            feature=take(feats), threshold=take(thrs), gain=take(gains)
        )

    return fn


def centralized_choose_fn(cfg: TreeConfig, party_axis: str = mesh_roles.PARTY_AXIS):
    """Split chooser for the ``histogram`` mode: the gathered global histogram
    is evaluated identically on every party (the active party's computation,
    replicated by SPMD). The feature mask arrives as the local slice and is
    gathered to match the gathered histogram."""

    def fn(hist_global, feature_mask_local):
        fmask = jax.lax.all_gather(
            feature_mask_local, party_axis, axis=0, tiled=True
        )
        return split_mod.choose_splits(hist_global, fmask, cfg)

    return fn


def federated_route_fn(party_axis: str = mesh_roles.PARTY_AXIS):
    """Ownership-masked routing (Alg. 2 step 3 / SecureBoost step 4).

    The winning feature belongs to exactly one party; that party computes the
    left/right partition of the frontier samples and the bitmap is shared —
    in SPMD, a psum of the masked contribution.
    """

    def fn(binned_shard, assign, decision):
        n, d_party = binned_shard.shape
        rows = jnp.arange(n)
        p = jax.lax.axis_index(party_axis)
        f_global = decision.feature[assign]       # (n,) global ids, -1 = no split
        f_local = f_global - p * d_party
        owned = (f_local >= 0) & (f_local < d_party)
        fv = binned_shard[rows, jnp.clip(f_local, 0, d_party - 1)]
        thr = decision.threshold[assign]
        go_right_local = jnp.where(
            owned & (f_global >= 0), (fv > thr).astype(jnp.int32), 0
        )
        go_right = jax.lax.psum(go_right_local, party_axis)
        return assign * 2 + go_right

    return fn
