"""Per-party collectives of the VFL protocol, as jax.lax primitives.

Two aggregation modes (DESIGN.md §2, EXPERIMENTS.md §Perf):

* ``"histogram"`` — paper-faithful: every party ships its full per-shard
  histogram to the active party (Alg. 2 step 7). In SPMD this is an
  ``all_gather`` over the party axis; bytes = nodes * d_party * B * 3 per
  party per level.
* ``"argmax"`` — beyond-paper collective optimisation: each party evaluates
  its local best split and only the (gain, feature, threshold) candidates are
  exchanged; bytes = nodes * 3 per party per level, a ~d_party*B/1
  reduction of the dominant protocol message. Lossless: the global argmax of
  per-party argmaxes equals the argmax of the union (ties broken towards the
  lower party id, matching jnp.argmax's first-occurrence rule on the
  concatenated axis).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import histogram as hist_mod
from repro.core import split as split_mod
from repro.core.types import TreeConfig
from repro.federation import mesh_roles


def federated_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    base_fn: Callable = hist_mod.compute_histogram,
    meter=None,
):
    """Histogram provider running *inside* shard_map.

    Computes the local-shard histogram, psums over sample shards (the
    beyond-FATE multi-worker extension — histograms are additive), then
    all-gathers over parties so split selection sees the global histogram,
    mirroring "send summed ciphertext bins to the active party".

    ``meter`` (a ``compress.MessageMeter``) records the actual payload each
    party ships — the full local float32 (g, h, count) histogram.  Data-axis
    psums are intra-party (multi-worker) traffic, not protocol bytes, and
    are not metered.
    """

    def fn(binned_shard, g, h, weight, assign, num_nodes, num_bins):
        local = base_fn(binned_shard, g, h, weight, assign, num_nodes, num_bins)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        if meter is not None:
            meter.record("histograms", local)
        return jax.lax.all_gather(local, party_axis, axis=1, tiled=True)

    return fn


# Subtraction pipeline (DESIGN.md §8): the federated child providers are the
# generic ``histogram.as_child_fn`` adaptation of the providers above — the
# left-mask/parent-halve staging runs INSIDE the shard_map body, before the
# party collective, so the all_gather (and the quantized payload, and the
# meter record) all carry the half-frontier width.  Every party derives the
# right siblings locally after the merge (``tree.build_tree`` calls
# ``histogram.derive_sibling`` on the gathered result — in SPMD terms, the
# active party's subtraction, replicated).  ``build_tree`` derives the
# adaptation from the inner backend's ``histogram_fn`` automatically; no
# dedicated federated child provider is needed.


def local_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    base_fn: Callable = hist_mod.compute_histogram,
):
    """Like federated_histogram_fn but WITHOUT the party all-gather — used by
    the argmax aggregation mode, where histograms stay party-local."""

    def fn(binned_shard, g, h, weight, assign, num_nodes, num_bins):
        local = base_fn(binned_shard, g, h, weight, assign, num_nodes, num_bins)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        return local

    return fn


def local_leaf_fn(data_axes: tuple = ()):
    """Leaf-statistics provider (``histogram.leaf_stats`` signature): the
    active party owns g, h and the final routing in plaintext (Alg. 2 step
    14), so leaf stats are a local pass — psum'd over the sample shards only
    when the data axes are in play (the additive-stats extension)."""

    def fn(g, h, weight, assign, num_leaves):
        local = hist_mod.leaf_stats(g, h, weight, assign, num_leaves)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        return local

    return fn


def federated_choose_fn(cfg: TreeConfig, party_axis: str = mesh_roles.PARTY_AXIS,
                        meter=None):
    """Split chooser for the ``argmax`` mode: local best, then global argmax.

    Receives the *party-local* histogram (nodes, d_party, B, 3); returns a
    SplitDecision with global feature ids, identical on every party.
    ``meter`` records the candidate tuples each party ships (12 B per node).

    This IS ``compress.topk_choose_fn`` at k = 1 (one candidate per node per
    party); delegating keeps the lossless tie-break contract — party-major
    merge reproducing the centralized first-occurrence rule — in exactly one
    place.
    """
    from repro.federation import compress  # local: compress builds on this module

    return compress.topk_choose_fn(cfg, 1, party_axis, meter)


def centralized_choose_fn(cfg: TreeConfig, party_axis: str = mesh_roles.PARTY_AXIS,
                          meter=None):
    """Split chooser for the ``histogram`` mode: the gathered global histogram
    is evaluated identically on every party (the active party's computation,
    replicated by SPMD). The feature mask arrives as the local slice and is
    gathered to match the gathered histogram. ``meter`` records each party's
    mask-slice payload (1 B per local feature)."""

    def fn(hist_global, feature_mask_local):
        if meter is not None:
            meter.record("feature_mask", feature_mask_local)
        fmask = jax.lax.all_gather(
            feature_mask_local, party_axis, axis=0, tiled=True
        )
        return split_mod.choose_splits(hist_global, fmask, cfg)

    return fn


def federated_route_fn(party_axis: str = mesh_roles.PARTY_AXIS, meter=None):
    """Ownership-masked routing (Alg. 2 step 3 / SecureBoost step 4).

    The winning feature belongs to exactly one party; that party computes the
    left/right partition of the frontier samples and the bitmap is shared —
    in SPMD, a psum of the masked contribution.  ``meter`` records the
    partition payload once per level (int32 (n,) — the owner's message; the
    other parties' contributions are structurally zero).
    """

    def fn(binned_shard, assign, decision):
        n, d_party = binned_shard.shape
        rows = jnp.arange(n)
        p = jax.lax.axis_index(party_axis)
        f_global = decision.feature[assign]       # (n,) global ids, -1 = no split
        f_local = f_global - p * d_party
        owned = (f_local >= 0) & (f_local < d_party)
        fv = binned_shard[rows, jnp.clip(f_local, 0, d_party - 1)]
        thr = decision.threshold[assign]
        go_right_local = jnp.where(
            owned & (f_global >= 0), (fv > thr).astype(jnp.int32), 0
        )
        if meter is not None:
            meter.record("id_partition", go_right_local)
        go_right = jax.lax.psum(go_right_local, party_axis)
        return assign * 2 + go_right

    return fn
