"""Per-party collectives of the VFL protocol, as jax.lax primitives.

Two aggregation modes (DESIGN.md §2, EXPERIMENTS.md §Perf):

* ``"histogram"`` — paper-faithful: every party ships its full per-shard
  histogram to the active party (Alg. 2 step 7). In SPMD this is an
  ``all_gather`` over the party axis; bytes = nodes * d_party * B * 3 per
  party per level.
* ``"argmax"`` — beyond-paper collective optimisation: each party evaluates
  its local best split and only the (gain, feature, threshold) candidates are
  exchanged; bytes = nodes * 3 per party per level, a ~d_party*B/1
  reduction of the dominant protocol message. Lossless: the global argmax of
  per-party argmaxes equals the argmax of the union (ties broken towards the
  lower party id, matching jnp.argmax's first-occurrence rule on the
  concatenated axis).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import histogram as hist_mod
from repro.core import split as split_mod
from repro.core.types import TreeConfig
from repro.federation import mesh_roles


# Subtraction pipeline (DESIGN.md §6): the federated child providers are the
# generic ``histogram.as_round_child_fn`` adaptation of the providers below —
# the left-mask/parent-halve staging runs INSIDE the shard_map body, before
# the party collective, so the all_gather (and the quantized payload, and the
# meter record) all carry the half-frontier width.  Every party derives the
# right siblings locally after the merge (``tree.build_round`` calls
# ``histogram.derive_sibling`` on the gathered result — in SPMD terms, the
# active party's subtraction, replicated).  ``build_round`` derives the
# adaptation from the inner backend's ``round_histogram_fn`` automatically;
# no dedicated federated child provider is needed.


# ---------------------------------------------------------------------------
# Round-native collectives (DESIGN.md §9): the tree axis is explicit, so the
# per-level party exchange is ONE collective carrying the whole round's
# (T, active, d_party, B, 3) payload instead of a vmap-batched per-tree one.
# ---------------------------------------------------------------------------
def plain_gather(x, party_axis: str, axis: int):
    """The default (synchronous) level exchange: one tiled all_gather."""
    return jax.lax.all_gather(x, party_axis, axis=axis, tiled=True)


def federated_round_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    base_fn: Callable = hist_mod.compute_round_histogram,
    meter=None,
    gather: Callable = plain_gather,
):
    """Round histogram provider running *inside* shard_map.

    Computes the local-shard round histogram (one segment pass over all T
    trees; shared-root caching rides the ``root_delta_rows`` keyword and
    stays a local compute transformation — the collective payload is
    unchanged), psums over sample shards, then all-gathers the feature axis
    over parties: ONE collective per level for the whole round.

    ``meter`` records the actual payload each party ships — the full local
    float32 (T, nodes, d_party, B, 3) histogram (per-tree bytes × T; the
    probes trace at T = 1, and the run ledger scales by the schedule).

    ``gather`` is the exchange seam (DESIGN.md §10): ``plain_gather`` for
    the synchronous single all_gather, or ``async_exchange
    .double_buffered_gather`` to split the payload into two buffers whose
    transfers overlap.  Either way the meter records the payload ONCE —
    the split is a scheduling detail, not a protocol message.
    """

    def fn(binned_shard, g, h, weight, assign, num_nodes, num_bins,
           root_delta_rows=0, level=0):
        local = base_fn(binned_shard, g, h, weight, assign, num_nodes,
                        num_bins, root_delta_rows=root_delta_rows,
                        level=level)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        if meter is not None:
            meter.record("histograms", local)
        return gather(local, party_axis, 2)

    return fn


def local_round_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    base_fn: Callable = hist_mod.compute_round_histogram,
):
    """Like ``federated_round_histogram_fn`` but WITHOUT the party
    all-gather — the argmax aggregation keeps histograms party-local."""

    def fn(binned_shard, g, h, weight, assign, num_nodes, num_bins,
           root_delta_rows=0, level=0):
        local = base_fn(binned_shard, g, h, weight, assign, num_nodes,
                        num_bins, root_delta_rows=root_delta_rows,
                        level=level)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        return local

    return fn


def local_round_leaf_fn(data_axes: tuple = ()):
    """Round leaf-statistics provider ((T, n) → (T, leaves, 3)): a local
    pass on the active party (Alg. 2 step 14), psum'd over sample shards.
    Also serves the round engine's compaction liveness counts — weights and
    routing are party-replicated, so no party collective is needed."""

    def fn(g, h, weight, assign, num_leaves):
        local = hist_mod.round_leaf_stats(g, h, weight, assign, num_leaves)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        return local

    return fn


def centralized_round_choose_fn(
    cfg: TreeConfig, party_axis: str = mesh_roles.PARTY_AXIS, meter=None
):
    """Round split chooser for the ``histogram`` mode: the gathered global
    (T, nodes, d, B, 3) histogram is evaluated identically on every party.
    The per-tree feature masks arrive as the (T, d_party) local slice and
    are gathered to match.  ``meter`` records each party's mask payload
    (1 B per local feature per tree)."""

    def fn(hist_global, feature_mask_local):
        if meter is not None:
            meter.record("feature_mask", feature_mask_local)
        fmask = jax.lax.all_gather(
            feature_mask_local, party_axis, axis=1, tiled=True
        )
        return split_mod.choose_splits_round(hist_global, fmask, cfg)

    return fn


def pack_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Pack a (..., n) 0/1 array into (..., ceil(n/8)) uint8 bitmaps
    (little-endian within each byte).  The id_partition wire format:
    per-level go-right decisions are 1 bit/row, so the routing broadcast
    ships ``ceil(n/8)`` bytes instead of ``4·n`` (int32) — a 32× cut."""
    n = x.shape[-1]
    n_bytes = -(-n // 8)
    pad = [(0, 0)] * (x.ndim - 1) + [(0, n_bytes * 8 - n)]
    bits = jnp.pad(x.astype(jnp.uint8), pad)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(
        bits.reshape(x.shape[:-1] + (n_bytes, 8)) * weights,
        axis=-1, dtype=jnp.uint8,
    )


def unpack_bits(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of ``pack_bits``: (..., ceil(n/8)) uint8 → (..., n) int32."""
    bits = (packed[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & jnp.uint8(1)
    return bits.reshape(packed.shape[:-1] + (-1,))[..., :n].astype(jnp.int32)


def federated_round_route_fn(party_axis: str = mesh_roles.PARTY_AXIS,
                             meter=None):
    """Round ownership-masked routing: the whole round's (T, n) partition
    bitmaps travel in ONE psum per level (Alg. 2 step 3 / SecureBoost
    step 4, batched over the tree axis).

    Wire format: the go-right decisions are BIT-PACKED before the psum —
    each row's splitting feature is owned by exactly one party, so across
    parties every bit position has at most one non-zero contributor and the
    uint8 byte-sum is carry-free (identical to the bitwise OR).  The psum
    operand (and the metered payload) is the ``(T, ceil(n/8))`` bitmap the
    protocol inventory prices (one n-bit bitmap per level), 32× smaller
    than the unpacked int32 vector.
    """

    def fn(binned_shard, assign, decision):
        n, d_party = binned_shard.shape
        p = jax.lax.axis_index(party_axis)
        f_global = jnp.take_along_axis(decision.feature, assign, axis=1)
        thr = jnp.take_along_axis(decision.threshold, assign, axis=1)
        f_local = f_global - p * d_party
        owned = (f_local >= 0) & (f_local < d_party)
        fv = binned_shard[
            jnp.arange(n)[None, :], jnp.clip(f_local, 0, d_party - 1)
        ]  # (T, n)
        go_right_local = jnp.where(
            owned & (f_global >= 0), (fv > thr).astype(jnp.int32), 0
        )
        packed_local = pack_bits(go_right_local)  # (T, ceil(n/8)) uint8
        if meter is not None:
            meter.record("id_partition", packed_local)
        packed = jax.lax.psum(packed_local, party_axis)  # carry-free == OR
        return assign * 2 + unpack_bits(packed, n)

    return fn
