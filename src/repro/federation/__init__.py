from repro.federation import (  # noqa: F401
    aggregator,
    compress,
    mesh_roles,
    protocol,
    secure,
    vfl,
)
