from repro.federation import aggregator, mesh_roles, protocol, secure, vfl  # noqa: F401
