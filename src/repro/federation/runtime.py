"""Fault-tolerant federation runtime: retries and party-dropout degradation.

This module is the HOST-side half of the fault story (DESIGN.md §13).  The
in-graph half (``federation/chaos.py``) injects transport faults and recovers
them via checksum-verified retransmissions, so a chaotic run stays
bit-identical to a clean one.  Here we model the failures that retransmission
can NOT hide: a party that stops answering for a whole boosting round.

The coordinator's policy is deterministic and replayable:

* ``RetryPolicy`` — how many times a silent party is re-polled and with what
  exponential backoff before the round is *degraded*.
* ``dropout_schedule`` — a seeded per-round / per-party availability draw.
  Each unavailable (round, party) attempt consumes one retry; a party that
  exhausts ``max_retries`` straight attempts is degraded for that round.
* ``degradation_masks`` — lowers the schedule onto the feature axis: a
  degraded party's columns are removed from the round's split search via
  ``train_fedgbf(round_feature_mask=...)``.  The training result is therefore
  bit-identical to a run where those candidates never existed — the oracle
  ``selftest.check_degradation`` asserts exactly that.

Backoff is *simulated* (accounted in seconds, not slept) by default so tests
and benches stay fast; the driver may sleep if it wants real pacing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = [
    "RetryPolicy",
    "DropoutSchedule",
    "dropout_schedule",
    "degradation_masks",
    "degraded_parties",
    "party_column_slice",
]

# Distinct ``np.random.default_rng`` stream tag so the availability draw can
# never collide with chaos fault planning (streams 7919 / 104729 there).
_DROPOUT_STREAM = 15485863


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Coordinator-side retry/timeout policy for one level exchange.

    ``max_retries`` counts re-polls after the first attempt; attempt ``i``
    (0-based) waits ``backoff(i)`` seconds before retrying, doubling from
    ``base_delay_s`` and capped at ``max_delay_s``.  A party still silent
    after ``1 + max_retries`` attempts is degraded for the round.
    """

    max_retries: int = 3
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    timeout_s: float = 30.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.base_delay_s < 0 or self.max_delay_s < self.base_delay_s:
            raise ValueError("need 0 <= base_delay_s <= max_delay_s")

    def backoff(self, attempt: int) -> float:
        """Seconds to wait before retry ``attempt`` (0-based)."""
        return float(min(self.max_delay_s,
                         self.base_delay_s * (2.0 ** attempt)))


@dataclasses.dataclass(frozen=True)
class DropoutSchedule:
    """Replayable outcome of the availability draw for one training run.

    ``degraded[m, p]`` — party ``p`` exhausted its retries in round ``m``.
    ``retries[m, p]`` — re-poll attempts spent on party ``p`` in round ``m``
    (0 when the first poll answered; ``max_retries`` when degraded).
    ``backoff_s`` — total simulated backoff seconds across the run.
    """

    degraded: np.ndarray  # (rounds, parties) bool
    retries: np.ndarray   # (rounds, parties) int32
    backoff_s: float

    @property
    def degraded_rounds(self) -> int:
        return int(np.any(self.degraded, axis=1).sum())

    def round_summary(self, m: int) -> dict:
        """Per-round fault fields for ``--log-json`` / trace (0-based m)."""
        return {
            "retries": int(self.retries[m].sum()),
            "degraded_parties": [int(p) for p in
                                 np.nonzero(self.degraded[m])[0]],
        }


def dropout_schedule(
    rate: float,
    rounds: int,
    num_parties: int,
    seed: int = 0,
    policy: Optional[RetryPolicy] = None,
) -> DropoutSchedule:
    """Draw the deterministic per-round party-availability schedule.

    Each poll of a party fails independently with probability ``rate``;
    the coordinator re-polls up to ``policy.max_retries`` times with
    exponential backoff, then degrades the party for the round.  Identical
    ``(rate, rounds, num_parties, seed, policy)`` always yields the identical
    schedule — the replay property resume and the tests rely on.
    """
    if not 0.0 <= rate < 1.0:
        raise ValueError(f"dropout rate must be in [0, 1), got {rate}")
    policy = policy or RetryPolicy()
    rng = np.random.default_rng([int(seed), _DROPOUT_STREAM])
    attempts = 1 + policy.max_retries
    # One draw per (round, party, attempt): fail while < rate.
    fails = rng.random((rounds, num_parties, attempts)) < rate
    degraded = np.all(fails, axis=-1)
    # Retries spent: index of first success, or max_retries when degraded.
    first_ok = np.argmin(fails, axis=-1)  # argmin of bool = first False
    retries = np.where(degraded, policy.max_retries, first_ok)
    backoff_s = float(sum(
        policy.backoff(a)
        for m in range(rounds) for p in range(num_parties)
        for a in range(int(retries[m, p]))
    ))
    return DropoutSchedule(
        degraded=degraded,
        retries=retries.astype(np.int32),
        backoff_s=backoff_s,
    )


def party_column_slice(party: int, d: int, num_parties: int) -> slice:
    """Columns owned by ``party`` under the repo's even vertical split."""
    if d % num_parties:
        raise ValueError(f"d={d} not divisible by num_parties={num_parties}")
    dp = d // num_parties
    return slice(party * dp, (party + 1) * dp)


def degradation_masks(
    degraded: np.ndarray, d: int, num_parties: int
) -> Optional[np.ndarray]:
    """Lower a (rounds, parties) degradation table to a (rounds, d) mask.

    Round ``m``'s mask is False exactly on the columns of the parties
    degraded in that round — the shape ``train_fedgbf(round_feature_mask=)``
    consumes.  Returns None when nothing is degraded so the no-dropout path
    stays byte-for-byte the pre-§13 program.
    """
    degraded = np.asarray(degraded, dtype=bool)
    if not degraded.any():
        return None
    rounds = degraded.shape[0]
    mask = np.ones((rounds, d), dtype=bool)
    for p in range(num_parties):
        mask[degraded[:, p], party_column_slice(p, d, num_parties)] = False
    if not mask.any(axis=1).all():
        bad = int(np.nonzero(~mask.any(axis=1))[0][0])
        raise ValueError(
            f"round {bad + 1}: every party degraded — no candidates left; "
            "lower --party-dropout or raise the retry budget"
        )
    return mask


def degraded_parties(schedule: DropoutSchedule) -> List[int]:
    """Parties degraded in at least one round (gradientless-fallback set)."""
    return [int(p) for p in np.nonzero(schedule.degraded.any(axis=0))[0]]
