"""Chaos transport: seeded fault injection at the level-exchange seam.

Composes over ANY gather the federated backends use (plain, double-buffered
async, quantized payloads, top-k candidate stacks) and deterministically
injects faults into the party exchange — dropped (zeroed), bit-corrupted,
duplicated, and delayed level payloads — while a checksum channel lets the
receiver *detect* every fault and select the clean retransmission
(DESIGN.md §13).

Fault model
-----------
Each traced exchange — one gather call — is a *slot*.  ``plan_for_slot``
derives the slot's deterministic fault schedule from ``(spec.seed, slot)``
with numpy's counter-based generator: up to ``max_retries`` failed attempts
(drop or corrupt), then one clean transmission, optionally duplicated or
delayed.  The schedule is pure and host-side, so the *predicted* ledger can
replay it byte-for-byte (``protocol.wire_retry_bytes``) without touching the
device program.  By construction the in-graph transport always recovers
within the retry budget; retry *exhaustion* (true party dropout) is modeled
one layer up, in ``federation.runtime``, where the degraded party's feature
candidates leave the split search.

Detection + recovery
--------------------
Every transmission ships the sender's 4-byte checksum of its clean local
payload alongside the (possibly faulted) payload.  The checksum is a
position-weighted byte sum with odd weights, so ANY single bit flip and any
zeroed nonzero payload changes it.  The receiver recomputes per-party
checksums of the gathered result and folds the attempts, taking for every
party slice the first transmission whose checksum verified.  Because the
final attempt is clean, the folded result is bit-identical to the fault-free
gather — faults cost retransmitted bytes and latency, never correctness.
This is what makes the zero-fault configuration (and, for the training
output, even the faulty one) exactly the wrapped transport.

Accounting
----------
The meter records a ``"retries"`` phase: 4 checksum bytes per transmission
plus the full payload for every transmission after the first.  With zero
faults that is exactly 4 bytes per slot (the always-on integrity channel);
under faults it grows by the replayed payloads.  ``protocol.wire_run_cost``
reproduces the same arithmetic from the pure plan, so the ledger's
measured-vs-predicted reconciliation stays exact under retries.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

#: checksum channel width per transmission (uint32 on the wire)
CHECKSUM_BYTES = 4

_PLAN_STREAM = 7919     # rng stream for fault kinds (shared with the ledger)
_DETAIL_STREAM = 104729  # rng stream for victims/bit positions (graph only)


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Seeded fault-injection configuration (frozen + hashable: it rides in
    jit-static backend closures exactly like ``TransportSpec``)."""

    drop: float = 0.0      # P(attempt payload zeroed in flight)
    corrupt: float = 0.0   # P(attempt payload has one bit flipped)
    dup: float = 0.0       # P(clean transmission duplicated)
    delay: float = 0.0     # P(clean transmission delayed — event only)
    seed: int = 0
    max_retries: int = 3

    def __post_init__(self):
        for name in ("drop", "corrupt", "dup", "delay"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"chaos {name} rate {v} outside [0, 1]")
        if self.drop + self.corrupt >= 1.0:
            raise ValueError("drop + corrupt must be < 1 (a transmission "
                             "must be able to succeed)")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    @property
    def zero_fault(self) -> bool:
        return (self.drop == 0.0 and self.corrupt == 0.0
                and self.dup == 0.0 and self.delay == 0.0)

    @property
    def tag(self) -> str:
        return (f"chaos(drop={self.drop},corrupt={self.corrupt},"
                f"dup={self.dup},delay={self.delay},seed={self.seed})")


def plan_for_slot(spec: ChaosSpec, slot: int) -> tuple:
    """Deterministic fault schedule of exchange slot ``slot``.

    Returns ``(fails, final)`` where ``fails`` is a list of failed-attempt
    kinds (``"drop"`` | ``"corrupt"``, at most ``max_retries``) and
    ``final`` is the clean transmission's disposition (``"clean"`` |
    ``"dup"`` | ``"delay"``).  Pure host arithmetic: the ledger replays it.
    """
    rng = np.random.default_rng([spec.seed, _PLAN_STREAM, slot])
    fails = []
    for _ in range(spec.max_retries):
        u = rng.random()
        if u < spec.drop:
            fails.append("drop")
        elif u < spec.drop + spec.corrupt:
            fails.append("corrupt")
        else:
            break
    u = rng.random()
    final = ("dup" if u < spec.dup
             else "delay" if u < spec.dup + spec.delay else "clean")
    return fails, final


def slot_details(spec: ChaosSpec, slot: int, num_parties: int,
                 n_fails: int) -> list:
    """Victim party and bit position of every failed attempt in a slot —
    a separate rng stream, so the byte-accounting side never needs them."""
    rng = np.random.default_rng([spec.seed, _DETAIL_STREAM, slot])
    return [(int(rng.integers(num_parties)), int(rng.integers(1 << 30)))
            for _ in range(n_fails)]


def transmissions_for_slot(spec: ChaosSpec, slot: int) -> int:
    fails, final = plan_for_slot(spec, slot)
    return len(fails) + 1 + (1 if final == "dup" else 0)


def plan_summary(spec: ChaosSpec, n_slots: int) -> dict:
    """Fault events over one traced exchange program (= one boosting round:
    the round program replays the same slots every round)."""
    out = {"dropped": 0, "corrupted": 0, "duplicated": 0, "delayed": 0,
           "retries": 0, "slots": n_slots}
    for s in range(n_slots):
        fails, final = plan_for_slot(spec, s)
        out["dropped"] += sum(1 for k in fails if k == "drop")
        out["corrupted"] += sum(1 for k in fails if k == "corrupt")
        out["duplicated"] += 1 if final == "dup" else 0
        out["delayed"] += 1 if final == "delay" else 0
        out["retries"] += len(fails) + (1 if final == "dup" else 0)
    out["faults_injected"] = (out["dropped"] + out["corrupted"]
                             + out["duplicated"] + out["delayed"])
    return out


def n_slots_per_tree(aggregation: str, max_depth: int) -> int:
    """Exchange slots one traced forest program enumerates: one histogram
    gather per level, or three candidate-stack gathers per level (gain,
    feature, threshold) under argmax/top-k."""
    return max_depth if aggregation == "histogram" else 3 * max_depth


def payload_checksum(x: jnp.ndarray) -> jnp.ndarray:
    """uint32 checksum of a payload's raw bits: position-weighted byte sum
    with odd weights, so any single bit flip — and any zeroing of a nonzero
    payload — changes the value (mod 2^32, odd·2^b ≠ 0 for b < 32)."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)
    u = u.astype(jnp.uint32)
    idx = jnp.arange(u.shape[0], dtype=jnp.uint32)
    weights = idx * jnp.uint32(2654435761) + jnp.uint32(1)
    return jnp.sum(u * weights, dtype=jnp.uint32)


def _flip_one_bit(x: jnp.ndarray, rand: int) -> jnp.ndarray:
    """Flip a deterministic bit of ``x``'s raw representation."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint8)
    nbytes = int(np.prod(u.shape))
    pos = rand % (nbytes * 8)
    mask = np.zeros(nbytes, np.uint8)
    mask[pos // 8] = np.uint8(1 << (pos % 8))
    flipped = u.reshape(-1) ^ jnp.asarray(mask)
    return jax.lax.bitcast_convert_type(flipped.reshape(u.shape), x.dtype)


def _per_party_view(g: jnp.ndarray, axis: Optional[int], parties: int):
    """View the gathered payload as (party, slice): stacked gathers already
    lead with the party axis; tiled gathers fold it out of ``axis``."""
    if axis is None:
        return g, 0
    shape = g.shape
    new = (shape[:axis] + (parties, shape[axis] // parties)
           + shape[axis + 1:])
    return g.reshape(new), axis


class ChaoticGather:
    """Fault-injecting gather, composable over any base exchange.

    Call-compatible with both seams: ``gather(x, party_axis, axis)`` for the
    tiled histogram exchange and ``gather(x, party_axis)`` for the stacked
    top-k candidate exchange.  A trace-time slot counter indexes the fault
    plan; the backend resets it at every forest-builder entry so each traced
    program enumerates slots ``0..L-1`` deterministically.
    """

    def __init__(self, spec: ChaosSpec, base_gather, num_parties: int,
                 meter=None):
        self.spec = spec
        self.base_gather = base_gather
        self.num_parties = num_parties
        self.meter = meter
        self._slot = 0

    def begin_trace(self) -> None:
        self._slot = 0

    def _base(self, x, party_axis, axis):
        if axis is None:  # stacked candidate gather (leading party axis)
            return jax.lax.all_gather(x, party_axis)
        return self.base_gather(x, party_axis, axis)

    def __call__(self, x, party_axis, axis=None):
        slot, self._slot = self._slot, self._slot + 1
        spec, parties = self.spec, self.num_parties
        fails, final = plan_for_slot(spec, slot)
        details = slot_details(spec, slot, parties, len(fails))

        me = jax.lax.axis_index(party_axis)
        chk_clean = payload_checksum(x)
        gathered, oks = [], []
        n_tx = len(fails) + 1 + (1 if final == "dup" else 0)
        for t in range(n_tx):
            if t < len(fails):
                victim, rand = details[t]
                faulted = (jnp.zeros_like(x) if fails[t] == "drop"
                           else _flip_one_bit(x, rand))
                sent = jnp.where(me == victim, faulted, x)
            else:
                sent = x  # clean transmission (and its duplicate)
            g = self._base(sent, party_axis, axis)
            # checksum channel: sender's clean checksum rides every
            # transmission (4 bytes); the receiver verifies per party slice
            chk_all = jax.lax.all_gather(chk_clean, party_axis)
            pv, pax = _per_party_view(g, axis, parties)
            recomputed = jax.vmap(payload_checksum, in_axes=pax,
                                  out_axes=0)(pv)
            gathered.append(g)
            oks.append(recomputed == chk_all)
            if self.meter is not None:
                self.meter.record("retries", chk_all[:1])
                if t > 0:
                    self.meter.record("retries", x)

        # fold: per party slice, first transmission whose checksum verified
        # (the final attempt is clean by construction, so the fold always
        # lands on verified data — bit-identical to the fault-free gather)
        result = gathered[-1]
        for g, ok in zip(reversed(gathered[:-1]), reversed(oks[:-1])):
            pv_g, pax = _per_party_view(g, axis, parties)
            pv_r, _ = _per_party_view(result, axis, parties)
            okb = jnp.expand_dims(
                ok, tuple(i for i in range(pv_g.ndim) if i != pax))
            result = jnp.where(okb, pv_g, pv_r).reshape(g.shape)
        return result
