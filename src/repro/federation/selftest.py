"""Federated-vs-centralized self-checks: strict losslessness + tolerance.

Two equivalence contracts (DESIGN.md §5):

* **strict** (``check*``): lossless backends (raw transports, top-k
  candidate pruning, GOSS masks over a lossless transport) must produce
  trees *bit-identical* to the centralized builder — the SecureBoost
  property the paper's §4.2.1 relies on.
* **tolerance** (``check_tolerance``): lossy transports (quantized
  histogram exchange) cannot be bit-identical by construction; the contract
  is instead a bound on the end-metric delta of a full training run against
  the centralized model (same config, same rng, same masks).

Plus **reconciliation** (``check_reconciliation``): the bytes every
collective actually ships (``compress.probe_tree_cost``) must equal the
predicted wire model (``protocol.wire_run_cost``) *exactly*, for every
transport — payload sizes are shape-determined even when values are lossy.

Sibling subtraction (DESIGN.md §6) slots into the same lattice:
federated-vs-centralized stays *bit-identical* with the pipeline enabled on
both sides; subtraction-vs-direct is a float-reassociation *tolerance*
relation (``check_subtraction_vs_direct``), composing with q8's existing
tolerance bound; and the half-width child payloads reconcile exactly, with
the measured histogram-phase cut asserted >= 1.7x at depth 3
(``check_subtraction_hist_cut``).

The round engine (DESIGN.md §9) extends the lattice again: depth-4/5 trees
under frontier compaction stay *bit-identical* fed-vs-central (compaction is
deterministic in the TreeConfig, so both sides build the same trees); the
traced round program ships exactly ONE histogram collective per level
regardless of the round's tree count (``check_round_collective_counts``);
shared-root caching is a *tolerance* relation like subtraction-vs-direct
(``check_shared_root_tolerance``); and the active-width wire model
reconciles exactly at depth 5 under compaction.

Row sharding and the async exchange (DESIGN.md §8/§10) extend it once more:
training under an explicit ``data_shards=2`` grid — including n uneven over
the shards, padded with weight-0 rows inside the backend — stays
*bit-identical* fed-vs-central; the async double-buffered backends are
bit-identical to their synchronous twins, keep ONE logical histogram
collective per level, and reconcile byte-for-byte; and the bit-packed
id_partition bitmap measures ``ceil(n/8)`` per level (>= 8x under the
legacy encodings, ``check_id_partition_packing``) with the per-shard ceil
arithmetic exact for any shard count.

The objective layer (DESIGN.md §11) widens the whole lattice by a channel
axis: K-channel objectives (softmax3, constant-hessian quantile) must keep
fed-vs-central *bit-identical* through every backend combination, the
widened 2K+1-stat histograms and (n, K) grad broadcast must reconcile
exactly at any K, and the gradient-less party-local mode must ship ZERO
histogram/gradient/routing bytes — its margin/rate inventory reconciled
against ``gradientless.wire_cost`` (``check_gradientless``).

Run in a subprocess with multiple CPU devices, e.g.:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.federation.selftest

Exits non-zero on any mismatch. tests/test_federation.py shells out to this
module so the main pytest process keeps its single-device view.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import use_mesh
from repro.core import binning, boosting, forest, losses, metrics
from repro.core import objective as objective_mod
from repro.core.types import FedGBFConfig, TreeConfig
from repro.federation import compress, gradientless, protocol, vfl


def check(num_parties: int, aggregation: str, shard_samples: bool,
          subtraction: bool = False, max_depth: int = 3,
          max_active_nodes: int = 0, data_shards: int = 0,
          async_exchange: bool = False, n: int = 512,
          loss: str = "logistic") -> None:
    """Fed-vs-central bit-identity.  ``data_shards`` pins the mesh's data
    axis extent (0 = spread all remaining devices); an ``n`` not divisible
    by the data extent exercises the backend's weight-0 row padding.
    ``loss`` selects the objective (DESIGN.md §11): a K-channel objective
    widens g/h to (n, K) and the exchanged histograms to 2K+1 stats, and
    the bit-identity contract must hold unchanged."""
    mesh_axes = ("data", "model")
    n_dev = len(jax.devices())
    data_dim = data_shards or n_dev // num_parties
    mesh = jax.make_mesh((data_dim, num_parties), mesh_axes,
                         devices=jax.devices()[:data_dim * num_parties])

    rng = np.random.default_rng(0)
    obj = objective_mod.get_objective(loss)
    d = num_parties * 3
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, max(2, obj.n_classes), n), jnp.float32)
    cfg = TreeConfig(max_depth=max_depth, num_bins=16,
                     hist_subtraction=subtraction,
                     max_active_nodes=max_active_nodes)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = obj.grad_hess(y, obj.init_raw(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(7), n, d, 4, 0.8, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)

    backend = vfl.make_vfl_backend(
        mesh, cfg, aggregation=aggregation, shard_samples=shard_samples,
        async_exchange=async_exchange,
    )
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)

    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"feature mismatch ({aggregation}, shard_samples={shard_samples})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(
        f"OK lossless: parties={num_parties} aggregation={aggregation} "
        f"shard_samples={shard_samples} subtraction={subtraction} "
        f"depth={max_depth} budget={max_active_nodes} "
        f"data_shards={data_dim} async={async_exchange} n={n} loss={loss}"
    )


def check_no_valid_split(num_parties: int, aggregation: str, degenerate: str) -> None:
    """Equivalence on the degenerate frontier: when NO valid split exists
    anywhere (every gain <= 0, or min_child_weight filters every candidate),
    the federated builders must still produce trees bit-identical to the
    centralized one — all-(-1) features, threshold == B everywhere, and the
    single populated leaf carrying the global weight.  This is the edge the
    argmax aggregation is most exposed to (its per-party candidate exchange
    must agree on "no split" without exchanging histograms)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))

    rng = np.random.default_rng(13)
    n, d = 256, num_parties * 2
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    if degenerate == "gamma":
        # every candidate's gain is pushed below zero
        cfg = TreeConfig(max_depth=2, num_bins=8, gamma=1e9)
    else:
        # every candidate fails the child-weight filter -> gain = -inf
        cfg = TreeConfig(max_depth=2, num_bins=8, min_child_weight=1e9)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(3), n, d, 3, 0.9, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    assert np.all(np.asarray(trees_c.feature) == -1), "expected a split-free tree"

    backend = vfl.make_vfl_backend(mesh, cfg, aggregation=aggregation)
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)

    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"no-valid-split feature mismatch ({aggregation}, {degenerate})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(
        f"OK no-valid-split lossless: parties={num_parties} "
        f"aggregation={aggregation} degenerate={degenerate}"
    )


def check_topk_lossless(num_parties: int, k: int) -> None:
    """Top-k candidate pruning is lossless for ANY k >= 1: every party's own
    best candidate is in its top-k, and the party-major merge reproduces the
    centralized first-occurrence tie-break (compress.topk_choose_fn)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    rng = np.random.default_rng(5)
    n, d = 512, num_parties * 3
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    cfg = TreeConfig(max_depth=3, num_bins=16)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(7), n, d, 4, 0.8, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    backend = vfl.make_vfl_backend(
        mesh, cfg, aggregation="argmax",
        transport=compress.TransportSpec(kind="topk", k=k),
    )
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)
    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"topk feature mismatch (k={k})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(f"OK topk lossless: parties={num_parties} k={k}")


def check_goss_lossless(num_parties: int, aggregation: str) -> None:
    """GOSS is a masking policy, not a transport: the same weighted masks
    fed to the centralized and federated builders must yield bit-identical
    trees (weights ride the existing sample_mask channel)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    rng = np.random.default_rng(11)
    n, d = 512, num_parties * 2
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    cfg = TreeConfig(max_depth=3, num_bins=16)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    n_top, n_rand = forest.goss_counts(n, 0.4, 0.5)
    smask, fmask = forest.goss_masks(
        jax.random.PRNGKey(9), g, d, 3, n_top, n_rand, d
    )

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    backend = vfl.make_vfl_backend(mesh, cfg, aggregation=aggregation)
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)
    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"goss feature mismatch ({aggregation})",
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    print(f"OK goss lossless: parties={num_parties} aggregation={aggregation}")


def _metric_deltas(y, model_a, model_b, x) -> dict:
    out = {}
    for name, fn in (
        ("auc", lambda m: float(metrics.auc(y, boosting.predict(m, x)))),
        ("logloss", lambda m: float(losses.loss_value(
            "logistic", y, boosting.predict(m, x)))),
    ):
        out[name] = abs(fn(model_a) - fn(model_b))
    return out


def _tolerance_data(num_parties: int):
    rng = np.random.default_rng(17)
    n, d = 2000, num_parties * 2
    x = rng.normal(size=(n, d)).astype(np.float32)
    logit = x[:, 0] - 0.8 * x[:, 1] + 0.5 * x[:, 2] * x[:, 3]
    y = (logit + rng.normal(0, 0.7, n) > 0).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


def check_tolerance(
    num_parties: int, aggregation: str, transport, bound: float = 5e-3,
    subtraction: bool = False,
) -> None:
    """Tolerance-based equivalence for LOSSY transports (DESIGN.md §5).

    A quantized exchange cannot reproduce centralized trees bit-for-bit;
    the contract is a bound on the end-metric delta: train the same config
    with the same rng centralized and federated-lossy, and require
    |AUC_c - AUC_f| and |logloss_c - logloss_f| within ``bound``.

    ``subtraction`` composes the sibling-subtraction pipeline with the lossy
    transport ON BOTH SIDES (the federated-vs-centralized contract compares
    like with like; subtraction-vs-direct has its own check).
    """
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    x, y = _tolerance_data(num_parties)
    cfg = FedGBFConfig(
        rounds=4, n_trees_max=3, n_trees_min=2, rho_id_min=0.5, rho_id_max=0.8,
        tree=TreeConfig(max_depth=3, num_bins=32, hist_subtraction=subtraction),
    )

    model_c, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    backend = vfl.make_vfl_backend(
        mesh, cfg.tree, aggregation=aggregation, transport=transport
    )
    with use_mesh(mesh):
        model_f, _ = boosting.train_fedgbf(
            x, y, cfg, jax.random.PRNGKey(0), backend=backend
        )
    deltas = _metric_deltas(y, model_c, model_f, x)
    for name, delta in deltas.items():
        assert delta <= bound, (
            f"{name} delta {delta:.2e} exceeds tolerance {bound:.0e} "
            f"({aggregation}, transport={transport.tag}, "
            f"subtraction={subtraction})"
        )
    print(
        f"OK tolerance: parties={num_parties} transport={transport.tag} "
        f"subtraction={subtraction} "
        + " ".join(f"d_{k}={v:.1e}" for k, v in deltas.items())
    )


def check_subtraction_vs_direct(bound: float = 5e-3) -> None:
    """Subtraction-vs-direct contract (DESIGN.md §6): the derived right
    siblings differ from directly accumulated ones only by float
    reassociation, so full-training end metrics must agree within the same
    tolerance class as the §5 lossy transports (the trees themselves are
    typically identical — a near-tie at a split can legitimately flip)."""
    x, y = _tolerance_data(2)
    # hist_subtraction defaults ON; the direct pass is the explicit oracle.
    base = FedGBFConfig(
        rounds=4, n_trees_max=3, n_trees_min=2, rho_id_min=0.5, rho_id_max=0.8,
        tree=TreeConfig(max_depth=3, num_bins=32, hist_subtraction=False),
    )
    import dataclasses

    sub = dataclasses.replace(
        base, tree=dataclasses.replace(base.tree, hist_subtraction=True)
    )
    model_d, _ = boosting.train_fedgbf(x, y, base, jax.random.PRNGKey(0))
    model_s, _ = boosting.train_fedgbf(x, y, sub, jax.random.PRNGKey(0))
    deltas = _metric_deltas(y, model_d, model_s, x)
    for name, delta in deltas.items():
        assert delta <= bound, (
            f"subtraction-vs-direct {name} delta {delta:.2e} exceeds "
            f"{bound:.0e}"
        )
    print("OK subtraction-vs-direct: "
          + " ".join(f"d_{k}={v:.1e}" for k, v in deltas.items()))


def check_reconciliation(num_parties: int, aggregation: str, transport,
                         shard_samples: bool = False,
                         subtraction: bool = False,
                         max_depth: int = 3,
                         max_active_nodes: int = 0,
                         async_exchange: bool = False,
                         n: int = 1536,
                         n_channels: int = 1) -> None:
    """Measured collective payloads == predicted wire model, exactly —
    including the round engine's active-width model under compaction, the
    data-shard-aware bit-packed id_partition arithmetic (an ``n`` uneven
    over the shards exercises the per-shard ceil), the async exchange
    (double-buffering must not change a byte), and any channel count
    (``n_channels=K`` widens histograms to 2K stats + count and the grad
    broadcast to 2K floats per row; DESIGN.md §11)."""
    data_dim = len(jax.devices()) // num_parties if shard_samples else 1
    mesh = jax.make_mesh((data_dim, num_parties), ("data", "model"))
    tree = TreeConfig(max_depth=max_depth, num_bins=32,
                      hist_subtraction=subtraction,
                      max_active_nodes=max_active_nodes)
    d = num_parties * 2
    per_tree, grad = compress.probe_tree_cost(
        mesh, tree, aggregation=aggregation, transport=transport,
        n_samples=n, num_features=d, shard_samples=shard_samples,
        async_exchange=async_exchange, n_channels=n_channels,
    )
    cfg = FedGBFConfig(rounds=3, n_trees_max=4, n_trees_min=2,
                       rho_id_min=0.2, rho_id_max=0.5)
    spec = protocol.ProtocolSpec(
        n_samples=n, party_dims=(d // num_parties,) * num_parties,
        num_bins=tree.num_bins, max_depth=tree.max_depth,
        aggregation=aggregation, hist_subtraction=subtraction,
        max_active_nodes=max_active_nodes,
        data_shards=data_dim if shard_samples else 1,
        n_channels=n_channels,
    )
    ledger = protocol.ProtocolLedger(spec=spec, cfg=cfg, transport=transport)
    ledger.record_run(per_tree, grad)
    rec = ledger.reconcile()
    assert ledger.matches(), (
        f"measured != predicted for {aggregation}"
        f"/{transport.tag if transport else 'raw'}"
        f"{'+sub' if subtraction else ''}"
        f"{'+async' if async_exchange else ''}: {rec}"
    )
    tag = transport.tag if transport else "raw"
    print(
        f"OK reconciliation: parties={num_parties} {aggregation}/{tag} "
        f"shard_samples={shard_samples} subtraction={subtraction} "
        f"depth={max_depth} budget={max_active_nodes} "
        f"async={async_exchange} n={n} K={n_channels} "
        f"total={rec['total']['measured']} bytes (exact match)"
    )


def check_gradientless(num_parties: int, loss: str = "logistic",
                       n: int = 600) -> None:
    """Gradient-less party-local mode (DESIGN.md §11): no gradient or
    histogram message exists; the wire inventory is passive-party margin
    blocks in + the learned rate vector out, and the measured payloads
    must equal ``gradientless.wire_cost`` exactly (with every protocol
    phase of the gradient-sharing mode identically zero).  The rate fit
    must improve on the plain concatenation of the local models, and every
    tree must reference only its owning party's global column range."""
    obj = objective_mod.get_objective(loss)
    rng = np.random.default_rng(23)
    d = num_parties * 3
    x_np = rng.normal(size=(n, d)).astype(np.float32)
    logit = x_np[:, 0] - 0.8 * x_np[:, 1] + 0.5 * x_np[:, 2] * x_np[:, 3]
    if obj.n_classes > 1:
        cuts = np.quantile(logit, np.linspace(0, 1, obj.n_classes + 1)[1:-1])
        y_np = np.searchsorted(cuts, logit).astype(np.float32)
    else:
        y_np = (logit + rng.normal(0, 0.7, n) > 0).astype(np.float32)
    x, y = jnp.asarray(x_np), jnp.asarray(y_np)
    cfg = FedGBFConfig(
        rounds=3, n_trees_max=3, n_trees_min=2, rho_id_min=0.5,
        rho_id_max=0.8, loss=loss,
        tree=TreeConfig(max_depth=3, num_bins=16),
    )
    meter = compress.MessageMeter()
    packed, info = gradientless.train_gradientless(
        x, y, cfg, jax.random.PRNGKey(0), num_parties, meter=meter,
    )
    assert info["loss_after"] <= info["loss_before"] + 1e-6, info
    # party-locality: party p's trees may only touch columns [p*dp, (p+1)*dp)
    d_party = d // num_parties
    offset = 0
    for p, t_p in enumerate(info["tree_counts"]):
        feats = np.asarray(packed.feature[offset:offset + t_p])
        real = feats[feats >= 0]
        assert ((real >= p * d_party) & (real < (p + 1) * d_party)).all(), (
            f"party {p} tree references foreign columns"
        )
        offset += t_p
    predicted = gradientless.wire_cost(n, info["tree_counts"],
                                       n_channels=obj.n_classes)
    measured = meter.phase_totals()
    for phase in ("histograms", "grad_broadcast", "id_partition"):
        assert measured.get(phase, 0) == 0 == predicted[phase], (
            f"gradient-less mode must ship zero {phase} bytes"
        )
    for phase in ("tree_margins", "tree_scales"):
        assert measured[phase] == predicted[phase], (
            f"{phase}: measured {measured[phase]} != "
            f"predicted {predicted[phase]}"
        )
    print(
        f"OK gradientless: parties={num_parties} loss={loss} "
        f"loss {info['loss_before']:.3f} -> {info['loss_after']:.3f}, "
        f"wire={sum(measured.values())} bytes "
        f"(margins+rates only, exact match)"
    )


def check_round_collective_counts(num_parties: int, n_trees: int,
                                  transport=None,
                                  async_exchange: bool = False) -> None:
    """Round-engine structural contract (DESIGN.md §9): the traced round
    program records exactly ONE histogram collective per level — the whole
    round's (T, active, d_party, B, 3) payload — independent of T.  The
    async backends (§10) must preserve the counts: double-buffering splits
    the transfer, never the logical message (quantized transports record 2
    per level either way: int payload + scales)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    tree = TreeConfig(max_depth=3, num_bins=16)
    rc = compress.probe_round_collectives(
        mesh, tree, n_trees, aggregation="histogram", transport=transport,
        n_samples=512, num_features=num_parties * 2,
        async_exchange=async_exchange,
    )
    counts = rc["counts"]
    per_level = 2 if transport is not None else 1
    assert counts.get("histograms") == per_level * tree.max_depth, counts
    assert counts.get("feature_mask") == tree.max_depth, counts
    assert counts.get("id_partition") == tree.max_depth, counts
    tag = transport.tag if transport else "raw"
    print(f"OK round collectives: parties={num_parties} T={n_trees} "
          f"transport={tag} async={async_exchange} histogram records per "
          f"level == {per_level} ({tree.max_depth} levels)")


def check_id_partition_packing(num_parties: int) -> None:
    """The bit-packed routing broadcast: measured id_partition bytes are
    the ceil(n/8) bitmap, >= 8x under the legacy 1-byte-per-row encoding
    and 32x under the int32 vector the implementation used to psum."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    tree = TreeConfig(max_depth=3, num_bins=16)
    n, d = 1536, num_parties * 2
    per_tree, _ = compress.probe_tree_cost(
        mesh, tree, aggregation="histogram", n_samples=n, num_features=d,
    )
    packed = per_tree["id_partition"]
    assert packed == tree.max_depth * ((n + 7) // 8), per_tree
    unpacked_int32 = tree.max_depth * n * 4
    cut = unpacked_int32 / packed
    assert cut >= 8.0, f"id_partition cut {cut:.1f}x below the 8x bar"
    print(f"OK id_partition packing: {unpacked_int32} -> {packed} B/tree "
          f"({cut:.0f}x cut)")


def check_shared_root_tolerance(num_parties: int, bound: float = 5e-3) -> None:
    """Shared-root caching (DESIGN.md §9) composes with the federated path:
    end metrics of a full run with shared_root on (high-rho schedule, so the
    engines take the delta path) track the direct pipeline within the §5/§6
    tolerance class — centralized and federated alike."""
    import dataclasses

    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    x, y = _tolerance_data(num_parties)
    base = FedGBFConfig(
        rounds=4, n_trees_max=3, n_trees_min=2, rho_id_min=0.6, rho_id_max=0.9,
        tree=TreeConfig(max_depth=3, num_bins=32),
    )
    shared = dataclasses.replace(
        base, tree=dataclasses.replace(base.tree, shared_root=True)
    )
    model_d, _ = boosting.train_fedgbf(x, y, base, jax.random.PRNGKey(0))
    model_s, _ = boosting.train_fedgbf(x, y, shared, jax.random.PRNGKey(0))
    backend = vfl.make_vfl_backend(mesh, shared.tree, aggregation="histogram")
    with use_mesh(mesh):
        model_f, _ = boosting.train_fedgbf(
            x, y, shared, jax.random.PRNGKey(0), backend=backend
        )
    for name, pair in (("central", model_s), ("federated", model_f)):
        deltas = _metric_deltas(y, model_d, pair, x)
        for metric, delta in deltas.items():
            assert delta <= bound, (
                f"shared-root {name} {metric} delta {delta:.2e} exceeds "
                f"{bound:.0e}"
            )
    print("OK shared-root tolerance: central + federated within "
          f"{bound:.0e} of the direct pipeline")


def check_subtraction_hist_cut(num_parties: int, transport) -> None:
    """The subtraction pipeline's measured (ledger-reconciled) histogram-phase
    bytes must show the depth-3 cut: 7 -> 4 node-histograms per tree, i.e.
    exactly 1.75x (>= the 1.7x acceptance bar) — measured from the traced
    programs of both pipelines, not from the formulas."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    n, d = 1536, num_parties * 2
    measured = {}
    for sub in (False, True):
        tree = TreeConfig(max_depth=3, num_bins=32, hist_subtraction=sub)
        per_tree, _ = compress.probe_tree_cost(
            mesh, tree, aggregation="histogram", transport=transport,
            n_samples=n, num_features=d,
        )
        measured[sub] = per_tree["histograms"]
    cut = measured[False] / measured[True]
    tag = transport.tag if transport else "raw"
    assert cut >= 1.7, (
        f"histogram-phase cut {cut:.3f}x below the 1.7x bar ({tag})"
    )
    print(f"OK subtraction hist cut: {tag} "
          f"{measured[False]} -> {measured[True]} B/tree ({cut:.2f}x)")


def _train_named(mesh, tcfg, cfg, x, y, backend_name, **kw):
    from repro.core.backend import get_backend

    with use_mesh(mesh):
        bk = get_backend(backend_name, mesh=mesh, tree=tcfg, **kw)
        model, _ = boosting.train_fedgbf(
            x, y, cfg, jax.random.PRNGKey(0), backend=bk, engine="scan"
        )
    return [np.asarray(l) for l in jax.tree.leaves(model)]


def check_chaos(backend_name: str, num_parties: int = 4,
                n: int = 512) -> None:
    """Chaos transport equivalence (DESIGN.md §13): the ``-chaos`` twin of a
    registry backend must train a bit-identical model — under the zero-fault
    spec (checksums verify but never fire) AND under injected faults (every
    dropped/corrupted transmission is detected by the payload checksum and
    recovered from a retransmission, so faults cost only wire bytes, never
    bits of the result)."""
    from repro.federation import chaos as chaos_mod

    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    tcfg = TreeConfig(max_depth=3, num_bins=16)
    cfg = FedGBFConfig(rounds=2, n_trees_max=3, n_trees_min=2,
                       rho_id_min=0.5, rho_id_max=0.8, tree=tcfg)
    rng = np.random.default_rng(0)
    d = num_parties * 2
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    y = jnp.asarray((rng.normal(size=n) + x[:, 0] > 0).astype(np.float32))

    base = _train_named(mesh, tcfg, cfg, x, y, backend_name)
    zero_fault = _train_named(mesh, tcfg, cfg, x, y, backend_name + "-chaos")
    for a, b in zip(base, zero_fault):
        assert a.shape == b.shape and (a == b).all(), (
            f"{backend_name}-chaos (zero-fault) diverged from {backend_name}"
        )
    spec = chaos_mod.ChaosSpec(drop=0.10, corrupt=0.05, dup=0.05, seed=7)
    faulty = _train_named(mesh, tcfg, cfg, x, y, backend_name + "-chaos",
                          chaos=spec)
    for a, b in zip(base, faulty):
        assert (a == b).all(), (
            f"{backend_name}-chaos under {spec.tag} diverged: a fault "
            "escaped checksum detection"
        )
    print(f"OK chaos bit-identity: {backend_name} (zero-fault AND "
          f"{spec.tag})")


def check_chaos_reconciliation(aggregation: str, transport,
                               num_parties: int = 4, n: int = 777) -> None:
    """Under injected faults the ledger must still reconcile EXACTLY: the
    retried payloads + per-transmission checksums land in the dedicated
    ``retries`` wire phase on both the measured and predicted side."""
    from repro.federation import chaos as chaos_mod

    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    tcfg = TreeConfig(max_depth=3, num_bins=16)
    cfg = FedGBFConfig(rounds=3, n_trees_max=4, n_trees_min=2,
                       rho_id_min=0.2, rho_id_max=0.5)
    spec = chaos_mod.ChaosSpec(drop=0.10, corrupt=0.05, dup=0.05, seed=7)
    ledger = compress.reconciled_ledger(
        mesh, tcfg, cfg, aggregation=aggregation, transport=transport,
        n_samples=n, num_features=num_parties * 2, chaos=spec,
    )
    rec = ledger.reconcile()
    tag = transport.tag if transport else "raw"
    assert ledger.matches(), f"chaos {aggregation}/{tag}: {rec}"
    assert rec["retries"]["measured"] > 0, (
        f"chaos {aggregation}/{tag}: no retry bytes measured under faults"
    )
    print(f"OK chaos reconciliation: {aggregation}/{tag} "
          f"retries={rec['retries']['measured']}B "
          f"total={rec['total']['measured']}B (exact match)")


def check_degradation(num_parties: int = 4, n: int = 512) -> None:
    """Party-dropout degradation oracle (DESIGN.md §13): training with a
    degraded party's columns masked via ``round_feature_mask`` must be
    bit-identical federated-vs-central (the mask composes with the sampled
    candidate masks before the exchange), and no tree may split on a
    degraded column in a masked round."""
    from repro.core.types import pack_ensemble
    from repro.federation import runtime

    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    tcfg = TreeConfig(max_depth=3, num_bins=16)
    cfg = FedGBFConfig(rounds=4, n_trees_max=3, n_trees_min=2,
                       rho_id_min=0.5, rho_id_max=0.8, tree=tcfg)
    rng = np.random.default_rng(3)
    d = num_parties * 2
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    y = jnp.asarray((rng.normal(size=n) + x[:, 0] > 0).astype(np.float32))

    sched = runtime.dropout_schedule(0.6, cfg.rounds, num_parties, seed=11,
                                     policy=runtime.RetryPolicy(max_retries=0))
    mask = runtime.degradation_masks(sched.degraded, d, num_parties)
    assert mask is not None and not mask.all(), (
        "oracle needs at least one degraded (round, party); reseed"
    )
    backend = vfl.make_vfl_backend(mesh, tcfg, aggregation="histogram")
    with use_mesh(mesh):
        model_f, _ = boosting.train_fedgbf(
            x, y, cfg, jax.random.PRNGKey(0), backend=backend,
            round_feature_mask=mask, engine="scan",
        )
    model_c, _ = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), round_feature_mask=mask,
        engine="scan",
    )
    for a, b in zip(jax.tree.leaves(model_f), jax.tree.leaves(model_c)):
        assert (np.asarray(a) == np.asarray(b)).all(), (
            "degraded fed run diverged from the masked-candidate oracle"
        )
    # no split on a masked column: walk each round's trees
    packed = pack_ensemble(model_c)
    for r in range(packed.rounds):
        trees_r = packed.round_trees(r)
        feats = np.asarray(trees_r.feature)
        gains = np.asarray(trees_r.gain)
        banned = np.nonzero(~mask[r])[0]
        hit = np.isin(feats, banned) & (gains > 0)
        assert not hit.any(), (
            f"round {r + 1} split on degraded column(s) "
            f"{np.unique(feats[hit])}"
        )
    n_deg = int(sched.degraded.sum())
    print(f"OK degradation oracle: {n_deg} degraded (round, party) cells, "
          "fed == masked-candidate central (bit-identical), no banned splits")


def chaos_main() -> int:
    """The §13 slice of the lattice (``--chaos``): chaos twins across the
    transport x aggregation x async x sharded axes, exact reconciliation
    under faults, and the party-dropout degradation oracle."""
    n_dev = len(jax.devices())
    if n_dev < 4:
        print(f"need >= 4 devices, got {n_dev} (set XLA_FLAGS)",
              file=sys.stderr)
        return 2
    for name in ("vfl-histogram", "vfl-histogram-q8", "vfl-histogram-q16",
                 "vfl-argmax", "vfl-argmax-topk", "vfl-histogram-async",
                 "vfl-histogram-async-q8", "vfl-histogram-sharded"):
        check_chaos(name)
    for aggregation, transport in (
        ("histogram", None), ("histogram", compress.Q8),
        ("argmax", None), ("argmax", compress.TOPK),
    ):
        check_chaos_reconciliation(aggregation, transport)
    check_degradation()
    print("ALL CHAOS SELF-TESTS PASSED")
    return 0


def main() -> int:
    n_dev = len(jax.devices())
    if n_dev < 4:
        print(f"need >= 4 devices, got {n_dev} (set XLA_FLAGS)", file=sys.stderr)
        return 2
    for aggregation in ("histogram", "argmax"):
        for shard_samples in (False, True):
            check(num_parties=4, aggregation=aggregation, shard_samples=shard_samples)
    check(num_parties=2, aggregation="histogram", shard_samples=True)
    # Row sharding (DESIGN.md §8): explicit data_shards=2 grid, both
    # aggregations, plus an n uneven over the shards — the backend pads
    # with weight-0 rows and the result stays bit-identical.
    for aggregation in ("histogram", "argmax"):
        check(num_parties=2, aggregation=aggregation, shard_samples=True,
              data_shards=2)
    check(num_parties=2, aggregation="histogram", shard_samples=True,
          data_shards=2, n=509)
    check(num_parties=4, aggregation="histogram", shard_samples=True,
          data_shards=2, subtraction=True, n=507)
    # Async double-buffered exchange (DESIGN.md §10): bit-identical to the
    # synchronous path, composing with sharding, subtraction, compaction.
    check(num_parties=4, aggregation="histogram", shard_samples=False,
          async_exchange=True)
    check(num_parties=4, aggregation="histogram", shard_samples=True,
          async_exchange=True, subtraction=True)
    check(num_parties=2, aggregation="histogram", shard_samples=True,
          data_shards=2, async_exchange=True, n=509)
    check(num_parties=4, aggregation="histogram", shard_samples=False,
          async_exchange=True, subtraction=True, max_depth=4,
          max_active_nodes=4)
    # K-channel objectives (DESIGN.md §11): softmax3 widens g/h to (n, 3)
    # and the exchanged histograms to 7 stats — bit-identity must survive
    # every backend axis it composes with (sharding, subtraction, async,
    # compaction), and quantile exercises the constant-hessian path.
    for aggregation in ("histogram", "argmax"):
        check(num_parties=4, aggregation=aggregation, shard_samples=False,
              loss="softmax3")
    check(num_parties=4, aggregation="histogram", shard_samples=True,
          subtraction=True, loss="softmax3")
    check(num_parties=4, aggregation="histogram", shard_samples=False,
          async_exchange=True, subtraction=True, loss="softmax3")
    check(num_parties=2, aggregation="histogram", shard_samples=True,
          data_shards=2, loss="softmax3", n=509)
    check(num_parties=4, aggregation="histogram", shard_samples=False,
          subtraction=True, max_depth=4, max_active_nodes=4, loss="softmax3")
    check(num_parties=4, aggregation="histogram", shard_samples=False,
          loss="quantile@0.9")
    # Gradient-less party-local mode (DESIGN.md §11): zero-histogram wire
    # inventory, exact margin/rate byte accounting, party-local trees.
    check_gradientless(num_parties=4, loss="logistic")
    check_gradientless(num_parties=2, loss="softmax3")
    # Sibling subtraction (DESIGN.md §6): federated-vs-centralized stays
    # bit-identical with the pipeline enabled on BOTH sides; the
    # subtraction-vs-direct relation is a separate tolerance contract.
    for aggregation in ("histogram", "argmax"):
        check(num_parties=4, aggregation=aggregation, shard_samples=False,
              subtraction=True)
    check(num_parties=4, aggregation="histogram", shard_samples=True,
          subtraction=True)
    check_subtraction_vs_direct()
    # Round engine (DESIGN.md §9): deep trees under frontier compaction stay
    # bit-identical fed-vs-central (compaction is deterministic in the cfg,
    # so both sides build the same trees), one collective per level
    # regardless of T, and shared-root caching stays in tolerance.
    for max_depth, budget in ((4, 4), (5, 4), (5, 8)):
        check(num_parties=4, aggregation="histogram", shard_samples=False,
              subtraction=True, max_depth=max_depth, max_active_nodes=budget)
    check(num_parties=4, aggregation="argmax", shard_samples=False,
          subtraction=False, max_depth=5, max_active_nodes=4)
    check(num_parties=4, aggregation="histogram", shard_samples=True,
          subtraction=True, max_depth=4, max_active_nodes=4)
    for n_trees in (1, 4):
        check_round_collective_counts(num_parties=4, n_trees=n_trees)
    # one logical collective per level survives the async double-buffering
    for transport in (None, compress.Q8):
        check_round_collective_counts(num_parties=4, n_trees=4,
                                      transport=transport,
                                      async_exchange=True)
    check_id_partition_packing(num_parties=4)
    check_shared_root_tolerance(num_parties=2)
    for aggregation in ("histogram", "argmax"):
        for degenerate in ("gamma", "min_child_weight"):
            check_no_valid_split(4, aggregation, degenerate)
    # Compression subsystem (DESIGN.md §5): strict for the lossless pieces,
    # tolerance for the quantized transports, exact byte reconciliation for all.
    for k in (1, 4):
        check_topk_lossless(num_parties=4, k=k)
    for aggregation in ("histogram", "argmax"):
        check_goss_lossless(num_parties=4, aggregation=aggregation)
    for transport in (compress.Q8, compress.Q16):
        check_tolerance(num_parties=2, aggregation="histogram",
                        transport=transport)
    # q8 composes with the subtraction pipeline under the same bound.
    check_tolerance(num_parties=2, aggregation="histogram",
                    transport=compress.Q8, subtraction=True)
    for aggregation, transport in (
        ("histogram", None), ("histogram", compress.Q8),
        ("histogram", compress.Q16), ("argmax", None),
        ("argmax", compress.TOPK),
    ):
        check_reconciliation(4, aggregation, transport)
    # subtraction: half-width child payloads must reconcile exactly too,
    # and the measured histogram-phase cut must clear the 1.7x bar.
    for aggregation, transport in (
        ("histogram", None), ("histogram", compress.Q8), ("argmax", None),
    ):
        check_reconciliation(4, aggregation, transport, subtraction=True)
    for transport in (None, compress.Q8):
        check_subtraction_hist_cut(4, transport)
    # depth-5 compaction: the active-width wire model reconciles exactly,
    # raw and quantized, with and without the subtraction halving.
    for transport, subtraction in ((None, True), (None, False),
                                   (compress.Q8, True)):
        check_reconciliation(4, "histogram", transport,
                             subtraction=subtraction, max_depth=5,
                             max_active_nodes=4)
    # sharded: the data-sharded routing psum must scale back to the global
    # payload (per-shard slice x shard count)
    check_reconciliation(4, "histogram", compress.Q8, shard_samples=True)
    check_reconciliation(2, "argmax", None, shard_samples=True)
    # uneven n over the shards: the per-shard ceil(ceil(n/shards)/8) bitmap
    # arithmetic must reconcile exactly (rows pad inside the backend)
    check_reconciliation(4, "histogram", None, shard_samples=True, n=1531)
    check_reconciliation(2, "argmax", None, shard_samples=True, n=999)
    # async: double-buffering must not change a single byte
    check_reconciliation(4, "histogram", None, async_exchange=True)
    check_reconciliation(4, "histogram", compress.Q16, async_exchange=True)
    check_reconciliation(4, "histogram", compress.Q8, shard_samples=True,
                         subtraction=True, async_exchange=True, n=1531)
    # K channels: the widened stats axis (2K floats per bin + per-channel
    # q8/q16 scales) and the (n, K) grad broadcast reconcile exactly at
    # K=3, raw and quantized, composing with subtraction + sharding + async
    check_reconciliation(4, "histogram", None, n_channels=3)
    check_reconciliation(4, "histogram", compress.Q8, subtraction=True,
                         n_channels=3)
    check_reconciliation(4, "histogram", compress.Q8, shard_samples=True,
                         subtraction=True, async_exchange=True, n=1531,
                         n_channels=3)
    print("ALL FEDERATION SELF-TESTS PASSED")
    return 0


if __name__ == "__main__":
    # ``--chaos`` runs ONLY the §13 fault-tolerance slice (chaos twins,
    # faulty reconciliation, degradation oracle); the default run is the
    # original lattice, so tier-1 runtime is unchanged.
    sys.exit(chaos_main() if "--chaos" in sys.argv[1:] else main())
