"""Losslessness self-check: federated (shard_map) == centralized trees.

Run in a subprocess with multiple CPU devices, e.g.:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.federation.selftest

Exits non-zero on any mismatch. tests/test_federation.py shells out to this
module so the main pytest process keeps its single-device view.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import use_mesh
from repro.core import binning, forest, losses
from repro.core.types import TreeConfig
from repro.federation import vfl


def check(num_parties: int, aggregation: str, shard_samples: bool) -> None:
    mesh_axes = ("data", "model")
    n_dev = len(jax.devices())
    data_dim = n_dev // num_parties
    mesh = jax.make_mesh((data_dim, num_parties), mesh_axes)

    rng = np.random.default_rng(0)
    n, d = 512, num_parties * 3
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    cfg = TreeConfig(max_depth=3, num_bins=16)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(7), n, d, 4, 0.8, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)

    backend = vfl.make_vfl_backend(
        mesh, cfg, aggregation=aggregation, shard_samples=shard_samples
    )
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)

    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"feature mismatch ({aggregation}, shard_samples={shard_samples})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(
        f"OK lossless: parties={num_parties} aggregation={aggregation} "
        f"shard_samples={shard_samples}"
    )


def check_no_valid_split(num_parties: int, aggregation: str, degenerate: str) -> None:
    """Equivalence on the degenerate frontier: when NO valid split exists
    anywhere (every gain <= 0, or min_child_weight filters every candidate),
    the federated builders must still produce trees bit-identical to the
    centralized one — all-(-1) features, threshold == B everywhere, and the
    single populated leaf carrying the global weight.  This is the edge the
    argmax aggregation is most exposed to (its per-party candidate exchange
    must agree on "no split" without exchanging histograms)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))

    rng = np.random.default_rng(13)
    n, d = 256, num_parties * 2
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    if degenerate == "gamma":
        # every candidate's gain is pushed below zero
        cfg = TreeConfig(max_depth=2, num_bins=8, gamma=1e9)
    else:
        # every candidate fails the child-weight filter -> gain = -inf
        cfg = TreeConfig(max_depth=2, num_bins=8, min_child_weight=1e9)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(3), n, d, 3, 0.9, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    assert np.all(np.asarray(trees_c.feature) == -1), "expected a split-free tree"

    backend = vfl.make_vfl_backend(mesh, cfg, aggregation=aggregation)
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)

    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"no-valid-split feature mismatch ({aggregation}, {degenerate})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(
        f"OK no-valid-split lossless: parties={num_parties} "
        f"aggregation={aggregation} degenerate={degenerate}"
    )


def main() -> int:
    n_dev = len(jax.devices())
    if n_dev < 4:
        print(f"need >= 4 devices, got {n_dev} (set XLA_FLAGS)", file=sys.stderr)
        return 2
    for aggregation in ("histogram", "argmax"):
        for shard_samples in (False, True):
            check(num_parties=4, aggregation=aggregation, shard_samples=shard_samples)
    check(num_parties=2, aggregation="histogram", shard_samples=True)
    for aggregation in ("histogram", "argmax"):
        for degenerate in ("gamma", "min_child_weight"):
            check_no_valid_split(4, aggregation, degenerate)
    print("ALL FEDERATION SELF-TESTS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
