"""Federated-vs-centralized self-checks: strict losslessness + tolerance.

Two equivalence contracts (DESIGN.md §7):

* **strict** (``check*``): lossless backends (raw transports, top-k
  candidate pruning, GOSS masks over a lossless transport) must produce
  trees *bit-identical* to the centralized builder — the SecureBoost
  property the paper's §4.2.1 relies on.
* **tolerance** (``check_tolerance``): lossy transports (quantized
  histogram exchange) cannot be bit-identical by construction; the contract
  is instead a bound on the end-metric delta of a full training run against
  the centralized model (same config, same rng, same masks).

Plus **reconciliation** (``check_reconciliation``): the bytes every
collective actually ships (``compress.probe_tree_cost``) must equal the
predicted wire model (``protocol.wire_run_cost``) *exactly*, for every
transport — payload sizes are shape-determined even when values are lossy.

Run in a subprocess with multiple CPU devices, e.g.:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.federation.selftest

Exits non-zero on any mismatch. tests/test_federation.py shells out to this
module so the main pytest process keeps its single-device view.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import use_mesh
from repro.core import binning, boosting, forest, losses, metrics
from repro.core.types import FedGBFConfig, TreeConfig
from repro.federation import compress, protocol, vfl


def check(num_parties: int, aggregation: str, shard_samples: bool) -> None:
    mesh_axes = ("data", "model")
    n_dev = len(jax.devices())
    data_dim = n_dev // num_parties
    mesh = jax.make_mesh((data_dim, num_parties), mesh_axes)

    rng = np.random.default_rng(0)
    n, d = 512, num_parties * 3
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    cfg = TreeConfig(max_depth=3, num_bins=16)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(7), n, d, 4, 0.8, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)

    backend = vfl.make_vfl_backend(
        mesh, cfg, aggregation=aggregation, shard_samples=shard_samples
    )
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)

    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"feature mismatch ({aggregation}, shard_samples={shard_samples})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(
        f"OK lossless: parties={num_parties} aggregation={aggregation} "
        f"shard_samples={shard_samples}"
    )


def check_no_valid_split(num_parties: int, aggregation: str, degenerate: str) -> None:
    """Equivalence on the degenerate frontier: when NO valid split exists
    anywhere (every gain <= 0, or min_child_weight filters every candidate),
    the federated builders must still produce trees bit-identical to the
    centralized one — all-(-1) features, threshold == B everywhere, and the
    single populated leaf carrying the global weight.  This is the edge the
    argmax aggregation is most exposed to (its per-party candidate exchange
    must agree on "no split" without exchanging histograms)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))

    rng = np.random.default_rng(13)
    n, d = 256, num_parties * 2
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    if degenerate == "gamma":
        # every candidate's gain is pushed below zero
        cfg = TreeConfig(max_depth=2, num_bins=8, gamma=1e9)
    else:
        # every candidate fails the child-weight filter -> gain = -inf
        cfg = TreeConfig(max_depth=2, num_bins=8, min_child_weight=1e9)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(3), n, d, 3, 0.9, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    assert np.all(np.asarray(trees_c.feature) == -1), "expected a split-free tree"

    backend = vfl.make_vfl_backend(mesh, cfg, aggregation=aggregation)
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)

    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"no-valid-split feature mismatch ({aggregation}, {degenerate})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(
        f"OK no-valid-split lossless: parties={num_parties} "
        f"aggregation={aggregation} degenerate={degenerate}"
    )


def check_topk_lossless(num_parties: int, k: int) -> None:
    """Top-k candidate pruning is lossless for ANY k >= 1: every party's own
    best candidate is in its top-k, and the party-major merge reproduces the
    centralized first-occurrence tie-break (compress.topk_choose_fn)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    rng = np.random.default_rng(5)
    n, d = 512, num_parties * 3
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    cfg = TreeConfig(max_depth=3, num_bins=16)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(7), n, d, 4, 0.8, 1.0)

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    backend = vfl.make_vfl_backend(
        mesh, cfg, aggregation="argmax",
        transport=compress.TransportSpec(kind="topk", k=k),
    )
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)
    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"topk feature mismatch (k={k})",
    )
    np.testing.assert_array_equal(
        np.asarray(trees_c.threshold), np.asarray(trees_f.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(pred_c), np.asarray(pred_f), rtol=1e-5, atol=1e-6
    )
    print(f"OK topk lossless: parties={num_parties} k={k}")


def check_goss_lossless(num_parties: int, aggregation: str) -> None:
    """GOSS is a masking policy, not a transport: the same weighted masks
    fed to the centralized and federated builders must yield bit-identical
    trees (weights ride the existing sample_mask channel)."""
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    rng = np.random.default_rng(11)
    n, d = 512, num_parties * 2
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    cfg = TreeConfig(max_depth=3, num_bins=16)

    binned, _ = binning.fit_bin(x, cfg.num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    n_top, n_rand = forest.goss_counts(n, 0.4, 0.5)
    smask, fmask = forest.goss_masks(
        jax.random.PRNGKey(9), g, d, 3, n_top, n_rand, d
    )

    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    backend = vfl.make_vfl_backend(mesh, cfg, aggregation=aggregation)
    with use_mesh(mesh):
        trees_f, pred_f = backend.build_forest(binned, g, h, smask, fmask, cfg)
    np.testing.assert_array_equal(
        np.asarray(trees_c.feature), np.asarray(trees_f.feature),
        err_msg=f"goss feature mismatch ({aggregation})",
    )
    np.testing.assert_allclose(
        np.asarray(trees_c.leaf_weight), np.asarray(trees_f.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    print(f"OK goss lossless: parties={num_parties} aggregation={aggregation}")


def check_tolerance(
    num_parties: int, aggregation: str, transport, bound: float = 5e-3
) -> None:
    """Tolerance-based equivalence for LOSSY transports (DESIGN.md §7).

    A quantized exchange cannot reproduce centralized trees bit-for-bit;
    the contract is a bound on the end-metric delta: train the same config
    with the same rng centralized and federated-lossy, and require
    |AUC_c - AUC_f| and |logloss_c - logloss_f| within ``bound``.
    """
    mesh = jax.make_mesh((1, num_parties), ("data", "model"))
    rng = np.random.default_rng(17)
    n, d = 2000, num_parties * 2
    x = rng.normal(size=(n, d)).astype(np.float32)
    logit = x[:, 0] - 0.8 * x[:, 1] + 0.5 * x[:, 2] * x[:, 3]
    y = (logit + rng.normal(0, 0.7, n) > 0).astype(np.float32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    cfg = FedGBFConfig(
        rounds=4, n_trees_max=3, n_trees_min=2, rho_id_min=0.5, rho_id_max=0.8,
        tree=TreeConfig(max_depth=3, num_bins=32),
    )

    model_c, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    backend = vfl.make_vfl_backend(
        mesh, cfg.tree, aggregation=aggregation, transport=transport
    )
    with use_mesh(mesh):
        model_f, _ = boosting.train_fedgbf(
            x, y, cfg, jax.random.PRNGKey(0), backend=backend
        )
    deltas = {}
    for name, fn in (
        ("auc", lambda m: float(metrics.auc(y, boosting.predict(m, x)))),
        ("logloss", lambda m: float(losses.loss_value(
            "logistic", y, boosting.predict(m, x)))),
    ):
        deltas[name] = abs(fn(model_c) - fn(model_f))
        assert deltas[name] <= bound, (
            f"{name} delta {deltas[name]:.2e} exceeds tolerance {bound:.0e} "
            f"({aggregation}, transport={transport.tag})"
        )
    print(
        f"OK tolerance: parties={num_parties} transport={transport.tag} "
        + " ".join(f"d_{k}={v:.1e}" for k, v in deltas.items())
    )


def check_reconciliation(num_parties: int, aggregation: str, transport,
                         shard_samples: bool = False) -> None:
    """Measured collective payloads == predicted wire model, exactly."""
    data_dim = len(jax.devices()) // num_parties if shard_samples else 1
    mesh = jax.make_mesh((data_dim, num_parties), ("data", "model"))
    tree = TreeConfig(max_depth=3, num_bins=32)
    n, d = 1536, num_parties * 2
    per_tree, grad = compress.probe_tree_cost(
        mesh, tree, aggregation=aggregation, transport=transport,
        n_samples=n, num_features=d, shard_samples=shard_samples,
    )
    cfg = FedGBFConfig(rounds=3, n_trees_max=4, n_trees_min=2,
                       rho_id_min=0.2, rho_id_max=0.5)
    spec = protocol.ProtocolSpec(
        n_samples=n, party_dims=(d // num_parties,) * num_parties,
        num_bins=tree.num_bins, max_depth=tree.max_depth,
        aggregation=aggregation,
    )
    ledger = protocol.ProtocolLedger(spec=spec, cfg=cfg, transport=transport)
    ledger.record_run(per_tree, grad)
    rec = ledger.reconcile()
    assert ledger.matches(), (
        f"measured != predicted for {aggregation}"
        f"/{transport.tag if transport else 'raw'}: {rec}"
    )
    tag = transport.tag if transport else "raw"
    print(
        f"OK reconciliation: parties={num_parties} {aggregation}/{tag} "
        f"shard_samples={shard_samples} "
        f"total={rec['total']['measured']} bytes (exact match)"
    )


def main() -> int:
    n_dev = len(jax.devices())
    if n_dev < 4:
        print(f"need >= 4 devices, got {n_dev} (set XLA_FLAGS)", file=sys.stderr)
        return 2
    for aggregation in ("histogram", "argmax"):
        for shard_samples in (False, True):
            check(num_parties=4, aggregation=aggregation, shard_samples=shard_samples)
    check(num_parties=2, aggregation="histogram", shard_samples=True)
    for aggregation in ("histogram", "argmax"):
        for degenerate in ("gamma", "min_child_weight"):
            check_no_valid_split(4, aggregation, degenerate)
    # Compression subsystem (DESIGN.md §7): strict for the lossless pieces,
    # tolerance for the quantized transports, exact byte reconciliation for all.
    for k in (1, 4):
        check_topk_lossless(num_parties=4, k=k)
    for aggregation in ("histogram", "argmax"):
        check_goss_lossless(num_parties=4, aggregation=aggregation)
    for transport in (compress.Q8, compress.Q16):
        check_tolerance(num_parties=2, aggregation="histogram",
                        transport=transport)
    for aggregation, transport in (
        ("histogram", None), ("histogram", compress.Q8),
        ("histogram", compress.Q16), ("argmax", None),
        ("argmax", compress.TOPK),
    ):
        check_reconciliation(4, aggregation, transport)
    # sharded: the data-sharded routing psum must scale back to the global
    # payload (per-shard slice x shard count)
    check_reconciliation(4, "histogram", compress.Q8, shard_samples=True)
    check_reconciliation(2, "argmax", None, shard_samples=True)
    print("ALL FEDERATION SELF-TESTS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
