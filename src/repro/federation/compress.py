"""Message compression for the VFL transport (DESIGN.md §5).

The paper's pitch is cutting SecureBoost's "high interactive communication
costs"; this module supplies the two standard levers SecureBoost+ applies to
the dominant protocol message (the per-level histogram exchange) plus the
measurement plumbing that makes every saving verifiable:

* **Quantized histogram exchange** (``TransportSpec(kind="quantized")``):
  each party quantizes its local (g, h) histogram channels to int8/int16
  with one float32 scale per (node, feature, channel) and ships the integer
  payload + scales instead of full-precision float32 triples.  Rounding is
  stochastic (unbiased) by default.  The count channel is *not* shipped —
  split search (``core.split.split_gains``) reads only the g/h channels, and
  leaf statistics are computed locally by the active party (Alg. 2 step 14)
  — so the dequantized global histogram carries a zero count channel.
  Bytes per (node, feature): ``B·2·bits/8 + 2·4`` vs ``B·3·4`` raw — 5.3×
  smaller for int8 at B = 32.

* **Top-k candidate pruning** (``TransportSpec(kind="topk")``): the argmax
  aggregation generalized — each party ships its k best (gain, feature,
  threshold) tuples per node instead of exactly one.  k = 1 *is* the argmax
  mode; any k ≥ 1 stays lossless for split selection (every party's own best
  is in its top-k, and the party-major merge order reproduces the
  centralized first-occurrence tie-break), so the knob buys headroom for
  gain-perturbing transports (quantized gains, DP noise) at k·12 bytes per
  node per party — still ~d·B/k smaller than the histogram exchange.

* **MessageMeter / probe_tree_cost**: every party-axis collective in
  ``federation/aggregator.py`` (and this module) reports the *actual* payload
  it ships — size × itemsize of the traced operand — into an optional meter.
  ``probe_tree_cost`` abstractly evaluates a backend's real forest program
  (``jax.eval_shape``, no FLOPs) with a fresh meter and returns measured
  bytes per tree, which ``federation.protocol`` reconciles against the
  predicted wire model (``ProtocolLedger``).  Measuring the traced program
  rather than re-deriving formulas is the point: any drift between the
  implementation and the cost model shows up as a reconciliation mismatch.

GOSS sample subsampling — the third SecureBoost+ lever — is a sampling-mask
policy, not a transport, and lives in ``core/forest.py``
(``goss_masks_from_keys``) gated by ``FedGBFConfig.sampling``.

Sibling subtraction (``TreeConfig.hist_subtraction``, DESIGN.md §6) is a
*pipeline* lever orthogonal to all of the above: levels >= 1 exchange only
the left-child histograms (``histogram.as_child_fn`` adapts every provider
here and in aggregator.py, so quantized payloads halve too) and the ledger's
wire model halves the per-level node count to match — the reconciliation
contract stays exact either way.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import histogram as hist_mod
from repro.core import split as split_mod
from repro.core.types import TreeConfig
from repro.federation import mesh_roles

#: histogram stat channels that traverse the wire under quantization for a
#: SCALAR (K = 1) objective — split search needs only (sum_g, sum_h); the
#: count channel stays local.  K-channel objectives ship 2K wire channels
#: (the providers slice ``[..., :-1]``: everything but the trailing count).
GH_STATS = 2


@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """Wire format of the per-level VFL exchange (hashable, jit-static).

    ``kind``:
      ``"raw"``        full-precision float32 payloads (the PR-1 behavior);
      ``"quantized"``  int``bits`` histogram payload + per-(node, feature,
                       channel) float32 scales (histogram aggregation only);
      ``"topk"``       ``k`` best (gain, feature, threshold) tuples per node
                       per party (argmax aggregation only).
    """

    kind: str = "raw"
    bits: int = 8          # quantized: integer payload width (8 | 16)
    k: int = 4             # topk: candidates per node per party
    stochastic: bool = True  # quantized: stochastic (unbiased) rounding
    seed: int = 0          # quantized: rounding-noise key root

    def __post_init__(self):
        if self.kind not in ("raw", "quantized", "topk"):
            raise ValueError(f"unknown transport kind {self.kind!r}")
        if self.kind == "quantized" and self.bits not in (8, 16):
            raise ValueError(f"quantized transport needs bits in (8, 16), got {self.bits}")
        if self.kind == "topk" and self.k < 1:
            raise ValueError(f"topk transport needs k >= 1, got {self.k}")

    @property
    def tag(self) -> str:
        """Short name used in backend impl strings ("q8", "q16", "topk")."""
        if self.kind == "quantized":
            return f"q{self.bits}"
        if self.kind == "topk":
            return "topk"
        return "raw"


RAW = TransportSpec()
Q8 = TransportSpec(kind="quantized", bits=8)
Q16 = TransportSpec(kind="quantized", bits=16)
TOPK = TransportSpec(kind="topk", k=4)


def reconciled_ledger(
    mesh,
    tree: TreeConfig,
    cfg,
    aggregation: str = "histogram",
    transport: Optional[TransportSpec] = None,
    n_samples: int = 1024,
    num_features: Optional[int] = None,
    shard_samples: bool = False,
    async_exchange: bool = False,
    n_channels: int = 1,
    chaos=None,
):
    """One-call measured-vs-predicted accounting for a training run.

    Probes the backend's actual per-tree payloads (``probe_tree_cost``),
    builds the matching ``ProtocolSpec`` (wire predictions need the even
    party shard dims and, under row sharding, the data-shard count — the
    per-shard id_partition bitmaps round up independently), and returns a
    ``protocol.ProtocolLedger`` with the measured side recorded — ready for
    ``reconcile()`` / ``breakdown()``.  The shared entry point of every
    driver (launcher, example, comm_bench), so the reconciliation contract
    lives in one place.  Pass the *backend's own* transport
    (``descriptor.transport_spec``) — never reconstruct it from the tag,
    which cannot carry non-default parameters.
    """
    from repro.federation import protocol  # local: protocol is core-only

    num_parties = mesh.shape[mesh_roles.PARTY_AXIS]
    d = num_features if num_features is not None else num_parties * 2
    per_tree, grad = probe_tree_cost(
        mesh, tree, aggregation=aggregation, transport=transport,
        n_samples=n_samples, num_features=d, shard_samples=shard_samples,
        async_exchange=async_exchange, n_channels=n_channels, chaos=chaos,
    )
    data_shards = 1
    if shard_samples:
        for ax in mesh_roles.data_axes(mesh):
            data_shards *= mesh.shape[ax]
    spec = protocol.ProtocolSpec(
        n_samples=n_samples, party_dims=(d // num_parties,) * num_parties,
        num_bins=tree.num_bins, max_depth=tree.max_depth,
        aggregation=aggregation, hist_subtraction=tree.hist_subtraction,
        max_active_nodes=tree.max_active_nodes, data_shards=data_shards,
        n_channels=n_channels,
    )
    ledger = protocol.ProtocolLedger(spec=spec, cfg=cfg, transport=transport,
                                     chaos=chaos)
    ledger.record_run(per_tree, grad)
    return ledger


# ---------------------------------------------------------------------------
# Quantization codec
# ---------------------------------------------------------------------------
def quantize_stats(
    x: jnp.ndarray, bits: int, key: jax.Array, stochastic: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize histogram stats to int``bits`` along the bin axis.

    Args:
      x: (..., B, C) float32 — per-bin stats (the bin axis is second-last).
      bits: 8 or 16.
      key: PRNG key for the stochastic-rounding noise.
      stochastic: unbiased stochastic rounding (floor(x/s + u)); nearest
        rounding otherwise.

    Returns:
      (q, scale): q (..., B, C) int8/int16; scale (..., C) float32 with
      ``x ≈ q * scale[..., None, :]``.  All-zero (node, feature, channel)
      slices get scale 1 so dequantization is exact there.
    """
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(x), axis=-2, keepdims=True)          # (..., 1, C)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    y = x / scale
    if stochastic:
        y = jnp.floor(y + jax.random.uniform(key, x.shape))
    else:
        y = jnp.round(y)
    dtype = jnp.int8 if bits == 8 else jnp.int16
    q = jnp.clip(y, -qmax, qmax).astype(dtype)
    return q, scale[..., 0, :].astype(jnp.float32)


def dequantize_stats(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``quantize_stats``: (..., B, C) int × (..., C) → float32."""
    return q.astype(jnp.float32) * scale[..., None, :]


# ---------------------------------------------------------------------------
# Measured-bytes plumbing
# ---------------------------------------------------------------------------
class MessageMeter:
    """Trace-time recorder of actual collective payload sizes.

    Collective wrappers call ``record(phase, operand)`` on the operand they
    are about to ship; the size is read off the (possibly abstract) array —
    ``size × dtype.itemsize`` — so metering works under ``jax.eval_shape``
    with zero run-time cost.  Entries accumulate once per *trace*, not per
    execution, so a meter is a probing device: attach a fresh meter to a
    fresh backend and trace exactly one program (``probe_tree_cost``), then
    scale by the schedule (``protocol.measured_run_cost``).  Backends built
    without a meter skip recording entirely.
    """

    def __init__(self) -> None:
        self.entries: list = []

    def record(self, phase: str, array) -> None:
        self.entries.append(
            {"phase": phase, "nbytes": int(array.size) * array.dtype.itemsize}
        )

    def phase_totals(self) -> dict:
        out: dict = {}
        for e in self.entries:
            out[e["phase"]] = out.get(e["phase"], 0) + e["nbytes"]
        return out

    def phase_counts(self) -> dict:
        """Number of recorded collectives per phase in the traced program —
        the round engine's 'one collective per level, not T' contract is
        checked against these counts (benchmarks/ci_guard.py)."""
        out: dict = {}
        for e in self.entries:
            out[e["phase"]] = out.get(e["phase"], 0) + 1
        return out

    def reset(self) -> None:
        self.entries = []


def probe_tree_cost(
    mesh,
    tree: TreeConfig,
    aggregation: str = "histogram",
    transport: Optional[TransportSpec] = None,
    n_samples: int = 1024,
    num_features: Optional[int] = None,
    shard_samples: bool = False,
    async_exchange: bool = False,
    n_channels: int = 1,
    chaos=None,
) -> tuple[dict, int]:
    """Measure one tree's actual per-phase wire bytes by abstract evaluation.

    Builds the requested VFL backend with a fresh ``MessageMeter`` and
    ``jax.eval_shape``s its real forest program on a single-tree mask, so
    every collective's traced operand reports the bytes it would ship — no
    device computation happens.

    Returns:
      (per_tree, grad_per_round): ``per_tree`` maps phase → bytes for ONE
      tree as recorded in the SPMD program (per *sending party* for the
      party-exchange phases — see ``protocol.PER_PASSIVE_PHASES`` for the
      scaling semantics); ``grad_per_round`` is the (g, h) broadcast payload
      per passive party per round.
    """
    from repro.compat import use_mesh
    from repro.federation import vfl  # local import: vfl imports compress

    num_parties = mesh.shape[mesh_roles.PARTY_AXIS]
    d = num_features if num_features is not None else num_parties * 2
    if d % num_parties:
        raise ValueError(f"num_features={d} must divide over {num_parties} parties")
    meter = MessageMeter()
    backend = vfl.make_vfl_backend(
        mesh, tree, aggregation=aggregation, transport=transport,
        shard_samples=shard_samples, meter=meter,
        async_exchange=async_exchange, chaos=chaos,
    )
    sds = jax.ShapeDtypeStruct
    # K-channel objectives (DESIGN.md §11) carry (n, K) derivatives; K = 1
    # keeps the historical (n,) vectors so the traced program is unchanged.
    gh_shape = (n_samples,) if n_channels == 1 else (n_samples, n_channels)
    with use_mesh(mesh):
        jax.eval_shape(
            backend.forest_builder,
            sds((n_samples, d), jnp.int32),
            sds(gh_shape, jnp.float32),
            sds(gh_shape, jnp.float32),
            sds((1, n_samples), jnp.float32),
            sds((1, d), bool),
        )
    totals = meter.phase_totals()
    if shard_samples and "id_partition" in totals:
        # The routing psum operand is the only data-sharded payload; the
        # SPMD trace records one shard's packed (ceil(n/shards/8),) bitmap
        # slice, but the protocol message covers all n samples (each shard
        # ships its bitmap), so the full wire payload is the per-shard
        # record times the shard count — matching the wire model's
        # per-shard ceil arithmetic (protocol.wire_party_tree_cost).
        shards = 1
        for ax in mesh_roles.data_axes(mesh):
            shards *= mesh.shape[ax]
        totals["id_partition"] *= shards
    grad = totals.pop("grad_broadcast", 0)
    return totals, grad


def probe_round_collectives(
    mesh,
    tree: TreeConfig,
    n_trees: int,
    aggregation: str = "histogram",
    transport: Optional[TransportSpec] = None,
    n_samples: int = 1024,
    num_features: Optional[int] = None,
    async_exchange: bool = False,
) -> dict:
    """Trace a T-tree ROUND program and report per-phase collective counts
    and bytes — the round engine's structural contract (DESIGN.md §9): the
    per-level exchange is ONE collective carrying the whole round's
    ``(T, active, d_party, B, ...)`` payload, so the histogram-phase record
    count equals the number of histogram levels regardless of T (2 per
    level under quantization: int payload + scales).  The async backends
    (DESIGN.md §10) must preserve these counts: double-buffering splits the
    transfer, not the logical message, and the meter records the payload
    before the split.

    Returns {"counts": phase → records/trace, "totals": phase → bytes}.
    """
    from repro.compat import use_mesh
    from repro.federation import vfl  # local import: vfl imports compress

    num_parties = mesh.shape[mesh_roles.PARTY_AXIS]
    d = num_features if num_features is not None else num_parties * 2
    meter = MessageMeter()
    backend = vfl.make_vfl_backend(
        mesh, tree, aggregation=aggregation, transport=transport, meter=meter,
        async_exchange=async_exchange,
    )
    sds = jax.ShapeDtypeStruct
    with use_mesh(mesh):
        jax.eval_shape(
            backend.forest_builder,
            sds((n_samples, d), jnp.int32),
            sds((n_samples,), jnp.float32),
            sds((n_samples,), jnp.float32),
            sds((n_trees, n_samples), jnp.float32),
            sds((n_trees, d), bool),
        )
    return {"counts": meter.phase_counts(), "totals": meter.phase_totals()}


# ---------------------------------------------------------------------------
# Compressed collective providers (shard_map inner fns)
# ---------------------------------------------------------------------------
def quantized_round_histogram_fn(
    party_axis: str = mesh_roles.PARTY_AXIS,
    data_axes: tuple = (),
    transport: TransportSpec = Q8,
    meter: Optional[MessageMeter] = None,
    base_fn: Callable = hist_mod.compute_round_histogram,
    gather: Optional[Callable] = None,
):
    """Round-native quantized histogram provider (DESIGN.md §9): one party
    ``all_gather`` per level carries the whole round's int payload
    ``(T, nodes, d_party, B, 2)`` + scales ``(T, nodes, d_party, 2)`` —
    one ``quantize_stats`` scale per (tree, node, feature, channel).  The
    count channel never traverses the wire (split search does not read it;
    leaf stats are a separate, local pass), so the returned global
    histogram has count ≡ 0.  The stochastic-rounding key derives from
    ``fold_in(seed, num_nodes) ⊕ party`` — deliberately not threaded from
    the training rng so the provider keeps the plain histogram-fn
    signature (unbiased per element; inputs change every round).
    Shared-root caching (``root_delta_rows``) is a local transformation
    applied *before* quantization, so the wire payload is unchanged.

    ``gather`` is the exchange seam (DESIGN.md §10): the int payload rides
    the pluggable gather (double-buffered under the async backends); the
    tiny per-(node, feature, channel) scale vector always ships in one
    plain all_gather — splitting it would buy nothing."""
    if transport.kind != "quantized":
        raise ValueError(f"need a quantized TransportSpec, got {transport!r}")
    from repro.federation import aggregator  # local: aggregator is sibling

    if gather is None:
        gather = aggregator.plain_gather

    def fn(binned_shard, g, h, weight, assign, num_nodes, num_bins,
           root_delta_rows=0, level=0):
        local = base_fn(binned_shard, g, h, weight, assign, num_nodes,
                        num_bins, root_delta_rows=root_delta_rows,
                        level=level)
        for ax in data_axes:
            local = jax.lax.psum(local, ax)
        # everything but the trailing count channel traverses the wire:
        # (T, nodes, d_party, B, 2K) — GH_STATS (= 2) at K = 1.
        payload = local[..., :-1]
        # fold the LEVEL (not just the width) into the key: subtraction and
        # compaction make several levels share a num_nodes, and equal-shape
        # payloads would otherwise draw bit-identical rounding noise.
        key = jax.random.fold_in(jax.random.PRNGKey(transport.seed), level)
        key = jax.random.fold_in(key, num_nodes)
        key = jax.random.fold_in(key, jax.lax.axis_index(party_axis))
        q, scale = quantize_stats(payload, transport.bits, key,
                                  transport.stochastic)
        if meter is not None:
            meter.record("histograms", q)
            meter.record("histograms", scale)
        q_g = gather(q, party_axis, 2)
        s_g = jax.lax.all_gather(scale, party_axis, axis=2, tiled=True)
        deq = dequantize_stats(q_g, s_g)  # (T, nodes, d, B, 2)
        count = jnp.zeros(deq.shape[:-1] + (1,), deq.dtype)
        return jnp.concatenate([deq, count], axis=-1)

    return fn


def topk_round_choose_fn(
    cfg: TreeConfig,
    k: int,
    party_axis: str = mesh_roles.PARTY_AXIS,
    meter: Optional[MessageMeter] = None,
    gather: Optional[Callable] = None,
):
    """Round-native top-k chooser: the per-tree candidate exchange batched
    over the explicit tree axis (one vmapped gather program — a single
    collective per level in the lowered program).  The lossless party-major
    tie-break contract is untouched: it delegates to ``topk_choose_fn``
    per tree."""
    per_tree = topk_choose_fn(cfg, k, party_axis, meter, gather=gather)
    return lambda hist, fmask: jax.vmap(per_tree)(hist, fmask)


def topk_choose_fn(
    cfg: TreeConfig,
    k: int,
    party_axis: str = mesh_roles.PARTY_AXIS,
    meter: Optional[MessageMeter] = None,
    gather: Optional[Callable] = None,
):
    """Split chooser exchanging each party's k best candidates per node.

    The argmax aggregation's candidate exchange, generalized (the raw
    argmax mode IS k = 1): each party evaluates its local gains, ``top_k``s them, and only the (gain,
    feature, threshold) tuples are gathered.  The merge flattens the
    gathered candidates *party-major* with each party's list in descending
    gain / ascending-flat-index order (``lax.top_k`` breaks ties toward the
    lower index), so ``argmax``'s first-occurrence rule reproduces the
    centralized tie-break exactly — the mode is lossless for any k ≥ 1.

    ``gather`` is the *stacking* exchange seam (``gather(x, party_axis)``
    -> leading party axis): the default is a direct ``all_gather``; the
    chaos transport (DESIGN.md §13) substitutes its fault-injecting,
    checksum-verified wrapper here.
    """
    if gather is None:
        gather = lambda x, pa: jax.lax.all_gather(x, pa)

    def fn(hist_local, feature_mask_local):
        num_nodes, d_party, num_bins, _ = hist_local.shape
        p = jax.lax.axis_index(party_axis)
        gains = split_mod.split_gains(hist_local, cfg)  # (nodes, d_party, B)
        gains = jnp.where(
            feature_mask_local[None, :, None], gains, split_mod.NEG_INF
        )
        flat = gains.reshape(num_nodes, d_party * num_bins)
        k_eff = min(k, d_party * num_bins)
        top_gain, top_idx = jax.lax.top_k(flat, k_eff)  # (nodes, k_eff)
        feat = (top_idx // num_bins).astype(jnp.int32) + p * d_party
        thr = (top_idx % num_bins).astype(jnp.int32)
        if meter is not None:
            for arr in (top_gain, feat, thr):
                meter.record("split_candidates", arr)
        gains_all = gather(top_gain, party_axis)  # (P, nodes, k)
        feats_all = gather(feat, party_axis)
        thrs_all = gather(thr, party_axis)
        num_parties = gains_all.shape[0]
        merge = lambda a: jnp.moveaxis(a, 1, 0).reshape(
            num_nodes, num_parties * k_eff
        )
        g2, f2, t2 = merge(gains_all), merge(feats_all), merge(thrs_all)
        best = jnp.argmax(g2, axis=1)
        take = lambda a: jnp.take_along_axis(a, best[:, None], axis=1)[:, 0]
        best_gain = take(g2)
        has_split = best_gain > 0.0
        return split_mod.SplitDecision(
            feature=jnp.where(has_split, take(f2), -1),
            threshold=jnp.where(has_split, take(t2), num_bins),
            gain=best_gain,
        )

    return fn
