"""Mesh-axis roles shared by the tabular VFL runtime and the LM substrate.

The same physical mesh serves both workloads (DESIGN.md §8):

  axis "model" — VFL *parties* (feature shards) for FedGBF;
                 tensor-parallel shards (heads / d_ff / experts) for the LMs.
  axis "data"  — sample shards (histograms are psum-additive);
                 batch shards / FSDP for the LMs.
  axis "pod"   — multi-pod replication folded into data parallelism.

Party 0 of the "model" axis is the *active* party (label holder); the
remaining shards are passive parties. For the dry-run the mesh is built by
``launch.mesh.make_production_mesh``.
"""

from __future__ import annotations

import jax

PARTY_AXIS = "model"
DATA_AXIS = "data"
POD_AXIS = "pod"


def party_index(axis: str = PARTY_AXIS) -> jax.Array:
    """This shard's party id (inside shard_map)."""
    return jax.lax.axis_index(axis)


def num_parties(mesh: jax.sharding.Mesh, axis: str = PARTY_AXIS) -> int:
    return mesh.shape[axis]


def data_axes(mesh: jax.sharding.Mesh) -> tuple:
    """All sample-sharding axes present in the mesh (pod folds into data)."""
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.shape)
