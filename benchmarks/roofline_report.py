"""Aggregate the dry-run matrix (reports/dryrun/*.json) into the roofline
table consumed by EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import REPORT_DIR, save_report

DRYRUN_DIR = os.path.join(REPORT_DIR, "dryrun")


def load_reports() -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_row(r: dict) -> str:
    roof = r["roofline"]
    peak = r["memory"].get("peak_bytes_per_device") or 0
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {roof['compute_s']*1e3:.2f} | {roof['memory_s']*1e3:.2f} "
        f"| {roof['collective_s']*1e3:.2f} | {roof['dominant']} "
        f"| {roof['useful_ratio']:.2f} | {peak/2**30:.2f} |"
    )


def markdown_table(reports: list, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | useful | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in reports:
        if r.get("status") == "ok" and r.get("mesh") == mesh and \
                "__" not in r["tag"].replace(f"{r['arch']}__{r['shape']}__{r['mesh']}", ""):
            lines.append(fmt_row(r))
    return "\n".join(lines)


def main() -> list:
    t0 = time.perf_counter()
    reports = load_reports()
    ok = [r for r in reports if r.get("status") == "ok" and not
          r["tag"].count("__") > 2]  # exclude hillclimb variants
    skipped = [r for r in reports if r.get("status") == "skipped"]
    errors = [r for r in reports if r.get("status") == "error"]
    if not reports:
        print("  no dry-run reports found; run repro.launch.dryrun first")
        return [("roofline/none", 0.0, "missing")]

    table = markdown_table(reports)
    with open(os.path.join(REPORT_DIR, "roofline_table.md"), "w") as f:
        f.write(table + "\n")
    print(table)
    if errors:
        for e in errors:
            print(f"  ERROR {e['tag']}: {e.get('error', '')[:120]}")

    dominant = {}
    for r in ok:
        dominant[r["roofline"]["dominant"]] = dominant.get(
            r["roofline"]["dominant"], 0) + 1
    save_report("roofline_summary", {
        "ok": len(ok), "skipped": len(skipped), "errors": len(errors),
        "dominant_histogram": dominant,
    })
    return [(
        "roofline/matrix",
        (time.perf_counter() - t0) * 1e6,
        f"ok={len(ok)};skipped={len(skipped)};errors={len(errors)};"
        f"dominant={dominant}",
    )]


if __name__ == "__main__":
    for row in main():
        print(row)
