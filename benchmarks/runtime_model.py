"""Runtime-model benchmark (eqs. 8-11 + appendix A.1/A.2).

1. A.1 check: measured single-tree build time vs data size follows
   T_{alpha n} / T_n ~ alpha + log2(alpha)/log2(n).
2. A.2-style error table: the paper validates its ESTIMATED SecureBoost time
   against real FATE runs (<10% error). We do the analogue entirely within
   our system: estimate T_S = M * T_unit from one measured tree, compare
   against the real measured M-round training loop.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, save_report, scale
from repro.core import binning, boosting, forest, losses, runtime_model
from repro.core.types import TreeConfig
from repro.data import synthetic


def subsample_scaling(ds, cfg, alphas=(0.25, 0.5, 0.75, 1.0)) -> list:
    """Measured vs predicted (A.1) time ratios under row subsampling.

    Our vectorised builder is mask-based, so histogram work is O(n) regardless
    of alpha; to honour the paper's setting we physically slice the rows."""
    rows = []
    n = ds.x_train.shape[0]
    base = None
    for alpha in alphas:
        k = int(n * alpha)
        x = jnp.asarray(ds.x_train[:k])
        y = jnp.asarray(ds.y_train[:k])
        binned, _ = binning.fit_bin(x, cfg.num_bins)
        g, h = losses.grad_hess("logistic", y, jnp.zeros_like(y))
        smask = jnp.ones((1, k), jnp.float32)
        fmask = jnp.ones((1, x.shape[1]), bool)
        trees, _ = forest.build_forest(binned, g, h, smask, fmask, cfg)
        jax.block_until_ready(trees)
        with Timer() as t:
            for _ in range(3):
                trees, _ = forest.build_forest(binned, g, h, smask, fmask, cfg)
                jax.block_until_ready(trees)
        measured = t.seconds / 3
        if alpha == alphas[-1]:
            base = measured
        rows.append({"alpha": alpha, "measured_s": measured})
    for r in rows:
        r["measured_ratio"] = r["measured_s"] / base
        r["predicted_ratio"] = runtime_model.subsample_time_ratio(r["alpha"], n)
    return rows


def estimation_error(ds, cfg_tree, rounds_list) -> list:
    """A.2 analogue: estimated vs real SecureBoost wall time in-system.

    The paper's T_unit is a warm per-tree time; the real run must therefore
    also be measured warm (first call carries XLA compilation, which FATE's
    setup time T_0 models separately) — we warm with a 2-round run first."""
    x = jnp.asarray(ds.x_train)
    y = jnp.asarray(ds.y_train)

    # T_unit = warm marginal cost of one boosting round (one full-data tree,
    # INCLUSIVE of the per-round machinery, exactly what FATE's measured
    # single-tree time includes): (t[M=6] - t[M=2]) / 4 after a warm run.
    def timed(rounds):
        cfg = boosting.secureboost_config(rounds=rounds, tree=cfg_tree)
        with Timer() as t:
            boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0),
                                  eval_every=rounds)
        return t.seconds

    timed(2)  # warm compile
    t2, t6 = timed(2), timed(6)
    t_unit = max((t6 - t2) / 4.0, 1e-6)
    t0 = max(t2 - 2 * t_unit, 0.0)  # setup analogue of the paper's T_0
    rows = []
    for rounds in rounds_list:
        cfg = boosting.secureboost_config(rounds=rounds, tree=cfg_tree)
        with Timer() as t:
            boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0),
                                  eval_every=rounds)
        est = runtime_model.estimate_secureboost_runtime(rounds, t_unit, t0_s=t0)
        rows.append({
            "rounds": rounds,
            "estimated_s": est,
            "real_s": t.seconds,
            "error_rate": runtime_model.error_rate(est, t.seconds),
        })
        print(f"  M={rounds:3d} estimated={est:.1f}s real={t.seconds:.1f}s "
              f"err={rows[-1]['error_rate']:.2%}")
    return rows


def main() -> list:
    quick = scale() == "quick"
    # full-size default-credit even in quick mode: sub-second runs are too
    # noisy for the A.2 error measurement on a shared CPU core
    ds = synthetic.load("default_credit_card")
    cfg_tree = TreeConfig(max_depth=3, num_bins=32)

    t0 = time.perf_counter()
    a1 = subsample_scaling(ds, cfg_tree)
    rounds_list = [10, 20] if quick else [20, 50, 100]
    a2 = estimation_error(ds, cfg_tree, rounds_list)
    save_report("runtime_model", {"a1_scaling": a1, "a2_error": a2})

    worst_err = max(r["error_rate"] for r in a2)
    us = (time.perf_counter() - t0) * 1e6 / (len(a1) + len(a2))
    return [(
        "runtime_model/a2_error", us,
        f"worst_estimation_error={worst_err:.2%};paper_bound=10%",
    )]


if __name__ == "__main__":
    for row in main():
        print(row)
