"""Ensemble-inference benchmark: looped vs packed vs Pallas-kernel prediction.

Measures the serving hot path (DESIGN.md §3) on a dynamic-schedule model
(rounds with different tree counts, the case the packed layout exists for):

  * ``loop``    — legacy O(rounds) per-round loop (jitted, pre-binned input,
                  same as the others — only the traversal structure differs);
  * ``packed``  — per-round segmented accumulation over the static
                  round_offsets (bit-for-bit equal to loop; each round's
                  (n_trees_r, n) block is a transient — the historical
                  all-trees vmap materialised the full (total_trees, n)
                  matrix and measured 0.34x of loop);
  * ``weighted``— lax.scan over the packed tree axis with a streaming
                  accumulator (one compiled tree body, O(1) compile cost in
                  ensemble size, no per-tree matrix);
  * ``pallas``  — fused ensemble_predict kernel. On this CPU container it
                  runs in interpret mode (a correctness vehicle, not a speed
                  one — its number here is NOT representative of TPU).

Results land in reports/predict_bench.json and the repo-root
BENCH_predict.json the ISSUE tracks.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_report, scale
from repro.core import binning, boosting, tree as tree_mod
from repro.core.types import pack_ensemble
from repro.kernels.ensemble_predict.ops import predict_packed_pallas


def bench(fn, repeats=5) -> float:
    jax.block_until_ready(fn())  # warm (compile)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> list:
    quick = scale() == "quick"
    n_train, n_serve, d = (8_000, 100_000, 23) if quick else (30_000, 1_000_000, 23)
    rounds = 10 if quick else 20

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n_train, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n_train), jnp.float32)
    cfg = boosting.dynamic_fedgbf_config(rounds=rounds)
    model, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    packed = pack_ensemble(model)

    x_serve = jnp.asarray(rng.normal(size=(n_serve, d)), jnp.float32)
    binned = binning.bin_data(x_serve, packed.bin_edges)
    jax.block_until_ready(binned)

    # Prediction only: every impl consumes the SAME pre-binned array and is
    # jit-wrapped, so the comparison isolates the traversal layout (the part
    # the packed representation changes), not binning or dispatch overhead.
    def loop_predict(b):
        out = jnp.full((b.shape[0],), packed.base_score, jnp.float32)
        for trees in model.forests:
            out = out + model.learning_rate * tree_mod.predict_forest(
                trees, b, model.max_depth
            )
        return out

    impls = {
        "loop": jax.jit(loop_predict).__call__,
        "packed": jax.jit(
            lambda b: tree_mod.predict_packed(packed, b)
        ).__call__,
        "weighted": jax.jit(
            lambda b: tree_mod.predict_packed_weighted(packed, b)
        ).__call__,
        "pallas_interpret": lambda b: predict_packed_pallas(packed, b),
    }
    results = {
        "n_serve": n_serve, "d": d, "rounds": rounds,
        "total_trees": packed.total_trees, "max_depth": packed.max_depth,
        "backend": jax.default_backend(),
        "note": ("pallas runs in interpret mode on CPU; its wall time is a "
                 "correctness artifact, not kernel performance"),
    }
    t_loop = bench(lambda: impls["loop"](binned))
    results["loop_s"] = t_loop
    t_packed = bench(lambda: impls["packed"](binned))
    results["packed_s"] = t_packed
    t_weighted = bench(lambda: impls["weighted"](binned))
    results["weighted_s"] = t_weighted
    if quick:
        # keep interpret-mode pallas tractable: bench a 32k-row slice
        b_small = binned[:32_768]
        t_pal = bench(lambda: impls["pallas_interpret"](b_small), repeats=2)
        results["pallas_interpret_s_32k"] = t_pal
    results["packed_speedup_vs_loop"] = t_loop / t_packed
    results["weighted_speedup_vs_loop"] = t_loop / t_weighted
    results["rows_per_s_packed"] = n_serve / t_packed
    results["rows_per_s_weighted"] = n_serve / t_weighted
    results["interpretation"] = (
        "the default packed path now accumulates per-round sums over the "
        "static round_offsets segments (no (total_trees, n) matrix), "
        "restoring parity with the jitted unrolled loop while staying "
        "bit-exact; the scan-based weighted combiner trades ~10-25% for "
        "O(1) compile cost in ensemble size. The packed layout additionally "
        "buys uniform serving/checkpointing and the fused Pallas kernel "
        "path on TPU."
    )

    save_report("predict_bench", results)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_predict.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)

    print(f"  loop: {t_loop*1e3:.1f} ms  packed: {t_packed*1e3:.1f} ms "
          f"({results['packed_speedup_vs_loop']:.1f}x, "
          f"{results['rows_per_s_packed']/1e6:.2f} M rows/s)  "
          f"weighted: {t_weighted*1e3:.1f} ms")
    return [
        ("predict/loop", t_loop * 1e6,
         f"{rounds} rounds x traversal"),
        ("predict/packed", t_packed * 1e6,
         f"{packed.total_trees} trees one traversal, "
         f"{results['packed_speedup_vs_loop']:.1f}x vs loop"),
        ("predict/weighted", t_weighted * 1e6, "single scale reduction"),
    ]


if __name__ == "__main__":
    main()
