"""Observability-layer benchmark: structured round logs + trace export.

Drives the unified telemetry layer (DESIGN.md §12) end-to-end the way a
downstream consumer would: run ``train_fedgbf --log-json --trace`` as a
subprocess on a small local-backend config, parse the per-round JSON lines
back with ``repro.obs.log.parse_round_log`` (this module IS the consumer the
``--log-json`` satellite names), and validate the exported Chrome-trace
artifact loads and carries the expected event schema.

Reported:
  * ``rounds_parsed``     — structured lines recovered from mixed stdout
    (banners + JSON interleaved, exactly like a real log pipeline);
  * ``total_wall_s``      — sum of per-round ``wall_s`` from the log lines
    (the per-segment-true timings, not the old uniform smear);
  * ``log_line_bytes_mean`` — per-round log-line cost on the wire;
  * ``trace_events`` / ``trace_bytes`` — exported trace size and the
    schema checks (X events per round, thread_name tracks, counters);
  * ``faults_injected`` / ``fault_retries`` — a second federated run under
    a pinned faulty chaos spec + party dropout (DESIGN.md §13): every
    round line must carry the ``faults`` record (faults_injected /
    retries / degraded_parties) through ``parse_round_log``, and the
    Perfetto export must carry the ``faults`` track.

    PYTHONPATH=src python -m benchmarks.obs_bench
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import save_report, scale
from repro.obs import log as obs_log

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(smoke: bool = False) -> list:
    quick = smoke or scale() == "quick"
    rounds = 4 if quick else 12
    n = 2_000 if quick else 10_000

    trace_path = os.path.join(tempfile.mkdtemp(prefix="obs_bench_"),
                              "train_trace.json")
    cmd = [
        sys.executable, "-m", "repro.launch.train_fedgbf",
        "--dataset", "default_credit_card", "--n", str(n),
        "--rounds", str(rounds), "--eval-every", "2",
        "--log-json", "--trace", trace_path,
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(cmd, env=env, check=True, capture_output=True,
                          text=True, cwd=ROOT)

    # -- consume the structured log exactly as a pipeline would --------------
    recs = obs_log.parse_round_log(proc.stdout)
    assert len(recs) == rounds, (
        f"expected {rounds} round lines, parsed {len(recs)}:\n{proc.stdout}"
    )
    assert [r["round"] for r in recs] == list(range(1, rounds + 1))
    evaluated = [r for r in recs if r["metrics"] is not None]
    assert evaluated, "eval_every rounds must carry metrics in the log"
    json_lines = [l for l in proc.stdout.splitlines()
                  if l.startswith("{")]
    line_bytes = sum(len(l.encode()) for l in json_lines) / len(json_lines)

    # -- trace artifact schema ----------------------------------------------
    with open(trace_path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    round_spans = [e for e in xs if e["name"].startswith("round ")]
    assert len(round_spans) == rounds, (
        f"trace must carry one round span per round "
        f"(got {len(round_spans)}/{rounds})"
    )
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in events)
    assert any(e["ph"] == "C" for e in events), "liveness counters missing"

    # -- fault telemetry (DESIGN.md §13): chaos + dropout run ----------------
    # Re-run federated under a seeded faulty chaos spec + party dropout and
    # assert the per-round fault fields survive the full pipeline: emitted
    # in the --log-json lines, recovered by parse_round_log, and exported
    # as the Perfetto "faults" track.  Seeds are pinned so the plan is
    # deterministic: chaos seed 1 injects >= 1 fault over the 3-slot tree,
    # dropout seed 0 degrades parties without ever losing a whole round.
    fault_trace = os.path.join(os.path.dirname(trace_path),
                               "fault_trace.json")
    fault_cmd = [
        sys.executable, "-m", "repro.launch.train_fedgbf",
        "--dataset", "default_credit_card", "--n", str(min(n, 2_000)),
        "--rounds", str(rounds), "--eval-every", "2",
        "--backend", "vfl-histogram", "--parties", "2",
        "--chaos-drop", "0.2", "--chaos-corrupt", "0.1", "--chaos-seed", "1",
        "--party-dropout", "0.6", "--dropout-seed", "0", "--retry-max", "1",
        "--log-json", "--trace", fault_trace,
    ]
    fault_env = dict(env)
    fault_env.setdefault("XLA_FLAGS",
                         "--xla_force_host_platform_device_count=8")
    fproc = subprocess.run(fault_cmd, env=fault_env, check=True,
                           capture_output=True, text=True, cwd=ROOT)
    frecs = obs_log.parse_round_log(fproc.stdout)
    assert len(frecs) == rounds, (
        f"chaos run: expected {rounds} round lines, parsed {len(frecs)}:\n"
        f"{fproc.stdout}"
    )
    assert all("faults" in r for r in frecs), (
        "every round line of a chaos run must carry the faults record"
    )
    assert all({"faults_injected", "retries", "degraded_parties"}
               <= set(r["faults"]) for r in frecs), (
        "fault records must carry faults_injected/retries/degraded_parties"
    )
    faults_injected = sum(r["faults"]["faults_injected"] for r in frecs)
    fault_retries = sum(r["faults"]["retries"] for r in frecs)
    assert faults_injected > 0, "pinned chaos seed must inject faults"
    assert fault_retries > 0, "injected faults must surface as retries"
    with open(fault_trace) as f:
        fdoc = json.load(f)
    fault_spans = [e for e in fdoc["traceEvents"]
                   if e["ph"] == "X" and e["name"].startswith("faults ")]
    assert fault_spans, "Perfetto export must carry the faults track"

    results = {
        "rounds": rounds, "n": n,
        "rounds_parsed": len(recs),
        "rounds_evaluated": len(evaluated),
        "total_wall_s": sum(r["wall_s"] for r in recs),
        "log_line_bytes_mean": line_bytes,
        "trace_events": len(events),
        "trace_bytes": os.path.getsize(trace_path),
        "liveness_in_log": all("liveness" in r for r in recs),
        "fault_rounds_parsed": len(frecs),
        "faults_injected": faults_injected,
        "fault_retries": fault_retries,
        "fault_trace_spans": len(fault_spans),
    }
    save_report("obs_bench", results)
    print(
        f"  {len(recs)} round lines parsed ({line_bytes:.0f} B/line, "
        f"{len(evaluated)} with metrics), total wall "
        f"{results['total_wall_s']*1e3:.1f} ms\n"
        f"  trace: {len(events)} events, "
        f"{results['trace_bytes']/1e3:.1f} kB -> ui.perfetto.dev\n"
        f"  faults: {faults_injected} injected / {fault_retries} retries "
        f"across {len(frecs)} chaos rounds, {len(fault_spans)} fault "
        f"spans in the trace"
    )
    return [
        ("obs/log_line", line_bytes,
         f"{len(recs)} structured rounds parsed back"),
        ("obs/trace_export", float(results["trace_bytes"]),
         f"{len(events)} events, schema-validated"),
    ]


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
