"""Histogram-path microbenchmark.

On this CPU container the Pallas kernel runs in interpret mode (a correctness
vehicle, not a speed one), so wall-clock here measures the PRODUCTION CPU
path (segment-sum) and the algebraic one-hot formulation; the Pallas kernel's
TPU performance is governed by the roofline numbers in EXPERIMENTS.md.
Derived column reports achieved histogram-update throughput and the VMEM
working set the kernel's BlockSpecs claim per grid step.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, save_report, scale
from repro.core.histogram import (
    as_child_fn,
    compute_histogram,
    compute_round_histogram,
    compute_histogram_onehot,
)


def bench(fn, args, repeats=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> list:
    quick = scale() == "quick"
    n = 200_000 if quick else 1_000_000
    d, B, nodes = 23, 32, 4
    rng = np.random.default_rng(0)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n), jnp.float32)
    w = jnp.ones(n, jnp.float32)
    assign = jnp.asarray(rng.integers(0, nodes, n), jnp.int32)

    seg = jax.jit(compute_histogram, static_argnums=(5, 6))
    oh = jax.jit(compute_histogram_onehot, static_argnums=(5, 6))
    # Child-only pass of the subtraction pipeline (DESIGN.md §6): same inputs
    # at the SAME frontier (``assign`` spans ``nodes``), accumulating only the
    # left children at half width — the per-level work replacing a full
    # ``nodes``-wide pass at every level >= 1.  On the one-hot/MXU
    # formulation the contraction width (and FLOPs) literally halve; the
    # segment path saves the segment count.
    seg_child = jax.jit(as_child_fn(compute_histogram), static_argnums=(5, 6))
    oh_child = jax.jit(as_child_fn(compute_histogram_onehot),
                       static_argnums=(5, 6))

    t_seg = bench(lambda: seg(binned, g, h, w, assign, nodes, B), ())
    t_oh = bench(lambda: oh(binned, g, h, w, assign, nodes, B), ())
    t_seg_child = bench(
        lambda: seg_child(binned, g, h, w, assign, nodes // 2, B), ())
    t_oh_child = bench(
        lambda: oh_child(binned, g, h, w, assign, nodes // 2, B), ())

    # Round-native pass (DESIGN.md §9): T trees in ONE segment program (the
    # tree folds into the segment ids) vs T sequential per-tree passes —
    # the provider contract the round engine drives at every level.
    T = 5
    w_round = jnp.ones((T, n), jnp.float32)
    assign_round = jnp.tile(assign[None], (T, 1))
    rnd = jax.jit(compute_round_histogram, static_argnums=(5, 6))
    t_round = bench(
        lambda: rnd(binned, g, h, w_round, assign_round, nodes, B), ())
    per_tree_equiv = t_seg * T

    updates = n * d  # one (g,h,count) update per (row, feature)
    vmem_bytes = 512 * nodes * B * 4 + 512 * 8 * 4 * 2  # onehot + ids + data
    save_report("kernel_bench", {
        "n": n, "d": d, "segment_s": t_seg, "onehot_s": t_oh,
        "segment_child_s": t_seg_child, "onehot_child_s": t_oh_child,
        "updates_per_s_segment": updates / t_seg,
        "child_speedup_segment_x": t_seg / t_seg_child,
        "child_speedup_onehot_x": t_oh / t_oh_child,
        "round_trees": T, "round_s": t_round,
        "round_vs_sequential_per_tree_x": per_tree_equiv / t_round,
    })
    print(f"  segment_sum: {t_seg*1e3:.1f} ms  onehot: {t_oh*1e3:.1f} ms "
          f"({updates/t_seg/1e9:.2f} G updates/s)\n"
          f"  child-only:  {t_seg_child*1e3:.1f} ms "
          f"({t_seg/t_seg_child:.2f}x)  onehot child: {t_oh_child*1e3:.1f} ms "
          f"({t_oh/t_oh_child:.2f}x)\n"
          f"  round (T={T}): {t_round*1e3:.1f} ms "
          f"({per_tree_equiv/t_round:.2f}x vs {T} sequential passes)")
    return [
        ("kernel/histogram_segment", t_seg * 1e6,
         f"{updates/t_seg/1e9:.2f}Gupd/s;n={n};d={d}"),
        ("kernel/histogram_onehot_alg", t_oh * 1e6,
         f"vmem_per_step={vmem_bytes/1024:.0f}KiB"),
        ("kernel/histogram_child_segment", t_seg_child * 1e6,
         f"{t_seg/t_seg_child:.2f}x_vs_full;half_frontier"),
        ("kernel/histogram_child_onehot", t_oh_child * 1e6,
         f"{t_oh/t_oh_child:.2f}x_vs_full;half_contraction_width"),
        ("kernel/histogram_round", t_round * 1e6,
         f"T={T};{per_tree_equiv/t_round:.2f}x_vs_sequential"),
    ]


if __name__ == "__main__":
    for row in main():
        print(row)
