"""Histogram-path microbenchmark.

On this CPU container the Pallas kernel runs in interpret mode (a correctness
vehicle, not a speed one), so wall-clock here measures the PRODUCTION CPU
path (segment-sum) and the algebraic one-hot formulation; the Pallas kernel's
TPU performance is governed by the roofline numbers in EXPERIMENTS.md.
Derived column reports achieved histogram-update throughput and the VMEM
working set the kernel's BlockSpecs claim per grid step.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, save_report, scale
from repro.core.histogram import compute_histogram, compute_histogram_onehot


def bench(fn, args, repeats=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> list:
    quick = scale() == "quick"
    n = 200_000 if quick else 1_000_000
    d, B, nodes = 23, 32, 4
    rng = np.random.default_rng(0)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n), jnp.float32)
    w = jnp.ones(n, jnp.float32)
    assign = jnp.asarray(rng.integers(0, nodes, n), jnp.int32)

    seg = jax.jit(compute_histogram, static_argnums=(5, 6))
    oh = jax.jit(compute_histogram_onehot, static_argnums=(5, 6))

    t_seg = bench(lambda: seg(binned, g, h, w, assign, nodes, B), ())
    t_oh = bench(lambda: oh(binned, g, h, w, assign, nodes, B), ())

    updates = n * d  # one (g,h,count) update per (row, feature)
    vmem_bytes = 512 * nodes * B * 4 + 512 * 8 * 4 * 2  # onehot + ids + data
    save_report("kernel_bench", {
        "n": n, "d": d, "segment_s": t_seg, "onehot_s": t_oh,
        "updates_per_s_segment": updates / t_seg,
    })
    print(f"  segment_sum: {t_seg*1e3:.1f} ms  onehot: {t_oh*1e3:.1f} ms "
          f"({updates/t_seg/1e9:.2f} G updates/s)")
    return [
        ("kernel/histogram_segment", t_seg * 1e6,
         f"{updates/t_seg/1e9:.2f}Gupd/s;n={n};d={d}"),
        ("kernel/histogram_onehot_alg", t_oh * 1e6,
         f"vmem_per_step={vmem_bytes/1024:.0f}KiB"),
    ]


if __name__ == "__main__":
    for row in main():
        print(row)
