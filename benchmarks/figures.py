"""Figures 2-3 analogue: AUC / loss / estimated-time CURVES vs boosting round
for Dynamic FedGBF and SecureBoost (the paper plots these at M = 100).
Writes reports/figures.json with per-round series ready for plotting."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_report, scale
from repro.core import boosting, runtime_model
from repro.core.types import TreeConfig
from repro.data import synthetic


def curves(name: str, rounds: int, n=None) -> dict:
    ds = synthetic.load(name, n=n)
    tree = TreeConfig(max_depth=3, num_bins=32)
    out = {}
    t_unit = 1.0  # curves in tree-units; absolute scaling in runtime_model.py
    for model_name, cfg in (
        ("dynamic_fedgbf", boosting.dynamic_fedgbf_config(rounds, tree=tree)),
        ("secureboost", boosting.secureboost_config(rounds, tree=tree)),
    ):
        _, hist = boosting.train_fedgbf(
            jnp.asarray(ds.x_train), jnp.asarray(ds.y_train), cfg,
            jax.random.PRNGKey(0),
            x_valid=jnp.asarray(ds.x_test), y_valid=jnp.asarray(ds.y_test),
        )
        # cumulative estimated time (eqs. 8-10) per round
        cum_lo, cum_hi, lo, hi = [], [], 0.0, 0.0
        for n_i, a_i, b_i in runtime_model.round_schedules(cfg):
            lo += a_i * b_i * t_unit
            hi += n_i * a_i * b_i * t_unit
            cum_lo.append(lo)
            cum_hi.append(hi)
        out[model_name] = {
            "round": hist.rounds,
            "train_auc": [m["auc"] for m in hist.train],
            "valid_auc": [m["auc"] for m in hist.valid],
            "train_loss": [m["loss"] for m in hist.train],
            "n_trees": hist.n_trees,
            "est_time_lower": cum_lo,
            "est_time_upper": cum_hi,
        }
    return out


def main() -> list:
    quick = scale() == "quick"
    rounds = 30 if quick else 100
    t0 = time.perf_counter()
    fig = {
        "default_credit_card": curves(
            "default_credit_card", rounds, n=15_000 if quick else None
        ),
    }
    if not quick:
        fig["give_me_some_credit"] = curves("give_me_some_credit", rounds)
    save_report("figures", fig)

    rows = []
    for dsname, series in fig.items():
        fg = series["dynamic_fedgbf"]
        sb = series["secureboost"]
        # round at which each model first reaches SecureBoost's final AUC-0.005
        target = sb["valid_auc"][-1] - 0.005
        def first_round(s):
            for r, a in zip(s["round"], s["valid_auc"]):
                if a >= target:
                    return r
            return s["round"][-1]
        r_fg, r_sb = first_round(fg), first_round(sb)
        # estimated time (ideal parallel) to reach that quality
        t_fg = fg["est_time_lower"][r_fg - 1]
        t_sb = sb["est_time_lower"][r_sb - 1]
        rows.append((
            f"figures/{dsname}",
            (time.perf_counter() - t0) * 1e6,
            f"rounds_to_target fg={r_fg} sb={r_sb};"
            f"time_to_target_ratio={t_fg/max(t_sb,1e-9):.2f}",
        ))
        print(f"  {dsname}: FedGBF reaches SecureBoost-final AUC at round "
              f"{r_fg} vs {r_sb} (est. ideal-parallel time ratio "
              f"{t_fg/max(t_sb,1e-9):.2f})")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
