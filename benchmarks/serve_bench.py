"""Serving-tier benchmark (DESIGN.md §14): fused bin+traverse vs the
two-program baseline, f32 vs quantized, vmap vs Pallas kernel.

Every variant streams the SAME microbatched request loop (pad + dispatch +
block, latencies into a log-bucket histogram) so the only difference under
measurement is the serving program structure:

  * ``two_program_f32_vmap`` — the pre-§14 shape: one jitted binning
    dispatch (``bin_data``) THEN one jitted traversal dispatch per batch;
  * ``fused_f32_vmap`` / ``fused_q8_vmap`` — ONE program on raw floats
    (value-space thresholds; quantized leaves dequantize in-graph);
  * ``fused_f32_pallas`` / ``fused_q8_pallas`` — the fused Pallas
    ``ensemble_predict`` kernel.  On this CPU container it runs in
    interpret mode over a reduced row count — a correctness vehicle, NOT
    representative of TPU throughput (flagged in the banked row).

The quantized section measures the max |margin_q − margin_f32| on the
request sample against the PROVABLE ``types.margin_delta_bound`` — a
machine-independent exactness contract ci_guard re-checks in CI.

Results land in reports/serve_bench.json and the repo-root
BENCH_serve.json with the ci_guard floors (rows/s floor, p99 ceiling).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_report, scale
from repro.core import binning, boosting, objective as objective_mod
from repro.core import tree as tree_mod
from repro.core.types import margin_delta_bound, pack_ensemble, quantize_ensemble
from repro.launch import serve_fedgbf
from repro.obs import metrics as obs_metrics

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _stream(fn, x: np.ndarray, batch: int, repeats: int) -> dict:
    """Steady-state stream measurement: rows/s (best full-stream wall over
    ``repeats``) + p50/p99 from the accumulated latency histogram."""
    n = x.shape[0]
    hist = obs_metrics.LogBucketHistogram("lat", lo=1e-6, hi=60.0)
    jax.block_until_ready(fn(jnp.asarray(x[:batch])))  # warm/compile
    best_wall = float("inf")
    for _ in range(repeats):
        wall0 = time.perf_counter()
        for start in range(0, n, batch):
            chunk = x[start:start + batch]
            if chunk.shape[0] < batch:
                chunk = np.concatenate(
                    [chunk, np.zeros((batch - chunk.shape[0],) + x.shape[1:],
                                     x.dtype)])
            t0 = time.perf_counter()
            jax.block_until_ready(fn(jnp.asarray(chunk)))
            hist.observe(time.perf_counter() - t0)
        best_wall = min(best_wall, time.perf_counter() - wall0)
    return {
        "rows_per_s": n / best_wall,
        "p50_ms": hist.quantile(0.5) * 1e3,
        "p99_ms": hist.quantile(0.99) * 1e3,
        "batches": hist.count,
    }


def main(smoke: bool = False) -> list:
    quick = scale() == "quick"
    if smoke:
        n_train, n_serve, rounds, batch = 4_000, 32_768, 6, 1024
        n_pallas, repeats = 2_048, 2
    elif quick:
        n_train, n_serve, rounds, batch = 8_000, 131_072, 10, 1024
        n_pallas, repeats = 4_096, 3
    else:
        n_train, n_serve, rounds, batch = 30_000, 1_048_576, 20, 4096
        n_pallas, repeats = 8_192, 3
    d = 23

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n_train, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n_train), jnp.float32)
    cfg = boosting.dynamic_fedgbf_config(rounds=rounds)
    model, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    packed = pack_ensemble(model)
    q8 = quantize_ensemble(packed, bits=8, key=jax.random.PRNGKey(1))
    q16 = quantize_ensemble(packed, bits=16, key=jax.random.PRNGKey(1))

    requests = rng.normal(size=(n_serve, d)).astype(np.float32)
    act = objective_mod.get_objective(packed.loss).activation

    # the two-program baseline: serve-time binning as its OWN dispatch,
    # then the binned traversal — what serving looked like before §14
    bin_prog = jax.jit(lambda xb: binning.bin_data(xb, packed.bin_edges))
    trav_prog = jax.jit(
        lambda b: act(tree_mod.predict_packed_weighted(packed, b)))

    def two_program(xb):
        return trav_prog(bin_prog(xb))

    variants = {
        "two_program_f32_vmap": (two_program, requests),
        "fused_f32_vmap": (
            lambda xb: serve_fedgbf._score_batch(packed, xb, "fused"),
            requests),
        "fused_q8_vmap": (
            lambda xb: serve_fedgbf._score_batch(q8, xb, "fused"),
            requests),
        # interpret-mode Pallas on CPU: reduced rows, correctness vehicle
        "fused_f32_pallas": (
            lambda xb: serve_fedgbf._score_batch(packed, xb, "fused-pallas"),
            requests[:n_pallas]),
        "fused_q8_pallas": (
            lambda xb: serve_fedgbf._score_batch(q8, xb, "fused-pallas"),
            requests[:n_pallas]),
    }
    on_tpu = jax.default_backend() == "tpu"
    results, rows = {}, []
    for name, (fn, req) in variants.items():
        pallas = name.endswith("pallas")
        b = min(batch, req.shape[0])
        r = _stream(fn, req, b, repeats)
        r["requests"] = int(req.shape[0])
        r["batch_size"] = b
        if pallas:
            r["interpret"] = not on_tpu
        results[name] = r
        note = "interpret-mode, not TPU-representative" if pallas and not on_tpu \
            else f"{r['rows_per_s']:,.0f} rows/s"
        print(f"  {name}: {r['rows_per_s']:,.0f} rows/s "
              f"p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms")
        rows.append((f"serve/{name}",
                     r["p50_ms"] * 1e3,
                     note))

    # quantized accuracy: measured max margin delta vs the provable bound
    sample = jnp.asarray(requests[:min(n_serve, 16_384)])
    m32 = boosting.predict(packed, sample, impl="fused")
    quant = {}
    for tag, qe in (("bits8", q8), ("bits16", q16)):
        mq = boosting.predict(qe, sample, impl="fused")
        delta = float(jnp.max(jnp.abs(mq - m32)))
        bound = margin_delta_bound(qe)
        quant[tag] = {"margin_delta": delta, "margin_bound": bound,
                      "within_bound": delta <= bound}
        print(f"  quantized {tag}: max margin delta {delta:.3e} "
              f"<= bound {bound:.3e}: {delta <= bound}")

    fused = results["fused_f32_vmap"]
    two = results["two_program_f32_vmap"]
    speedup = fused["rows_per_s"] / two["rows_per_s"]
    acceptance = {
        "fused_vs_two_program_x": speedup,
        "fused_beats_two_program": speedup > 1.0,
        "q8_delta_within_bound": quant["bits8"]["within_bound"],
        "q16_delta_within_bound": quant["bits16"]["within_bound"],
    }
    print(f"  fused vs two-program: {speedup:.2f}x "
          f"({'OK' if speedup > 1.0 else 'REGRESSION'})")
    rows.append(("serve/fused_vs_two_program", 0.0, f"{speedup:.2f}x"))

    payload = {
        "scale": "smoke" if smoke else scale(),
        "requests": n_serve,
        "batch_size": batch,
        "rounds": rounds,
        "total_trees": int(packed.total_trees),
        "variants": results,
        "quantized": quant,
        "acceptance": acceptance,
        # conservative machine-crossing floors (ci_guard): a fresh smoke run
        # must keep >= 35% of the banked fused throughput and stay under 5x
        # the banked p99 — wide enough for CI-runner variance, tight enough
        # to catch a serving-path regression (e.g. a silent fallback to the
        # two-program shape, which alone costs more than the slack)
        "ci": {
            "fused_rows_per_s_floor": 0.35 * fused["rows_per_s"],
            "fused_p99_ceiling_ms": 5.0 * fused["p99_ms"],
        },
    }
    save_report("serve_bench", payload)
    with open(os.path.join(ROOT, "BENCH_serve.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return rows


if __name__ == "__main__":
    import sys

    sys.exit(0 if main(smoke="--smoke" in sys.argv) is not None else 1)
