"""CI perf-regression guard for the training/communication hot paths.

Reads the COMMITTED BENCH_train.json / BENCH_comm.json baselines first, then
re-runs ``train_bench --smoke`` and ``comm_bench --smoke`` (which overwrite
those files with fresh results), and fails the build if the fresh run
regresses on any of the contracts this repo has already banked:

  * **compile counts** — the scanned engine (direct AND subtraction
    pipeline) must still compile exactly 1 XLA program;
  * **wire bytes** — every backend's measured histogram-phase reduction
    ratio must not drop below the committed baseline (ratios are
    shape-determined, so any drop is a real transport change, not noise),
    and every measured-vs-predicted reconciliation must stay exact;
  * **acceptance bars** — q8 >= 4x and subtraction >= 1.7x histogram-phase
    cuts stay satisfied;
  * **subtraction speedup floor** — the subtraction pipeline's measured
    on/off speedup must not fall below the conservative ``speedup_floor``
    recorded in the committed BENCH_train.json (0.75x of the measurement at
    record time, so CI timing noise passes but a pipeline regression fails);
  * **round-engine floors** (DESIGN.md §9) — the traced T-tree round
    program ships exactly ONE histogram collective per level (not T); the
    shared-root level-0 row volume equals ``n + T·rdr`` exactly (vs the
    direct ``T·n``) and cuts >= 1.5x at the probed rho = 0.8 / T = 4
    point; and depth-5 frontier compaction cuts histogram-phase bytes vs
    the uncompacted 2^L frontier with exact reconciliation (all of these
    are shape-determined, so equality/ratio checks are exact);
  * **sharding + async floors** (DESIGN.md §8/§10) — the bit-packed
    id_partition broadcast cuts >= 8x vs the int32 wire and the measured
    bytes sit on the packed model exactly; the async double-buffered
    exchange matches the sync wire bytes/AUC with exact reconciliation;
    and the >= 1M-row row-sharded training throughput stays above the
    committed ``rows_per_s_floor`` in BENCH_train.json (half the banked
    measurement, so machine variance passes but a sharded-pipeline
    regression or a silent single-device fallback fails);
  * **K-channel floors** (DESIGN.md §11) — measured wire bytes reconcile
    exactly against the K-generalized wire model at K=1 AND K=3 (the
    softmax3 row's widened 2K+1-stat exchange), and the federated
    multiclass accuracy beats the majority-class baseline;
  * **telemetry overhead** (DESIGN.md §12) — the traced scan engine
    (telemetry block + live Tracer + segment ticks) must stay within 5%
    of the untraced steady-round time of the SAME bench run (ratio of the
    same run, machine-independent), and the traced variant must itself
    compile exactly 1 program (the telemetry flag is jit-static);
  * **serving-tier floors** (DESIGN.md §14) — the fused bin+traverse
    program must beat the two-program (separate bin then traverse)
    baseline on steady-state rows/s WITHIN the same fresh run (ratio of
    the same run, machine-independent); the quantized ensembles' measured
    max margin delta vs the f32 oracle must sit inside the provable
    ``margin_delta_bound`` at 8 AND 16 bits; and the fused vmap
    throughput / p99 must stay above the committed rows/s floor and
    below the committed p99 ceiling in BENCH_serve.json (0.35x / 5x of
    the banked measurement — wide enough for runner variance, tighter
    than the cost of silently falling back to the two-program shape);
  * **chaos transport floors** (DESIGN.md §13) — the ``-chaos`` wrapper at
    a zero-fault spec is bit-identical to the wrapped backend and within
    5% of its warm train wall (ratio of the same run); under seeded
    drop/corrupt faults the checksum-verified retransmission keeps the
    model (and AUC) bit-identical to the raw backend, meters > 0 retry
    bytes, and the ledger reconciles exactly including the ``retries``
    phase.

Timing comparisons are deliberately ratio-of-the-same-run (subtraction on vs
off inside one bench invocation), never absolute seconds across machines.

    PYTHONPATH=src python -m benchmarks.ci_guard
"""

from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
#: ratio slack for shape-determined byte ratios: these are exact quantities,
#: the epsilon only absorbs float formatting round-trips.
RATIO_EPS = 1e-6


def _load(name: str) -> dict:
    with open(os.path.join(ROOT, name)) as f:
        return json.load(f)


def main() -> int:
    base_train = _load("BENCH_train.json")
    base_comm = _load("BENCH_comm.json")
    try:
        base_serve = _load("BENCH_serve.json")
    except FileNotFoundError:
        base_serve = {}

    from benchmarks import comm_bench, serve_bench, train_bench

    print("== ci_guard: re-running train_bench --smoke ==")
    train_bench.main(smoke=True)
    print("== ci_guard: re-running comm_bench --smoke ==")
    comm_bench.main(smoke=True)
    print("== ci_guard: re-running serve_bench --smoke ==")
    serve_bench.main(smoke=True)

    fresh_train = _load("BENCH_train.json")
    fresh_comm = _load("BENCH_comm.json")
    fresh_serve = _load("BENCH_serve.json")

    failures = []

    def check(cond: bool, msg: str) -> None:
        print(f"  [{'OK' if cond else 'FAIL'}] {msg}")
        if not cond:
            failures.append(msg)

    # -- compile counts ------------------------------------------------------
    check(fresh_train.get("scan_compiles") == 1,
          f"scan engine compiles == 1 (got {fresh_train.get('scan_compiles')})")
    sub = fresh_train.get("subtraction", {})
    check(sub.get("scan_compiles") == 1,
          f"subtraction scan compiles == 1 (got {sub.get('scan_compiles')})")

    # -- telemetry overhead (ISSUE 8) ----------------------------------------
    tele = fresh_train.get("telemetry", {})
    check(tele.get("scan_compiles") == 1,
          f"traced scan compiles == 1 (got {tele.get('scan_compiles')})")
    ovh = tele.get("overhead_x", float("inf"))
    check(ovh <= 1.05,
          f"traced steady round within 5% of untraced ({ovh:.3f}x <= 1.05x)")

    # -- wire-byte ratios + reconciliation -----------------------------------
    for name, fresh in fresh_comm.get("backends", {}).items():
        check(fresh.get("measured_matches_predicted") is True,
              f"{name}: measured == predicted (ledger reconciliation)")
        base = base_comm.get("backends", {}).get(name)
        if base is None:
            continue  # a newly added backend has no baseline yet
        b, f = (base.get("histogram_phase_reduction_x"),
                fresh.get("histogram_phase_reduction_x"))
        if b is not None and f is not None:
            check(f >= b - RATIO_EPS,
                  f"{name}: histogram-phase reduction {f:.3f}x >= "
                  f"baseline {b:.3f}x")

    acc = fresh_comm.get("acceptance", {})
    check(acc.get("q8_histogram_phase_reduction_ge_4x") is True,
          "q8 histogram-phase reduction >= 4x")
    check(acc.get("sub_histogram_phase_reduction_ge_1.7x") is True,
          "subtraction histogram-phase reduction >= 1.7x")

    # -- round-engine floors (ISSUE 5) ---------------------------------------
    check(acc.get("round_one_collective_per_level") is True,
          "round engine: one histogram collective per level (not T)")
    check(acc.get("round_level0_rows_exact") is True,
          "round engine: level-0 pass rows == T*n (direct) / n + T*rdr "
          "(shared-root), exactly")
    cut = acc.get("round_level0_row_cut_x", 0.0)
    check(cut >= 1.5,
          f"round engine: shared-root level-0 row cut {cut:.2f}x >= 1.5x")
    d5 = acc.get("depth5_compaction_hist_byte_cut_x", 0.0)
    check(d5 > 1.0 + RATIO_EPS,
          f"depth-5 compaction cuts histogram bytes ({d5:.2f}x > 1x)")
    check(acc.get("depth5_compaction_reconciled") is True,
          "depth-5 compaction: measured == active-width wire model")
    base_d5 = base_comm.get("acceptance", {}).get(
        "depth5_compaction_hist_byte_cut_x")
    if base_d5 is not None:
        check(d5 >= base_d5 - RATIO_EPS,
              f"depth-5 compaction cut {d5:.3f}x >= baseline {base_d5:.3f}x")

    # -- K-channel objective layer (ISSUE 7) ---------------------------------
    check(acc.get("k1_measured_match_predicted") is True,
          "K=1 (binary) measured bytes == wire model exactly")
    check(acc.get("k3_measured_match_predicted") is True,
          "K=3 (softmax3) measured bytes == wire model exactly "
          "(widened 2K+1-stat exchange)")
    mc_acc = acc.get("multiclass_acc", 0.0)
    check(mc_acc >= 0.55,
          f"softmax3 federated accuracy {mc_acc:.3f} beats the 3-class "
          f"majority baseline")

    # -- chaos transport floors (ISSUE 9) ------------------------------------
    check(acc.get("chaos_zero_fault_bit_identical") is True,
          "chaos wrapper at zero faults: model bit-identical to the "
          "wrapped backend")
    ch_ovh = acc.get("chaos_zero_fault_overhead_x", float("inf"))
    check(ch_ovh <= 1.05,
          f"chaos wrapper at zero faults within 5% of raw warm wall "
          f"({ch_ovh:.3f}x <= 1.05x)")
    check(acc.get("chaos_faulty_bit_identical") is True,
          "chaos faulty run: checksum-verified retransmission keeps the "
          "model bit-identical to the raw backend")
    check(acc.get("chaos_faulty_auc_equal_raw") is True,
          "chaos faulty run: AUC == raw backend exactly")
    check(acc.get("chaos_faulty_reconciled") is True,
          "chaos faulty run: measured == predicted incl. the retries phase")
    check(acc.get("chaos_retry_bytes_gt_0") is True,
          f"chaos faulty run meters retransmission bytes "
          f"({acc.get('chaos_retry_bytes', 0)} B > 0)")

    # -- sharding + async floors (ISSUE 6) -----------------------------------
    check(acc.get("id_partition_cut_ge_8x") is True,
          f"id_partition bit-packing cut "
          f"{acc.get('id_partition_cut_x', 0):.1f}x >= 8x")
    check(acc.get("id_partition_measured_on_packed_model") is True,
          "id_partition measured bytes sit on the packed (1 bit/row) model")
    check(acc.get("async_measured_match_predicted") is True,
          "async exchange: measured == predicted (one logical collective "
          "per level)")
    check(acc.get("async_bytes_equal_sync") is True,
          "async exchange: wire bytes == sync vfl-histogram exactly")
    check(acc.get("async_auc_equal_sync") is True,
          "async exchange: AUC == sync vfl-histogram exactly")

    sh = fresh_train.get("sharded", {})
    check(sh.get("n", 0) >= 1_000_000,
          f"sharded throughput bench runs >= 1M rows (got {sh.get('n')})")
    check(sh.get("data_shards", 0) >= 2,
          f"sharded bench uses >= 2 data shards (got {sh.get('data_shards')})")
    rows_floor = base_train.get("sharded", {}).get("rows_per_s_floor")
    if rows_floor is not None:
        got_rows = sh.get("rows_per_s", 0.0)
        check(got_rows >= rows_floor,
              f"sharded rows/s {got_rows:,.0f} >= committed floor "
              f"{rows_floor:,.0f}")
    else:
        print("  [--] no committed sharded rows/s floor yet (first run)")

    # -- serving-tier floors (ISSUE 10) --------------------------------------
    sacc = fresh_serve.get("acceptance", {})
    sx = sacc.get("fused_vs_two_program_x", 0.0)
    check(sacc.get("fused_beats_two_program") is True,
          f"fused bin+traverse beats two-program baseline "
          f"({sx:.2f}x > 1x, same-run ratio)")
    check(sacc.get("q8_delta_within_bound") is True,
          "q8 serving: measured margin delta within the provable bound")
    check(sacc.get("q16_delta_within_bound") is True,
          "q16 serving: measured margin delta within the provable bound")
    sfused = fresh_serve.get("variants", {}).get("fused_f32_vmap", {})
    srows_floor = base_serve.get("ci", {}).get("fused_rows_per_s_floor")
    if srows_floor is not None:
        got_srows = sfused.get("rows_per_s", 0.0)
        check(got_srows >= srows_floor,
              f"fused serving rows/s {got_srows:,.0f} >= committed floor "
              f"{srows_floor:,.0f}")
    else:
        print("  [--] no committed serving rows/s floor yet (first run)")
    sp99_ceil = base_serve.get("ci", {}).get("fused_p99_ceiling_ms")
    if sp99_ceil is not None:
        got_p99 = sfused.get("p99_ms", float("inf"))
        check(got_p99 <= sp99_ceil,
              f"fused serving p99 {got_p99:.2f}ms <= committed ceiling "
              f"{sp99_ceil:.2f}ms")
    else:
        print("  [--] no committed serving p99 ceiling yet (first run)")

    # -- subtraction speedup floor -------------------------------------------
    floor = base_train.get("subtraction", {}).get("speedup_floor")
    if floor is not None:
        got = sub.get("on_off_speedup_x", 0.0)
        check(got >= floor,
              f"subtraction on/off speedup {got:.3f}x >= committed floor "
              f"{floor:.3f}x")
    else:
        print("  [--] no committed subtraction speedup floor yet (first run)")

    if failures:
        print(f"\nci_guard: {len(failures)} check(s) FAILED")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nci_guard: all perf-regression checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
