"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick scale
    REPRO_BENCH_SCALE=full PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark claim) and
writes JSON artifacts under reports/.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        comm_bench,
        communication,
        figures,
        kernel_bench,
        obs_bench,
        paper_tables,
        predict_bench,
        roofline_report,
        runtime_model,
        serve_bench,
        train_bench,
    )

    modules = [
        ("communication", communication),
        ("comm_bench", comm_bench),
        ("kernel_bench", kernel_bench),
        ("train_bench", train_bench),
        ("predict_bench", predict_bench),
        ("serve_bench", serve_bench),
        ("obs_bench", obs_bench),
        ("runtime_model", runtime_model),
        ("paper_tables", paper_tables),
        ("figures", figures),
        ("roofline_report", roofline_report),
    ]
    rows = []
    failures = 0
    for name, mod in modules:
        print(f"== {name} ==")
        try:
            rows.extend(mod.main())
        except Exception:
            failures += 1
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
