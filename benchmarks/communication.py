"""Communication-volume benchmark (the paper's qualitative efficiency claim,
§1/§5, made quantitative via the protocol ledger).

Compares, per training run: SecureBoost vs FedGBF vs Dynamic FedGBF under
(a) the paper-faithful full-histogram exchange and (b) the beyond-paper
argmax candidate exchange (aggregator.py) — the collective-term optimisation
carried into §Perf.

This module prices the *paper-world* Paillier protocol model only; the
compressed-transport subsystem's **measured** wire bytes (q8/q16/top-k/GOSS,
reconciled against the wire model) live in benchmarks/comm_bench.py ->
BENCH_comm.json (DESIGN.md §5).
"""

from __future__ import annotations

import time

from benchmarks.common import save_report
from repro.core import boosting
from repro.federation import protocol


def main() -> list:
    specs = {
        "give_me_some_credit": protocol.ProtocolSpec(
            n_samples=105_000, party_dims=(5, 5), num_bins=32, max_depth=3
        ),
        "default_credit_card": protocol.ProtocolSpec(
            n_samples=21_000, party_dims=(13, 10), num_bins=32, max_depth=3
        ),
    }
    configs = {
        "secureboost": boosting.secureboost_config(rounds=20),
        "fedgbf_static": boosting.FedGBFConfig(
            rounds=20, n_trees_max=5, n_trees_min=5,
            rho_id_min=0.3, rho_id_max=0.3,
        ),
        "dynamic_fedgbf": boosting.dynamic_fedgbf_config(rounds=20),
    }

    t0 = time.perf_counter()
    table = {}
    rows = []
    for ds, spec in specs.items():
        for model, cfg in configs.items():
            for agg in ("histogram", "argmax"):
                s = protocol.ProtocolSpec(
                    n_samples=spec.n_samples, party_dims=spec.party_dims,
                    num_bins=spec.num_bins, max_depth=spec.max_depth,
                    aggregation=agg,
                )
                cost = protocol.run_cost(s, cfg)
                table[f"{ds}/{model}/{agg}"] = cost.breakdown()
                print(f"  {ds:22s} {model:15s} {agg:9s} "
                      f"total={cost.total/1e6:8.1f} MB "
                      f"(hist={cost.histograms/1e6:7.1f}, "
                      f"grad={cost.grad_broadcast/1e6:7.1f})")

    save_report("communication", table)
    for ds in specs:
        sb = table[f"{ds}/secureboost/histogram"]["total"]
        dyn = table[f"{ds}/dynamic_fedgbf/histogram"]["total"]
        dyn_arg = table[f"{ds}/dynamic_fedgbf/argmax"]["total"]
        rows.append((
            f"communication/{ds}",
            (time.perf_counter() - t0) * 1e6 / 12,
            f"dyn_vs_sb={dyn/sb:.2f}x;argmax_saves={1 - dyn_arg/dyn:.2%}",
        ))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
