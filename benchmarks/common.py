"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import time

REPORT_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "reports")


def scale() -> str:
    """REPRO_BENCH_SCALE=full reproduces the paper's exact round counts and
    dataset sizes; the default 'quick' keeps `-m benchmarks.run` under ~10 min
    on one CPU core (same relative comparisons, smaller n / fewer rounds)."""
    return os.environ.get("REPRO_BENCH_SCALE", "quick")


def save_report(name: str, payload) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
