"""Tables 2-3 reproduction: Dynamic FedGBF vs SecureBoost (vs Federated
Forest) — AUC/ACC/F1 + estimated runtimes [T_F^L, T_F^U] and T_S.

Quality numbers come from REAL training runs on the synthetic stand-in
datasets (data/synthetic.py; the Kaggle originals are offline-unavailable).
Runtime estimates follow the paper's own methodology (eqs. 8-11): measure
T_unit = one full-data full-feature tree, then scale analytically.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, save_report, scale
from repro.core import binning, boosting, forest, losses, metrics, runtime_model
from repro.core.types import TreeConfig
from repro.data import synthetic


def measure_t_unit(x, y, cfg: TreeConfig, repeats: int = 3) -> float:
    """T_unit: one decision tree on ALL data and features (paper §4.2.2)."""
    binned, _ = binning.fit_bin(jnp.asarray(x), cfg.num_bins)
    yj = jnp.asarray(y)
    g, h = losses.grad_hess("logistic", yj, jnp.zeros_like(yj))
    n, d = binned.shape
    smask = jnp.ones((1, n), jnp.float32)
    fmask = jnp.ones((1, d), bool)
    # warmup/compile
    trees, _ = forest.build_forest(binned, g, h, smask, fmask, cfg)
    jax.block_until_ready(trees)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        trees, _ = forest.build_forest(binned, g, h, smask, fmask, cfg)
        jax.block_until_ready(trees)
        times.append(time.perf_counter() - t0)
    return min(times)


def run_dataset(name: str, rounds_list, n_override=None) -> dict:
    ds = synthetic.load(name, n=n_override)
    xtr = jnp.asarray(ds.x_train)
    ytr = jnp.asarray(ds.y_train)
    xte = jnp.asarray(ds.x_test)
    yte = jnp.asarray(ds.y_test)
    tree_cfg = TreeConfig(max_depth=3, num_bins=32)
    t_unit = measure_t_unit(ds.x_train, ds.y_train, tree_cfg)

    rows = []
    for rounds in rounds_list:
        for model_name, cfg_fn in (
            ("dynamic_fedgbf", boosting.dynamic_fedgbf_config),
            ("secureboost", boosting.secureboost_config),
            ("federated_forest", None),
        ):
            if cfg_fn is None:
                cfg = boosting.federated_forest_config(
                    n_trees=rounds, rho_id=0.6, tree=tree_cfg
                )
            else:
                cfg = cfg_fn(rounds=rounds, tree=tree_cfg)
            with Timer() as t:
                model, hist = boosting.train_fedgbf(
                    xtr, ytr, cfg, jax.random.PRNGKey(0), eval_every=rounds
                )
            test_margin = boosting.predict(model, xte)
            train_rep = hist.train[-1]
            test_rep = metrics.classification_report(yte, test_margin)

            if model_name == "secureboost":
                est = runtime_model.estimate_secureboost_runtime(rounds, t_unit)
                est_lo = est_hi = est
            else:
                r = runtime_model.estimate_fedgbf_runtime(cfg, t_unit)
                est_lo, est_hi = r.as_interval()
            rows.append({
                "dataset": name, "model": model_name, "rounds": rounds,
                "train_auc": train_rep["auc"], "train_acc": train_rep["acc"],
                "train_f1": train_rep["f1"],
                "test_auc": test_rep["auc"], "test_acc": test_rep["acc"],
                "test_f1": test_rep["f1"],
                "estimated_time_lo_s": est_lo, "estimated_time_hi_s": est_hi,
                "wall_time_s": t.seconds,
                "total_trees": model.total_trees,
            })
            print(
                f"  {name} {model_name:17s} M={rounds:3d} "
                f"test_auc={test_rep['auc']:.4f} acc={test_rep['acc']:.4f} "
                f"f1={test_rep['f1']:.4f} est=[{est_lo:.1f},{est_hi:.1f}]s "
                f"wall={t.seconds:.1f}s"
            )
    return {"t_unit_s": t_unit, "rows": rows}


def main() -> list:
    quick = scale() == "quick"
    rounds_list = [20, 50] if quick else [20, 50, 100]
    results = {}
    t0 = time.perf_counter()
    results["default_credit_card"] = run_dataset(
        "default_credit_card", rounds_list,
        n_override=15_000 if quick else None,
    )
    results["give_me_some_credit"] = run_dataset(
        "give_me_some_credit", rounds_list,
        n_override=30_000 if quick else None,
    )
    save_report("paper_tables", results)

    # Headline claims (paper §4.3): quality parity + >=70% ideal-parallel
    # time reduction at equal rounds.
    out = []
    for dsname, res in results.items():
        rows = res["rows"]
        for rounds in rounds_list:
            fg = next(r for r in rows if r["model"] == "dynamic_fedgbf"
                      and r["rounds"] == rounds)
            sb = next(r for r in rows if r["model"] == "secureboost"
                      and r["rounds"] == rounds)
            auc_gap = sb["test_auc"] - fg["test_auc"]
            reduction = 1.0 - fg["estimated_time_lo_s"] / sb["estimated_time_lo_s"]
            out.append((
                f"paper_tables/{dsname}/M{rounds}",
                (time.perf_counter() - t0) * 1e6 / max(len(rows), 1),
                f"auc_gap={auc_gap:.4f};ideal_time_reduction={reduction:.2%}",
            ))
    return out


if __name__ == "__main__":
    for row in main():
        print(row)
