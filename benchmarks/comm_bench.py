"""Communication-efficiency benchmark: measured bytes/round + AUC per backend.

The companion of the compression subsystem (federation/compress.py,
DESIGN.md §5): trains the synthetic credit benchmark under every VFL
transport and reports, per backend,

  * **measured** wire bytes (every collective's actual payload, via
    ``compress.probe_tree_cost`` scaled by the training schedule),
  * the **predicted** wire model and the exact-match reconciliation verdict
    (``protocol.ProtocolLedger``),
  * the paper-world **Paillier protocol** prediction alongside,
  * validation **AUC** and its delta against the uncompressed
    ``vfl-histogram`` baseline,

plus ±GOSS rows (a sampling policy, not a transport: same wire bytes,
different statistical efficiency — and a smaller Paillier-model gradient
volume at lower rho).  Results land in reports/comm_bench.json and the
repo-root BENCH_comm.json.

Acceptance tracked here (ISSUE 3): >= 4x histogram-phase reduction for
``vfl-histogram-q8`` vs ``vfl-histogram`` at AUC delta <= 1e-3; measured ==
predicted exactly for the lossless backends.  (ISSUE 4): >= 1.7x
histogram-phase reduction for the sibling-subtraction rows (``+sub``,
DESIGN.md §6) with exact reconciliation, composing with q8.  (ISSUE 5,
round engine): the ``round_engine`` section records the structural floors
``benchmarks/ci_guard.py`` enforces — exactly ONE histogram collective per
level (not T), the shared-root level-0 row volume ``n + T·rdr`` vs the
direct ``T·n``, and the depth-5 frontier-compaction histogram-byte cut vs
the uncompacted 2^L frontier (exact reconciliation either way).  (ISSUE 6):
the bit-packed id_partition broadcast cuts >= 8x vs the int32 wire (32x
measured), and the ``vfl-histogram-async`` double-buffered exchange
(DESIGN.md §10) matches the sync row's wire bytes and AUC exactly with an
exact ledger reconciliation.  (ISSUE 9, chaos transport): the ``-chaos``
wrapper is bit-identical and <= 1.05x warm wall at zero faults, and under
seeded drop/corrupt faults the checksum-verified retransmission keeps the
model bit-identical with the retried bytes reconciling exactly.

    PYTHONPATH=src python -m benchmarks.comm_bench [--smoke] [--dataset X]

``--dataset`` grounds the AUC deltas on real data: a path to a labelled
CSV (``repro.data.tabular.load_csv``; opt-in) — the synthetic credit
generator stays the CI default.

(Forces 8 host devices when XLA_FLAGS is unset — the VFL backends need a
party axis.)
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_report, scale
from repro.compat import use_mesh
from repro.core import boosting, metrics
from repro.core.types import TreeConfig
from repro.data import synthetic, tabular
from repro.federation import compress, protocol, vfl

PARTIES = 2

#: benchmarked backends:
#:   name -> (aggregation, transport, sampling, hist_sub, async_exchange)
#: ``+sub`` rows run the sibling-subtraction pipeline (DESIGN.md §6):
#: same registry backend, ``TreeConfig.hist_subtraction`` switched on — the
#: per-level exchange ships only the left children (1.75x histogram-phase
#: cut at depth 3), composing multiplicatively with quantization.
#: ``-async`` rows run the double-buffered level exchange (DESIGN.md §10):
#: identical logical payload in two overlapping transfers — wire bytes,
#: reconciliation, and AUC must all match the sync row exactly.
BACKENDS = {
    "vfl-histogram": ("histogram", None, "uniform", False, False),
    "vfl-argmax": ("argmax", None, "uniform", False, False),
    "vfl-histogram-q8": ("histogram", compress.Q8, "uniform", False, False),
    "vfl-histogram-q16": ("histogram", compress.Q16, "uniform", False, False),
    "vfl-argmax-topk": ("argmax", compress.TOPK, "uniform", False, False),
    "vfl-histogram+goss": ("histogram", None, "goss", False, False),
    "vfl-histogram-q8+goss": ("histogram", compress.Q8, "goss", False, False),
    "vfl-histogram+sub": ("histogram", None, "uniform", True, False),
    "vfl-histogram-q8+sub": ("histogram", compress.Q8, "uniform", True, False),
    "vfl-histogram-async": ("histogram", None, "uniform", False, True),
    "vfl-histogram-async-q8+sub": ("histogram", compress.Q8, "uniform", True,
                                   True),
}


def run_backend(name, mesh, ds, x_train, x_test, d_pad, cfg, tree_cfg):
    aggregation, transport, sampling, hist_sub, async_ex = BACKENDS[name]
    tree_cfg = dataclasses.replace(tree_cfg, hist_subtraction=hist_sub)
    run_cfg = dataclasses.replace(cfg, sampling=sampling, tree=tree_cfg)
    backend = vfl.make_vfl_backend(
        mesh, tree_cfg, aggregation=aggregation, transport=transport,
        async_exchange=async_ex,
    )
    t0 = time.perf_counter()
    model, _ = boosting.train_fedgbf(
        jnp.asarray(x_train), jnp.asarray(ds.y_train), run_cfg,
        jax.random.PRNGKey(0), backend=backend,
    )
    train_s = time.perf_counter() - t0
    auc = float(metrics.auc(
        jnp.asarray(ds.y_test), boosting.predict(model, jnp.asarray(x_test))
    ))

    # Measured bytes: abstract-evaluate the backend's real program; the
    # ledger scales per-tree payloads by the schedule and reconciles against
    # the predicted wire model.
    ledger = compress.reconciled_ledger(
        mesh, tree_cfg, run_cfg, aggregation=aggregation, transport=transport,
        n_samples=x_train.shape[0], num_features=d_pad,
        async_exchange=async_ex,
    )
    breakdown = ledger.breakdown()
    return {
        "auc": auc,
        "train_s": train_s,
        "measured_bytes": breakdown["measured"],
        "measured_total": breakdown["measured_total"],
        "measured_bytes_per_round": breakdown["measured_total"] / run_cfg.rounds,
        "predicted_wire": breakdown["predicted"],
        "measured_matches_predicted": ledger.matches(),
        "paillier_model_total": breakdown["predicted_paillier"]["total"],
        "wire_mode_totals": breakdown["modes"],
        "hist_phase_by_mode": breakdown["hist_phase_by_mode"],
        # per-level histogram bytes one party ships per tree: the level
        # profile the subtraction pipeline reshapes (full root, half below)
        "hist_bytes_per_level_per_party_tree": (
            protocol.wire_hist_level_bytes(
                d_pad // PARTIES, tree_cfg.num_bins, tree_cfg.max_depth,
                transport, tree_cfg.hist_subtraction,
            ) if aggregation == "histogram" else []
        ),
    }


def multiclass_row(mesh, rounds: int, quick: bool) -> dict:
    """K=3 softmax over the 3-tier synthetic credit dataset (DESIGN.md §11):
    federated histogram training with the widened 2K+1-stat exchange, its
    accuracy/macro-F1, and the exact byte reconciliation at K=3 — the
    K-channel wire model ci_guard holds alongside the K=1 rows."""
    ds = synthetic.load("credit_risk_tiers", n=3_000 if quick else 8_000)
    x_train, d_pad = tabular.pad_features(ds.x_train, PARTIES)
    x_test, _ = tabular.pad_features(ds.x_test, PARTIES)
    tree_cfg = TreeConfig(max_depth=3, num_bins=32)
    cfg = boosting.dynamic_fedgbf_config(
        rounds=rounds, tree=tree_cfg, loss="softmax3"
    )
    backend = vfl.make_vfl_backend(mesh, tree_cfg, aggregation="histogram")
    t0 = time.perf_counter()
    model, _ = boosting.train_fedgbf(
        jnp.asarray(x_train), jnp.asarray(ds.y_train), cfg,
        jax.random.PRNGKey(0), backend=backend,
    )
    train_s = time.perf_counter() - t0
    rep = metrics.multiclass_report(
        jnp.asarray(ds.y_test), boosting.predict(model, jnp.asarray(x_test))
    )
    ledger = compress.reconciled_ledger(
        mesh, tree_cfg, cfg, aggregation="histogram", transport=None,
        n_samples=x_train.shape[0], num_features=d_pad, n_channels=3,
    )
    breakdown = ledger.breakdown()
    return {
        "dataset": "credit_risk_tiers(synthetic)",
        "loss": "softmax3",
        "n_channels": 3,
        "acc": rep["acc"],
        "macro_f1": rep["macro_f1"],
        "train_s": train_s,
        "measured_bytes": breakdown["measured"],
        "measured_total": breakdown["measured_total"],
        "predicted_wire": breakdown["predicted"],
        "measured_matches_predicted": ledger.matches(),
    }


def round_engine_metrics(mesh, tree_cfg, n: int, d_pad: int, n_trees: int) -> dict:
    """Round-engine structural measurements (DESIGN.md §9) for ci_guard:

    * ``hist_collectives_per_level`` — histogram records in the traced
      T-tree round program divided by the level count (must be exactly 1:
      one ``(T, active, d_party, B, 3)`` collective per level, not T);
    * ``level0_rows_*`` — trace-time histogram row volume at level 0,
      direct (``T·n``) vs shared-root (``n + T·rdr``), both shape-exact;
    * ``depth5_compaction`` — measured (ledger-reconciled) histogram-phase
      bytes of a depth-5 tree with and without a ``max_active_nodes``
      budget, and the cut ratio vs the uncompacted 2^L frontier.
    """
    from repro.core import histogram as hist_mod
    from repro.core import tree as tree_mod

    rc = compress.probe_round_collectives(
        mesh, tree_cfg, n_trees, aggregation="histogram",
        n_samples=n, num_features=d_pad,
    )
    out = {
        "n_trees": n_trees,
        "collective_counts": rc["counts"],
        "hist_collectives_per_level":
            rc["counts"].get("histograms", 0) / tree_cfg.max_depth,
    }

    # level-0 pass volume: probe the centralized round program's histogram
    # row traffic through the trace-time pass meter.
    import jax as _jax
    import jax.numpy as jnp
    rdr = max(1, n - int(round(n * 0.8)))  # the rho = 0.8 crossover point

    def _probe(rows):
        hist_mod.PASS_METER = []
        try:
            sds = _jax.ShapeDtypeStruct
            _jax.eval_shape(
                lambda b, g, h, sm, fm: tree_mod.build_round(
                    b, g, h, sm, fm, tree_cfg, root_delta_rows=rows
                ),
                sds((n, d_pad), jnp.int32), sds((n,), jnp.float32),
                sds((n,), jnp.float32), sds((n_trees, n), jnp.float32),
                sds((n_trees, d_pad), bool),
            )
            level0 = [e for e in hist_mod.PASS_METER
                      if e["tag"] in ("round", "root_delta")]
            first = level0[0]
            total = first["rows"] * first["trees"]
            if rows and len(level0) > 1:
                total += level0[1]["rows"] * level0[1]["trees"]
            return total
        finally:
            hist_mod.PASS_METER = None

    out["level0_rows_direct"] = _probe(0)
    out["level0_rows_shared_root"] = _probe(rdr)
    out["level0_rows_expected_direct"] = n_trees * n
    out["level0_rows_expected_shared_root"] = n + n_trees * rdr
    out["level0_row_cut_x"] = (
        out["level0_rows_direct"] / out["level0_rows_shared_root"]
    )

    # depth-5 compaction: measured histogram-phase bytes (exact-reconciled)
    # with and without the static live-slot budget.
    budget = 4
    depth5 = {}
    for tag, cap in (("uncompacted", 0), ("budget", budget)):
        tcfg = dataclasses.replace(tree_cfg, max_depth=5, max_active_nodes=cap)
        per_tree, _ = compress.probe_tree_cost(
            mesh, tcfg, aggregation="histogram",
            n_samples=n, num_features=d_pad,
        )
        wire = protocol.wire_party_tree_cost(
            n, d_pad // PARTIES, tcfg.num_bins, 5, "histogram", None,
            tcfg.hist_subtraction, cap,
        )
        depth5[tag] = {
            "hist_bytes_per_tree": per_tree["histograms"],
            "reconciled": per_tree["histograms"] == wire["histograms"],
        }
    depth5["max_active_nodes"] = budget
    depth5["hist_byte_cut_x"] = (
        depth5["uncompacted"]["hist_bytes_per_tree"]
        / depth5["budget"]["hist_bytes_per_tree"]
    )
    out["depth5_compaction"] = depth5
    return out


def chaos_rows(mesh, ds, x_train, x_test, d_pad, cfg, tree_cfg) -> dict:
    """Chaos-transport rows (DESIGN.md §13) for ci_guard:

    * **zero-fault**: the ``-chaos`` wrapper at a zero-fault spec must be
      bit-identical to the wrapped backend and cost <= 1.05x its warm
      train wall (the checksum verify is the only extra work);
    * **faulty** (5% drop + 2% corrupt): training must complete with the
      model STILL bit-identical (checksum-verified retransmission recovers
      every fault) and the ledger must reconcile exactly — the retried
      payloads + checksums land in the dedicated ``retries`` phase.
    """
    from repro.federation import chaos as chaos_mod

    def make_runner(chaos):
        backend = vfl.make_vfl_backend(
            mesh, tree_cfg, aggregation="histogram", chaos=chaos
        )

        def once():
            t0 = time.perf_counter()
            model, _ = boosting.train_fedgbf(
                jnp.asarray(x_train), jnp.asarray(ds.y_train), cfg,
                jax.random.PRNGKey(0), backend=backend,
            )
            return model, time.perf_counter() - t0

        return once

    def model_bytes(model):
        from repro.core.types import pack_ensemble

        return b"".join(np.ascontiguousarray(np.asarray(l)).tobytes()
                        for l in jax.tree.leaves(pack_ensemble(model)))

    def auc_of(model):
        return float(metrics.auc(
            jnp.asarray(ds.y_test),
            boosting.predict(model, jnp.asarray(x_test)),
        ))

    spec = chaos_mod.ChaosSpec(drop=0.05, corrupt=0.02, seed=13)
    base_run = make_runner(None)
    zf_run = make_runner(chaos_mod.ChaosSpec())
    faulty_run = make_runner(spec)
    base_model = base_run()[0]  # cold calls: trace + compile
    zf_model = zf_run()[0]
    faulty_model = faulty_run()[0]
    # overhead_x compares min-of-N *interleaved* warm repeats: single
    # warm calls are ~1s at smoke scale, so both scheduler noise and
    # slow machine-load drift between measurements would swamp the
    # checksum overhead being measured — interleaving cancels the drift.
    base_s = zf_s = faulty_s = float("inf")
    for _ in range(5):
        base_s = min(base_s, base_run()[1])
        zf_s = min(zf_s, zf_run()[1])
        faulty_s = min(faulty_s, faulty_run()[1])

    base_bytes = model_bytes(base_model)
    ledger = compress.reconciled_ledger(
        mesh, tree_cfg, cfg, aggregation="histogram", transport=None,
        n_samples=x_train.shape[0], num_features=d_pad, chaos=spec,
    )
    rec = ledger.reconcile()
    return {
        "spec": spec.tag,
        "zero_fault_bit_identical": model_bytes(zf_model) == base_bytes,
        "faulty_bit_identical": model_bytes(faulty_model) == base_bytes,
        "auc_raw": auc_of(base_model),
        "auc_faulty": auc_of(faulty_model),
        "base_warm_s": base_s,
        "zero_fault_warm_s": zf_s,
        "faulty_warm_s": faulty_s,
        "zero_fault_overhead_x": zf_s / base_s if base_s > 0 else 1.0,
        "faulty_measured_match_predicted": ledger.matches(),
        "retry_bytes": rec["retries"]["measured"],
        "measured_total": rec["total"]["measured"],
    }


def main(smoke: bool = False, dataset: str | None = None) -> list:
    if len(jax.devices()) < PARTIES:
        # Another benchmark module initialized jax single-device before our
        # XLA_FLAGS hook could run (the benchmarks.run path): re-exec in a
        # subprocess with forced host devices, same artifact either way.
        import subprocess
        import sys

        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        cmd = [sys.executable, "-m", "benchmarks.comm_bench"]
        if smoke:
            cmd.append("--smoke")
        if dataset:
            cmd += ["--dataset", dataset]
        subprocess.run(cmd, env=env, check=True)
        return [("comm/subprocess", 0.0, "see BENCH_comm.json")]
    quick = smoke or scale() == "quick"
    n, rounds = (3_000, 4) if quick else (8_000, 8)

    if dataset:
        # opt-in real data (tabular.load_csv); synthetic stays the CI
        # default so committed baselines are machine-independent.
        ds = tabular.load_csv(dataset, max_rows=None if not quick else n)
    else:
        ds = synthetic.load("default_credit_card", n=n)
    x_train, d_pad = tabular.pad_features(ds.x_train, PARTIES)
    x_test, _ = tabular.pad_features(ds.x_test, PARTIES)
    mesh = jax.make_mesh(
        (len(jax.devices()) // PARTIES, PARTIES), ("data", "model")
    )
    tree_cfg = TreeConfig(max_depth=3, num_bins=32)
    cfg = boosting.dynamic_fedgbf_config(rounds=rounds, tree=tree_cfg)

    results = {
        "dataset": ds.name if dataset else "default_credit_card(synthetic)",
        "n_train": int(x_train.shape[0]), "d": int(d_pad),
        "rounds": rounds, "parties": PARTIES,
        "schedule": "dynamic fedgbf (trees 5 -> 2, rho 0.1 -> 0.3)",
        "backends": {},
    }
    n = int(x_train.shape[0])
    with use_mesh(mesh):
        for name in BACKENDS:
            results["backends"][name] = run_backend(
                name, mesh, ds, x_train, x_test, d_pad, cfg, tree_cfg
            )
            r = results["backends"][name]
            print(f"  {name:24s} auc={r['auc']:.4f} "
                  f"bytes/round={r['measured_bytes_per_round']/1e3:8.1f} kB "
                  f"(hist {r['measured_bytes'].get('histograms', 0)/1e3:8.1f} kB) "
                  f"match={r['measured_matches_predicted']}")
        results["multiclass"] = multiclass_row(mesh, rounds, quick)
        mc = results["multiclass"]
        print(f"  {'softmax3 (K=3)':24s} acc={mc['acc']:.4f} "
              f"macro_f1={mc['macro_f1']:.4f} "
              f"bytes={mc['measured_total']/1e3:8.1f} kB "
              f"match={mc['measured_matches_predicted']}")
        results["round_engine"] = round_engine_metrics(
            mesh, tree_cfg, n, d_pad, n_trees=4
        )
        re = results["round_engine"]
        print(f"  round engine: {re['hist_collectives_per_level']:.0f} "
              f"hist collective(s)/level at T={re['n_trees']}, "
              f"level-0 rows {re['level0_rows_direct']} -> "
              f"{re['level0_rows_shared_root']} "
              f"({re['level0_row_cut_x']:.2f}x shared-root), depth-5 "
              f"compaction {re['depth5_compaction']['hist_byte_cut_x']:.2f}x")
        results["chaos"] = chaos_rows(
            mesh, ds, x_train, x_test, d_pad, cfg, tree_cfg
        )
        ch = results["chaos"]
        print(f"  chaos [{ch['spec']}]: zero-fault overhead "
              f"{ch['zero_fault_overhead_x']:.3f}x, faulty bit-identical "
              f"{ch['faulty_bit_identical']}, retry bytes "
              f"{ch['retry_bytes']}, reconciled "
              f"{ch['faulty_measured_match_predicted']}")

    base = results["backends"]["vfl-histogram"]
    hist_base = base["measured_bytes"].get("histograms", 1)
    for name, r in results["backends"].items():
        r["auc_delta_vs_histogram"] = r["auc"] - base["auc"]
        h = r["measured_bytes"].get("histograms", 0)
        r["histogram_phase_reduction_x"] = (hist_base / h) if h else float("inf")
        r["total_reduction_x"] = base["measured_total"] / r["measured_total"]

    q8 = results["backends"]["vfl-histogram-q8"]
    sub = results["backends"]["vfl-histogram+sub"]
    q8sub = results["backends"]["vfl-histogram-q8+sub"]
    async_b = results["backends"]["vfl-histogram-async"]
    # id_partition bit-packing (DESIGN.md §8): the routing broadcast ships
    # 1 bit/row instead of the pre-packing int32 — both sides shape-exact,
    # so the cut is measured-bytes vs the int32-equivalent volume.
    id_meas = base["measured_bytes"].get("id_partition", 0)
    id_packed_per_level = (n + 7) // 8
    id_cut = (n * 4) / id_packed_per_level
    results["acceptance"] = {
        "q8_histogram_phase_reduction_x": q8["histogram_phase_reduction_x"],
        "q8_histogram_phase_reduction_ge_4x":
            q8["histogram_phase_reduction_x"] >= 4.0,
        "q8_abs_auc_delta": abs(q8["auc_delta_vs_histogram"]),
        "q8_auc_delta_le_1e-3": abs(q8["auc_delta_vs_histogram"]) <= 1e-3,
        "lossless_measured_match_predicted": all(
            results["backends"][b]["measured_matches_predicted"]
            for b in ("vfl-histogram", "vfl-argmax", "vfl-argmax-topk")
        ),
        # ISSUE 4: subtraction pipeline — measured (ledger-reconciled)
        # histogram-phase cut >= 1.7x at depth 3 / B = 32, reconciliation
        # exact, and the q8 composition multiplies the two levers.
        "sub_histogram_phase_reduction_x": sub["histogram_phase_reduction_x"],
        "sub_histogram_phase_reduction_ge_1.7x":
            sub["histogram_phase_reduction_x"] >= 1.7,
        "sub_measured_match_predicted": sub["measured_matches_predicted"],
        "sub_abs_auc_delta": abs(sub["auc_delta_vs_histogram"]),
        "q8_sub_histogram_phase_reduction_x":
            q8sub["histogram_phase_reduction_x"],
        # ISSUE 6: bit-packed routing broadcast — >= 8x cut vs the int32
        # id_partition wire (measured bytes must be on the packed model,
        # i.e. an exact multiple of ceil(n/8) per level).
        "id_partition_cut_x": id_cut,
        "id_partition_cut_ge_8x": id_cut >= 8.0,
        "id_partition_measured_on_packed_model":
            id_meas > 0 and id_meas % id_packed_per_level == 0,
        # ISSUE 6: async double-buffered exchange — the split transfer is
        # a transport detail, not a payload change: wire bytes and AUC
        # must equal the sync vfl-histogram row exactly, and the ledger
        # (which counts ONE logical collective per level) reconciles.
        "async_measured_match_predicted":
            async_b["measured_matches_predicted"],
        "async_bytes_equal_sync":
            async_b["measured_total"] == base["measured_total"],
        "async_auc_equal_sync": async_b["auc"] == base["auc"],
        # ISSUE 5: round-engine floors (all shape-exact quantities).
        "round_one_collective_per_level":
            results["round_engine"]["hist_collectives_per_level"] == 1.0,
        "round_level0_rows_exact": (
            results["round_engine"]["level0_rows_direct"]
            == results["round_engine"]["level0_rows_expected_direct"]
            and results["round_engine"]["level0_rows_shared_root"]
            == results["round_engine"]["level0_rows_expected_shared_root"]
        ),
        "round_level0_row_cut_x": results["round_engine"]["level0_row_cut_x"],
        "depth5_compaction_hist_byte_cut_x":
            results["round_engine"]["depth5_compaction"]["hist_byte_cut_x"],
        "depth5_compaction_reconciled": (
            results["round_engine"]["depth5_compaction"]["uncompacted"]["reconciled"]
            and results["round_engine"]["depth5_compaction"]["budget"]["reconciled"]
        ),
        # ISSUE 7: K-channel objective layer (DESIGN.md §11) — measured
        # bytes == wire model exactly at K=1 (the binary rows above) AND
        # K=3 (the softmax3 row's widened 2K+1-stat exchange).
        "k1_measured_match_predicted": base["measured_matches_predicted"],
        "k3_measured_match_predicted":
            results["multiclass"]["measured_matches_predicted"],
        "multiclass_acc": results["multiclass"]["acc"],
        # ISSUE 9: chaos transport (DESIGN.md §13) — the wrapper is free at
        # zero faults (bit-identical model, <= 1.05x warm train wall) and
        # under injected faults the checksum-verified retransmission
        # recovers every payload exactly (model STILL bit-identical to the
        # raw backend) with the retried bytes + checksums reconciling
        # exactly in the dedicated ``retries`` phase.
        "chaos_zero_fault_bit_identical": ch["zero_fault_bit_identical"],
        "chaos_zero_fault_overhead_x": ch["zero_fault_overhead_x"],
        "chaos_zero_fault_overhead_le_1.05x":
            ch["zero_fault_overhead_x"] <= 1.05,
        "chaos_faulty_bit_identical": ch["faulty_bit_identical"],
        "chaos_faulty_auc_equal_raw": ch["auc_faulty"] == ch["auc_raw"],
        "chaos_faulty_reconciled": ch["faulty_measured_match_predicted"],
        "chaos_retry_bytes": ch["retry_bytes"],
        "chaos_retry_bytes_gt_0": ch["retry_bytes"] > 0,
    }
    results["interpretation"] = (
        "the quantized transport ships int8 (g, h) payloads + one f32 scale "
        "per (node, feature, channel) instead of f32 triples — a "
        f"{q8['histogram_phase_reduction_x']:.1f}x histogram-phase cut at "
        f"{abs(q8['auc_delta_vs_histogram']):.1e} AUC delta; argmax/top-k "
        "prune the exchange to candidate tuples (lossless); GOSS reweights "
        "the sample budget toward large gradients at identical wire bytes; "
        "sibling subtraction ships only left-child histograms at levels >= 1 "
        f"(a {sub['histogram_phase_reduction_x']:.2f}x phase cut at depth 3) "
        "and composes multiplicatively with q8 "
        f"({q8sub['histogram_phase_reduction_x']:.1f}x combined). "
        "Every row's measured bytes come from the traced program's actual "
        "collective payloads and reconcile exactly with the ledger's wire "
        "model."
    )

    save_report("comm_bench", results)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_comm.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)

    acc = results["acceptance"]
    print(f"  q8 histogram-phase reduction: "
          f"{acc['q8_histogram_phase_reduction_x']:.2f}x "
          f"(>=4x: {acc['q8_histogram_phase_reduction_ge_4x']}), "
          f"|AUC delta| = {acc['q8_abs_auc_delta']:.1e} "
          f"(<=1e-3: {acc['q8_auc_delta_le_1e-3']})")
    print(f"  subtraction histogram-phase reduction: "
          f"{acc['sub_histogram_phase_reduction_x']:.2f}x "
          f"(>=1.7x: {acc['sub_histogram_phase_reduction_ge_1.7x']}, "
          f"reconciled: {acc['sub_measured_match_predicted']}); "
          f"q8+sub combined: {acc['q8_sub_histogram_phase_reduction_x']:.1f}x")
    print(f"  id_partition bit-packing cut: {acc['id_partition_cut_x']:.1f}x "
          f"(>=8x: {acc['id_partition_cut_ge_8x']}); async exchange: "
          f"bytes==sync {acc['async_bytes_equal_sync']}, "
          f"auc==sync {acc['async_auc_equal_sync']}, "
          f"reconciled {acc['async_measured_match_predicted']}")
    return [
        (f"comm/{name}", r["train_s"] * 1e6 / rounds,
         f"auc={r['auc']:.4f};kB_round={r['measured_bytes_per_round']/1e3:.0f}"
         f";hist_x={r['histogram_phase_reduction_x']:.1f}")
        for name, r in results["backends"].items()
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (same comparisons)")
    ap.add_argument("--dataset", default=None,
                    help="opt-in real data: path to a labelled CSV "
                         "(repro.data.tabular.load_csv; last column = "
                         "label).  Default: the synthetic credit generator.")
    args = ap.parse_args()
    main(smoke=args.smoke, dataset=args.dataset)
