"""Training-engine benchmark: legacy per-round loop vs scanned engine.

Measures the training hot path this PR rebuilds (DESIGN.md §4) on the
paper's Dynamic FedGBF schedule (trees 5 -> 2, rho 0.1 -> 0.3), which is
exactly the case that breaks the legacy loop's compile story: every distinct
(n_trees,) shape compiles a fresh per-round XLA program, while the scanned
engine factors the schedule into constant-width segments scanned inside ONE
compiled program — no recompiles, no per-round host sync.

Reported:
  * ``*_compiles``      — XLA programs compiled per engine (loop: one per
    distinct scheduled tree count, >= 4 for 5 -> 2; scan: exactly 1),
    read from the engines' jit caches;
  * ``*_cold_s``        — first call, includes all compiles;
  * ``*_steady_round_s``— warm second call / rounds (the recompile-free
    per-round cost);
  * ``metric_max_abs_diff`` — max |loop - scan| over all history metrics
    (the 1e-5 equivalence bar of the ISSUE);
  * ``subtraction``      — the sibling-subtraction pipeline (DESIGN.md §6)
    on/off steady-state round time under the scanned engine, its compile
    count (must stay 1), metric drift vs the direct pipeline, and the
    conservative ``speedup_floor`` benchmarks/ci_guard.py enforces;
  * ``telemetry``        — the observability layer (DESIGN.md §12) on vs
    off: traced steady-round time (telemetry=True + live Tracer + segment
    ticks) against the untraced baseline, the overhead ratio ci_guard
    gates at <= 1.05x, and the traced variant's own compile count (the
    telemetry flag is jit-static, so each variant compiles exactly once).

Results land in reports/train_bench.json and the repo-root BENCH_train.json.

The ``sharded`` section (DESIGN.md §8) measures row-sharded multi-host
throughput at >= 1M synthetic rows under ``vfl-histogram-sharded`` on a
(data x model) grid of forced host devices — run in a subprocess so the
parent's jax device state is untouched (same re-exec pattern as
comm_bench).  The recorded ``rows_per_s_floor`` (half the measurement, so
CI machine variance passes but a sharding regression fails) is enforced by
benchmarks/ci_guard.py against the committed BENCH_train.json.

    PYTHONPATH=src python -m benchmarks.train_bench [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_report, scale
from repro.core import boosting
from repro.core import forest as forest_mod
from repro.core.types import TreeConfig
from repro.obs import trace as obs_trace

#: sharded-throughput bench shape: >= 1M rows (the ISSUE floor), modest
#: width/rounds so the CI smoke stays minutes, not hours, on one CPU.
SHARDED_N = 1_048_576
SHARDED_D = 8
SHARDED_ROUNDS = 2
SHARDED_GRID = (4, 2)  # (data_shards, parties) -> 8 forced host devices


def _sharded_child() -> None:
    """Child-process body: train vfl-histogram-sharded at >= 1M rows on a
    (4 data x 2 model) grid of forced host devices and print one JSON line
    (the parent parses stdout's last line)."""
    from repro.compat import use_mesh
    from repro.federation import vfl

    data_shards, parties = SHARDED_GRID
    mesh = jax.make_mesh((data_shards, parties), ("data", "model"),
                         devices=jax.devices()[:data_shards * parties])
    tree = TreeConfig(max_depth=3, num_bins=32, hist_subtraction=True)
    cfg = boosting.FedGBFConfig(
        rounds=SHARDED_ROUNDS, tree=tree, n_trees_max=2, n_trees_min=2,
        rho_id_min=0.3, rho_id_max=0.3,
    )
    backend = vfl.make_vfl_backend(
        mesh, tree, aggregation="histogram", shard_samples=True
    )
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(SHARDED_N, SHARDED_D)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, SHARDED_N), jnp.float32)

    with use_mesh(mesh):
        t0 = time.perf_counter()
        model, _ = boosting.train_fedgbf(
            x, y, cfg, jax.random.PRNGKey(0), backend=backend,
            eval_every=SHARDED_ROUNDS,
        )
        jax.block_until_ready(model.forests[-1].leaf_weight)
        cold = time.perf_counter() - t0
        warm = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            model, _ = boosting.train_fedgbf(
                x, y, cfg, jax.random.PRNGKey(0), backend=backend,
                eval_every=SHARDED_ROUNDS,
            )
            jax.block_until_ready(model.forests[-1].leaf_weight)
            warm = min(warm, time.perf_counter() - t0)

    print(json.dumps({
        "backend": "vfl-histogram-sharded",
        "n": SHARDED_N, "d": SHARDED_D, "rounds": SHARDED_ROUNDS,
        "data_shards": data_shards, "parties": parties,
        "cold_s": cold, "warm_s": warm,
        "rows_per_s": SHARDED_N * SHARDED_ROUNDS / warm,
    }))


def _sharded_bench() -> dict:
    """Run the >= 1M-row sharded throughput measurement in a subprocess with
    forced host devices (the parent may already hold a 1-device jax)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count="
        f"{SHARDED_GRID[0] * SHARDED_GRID[1]}"
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.train_bench", "--sharded-child"],
        env=env, check=True, capture_output=True, text=True,
    )
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # floor at half the measurement: CI machine variance passes, a real
    # sharded-pipeline regression (or a silent fallback to 1 device) fails
    out["rows_per_s_floor"] = round(0.5 * out["rows_per_s"], 1)
    return out


def _train(engine, x, y, cfg, eval_every, tracer=None, telemetry=False):
    t0 = time.perf_counter()
    model, hist = boosting.train_fedgbf(
        x, y, cfg, jax.random.PRNGKey(0), eval_every=eval_every,
        engine=engine, tracer=tracer, telemetry=telemetry,
    )
    jax.block_until_ready(model.forests[-1].leaf_weight)
    return model, hist, time.perf_counter() - t0


def main(smoke: bool = False) -> list:
    quick = smoke or scale() == "quick"
    n, d, rounds = (3_000, 12, 8) if quick else (30_000, 23, 20)
    eval_every = rounds  # isolate the engine: metrics only at the last round

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    # hist_subtraction now defaults ON; this bench contrasts the pipelines,
    # so the base config pins the direct pass explicitly.
    cfg = boosting.dynamic_fedgbf_config(
        rounds=rounds,
        tree=TreeConfig(max_depth=3, num_bins=32, hist_subtraction=False),
    )

    results = {
        "n": n, "d": d, "rounds": rounds,
        "n_trees_schedule": "5 -> 2 (dynamic decay)",
        "rho_id_schedule": "0.1 -> 0.3 (dynamic increase)",
        "backend": jax.default_backend(),
    }

    warm_repeats = 3  # steady state = best warm run (same policy as predict_bench)

    # -- legacy per-round loop ------------------------------------------------
    jax.clear_caches()
    _, h_loop_cold, cold_loop = _train("loop", x, y, cfg, eval_every)
    results["loop_compiles"] = forest_mod.build_forest._cache_size()
    warm_loop = float("inf")
    for _ in range(warm_repeats):
        _, h_loop, t = _train("loop", x, y, cfg, eval_every)
        warm_loop = min(warm_loop, t)
    results["loop_cold_s"] = cold_loop
    results["loop_steady_round_s"] = warm_loop / rounds

    # -- scanned engine -------------------------------------------------------
    jax.clear_caches()
    _, h_scan_cold, cold_scan = _train("scan", x, y, cfg, eval_every)
    results["scan_compiles"] = boosting._scan_train_program._cache_size()
    warm_scan = float("inf")
    for _ in range(warm_repeats):
        _, h_scan, t = _train("scan", x, y, cfg, eval_every)
        warm_scan = min(warm_scan, t)
    results["scan_cold_s"] = cold_scan
    results["scan_steady_round_s"] = warm_scan / rounds

    results["steady_round_speedup_vs_loop"] = (
        results["loop_steady_round_s"] / results["scan_steady_round_s"]
    )
    results["distinct_n_trees"] = len(set(h_loop.n_trees))
    results["metric_max_abs_diff"] = max(
        abs(a[k] - b[k])
        for a, b in zip(h_loop.train, h_scan.train) for k in a
    )

    # -- sibling-subtraction pipeline (DESIGN.md §6), scanned engine ----------
    # Same schedule with hist_subtraction on: levels >= 1 accumulate only the
    # left children and derive the siblings.  Tracked: steady-state round
    # time on vs off, the compile count (must stay exactly 1 — the switch is
    # jit-static), and the end-metric drift vs the direct pipeline.  The
    # recorded ``speedup_floor`` is a deliberately conservative fraction of
    # the measurement; benchmarks/ci_guard.py fails a future run that drops
    # below the committed floor.
    sub_cfg = dataclasses.replace(
        cfg, tree=dataclasses.replace(cfg.tree, hist_subtraction=True)
    )
    jax.clear_caches()
    _, h_sub_cold, cold_sub = _train("scan", x, y, sub_cfg, eval_every)
    sub_compiles = boosting._scan_train_program._cache_size()
    warm_sub = float("inf")
    for _ in range(warm_repeats):
        _, h_sub, t = _train("scan", x, y, sub_cfg, eval_every)
        warm_sub = min(warm_sub, t)
    on_round = warm_sub / rounds
    speedup = results["scan_steady_round_s"] / on_round
    results["subtraction"] = {
        "scan_compiles": sub_compiles,
        "cold_s": cold_sub,
        "on_steady_round_s": on_round,
        "off_steady_round_s": results["scan_steady_round_s"],
        "on_off_speedup_x": speedup,
        "metric_max_abs_diff_vs_direct": max(
            abs(a[k] - b[k])
            for a, b in zip(h_scan.train, h_sub.train) for k in a
        ),
        # guard floor: 75% of the measured speedup, so normal CI timing noise
        # passes but a real pipeline regression does not
        "speedup_floor": round(0.75 * speedup, 3),
    }
    # -- observability overhead (DESIGN.md §12), scanned engine ---------------
    # Traced = telemetry=True (in-graph liveness block through the scan ys)
    # + a live Tracer + segment-tick callbacks.  Measured with a fresh cache
    # so the traced variant's own compile count is visible: the telemetry
    # flag is jit-STATIC, so the traced program also compiles exactly once.
    # The overhead ratio ci_guard gates at <= 1.05x is taken from
    # INTERLEAVED traced/untraced warm runs (min of each) — alternating the
    # two variants inside one measurement window cancels machine drift that
    # would otherwise swamp a ~1% effect when the baseline was timed in a
    # different section of the bench.
    jax.clear_caches()
    tr = obs_trace.Tracer()
    _, _, cold_tele = _train("scan", x, y, cfg, eval_every,
                             tracer=tr, telemetry=True)
    tele_compiles = boosting._scan_train_program._cache_size()
    warm_tele = warm_plain = float("inf")
    for _ in range(warm_repeats + 2):
        _, h_tele, t = _train("scan", x, y, cfg, eval_every,
                              tracer=obs_trace.Tracer(), telemetry=True)
        warm_tele = min(warm_tele, t)
        _, _, t = _train("scan", x, y, cfg, eval_every)
        warm_plain = min(warm_plain, t)
    traced_round = warm_tele / rounds
    plain_round = warm_plain / rounds
    results["telemetry"] = {
        "scan_compiles": tele_compiles,
        "cold_s": cold_tele,
        "traced_steady_round_s": traced_round,
        "untraced_steady_round_s": plain_round,
        "overhead_x": traced_round / plain_round,
        "liveness_rounds": len(h_tele.telemetry.get("sampled_entries", [])),
        "segments": len(h_tele.segments),
    }

    # -- row-sharded multi-host throughput (DESIGN.md §8), >= 1M rows --------
    results["sharded"] = _sharded_bench()
    sh = results["sharded"]

    results["interpretation"] = (
        "the loop compiles one forest program per distinct scheduled tree "
        "count and host-syncs every round; the scanned engine factors the "
        "schedule into constant-width segments scanned inside ONE compiled "
        "program (masks drawn in one batched vmap, metrics evaluated "
        "in-graph), so it does exactly the scheduled work at the same "
        "vmapped width with zero recompiles and zero per-round "
        "dispatch/sync overhead."
    )

    save_report("train_bench", results)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_train.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)

    sub = results["subtraction"]
    print(
        f"  loop: {results['loop_compiles']} compiles, cold {cold_loop:.2f}s, "
        f"steady {results['loop_steady_round_s']*1e3:.1f} ms/round\n"
        f"  scan: {results['scan_compiles']} compile, cold {cold_scan:.2f}s, "
        f"steady {results['scan_steady_round_s']*1e3:.1f} ms/round "
        f"({results['steady_round_speedup_vs_loop']:.2f}x)\n"
        f"  scan+subtraction: {sub['scan_compiles']} compile, "
        f"steady {sub['on_steady_round_s']*1e3:.1f} ms/round "
        f"({sub['on_off_speedup_x']:.2f}x vs direct, "
        f"metric |diff| {sub['metric_max_abs_diff_vs_direct']:.1e})\n"
        f"  scan+telemetry: {results['telemetry']['scan_compiles']} compile, "
        f"steady {results['telemetry']['traced_steady_round_s']*1e3:.1f} "
        f"ms/round ({results['telemetry']['overhead_x']:.3f}x untraced)\n"
        f"  sharded ({sh['data_shards']}x{sh['parties']} grid, "
        f"n={sh['n']:,}): {sh['rows_per_s']/1e3:.0f}k rows/s "
        f"(floor {sh['rows_per_s_floor']/1e3:.0f}k)\n"
        f"  metric max |diff|: {results['metric_max_abs_diff']:.2e}"
    )
    return [
        ("train/loop_round", results["loop_steady_round_s"] * 1e6,
         f"{results['loop_compiles']} programs"),
        ("train/scan_round", results["scan_steady_round_s"] * 1e6,
         f"1 program, {results['steady_round_speedup_vs_loop']:.2f}x vs loop"),
        ("train/scan_round_subtraction", sub["on_steady_round_s"] * 1e6,
         f"1 program, {sub['on_off_speedup_x']:.2f}x vs direct pipeline"),
        ("train/scan_round_traced", results["telemetry"]
         ["traced_steady_round_s"] * 1e6,
         f"{results['telemetry']['overhead_x']:.3f}x untraced "
         f"(gate <= 1.05x)"),
        ("train/sharded_1M_rows", sh["warm_s"] * 1e6,
         f"{sh['rows_per_s']/1e3:.0f}k rows/s on "
         f"{sh['data_shards']}x{sh['parties']} grid"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (same comparisons; the "
                         "sharded section stays >= 1M rows)")
    ap.add_argument("--sharded-child", action="store_true",
                    help=argparse.SUPPRESS)  # internal: see _sharded_bench
    args = ap.parse_args()
    if args.sharded_child:
        _sharded_child()
    else:
        main(smoke=args.smoke)
