"""End-to-end driver (deliverable b): train a ~100M-param SmolLM-135M for a
few hundred steps on the synthetic token pipeline and show the loss dropping.

This is the FULL assigned config (30L, d_model 576, ~134M params) — runnable
on CPU with a small batch; pass --quick for the reduced config.

    PYTHONPATH=src python examples/lm_pretrain_e2e.py [--quick]
"""

import argparse
import sys

from repro.launch import train as train_driver

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args, _ = ap.parse_known_args()
    if args.quick:
        sys.argv = [sys.argv[0], "--arch", "smollm-135m", "--smoke",
                    "--steps", "60", "--batch", "8", "--seq", "128"]
    else:
        sys.argv = [sys.argv[0], "--arch", "smollm-135m",
                    "--steps", "300", "--batch", "4", "--seq", "256",
                    "--log-every", "20"]
    train_driver.main()
