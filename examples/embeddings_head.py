"""Boosted-forest head on frozen LM embeddings — where the paper's technique
and the assigned-architecture substrate literally compose (DESIGN.md §7).

Party A (embedding provider) runs a frozen SmolLM-family encoder over text
and holds the hidden-state features; party B (label holder) has repayment
labels. FedGBF trains on the vertically-joined table: LM features from A,
labels from B — a realistic VFL credit-scoring-with-text setup.

    PYTHONPATH=src python examples/embeddings_head.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import boosting, metrics
from repro.core.types import TreeConfig
from repro.data import tokens as tokens_mod
from repro.models import model as model_mod

rng = np.random.default_rng(0)

# --- Party A: frozen LM producing sequence embeddings -----------------------
cfg = get_smoke_config("smollm-135m")
params = model_mod.init_params(jax.random.PRNGKey(0), cfg)

N, S = 2000, 32
src = tokens_mod.MarkovZipfSource(cfg.vocab, seed=1)
toks = np.stack([src.sample(rng, S) for _ in range(N)])


@jax.jit
def embed(tokens):
    x = model_mod.layers.embed_tokens(params["embed"], tokens, cfg)
    x, _ = model_mod._stack_scan(params, x, cfg)
    return x.mean(axis=1)  # (B, D) mean-pooled sequence embedding


feats = np.asarray(
    jnp.concatenate([embed(jnp.asarray(toks[i:i + 256]))
                     for i in range(0, N, 256)])
).astype(np.float32)
print(f"party A produced {feats.shape} LM embedding features")

# Ground truth: default risk is a noisy nonlinear function of the text via a
# fixed scoring direction in embedding space (unknown to both parties).
z = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)
w_true = rng.normal(size=feats.shape[1])
risk_logit = z @ w_true / np.sqrt(len(w_true)) + 0.3 * np.abs(z[:, 0])
risk_logit += rng.normal(0, 0.3, N)
labels = (risk_logit > np.quantile(risk_logit, 0.75)).astype(np.float32)

# --- Party B: labels; FedGBF head on the vertical join -----------------------
k = int(0.7 * N)
cfg_fg = boosting.dynamic_fedgbf_config(
    rounds=10, tree=TreeConfig(max_depth=3, num_bins=16)
)
model, _ = boosting.train_fedgbf(
    jnp.asarray(feats[:k]), jnp.asarray(labels[:k]), cfg_fg,
    jax.random.PRNGKey(2),
)
rep = metrics.classification_report(
    jnp.asarray(labels[k:]), boosting.predict(model, jnp.asarray(feats[k:]))
)
print(f"FedGBF on LM embeddings: test auc={rep['auc']:.4f} "
      f"acc={rep['acc']:.4f} f1={rep['f1']:.4f}")
assert rep["auc"] > 0.7, "embedding head should beat chance comfortably"
