"""Quickstart: train Dynamic FedGBF and SecureBoost on credit data, compare
quality and the paper's runtime bounds — the whole paper in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import boosting, metrics, runtime_model
from repro.data import synthetic

# 1. Data: credit-default stand-in (30k x 23, ~22% positives; §4.1 shape).
ds = synthetic.load("default_credit_card", n=10_000)
x_train, y_train = jnp.asarray(ds.x_train), jnp.asarray(ds.y_train)
x_test, y_test = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)

# 2. Dynamic FedGBF (Alg. 3): forests of 5 -> 2 trees per boosting round,
#    sample rate 0.1 -> 0.3 (the paper's §4.2.2 schedules).
cfg = boosting.dynamic_fedgbf_config(rounds=15)
model, history = boosting.train_fedgbf(
    x_train, y_train, cfg, jax.random.PRNGKey(0), verbose=True
)

# 3. Baseline: SecureBoost == FedGBF degenerated to 1 tree / round.
sb_cfg = boosting.secureboost_config(rounds=15)
sb_model, _ = boosting.train_fedgbf(
    x_train, y_train, sb_cfg, jax.random.PRNGKey(0)
)

# 4. Compare quality (Tables 2-3 metrics)...
for name, m in [("dynamic_fedgbf", model), ("secureboost", sb_model)]:
    rep = metrics.classification_report(y_test, boosting.predict(m, x_test))
    print(f"{name:16s} test auc={rep['auc']:.4f} acc={rep['acc']:.4f} "
          f"f1={rep['f1']:.4f} trees={m.total_trees}")

# 4b. Explainability (the paper's §1 motivation for tree models in finance):
from repro.core import explain
from repro.data import tabular

imp = explain.feature_importance(model, x_train.shape[1])
part = tabular.partition_from_dims([13, 10])  # Table 1 vertical split
print("top-3 features by gain:", sorted(
    range(len(imp)), key=lambda i: -imp[i])[:3],
    "| per-party importance:", explain.party_importance(model, part))

# 5. ...and the runtime model (eqs. 8-11): FedGBF's per-round forests cost
#    [sum a_i b_i, sum N_i a_i b_i] tree-units vs SecureBoost's M units.
t_unit = 1.0  # abstract unit time; see benchmarks/runtime_model.py for measured
fg = runtime_model.estimate_fedgbf_runtime(cfg, t_unit)
sb = runtime_model.estimate_secureboost_runtime(15, t_unit)
print(f"runtime bounds (tree-units): FedGBF=[{fg.lower_s:.2f}, {fg.upper_s:.2f}]"
      f" vs SecureBoost={sb:.2f} -> ideal-parallel saving "
      f"{1 - fg.lower_s / sb:.0%}")
