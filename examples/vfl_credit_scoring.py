"""Vertical federated credit scoring: the full protocol flow on a device mesh.

Two parties (bank = active with labels, fintech = passive) hold disjoint
feature columns of the same customers. The forest builder runs under
shard_map with the party axis = mesh "model" axis; the message ledger
reconciles the bytes each collective *actually* ships against the predicted
wire model (and prices the paper-world Paillier protocol alongside); the
secure-aggregation simulation demonstrates the masking algebra on the
gradient broadcast.  The quantized transport (DESIGN.md §5) demonstrates
the compression subsystem end to end: same AUC to ~1e-4, ~5x fewer
histogram bytes.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/vfl_credit_scoring.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boosting, metrics
from repro.core.types import TreeConfig
from repro.data import synthetic, tabular
from repro.federation import compress, secure, vfl

if len(jax.devices()) < 2:
    raise SystemExit(
        "need >=2 devices: run with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8"
    )

PARTIES = 2
ds = synthetic.load("default_credit_card", n=8_000)
x_train, d_pad = tabular.pad_features(ds.x_train, PARTIES)
x_test, _ = tabular.pad_features(ds.x_test, PARTIES)
part = tabular.even_partition(d_pad, PARTIES)
print(f"bank (active) holds columns {part.columns(0)}, "
      f"fintech (passive) holds {part.columns(1)}")

# --- secure aggregation demo: parties mask their contributions; only the
# sum is visible to the aggregator (masks cancel exactly).
contrib = jnp.stack([jnp.ones(5) * 2.0, jnp.ones(5) * 3.0])
masks = secure.pairwise_masks(seed=42, num_parties=2, shape=(5,))
masked = secure.mask(contrib, masks)
print("masked party messages (unreadable):", np.asarray(masked[0][:3]))
print("aggregate (masks cancel):", np.asarray(secure.aggregate(masked)[:3]))

# --- federated training: lossless modes + the quantized transport
mesh = jax.make_mesh((len(jax.devices()) // PARTIES, PARTIES),
                     ("data", "model"))
tree_cfg = TreeConfig(max_depth=3, num_bins=32)
cfg = boosting.dynamic_fedgbf_config(rounds=8, tree=tree_cfg)

for aggregation, transport, subtraction in (
    ("histogram", None, False),         # paper-faithful full-histogram exchange
    ("argmax", None, False),            # beyond-paper candidate-only exchange
    ("histogram", compress.Q8, False),  # quantized exchange (DESIGN.md §5)
    ("histogram", compress.Q8, True),   # + sibling subtraction (DESIGN.md §6)
):
    run_tree = dataclasses.replace(tree_cfg, hist_subtraction=subtraction)
    run_cfg = dataclasses.replace(cfg, tree=run_tree)
    backend = vfl.make_vfl_backend(
        mesh, run_tree, aggregation=aggregation, transport=transport
    )
    model, _ = boosting.train_fedgbf(
        jnp.asarray(x_train), jnp.asarray(ds.y_train), run_cfg,
        jax.random.PRNGKey(0), backend=backend,
    )
    rep = metrics.classification_report(
        jnp.asarray(ds.y_test), boosting.predict(model, jnp.asarray(x_test))
    )
    # Measured bytes: every collective in the backend reports its actual
    # payload; the ledger reconciles them against the predicted wire model.
    ledger = compress.reconciled_ledger(
        mesh, run_tree, run_cfg, aggregation=aggregation, transport=transport,
        n_samples=x_train.shape[0], num_features=d_pad,
    )
    rec = ledger.reconcile()
    paillier = ledger.predicted_paillier()
    tag = (f"{aggregation}" + (f"-{transport.tag}" if transport else "")
           + ("+sub" if subtraction else ""))
    print(f"[{tag:17s}] test auc={rep['auc']:.4f} "
          f"wire measured={rec['total']['measured']/1e6:.1f} MB "
          f"predicted={rec['total']['predicted']/1e6:.1f} MB "
          f"(match={rec['total']['match']}, "
          f"histograms {rec['histograms']['measured']/1e6:.1f} MB) "
          f"paillier-model={paillier.total/1e6:.1f} MB")
print("-> same AUC at ~5x fewer histogram bytes under q8 (~9x with sibling "
      "subtraction on top); measured wire bytes reconcile exactly with the "
      "ledger's prediction")
