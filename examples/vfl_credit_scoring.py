"""Vertical federated credit scoring: the full protocol flow on a device mesh.

Two parties (bank = active with labels, fintech = passive) hold disjoint
feature columns of the same customers. The forest builder runs under
shard_map with the party axis = mesh "model" axis; the message ledger prices
every exchanged byte at Paillier rates; the secure-aggregation simulation
demonstrates the masking algebra on the gradient broadcast.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/vfl_credit_scoring.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boosting, metrics
from repro.core.types import TreeConfig
from repro.data import synthetic, tabular
from repro.federation import protocol, secure, vfl

if len(jax.devices()) < 2:
    raise SystemExit(
        "need >=2 devices: run with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8"
    )

PARTIES = 2
ds = synthetic.load("default_credit_card", n=8_000)
x_train, d_pad = tabular.pad_features(ds.x_train, PARTIES)
x_test, _ = tabular.pad_features(ds.x_test, PARTIES)
part = tabular.even_partition(d_pad, PARTIES)
print(f"bank (active) holds columns {part.columns(0)}, "
      f"fintech (passive) holds {part.columns(1)}")

# --- secure aggregation demo: parties mask their contributions; only the
# sum is visible to the aggregator (masks cancel exactly).
contrib = jnp.stack([jnp.ones(5) * 2.0, jnp.ones(5) * 3.0])
masks = secure.pairwise_masks(seed=42, num_parties=2, shape=(5,))
masked = secure.mask(contrib, masks)
print("masked party messages (unreadable):", np.asarray(masked[0][:3]))
print("aggregate (masks cancel):", np.asarray(secure.aggregate(masked)[:3]))

# --- federated training, both aggregation modes
mesh = jax.make_mesh((len(jax.devices()) // PARTIES, PARTIES),
                     ("data", "model"))
tree_cfg = TreeConfig(max_depth=3, num_bins=32)
cfg = boosting.dynamic_fedgbf_config(rounds=8, tree=tree_cfg)

for aggregation in ("histogram", "argmax"):
    backend = vfl.make_vfl_backend(mesh, tree_cfg, aggregation=aggregation)
    model, _ = boosting.train_fedgbf(
        jnp.asarray(x_train), jnp.asarray(ds.y_train), cfg,
        jax.random.PRNGKey(0), backend=backend,
    )
    rep = metrics.classification_report(
        jnp.asarray(ds.y_test), boosting.predict(model, jnp.asarray(x_test))
    )
    spec = protocol.ProtocolSpec(
        n_samples=x_train.shape[0],
        party_dims=part.dims(), num_bins=32, max_depth=3,
        aggregation=aggregation,
    )
    cost = protocol.run_cost(spec, cfg)
    print(f"[{aggregation:9s}] test auc={rep['auc']:.4f} "
          f"protocol={cost.total/1e6:.1f} MB "
          f"(histograms {cost.histograms/1e6:.1f} MB)")
print("-> identical AUC (lossless), argmax slashes histogram bytes "
      "(the beyond-paper collective optimisation)")
