"""Observability layer (DESIGN.md §12): spans, trace export, metrics,
MessageMeter reset semantics, and the scan engine's per-segment wall times."""

import json
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting
from repro.federation.compress import MessageMeter
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs import perfetto, trace


# ---------------------------------------------------------------------------
# MessageMeter: phase_counts / phase_totals / reset
# ---------------------------------------------------------------------------
def test_message_meter_totals_counts_and_reset():
    m = MessageMeter()
    m.record("histograms", np.zeros((4, 2), np.float32))   # 32 B
    m.record("histograms", np.zeros(8, np.int8))           # 8 B
    m.record("grad_broadcast", np.zeros(3, np.float32))    # 12 B
    assert m.phase_totals() == {"histograms": 40, "grad_broadcast": 12}
    assert m.phase_counts() == {"histograms": 2, "grad_broadcast": 1}

    m.reset()
    assert m.entries == []
    assert m.phase_totals() == {} and m.phase_counts() == {}

    # a fresh record after reset starts from zero, not from the old totals
    m.record("histograms", np.zeros(1, np.float32))
    assert m.phase_totals() == {"histograms": 4}
    assert m.phase_counts() == {"histograms": 1}


# ---------------------------------------------------------------------------
# Tracer: nesting, disabled path, global seam
# ---------------------------------------------------------------------------
def test_span_nesting_contains_child():
    tr = trace.Tracer()
    with tr.span("outer", cat="test"):
        with tr.span("inner", cat="test", args={"k": 1}):
            pass
    assert [s.name for s in tr.spans] == ["inner", "outer"]  # exit order
    inner, outer = tr.spans
    assert inner.depth == 1 and outer.depth == 0
    assert outer.t0 <= inner.t0 <= inner.t1 <= outer.t1
    assert inner.args == {"k": 1}
    # depth restored for a sibling span after the nest closes
    with tr.span("sibling"):
        pass
    assert tr.spans[-1].depth == 0


def test_disabled_tracer_is_allocation_free():
    tr = trace.NULL_TRACER
    assert tr.enabled is False
    # span() hands back ONE shared singleton — no per-call object
    assert tr.span("a") is tr.span("b")
    tr.add_span("x", 0.0, 1.0)
    tr.counter("c", {"v": 1})
    # and the hot loop allocates nothing measurable
    with tr.span("warm"):
        pass
    tracemalloc.start()
    before, _ = tracemalloc.get_traced_memory()
    for _ in range(1000):
        with tr.span("hot"):
            pass
    after, _ = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert after - before < 512  # loop-iterator slack only, no per-span cost


def test_global_tracer_seam():
    assert trace.global_tracer() is trace.NULL_TRACER
    t = trace.Tracer()
    try:
        trace.set_global_tracer(t)
        assert trace.global_tracer() is t
    finally:
        trace.set_global_tracer(None)
    assert trace.global_tracer() is trace.NULL_TRACER


# ---------------------------------------------------------------------------
# Perfetto / Chrome-trace export
# ---------------------------------------------------------------------------
def test_chrome_trace_export_schema(tmp_path):
    tr = trace.Tracer()
    with tr.span("compile", cat="host"):
        pass
    tr.add_span("round 1", 10.0, 11.0, cat="round", track="rounds",
                args={"n_trees": 5})
    tr.add_span("histograms", 10.0, 11.0, cat="wire", track="wire/histograms",
                args={"bytes": 1234})
    tr.counter("live_split_nodes", {"nodes": 7}, ts=10.5)

    path = tmp_path / "trace.json"
    n = perfetto.export_chrome_trace(str(path), tr, metadata={"backend": "x"})
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert n == len(events) and doc["metadata"] == {"backend": "x"}

    xs = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"compile", "round 1", "histograms"}
    for e in xs:  # complete events need ts/dur/pid/tid to load in Perfetto
        assert {"ts", "dur", "pid", "tid"} <= e.keys() and e["dur"] >= 0
    # tracks surface as thread_name metadata, one tid per track
    names = {e["args"]["name"]: e["tid"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"host", "rounds", "wire/histograms"} <= set(names)
    assert len(set(names.values())) == len(names)
    assert any(e["ph"] == "C" for e in events)
    # the wire-span byte args survive the round trip
    hist = [e for e in xs if e["name"] == "histograms"]
    assert hist[0]["args"]["bytes"] == 1234
    assert perfetto.wire_span_phase_totals(tr) == {"histograms": 1234}


# ---------------------------------------------------------------------------
# Metrics: log-bucket histogram, registry exposition
# ---------------------------------------------------------------------------
def test_log_bucket_histogram_quantiles_from_buckets():
    h = obs_metrics.LogBucketHistogram("lat", lo=1e-5, hi=60.0)
    vals = np.random.default_rng(0).lognormal(-5.0, 1.0, 5000)
    for v in vals:
        h.observe(float(v))
    assert h.count == 5000
    rel_err_bound = (h.growth - 1.0)  # midpoint estimate: half-bucket + slack
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(vals, q))
        assert abs(h.quantile(q) - exact) / exact <= rel_err_bound
    assert h.sum == pytest.approx(vals.sum(), rel=1e-9)


def test_log_bucket_histogram_memory_is_bounded():
    h = obs_metrics.LogBucketHistogram("lat")
    size0 = h.counts.size
    for v in np.random.default_rng(1).exponential(0.01, 20000):
        h.observe(float(v))
    # fixed bucket array, no raw-sample storage anywhere on the instance
    assert h.counts.size == size0
    assert not any(isinstance(v, list) for v in vars(h).values())
    assert np.isnan(obs_metrics.LogBucketHistogram("e").quantile(0.5))


def test_prometheus_exposition_format():
    r = obs_metrics.MetricsRegistry()
    c = r.counter("rows_total", "Rows scored.")
    g = r.gauge("occupancy")
    h = r.histogram("lat_seconds", "Latency.", lo=1e-3, hi=10.0)
    c.inc(5)
    g.set(0.75)
    for v in (0.002, 0.002, 0.5):
        h.observe(v)
    text = r.render()
    assert "# HELP rows_total Rows scored.\n# TYPE rows_total counter" in text
    assert "\nrows_total 5\n" in text
    assert "# TYPE occupancy gauge" in text and "\noccupancy 0.75\n" in text
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text
    # bucket lines are cumulative and ordered
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_seconds_bucket")]
    assert cums == sorted(cums)
    with pytest.raises(ValueError, match="duplicate"):
        r.counter("rows_total")
    with pytest.raises(ValueError):
        c.inc(-1)


# ---------------------------------------------------------------------------
# Structured round log
# ---------------------------------------------------------------------------
def _fake_history():
    h = boosting.TrainHistory(engine="scan")
    h.n_trees = [5, 4]
    h.rho_id = [0.1, 0.2]
    h.wall_time_s = [0.25, 0.125]
    h.rounds = [2]
    h.train = [{"auc": 0.9}]
    h.valid = []
    h.telemetry = {"split_nodes_per_level": [[5, 9, 11], [4, 8, 10]],
                   "sampled_entries": [51, 102],
                   "grad_absmean": [0.5, 0.4]}
    h.segments = [{"width": 5, "first_round": 0, "rounds": 1,
                   "root_delta_rows": 0, "wall_s": 0.25, "t0": 1.0, "t1": 1.25},
                  {"width": 4, "first_round": 1, "rounds": 1,
                   "root_delta_rows": 0, "wall_s": 0.125, "t0": 1.25,
                   "t1": 1.375}]
    return h


def test_round_log_renders_and_parses_back():
    hist = _fake_history()
    bytes_rows = [{"histograms": 100, "grad_broadcast": 8, "id_partition": 0},
                  {"histograms": 80, "grad_broadcast": 8, "id_partition": 0}]
    lines = obs_log.render_round_lines(hist, bytes_rows)
    assert len(lines) == 2
    noisy = "backend=vfl banner\n" + "\n".join(lines) + "\nTEST: auc=0.9\n"
    recs = obs_log.parse_round_log(noisy)
    assert [r["round"] for r in recs] == [1, 2]
    assert recs[0]["metrics"] is None and recs[1]["metrics"] == {"auc": 0.9}
    assert recs[0]["n_trees"] == 5 and recs[0]["wall_s"] == 0.25
    assert recs[0]["liveness"]["split_nodes_per_level"] == [5, 9, 11]
    assert recs[0]["bytes"] == {"histograms": 100, "grad_broadcast": 8}
    # zero-byte phases are dropped from the line, never miscounted
    assert "id_partition" not in recs[0]["bytes"]


def test_training_timeline_merges_rounds_and_wire_bytes():
    hist = _fake_history()
    tr = trace.Tracer()
    rows = [{"histograms": 100}, {"histograms": 80}]
    perfetto.add_training_timeline(tr, hist, rows)
    rounds = [s for s in tr.spans if s.track == "rounds"]
    assert [s.name for s in rounds] == ["round 1", "round 2"]
    assert rounds[0].args["n_trees"] == 5
    assert rounds[1].args["metrics"] == {"auc": 0.9}
    # wire spans carry exactly the ledger rows: totals reconcile by sum
    assert perfetto.wire_span_phase_totals(tr) == {"histograms": 180}
    # counters: liveness + cumulative wire bytes
    names = {c[0] for c in tr.counters}
    assert {"live_split_nodes", "wire_bytes/histograms"} <= names


# ---------------------------------------------------------------------------
# Scan engine: true per-segment wall time + in-graph telemetry
# ---------------------------------------------------------------------------
def _small_problem(n=256, d=6):
    rng = np.random.default_rng(7)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x[:, 0] - x[:, 1] > 0).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


def test_scan_wall_time_is_per_segment_not_smeared():
    x, y = _small_problem()
    cfg = boosting.dynamic_fedgbf_config(rounds=6)
    tr = trace.Tracer()
    _, hist = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0),
                                    tracer=tr, telemetry=True)
    assert len(hist.wall_time_s) == cfg.rounds
    assert all(v > 0 for v in hist.wall_time_s)
    # segments cover every round exactly once, in order
    assert sum(s["rounds"] for s in hist.segments) == cfg.rounds
    firsts = [s["first_round"] for s in hist.segments]
    assert firsts == sorted(firsts) and firsts[0] == 0
    # per-round wall is the segment wall smeared WITHIN the segment only
    i = 0
    for seg in hist.segments:
        per = seg["wall_s"] / seg["rounds"]
        for _ in range(seg["rounds"]):
            assert hist.wall_time_s[i] == pytest.approx(per)
            i += 1
        assert seg["t1"] >= seg["t0"]
    # the 5->2 schedule has >= 2 distinct segment widths: walls must be able
    # to differ across segments (the old engine forced them all equal)
    assert len({s["width"] for s in hist.segments}) >= 2
    assert hist.overhead_s >= 0.0
    # host spans recorded around the program call
    assert {"binning", "scan_program", "fetch_history"} <= {
        s.name for s in tr.spans}
    assert any(s.name.startswith("segment[T=") for s in tr.spans)

    # telemetry block: fetched per round in the single sync
    tele = hist.telemetry
    assert np.asarray(tele["split_nodes_per_level"]).shape == (6, 3)
    assert len(tele["sampled_entries"]) == 6
    assert all(v >= 0 for v in tele["sampled_entries"])

    # the timeline builder can place every round on the trace
    assert len(perfetto.round_intervals(hist)) == 6


def test_scan_and_loop_telemetry_agree():
    x, y = _small_problem()
    cfg = boosting.dynamic_fedgbf_config(rounds=4)
    _, hs = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0),
                                  telemetry=True)
    _, hl = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0),
                                  engine="loop", telemetry=True)
    assert hs.telemetry["split_nodes_per_level"] == \
        hl.telemetry["split_nodes_per_level"]
    assert hs.telemetry["sampled_entries"] == hl.telemetry["sampled_entries"]
    # loop engine records one single-round segment per round
    assert [s["rounds"] for s in hl.segments] == [1] * 4


def test_telemetry_off_leaves_history_clean():
    x, y = _small_problem(n=128)
    cfg = boosting.dynamic_fedgbf_config(rounds=3)
    _, hist = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(0))
    assert hist.telemetry == {}
    assert len(hist.wall_time_s) == 3 and hist.total_wall_time_s > 0


# ---------------------------------------------------------------------------
# Per-round wire rows sum exactly to the run totals (trace/ledger contract)
# ---------------------------------------------------------------------------
def test_per_round_cost_sums_to_assembled_run():
    from repro.core.types import FedGBFConfig
    from repro.federation import protocol

    cfg = FedGBFConfig(rounds=5, n_trees_max=5, n_trees_min=2,
                       rho_id_min=0.1, rho_id_max=0.3)
    per_tree = {"histograms": 1000, "feature_mask": 4, "id_partition": 64,
                "grad_broadcast": 0, "split_candidates": 0}
    rows = protocol.per_round_cost(per_tree, grad_per_round=512,
                                   passive_parties=3, cfg=cfg)
    assert len(rows) == 5
    total = protocol.measured_run_cost(per_tree, 512, 3, cfg)
    for phase in protocol.WIRE_PHASES:
        assert sum(r[phase] for r in rows) == total[phase]
    # ledger round-trip: record_run stores the probe, per_round_measured
    # reproduces self.measured exactly
    spec = protocol.ProtocolSpec(
        n_samples=512, party_dims=(2, 2), num_bins=32, max_depth=3)
    led = protocol.ProtocolLedger(spec=spec, cfg=cfg)
    led.record_run(per_tree, 512)
    rows2 = led.per_round_measured()
    for phase in protocol.WIRE_PHASES:
        assert sum(r[phase] for r in rows2) == led.measured[phase]
