"""Tree builder correctness: against a pure-numpy oracle and invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, forest, losses, split, tree
from repro.core.histogram import compute_histogram
from repro.core.types import TreeConfig


# ----------------------------------------------------------------------------
# Pure-numpy reference GBDT tree (level-wise, same semantics) — the oracle.
# ----------------------------------------------------------------------------
def numpy_build_tree(binned, g, h, w, fmask, cfg: TreeConfig):
    n, d = binned.shape
    assign = np.zeros(n, np.int32)
    feats, thrs = [], []
    for level in range(cfg.max_depth):
        num_nodes = 2**level
        level_feat = np.full(num_nodes, -1, np.int32)
        level_thr = np.full(num_nodes, cfg.num_bins, np.int32)
        for node in range(num_nodes):
            in_node = (assign == node) & (w > 0)
            best_gain, best = 0.0, None
            Gt, Ht = g[in_node].sum(), h[in_node].sum()
            parent = Gt**2 / (Ht + cfg.lambda_)
            for f in range(d):
                if not fmask[f]:
                    continue
                for b in range(cfg.num_bins - 1):
                    left = in_node & (binned[:, f] <= b)
                    Gl, Hl = g[left].sum(), h[left].sum()
                    Gr, Hr = Gt - Gl, Ht - Hl
                    if Hl < cfg.min_child_weight or Hr < cfg.min_child_weight:
                        continue
                    gain = 0.5 * (
                        Gl**2 / (Hl + cfg.lambda_)
                        + Gr**2 / (Hr + cfg.lambda_)
                        - parent
                    ) - cfg.gamma
                    if gain > best_gain:
                        best_gain, best = gain, (f, b)
            if best is not None:
                level_feat[node], level_thr[node] = best
        # route everyone (masked included), matching the JAX builder
        nf = level_feat[assign]
        nt = level_thr[assign]
        fv = binned[np.arange(n), np.clip(nf, 0, None)]
        go_right = (nf >= 0) & (fv > nt)
        assign = assign * 2 + go_right.astype(np.int32)
        feats.append(level_feat)
        thrs.append(level_thr)
    leaf_w = np.zeros(cfg.num_leaves, np.float64)
    for leaf in range(cfg.num_leaves):
        in_leaf = (assign == leaf) & (w > 0)
        if in_leaf.any():
            leaf_w[leaf] = -g[in_leaf].sum() / (h[in_leaf].sum() + cfg.lambda_)
    return np.concatenate(feats), np.concatenate(thrs), leaf_w, assign


@pytest.mark.parametrize("max_depth", [1, 2, 3])
@pytest.mark.parametrize("seed", [0, 1])
def test_tree_matches_numpy_oracle(max_depth, seed):
    # The numpy oracle accumulates every node's histogram directly, so the
    # JAX side must run the direct pipeline too (hist_subtraction now
    # defaults ON; sibling derivation is only tolerance-equivalent and has
    # its own parity suite in test_subtraction.py) — this keeps bit-exact
    # oracle coverage on the reference path.
    rng = np.random.default_rng(seed)
    n, d, B = 300, 6, 8
    cfg = TreeConfig(max_depth=max_depth, num_bins=B, lambda_=1.0,
                     hist_subtraction=False)
    binned = rng.integers(0, B, (n, d)).astype(np.int32)
    g = rng.normal(size=n).astype(np.float64)
    h = rng.random(n).astype(np.float64) + 0.1
    w = (rng.random(n) < 0.8).astype(np.float64)
    fmask = rng.random(d) < 0.9

    ref_f, ref_t, ref_w, ref_assign = numpy_build_tree(binned, g, h, w, fmask, cfg)

    tr, assign = tree.build_tree(
        jnp.asarray(binned), jnp.asarray(g, jnp.float32), jnp.asarray(h, jnp.float32),
        jnp.asarray(w, jnp.float32), jnp.asarray(fmask), cfg,
    )
    np.testing.assert_array_equal(np.asarray(tr.feature), ref_f)
    np.testing.assert_array_equal(np.asarray(tr.threshold), ref_t)
    np.testing.assert_allclose(np.asarray(tr.leaf_weight), ref_w, rtol=2e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(assign), ref_assign)


def test_predict_tree_consistent_with_build_routing():
    rng = np.random.default_rng(3)
    n, d, B = 500, 5, 16
    cfg = TreeConfig(max_depth=3, num_bins=B)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.ones(n, jnp.float32)
    tr, assign = tree.build_tree(
        binned, g, h, jnp.ones(n, jnp.float32), jnp.ones(d, bool), cfg
    )
    pred = tree.predict_tree(tr, binned, cfg.max_depth)
    np.testing.assert_allclose(
        np.asarray(pred), np.asarray(tr.leaf_weight)[np.asarray(assign)]
    )


def test_chosen_split_is_argmax_over_enumeration():
    """The gain of the selected split must dominate every enumerated candidate."""
    rng = np.random.default_rng(4)
    n, d, B = 400, 4, 8
    cfg = TreeConfig(max_depth=1, num_bins=B)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.1, jnp.float32)
    w = jnp.ones(n, jnp.float32)
    hist = compute_histogram(binned, g, h, w, jnp.zeros(n, jnp.int32), 1, B)
    decision = split.choose_splits(hist, jnp.ones(d, bool), cfg)
    gains = split.split_gains(hist, cfg)
    assert float(decision.gain[0]) == pytest.approx(float(jnp.max(gains)), rel=1e-6)


def test_unsplittable_node_routes_all_left():
    """Constant features -> no split -> all samples land in leaf 0."""
    n, d, B = 64, 3, 8
    cfg = TreeConfig(max_depth=2, num_bins=B)
    binned = jnp.zeros((n, d), jnp.int32)
    g = jnp.asarray(np.random.default_rng(0).normal(size=n), jnp.float32)
    tr, assign = tree.build_tree(
        binned, g, jnp.ones(n, jnp.float32), jnp.ones(n, jnp.float32),
        jnp.ones(d, bool), cfg,
    )
    assert np.all(np.asarray(tr.feature) == -1)
    assert np.all(np.asarray(assign) == 0)
    # the single populated leaf carries the global weight
    expected = -float(jnp.sum(g)) / (n + cfg.lambda_)
    assert float(tr.leaf_weight[0]) == pytest.approx(expected, rel=1e-5)


def test_forest_mean_combines_trees():
    rng = np.random.default_rng(5)
    n, d, B = 256, 4, 8
    cfg = TreeConfig(max_depth=2, num_bins=B)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.ones(n, jnp.float32)
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(0), n, d, 3, 0.7, 1.0)
    trees, train_pred = forest.build_forest(binned, g, h, smask, fmask, cfg)
    per_tree = jax.vmap(lambda t: tree.predict_tree(t, binned, cfg.max_depth))(trees)
    np.testing.assert_allclose(
        np.asarray(train_pred), np.asarray(per_tree.mean(0)), rtol=1e-5, atol=1e-6
    )


def test_sample_masks_exact_counts():
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(1), 1000, 10, 8, 0.3, 0.5)
    assert smask.shape == (8, 1000) and fmask.shape == (8, 10)
    np.testing.assert_array_equal(np.asarray(smask.sum(1)), np.full(8, 300.0))
    np.testing.assert_array_equal(np.asarray(fmask.sum(1)), np.full(8, 5))
