"""Serving-tier tests (DESIGN.md §14): fused bin+traverse, quantized
ensembles, the batch ladder's no-recompile property, mid-stream hot-swap,
and the metrics scrape endpoint."""

import os
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting
from repro.core.types import (
    PackedEnsemble,
    dequantize_ensemble,
    margin_delta_bound,
    pack_ensemble,
    quantize_ensemble,
)
from repro.checkpoint import io as ckpt_io
from repro.data import synthetic
from repro.launch import serve_fedgbf
from repro.obs import metrics as obs_metrics


@pytest.fixture(scope="module")
def model_a():
    ds = synthetic.load("default_credit_card")
    cfg = boosting.dynamic_fedgbf_config(rounds=4)
    m, _ = boosting.train_fedgbf(
        jnp.asarray(ds.x_train[:1500]), jnp.asarray(ds.y_train[:1500]),
        cfg, jax.random.PRNGKey(0),
    )
    return pack_ensemble(m), ds


@pytest.fixture(scope="module")
def model_b(model_a):
    _, ds = model_a
    cfg = boosting.dynamic_fedgbf_config(rounds=3)
    m, _ = boosting.train_fedgbf(
        jnp.asarray(ds.x_train[1500:3000]),
        jnp.asarray(ds.y_train[1500:3000]),
        cfg, jax.random.PRNGKey(7),
    )
    return pack_ensemble(m)


def _hard_rows(ds, n=301):
    """Request rows incl. the non-finite cases the fused path must route
    exactly like binning: NaN (NAN_BIN left), +inf / -inf (extreme bins)."""
    x = np.array(ds.x_test[:n], np.float32)
    x[0, 0] = np.nan
    x[1, 1] = np.inf
    x[2, 2] = -np.inf
    x[3, :] = np.nan
    return x


# ---------------------------------------------------------------------------
# Layer 1: fused bin+traverse
# ---------------------------------------------------------------------------
def test_fused_matches_binned_bit_exact(model_a):
    pe, ds = model_a
    x = jnp.asarray(_hard_rows(ds))
    ref = boosting.predict(pe, x, impl="weighted")
    fused = boosting.predict(pe, x, impl="fused")
    assert bool(jnp.all(ref == fused)), "fused must be bit-exact vs binned"


def test_fused_pallas_matches_binned_pallas_bit_exact(model_a):
    pe, ds = model_a
    x = jnp.asarray(_hard_rows(ds))
    ref = boosting.predict(pe, x, impl="pallas")
    fused = boosting.predict(pe, x, impl="fused-pallas")
    assert bool(jnp.all(ref == fused))


def test_fused_multiclass_channels(model_a):
    _, ds = model_a
    dsm = synthetic.load("credit_risk_tiers")
    cfg = boosting.dynamic_fedgbf_config(rounds=2, loss="softmax3")
    m, _ = boosting.train_fedgbf(
        jnp.asarray(dsm.x_train[:800]), jnp.asarray(dsm.y_train[:800]),
        cfg, jax.random.PRNGKey(0),
    )
    pe = pack_ensemble(m)
    x = jnp.asarray(np.array(dsm.x_test[:67], np.float32))
    ref = boosting.predict(pe, x, impl="weighted")
    fused = boosting.predict(pe, x, impl="fused")
    assert ref.shape == fused.shape == (67, 3)
    assert bool(jnp.all(ref == fused))


# ---------------------------------------------------------------------------
# Layer 2: quantized ensembles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8, 16])
def test_quantized_margin_within_provable_bound(model_a, bits):
    pe, ds = model_a
    q = quantize_ensemble(pe, bits=bits, key=jax.random.PRNGKey(3))
    x = jnp.asarray(_hard_rows(ds))
    oracle = boosting.predict(pe, x, impl="fused")
    got = boosting.predict(q, x, impl="fused")
    bound = margin_delta_bound(q)
    delta = float(jnp.max(jnp.abs(got - oracle)))
    assert delta <= bound, f"int{bits} delta {delta} exceeds bound {bound}"
    # structure is lossless: widening back must reproduce routing tables
    wide = dequantize_ensemble(q)
    assert bool(jnp.all(wide.feature == pe.feature))
    assert bool(jnp.all(wide.threshold == pe.threshold))


def test_quantized_checkpoint_roundtrip(model_a, tmp_path):
    pe, ds = model_a
    q = quantize_ensemble(pe, bits=8, key=jax.random.PRNGKey(3))
    path = str(tmp_path / "q8")
    ckpt_io.save_ensemble(path, q)
    loaded = ckpt_io.load_ensemble(path)
    assert type(loaded).__name__ == "QuantizedEnsemble"
    assert loaded.bits == 8
    assert loaded.leaf_q.dtype == jnp.int8
    x = jnp.asarray(np.array(ds.x_test[:64], np.float32))
    assert bool(jnp.all(boosting.predict(loaded, x, impl="fused")
                        == boosting.predict(q, x, impl="fused")))
    # quantized serves through the pallas fused kernel too, identically
    assert bool(jnp.all(boosting.predict(loaded, x, impl="fused-pallas")
                        == boosting.predict(q, x, impl="fused-pallas")))


# ---------------------------------------------------------------------------
# Layer 3: admission ladder — adaptivity without recompiles
# ---------------------------------------------------------------------------
def test_ladder_pick_respects_budget_and_queue():
    sm = serve_fedgbf.StreamMetrics(1024)
    ladder = serve_fedgbf.BatchLadder([256, 512, 1024])
    # queue cap: a short queue admits the smallest covering rung
    assert ladder.pick(100, None, sm) == 256
    assert ladder.pick(600, None, sm) == 1024
    assert ladder.pick(10_000, None, sm) == 1024
    # unobserved rungs are optimistic under a budget
    assert ladder.pick(10_000, 0.005, sm) == 1024
    # feed the top rung a latency history that breaks a 5 ms budget
    for _ in range(20):
        sm.rung_latency(1024).observe(0.050)
        sm.rung_latency(512).observe(0.002)
    assert ladder.pick(10_000, 0.005, sm) == 512
    # and a budget nothing satisfies falls to the smallest rung
    for _ in range(20):
        sm.rung_latency(256).observe(0.010)
    assert ladder.pick(10_000, 1e-6, sm) == 256


def test_adaptive_stream_never_recompiles(model_a):
    pe, ds = model_a
    x = np.array(ds.x_test[:700], np.float32)
    sizes = [128, 256, 512]
    ladder = serve_fedgbf.BatchLadder(sizes)
    ladder.warm(pe, x.shape[1], "fused")
    compiled = serve_fedgbf._score_batch._cache_size()
    slot = serve_fedgbf.ModelSlot(pe, "fused")
    out, sm = serve_fedgbf.serve_stream(
        slot, x, ladder=ladder, p99_budget_s=10.0)
    # 700 rows on a warm [128,256,512] ladder: adaptation ran (>1 rung) and
    # the jit cache did not grow — no mid-stream recompiles.
    assert serve_fedgbf._score_batch._cache_size() == compiled
    assert len(sm._rung_hists) > 1
    assert int(sm.rows.value) == 700
    ref, _ = serve_fedgbf.score_stream(pe, x, batch_size=512, impl="fused")
    np.testing.assert_array_equal(out, ref)


def test_clean_full_batch_not_copied(model_a):
    """Satellite: full clean batches go straight in — a read-only input
    array must serve fine (no mutation), and inf rows still force the
    copy-and-zero path without touching the caller's buffer."""
    pe, ds = model_a
    x = np.array(ds.x_test[:256], np.float32)
    x[7, 0] = np.inf
    x.setflags(write=False)
    before = x.copy()
    out, sm = serve_fedgbf.score_stream(pe, x, batch_size=128, impl="fused")
    np.testing.assert_array_equal(np.asarray(x), before)
    assert int(sm.rows_rejected.value) == 1
    assert np.isnan(out[7]) and np.isfinite(np.delete(out, 7)).all()


# ---------------------------------------------------------------------------
# Layer 4: mid-stream hot-swap
# ---------------------------------------------------------------------------
def test_mid_stream_swap_scores_match_each_oracle(model_a, model_b, tmp_path):
    pe_a, ds = model_a
    pe_b = model_b
    path_b = str(tmp_path / "model_b")
    ckpt_io.save_ensemble(path_b, pe_b)
    x = np.array(ds.x_test[:512], np.float32)

    sm = serve_fedgbf.StreamMetrics(128)
    ladder = serve_fedgbf.BatchLadder([128])
    slot = serve_fedgbf.ModelSlot(pe_a, "fused", metrics=sm,
                                  warm_sizes=[128])
    out, sm = serve_fedgbf.serve_stream(
        slot, x, ladder=ladder, metrics=sm, swap_plan={2: path_b})

    # batches 0-1 served model A, batches 2-3 model B — each side must be
    # bit-exact against that model's own oracle on the same rows
    oracle_a, _ = serve_fedgbf.score_stream(pe_a, x[:256], 128, "fused")
    oracle_b, _ = serve_fedgbf.score_stream(pe_b, x[256:], 128, "fused")
    np.testing.assert_array_equal(out[:256], oracle_a)
    np.testing.assert_array_equal(out[256:], oracle_b)
    assert int(sm.reloads.value) == 1
    assert int(sm.model_generation.value) == 1
    assert sm.swap_latency.count == 1
    # occupancy was re-segmented at the swap: only model B's two full
    # batches accumulate, so the gauge reads exactly 1.0
    assert sm.occupancy.value == 1.0


def test_occupancy_segments_at_swap(model_a, model_b, tmp_path):
    pe_a, ds = model_a
    path_b = str(tmp_path / "model_b2")
    ckpt_io.save_ensemble(path_b, model_b)
    # 2 full pre-swap batches, then a post-swap segment ending half-full:
    # blended occupancy would read 80/96; segmented must read 16/32 = 0.5
    x = np.array(ds.x_test[:80], np.float32)
    sm = serve_fedgbf.StreamMetrics(32)
    slot = serve_fedgbf.ModelSlot(pe_a, "fused", metrics=sm, warm_sizes=[32])
    _, sm = serve_fedgbf.serve_stream(
        slot, x, ladder=serve_fedgbf.BatchLadder([32]), metrics=sm,
        swap_plan={2: path_b})
    assert sm.occupancy.value == 0.5
    assert int(sm.padded_rows.value) == 16


def test_refused_candidate_never_perturbs_serving_histogram(
        model_a, tmp_path):
    pe, ds = model_a
    good = str(tmp_path / "good")
    ckpt_io.save_ensemble(good, pe)
    bad = str(tmp_path / "bad")
    ckpt_io.save_ensemble(bad, pe)
    # corrupt the npz payload so the sha256 check refuses the candidate
    with open(bad + ".npz", "r+b") as f:
        f.seek(120)
        byte = f.read(1)
        f.seek(120)
        f.write(bytes([byte[0] ^ 0xFF]))

    x = np.array(ds.x_test[:256], np.float32)

    def run(swap_plan):
        sm = serve_fedgbf.StreamMetrics(64)
        slot = serve_fedgbf.ModelSlot(pe, "fused", metrics=sm,
                                      warm_sizes=[64])
        out, sm = serve_fedgbf.serve_stream(
            slot, x, ladder=serve_fedgbf.BatchLadder([64]), metrics=sm,
            swap_plan=swap_plan)
        return out, sm

    base_out, base_sm = run(None)
    out, sm = run({2: bad})
    assert int(sm.reload_failures.value) == 1
    assert int(sm.reloads.value) == 0
    # scores AND every serving series identical to the no-swap run — the
    # refusal shows up ONLY on the failure counter (bucket CONTENTS carry
    # wall-clock noise; the observation counts and gauges must not move)
    np.testing.assert_array_equal(out, base_out)
    assert sm.latency.count == base_sm.latency.count == 4
    for cap, hist in sm._rung_hists.items():
        assert hist.count == base_sm._rung_hists[cap].count
    assert sm.swap_latency.count == 0
    assert int(sm.model_generation.value) == 0
    assert sm.occupancy.value == base_sm.occupancy.value
    assert int(sm.rows.value) == int(base_sm.rows.value)


# ---------------------------------------------------------------------------
# Metrics: labels + the HTTP scrape endpoint
# ---------------------------------------------------------------------------
def test_labeled_series_render_once_per_family():
    r = obs_metrics.MetricsRegistry()
    r.histogram("lat_seconds", "Latency.", labels={"batch_size": "128"})
    r.histogram("lat_seconds", "Latency.", labels={"batch_size": "256"})
    with pytest.raises(ValueError):
        r.histogram("lat_seconds", labels={"batch_size": "128"})
    text = r.render()
    assert text.count("# TYPE lat_seconds histogram") == 1
    assert 'lat_seconds_count{batch_size="128"} 0' in text
    assert 'lat_seconds_count{batch_size="256"} 0' in text


def test_metrics_http_endpoint_serves_live_registry():
    r = obs_metrics.MetricsRegistry()
    c = r.counter("reqs_total", "Requests.")
    server = obs_metrics.serve_metrics_http(r, port=0)
    try:
        c.inc(3)
        with urllib.request.urlopen(server.url) as resp:
            assert resp.status == 200
            assert "text/plain" in resp.headers["Content-Type"]
            body = resp.read().decode()
        assert body == r.render()
        assert "reqs_total 3" in body
        c.inc()  # live registry: the next scrape sees the new count
        with urllib.request.urlopen(server.url) as resp:
            assert "reqs_total 4" in resp.read().decode()
    finally:
        server.close()
