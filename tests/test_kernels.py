"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracle,
executed with interpret=True on CPU (the kernel body itself runs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.histogram import ref
from repro.kernels.histogram.ops import (
    compute_histogram_pallas,
    compute_histogram_pallas_fused,
)


def _random_case(rng, n, d, B, nodes, g_dtype):
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), g_dtype)
    h = jnp.asarray(rng.random(n) + 0.05, g_dtype)
    w = jnp.asarray(rng.integers(0, 2, n), g_dtype)
    assign = jnp.asarray(rng.integers(0, nodes, n), jnp.int32)
    return binned, g, h, w, assign


# Sweep: tile-divisible and ragged sample counts, feature counts around the
# feat_block boundary, bin counts, frontier widths incl. the non-128 NB case.
@pytest.mark.parametrize(
    "n,d,B,nodes",
    [
        (512, 8, 32, 1),       # exactly one tile, one feature block
        (1000, 10, 32, 4),     # ragged n and d (the paper's dataset shapes)
        (700, 23, 32, 4),      # default-credit width
        (256, 5, 16, 2),       # NB = 32 << 128 lane pad
        (2048, 3, 64, 8),      # NB = 512, deep frontier
        (130, 1, 8, 1),        # degenerate single feature (leaf-stats shape)
        (513, 9, 32, 2),       # off-by-one over the tile boundary
    ],
)
def test_histogram_kernel_matches_ref(n, d, B, nodes):
    rng = np.random.default_rng(n + d + B + nodes)
    binned, g, h, w, assign = _random_case(rng, n, d, B, nodes, jnp.float32)
    out = compute_histogram_pallas(binned, g, h, w, assign, nodes, B)
    expected = ref.histogram_ref(binned, g, h, w, assign, nodes, B)
    assert out.shape == (nodes, d, B, 3)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_histogram_kernel_dtypes(dtype):
    """bf16 inputs accumulate in f32 inside the kernel (preferred_element_type)."""
    rng = np.random.default_rng(99)
    binned, g, h, w, assign = _random_case(rng, 600, 7, 32, 4, dtype)
    out = compute_histogram_pallas(binned, g, h, w, assign, 4, 32)
    expected = ref.histogram_ref(
        binned, g.astype(jnp.float32), h.astype(jnp.float32),
        w.astype(jnp.float32), assign, 4, 32,
    )
    assert out.dtype == jnp.float32
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=tol, atol=tol)


@pytest.mark.parametrize("tile_n,feat_block", [(256, 4), (512, 8), (1024, 16)])
def test_histogram_kernel_tilings(tile_n, feat_block):
    """Block-shape sweep: result must be invariant to the BlockSpec tiling."""
    rng = np.random.default_rng(7)
    binned, g, h, w, assign = _random_case(rng, 900, 11, 32, 2, jnp.float32)
    out = compute_histogram_pallas(
        binned, g, h, w, assign, 2, 32, tile_n=tile_n, feat_block=feat_block
    )
    expected = ref.histogram_ref(binned, g, h, w, assign, 2, 32)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize(
    "n,d,B,nodes",
    [
        (512, 8, 32, 1),       # exactly one tile, one feature block
        (1000, 10, 32, 4),     # ragged n and d
        (700, 23, 32, 4),      # default-credit width
        (256, 5, 16, 2),       # NB = 32 << 128 lane pad
        (130, 1, 8, 1),        # degenerate single feature (leaf-stats shape)
        (513, 9, 32, 2),       # off-by-one over the tile boundary
    ],
)
def test_fused_train_histogram_kernel_matches_ref(n, d, B, nodes):
    """The training-side fused kernel (in-kernel id + stats staging) agrees
    with the oracle on the same sweep as the staged kernel."""
    rng = np.random.default_rng(1000 + n + d + B + nodes)
    binned, g, h, w, assign = _random_case(rng, n, d, B, nodes, jnp.float32)
    out = compute_histogram_pallas_fused(binned, g, h, w, assign, nodes, B)
    expected = ref.histogram_ref(binned, g, h, w, assign, nodes, B)
    assert out.shape == (nodes, d, B, 3)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("tile_n,feat_block", [(256, 4), (512, 8)])
def test_fused_train_histogram_kernel_tilings(tile_n, feat_block):
    rng = np.random.default_rng(17)
    binned, g, h, w, assign = _random_case(rng, 900, 11, 32, 2, jnp.float32)
    out = compute_histogram_pallas_fused(
        binned, g, h, w, assign, 2, 32, tile_n=tile_n, feat_block=feat_block
    )
    expected = ref.histogram_ref(binned, g, h, w, assign, 2, 32)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5
    )


def test_fused_kernel_vmaps_over_trees():
    """The forest layer vmaps the histogram over per-tree (weight, assign) —
    the fused kernel must batch exactly like the reference."""
    rng = np.random.default_rng(23)
    n, d, B, nodes, T = 600, 7, 16, 4, 3
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.05, jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, (T, n)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, nodes, (T, n)), jnp.int32)
    out = jax.vmap(
        lambda wt, at: compute_histogram_pallas_fused(
            binned, g, h, wt, at, nodes, B)
    )(w, assign)
    expected = jax.vmap(
        lambda wt, at: ref.histogram_ref(binned, g, h, wt, at, nodes, B)
    )(w, assign)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5
    )


def test_onehot_identity_matches_segment_sum():
    """The algebraic identity behind the kernel (DESIGN.md §2), in plain jnp."""
    rng = np.random.default_rng(11)
    binned, g, h, w, assign = _random_case(rng, 400, 6, 16, 4, jnp.float32)
    a = ref.histogram_ref(binned, g, h, w, assign, 4, 16)
    b = ref.compute_histogram_onehot(binned, g, h, w, assign, 4, 16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_kernel_inside_tree_builder():
    """End-to-end: trees built with the Pallas histogram == segment-sum trees.

    The staged kernel has no registry backend of its own, so it rides an
    ad-hoc ``TreeBackend`` (the per-provider kwargs of the historical
    ``build_tree`` shim are gone); ``build_round`` lifts the per-tree
    provider over the tree axis itself."""
    from repro.core import tree
    from repro.core.backend import BackendDescriptor, TreeBackend
    from repro.core.histogram import histogram_dispatch
    from repro.core.types import TreeConfig

    rng = np.random.default_rng(21)
    n, d, B = 800, 10, 32
    cfg = TreeConfig(max_depth=3, num_bins=B)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.1, jnp.float32)
    w = jnp.ones(n, jnp.float32)
    fm = jnp.ones(d, bool)

    bk = TreeBackend(
        BackendDescriptor(impl="adhoc-pallas-staged", histogram_impl="pallas"),
        histogram_fn=histogram_dispatch("pallas"),
    )
    t_ref, a_ref = tree.build_tree(binned, g, h, w, fm, cfg)
    t_pal, a_pal = tree.build_tree(binned, g, h, w, fm, cfg, backend=bk)
    np.testing.assert_array_equal(np.asarray(t_ref.feature), np.asarray(t_pal.feature))
    np.testing.assert_array_equal(
        np.asarray(t_ref.threshold), np.asarray(t_pal.threshold)
    )
    np.testing.assert_allclose(
        np.asarray(t_ref.leaf_weight), np.asarray(t_pal.leaf_weight),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_pal))


# ---------------------------------------------------------------------------
# round (tree-grid) kernel
# ---------------------------------------------------------------------------
from repro.kernels.histogram.ops import (  # noqa: E402
    compute_round_histogram_pallas_fused,
    compute_round_histogram_pallas_fused_child,
)


@pytest.mark.parametrize(
    "n,d,B,nodes,T",
    [
        (512, 8, 32, 1, 1),    # T = 1 degenerates to the per-tree kernel
        (700, 9, 16, 4, 3),    # ragged n/d, multi-tree round
        (513, 5, 8, 2, 5),     # off-by-one tile boundary, paper-width round
    ],
)
def test_round_kernel_matches_round_ref(n, d, B, nodes, T):
    """The tree-grid kernel (one launch, tree axis on the grid) agrees with
    the round-native segment reference for every tree of the round."""
    from repro.core.histogram import compute_round_histogram

    rng = np.random.default_rng(n + d + T)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.05, jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, (T, n)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, nodes, (T, n)), jnp.int32)
    out = compute_round_histogram_pallas_fused(binned, g, h, w, assign, nodes, B)
    ref = compute_round_histogram(binned, g, h, w, assign, nodes, B)
    assert out.shape == (T, nodes, d, B, 3)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


def test_round_child_kernel_matches_adapted_ref():
    """The tree-grid child kernel (in-kernel left-mask + parent ids) agrees
    with the generic ``as_round_child_fn`` adaptation."""
    from repro.core.histogram import as_round_child_fn, compute_round_histogram

    rng = np.random.default_rng(42)
    n, d, B, parents, T = 700, 9, 16, 4, 3
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.05, jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, (T, n)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, 2 * parents, (T, n)), jnp.int32)
    out = compute_round_histogram_pallas_fused_child(
        binned, g, h, w, assign, parents, B
    )
    ref = as_round_child_fn(compute_round_histogram)(
        binned, g, h, w, assign, parents, B
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# ensemble_predict kernel
# ---------------------------------------------------------------------------
import repro.core.forest as _forest
import repro.core.tree as _tree
from repro.core.types import TreeConfig as _TreeConfig
from repro.kernels.ensemble_predict.ops import predict_forest_pallas


@pytest.mark.parametrize(
    "n,d,B,D,ntrees",
    [
        (500, 10, 16, 3, 5),    # paper-shaped
        (300, 23, 32, 2, 3),    # wide features, shallow
        (257, 5, 8, 4, 2),      # ragged tile boundary, deeper
        (64, 3, 8, 1, 1),       # stumps, single tree
    ],
)
def test_predict_kernel_matches_traversal(n, d, B, D, ntrees):
    rng = np.random.default_rng(n + D)
    cfg = _TreeConfig(max_depth=D, num_bins=B)
    binned = jnp.asarray(rng.integers(0, B, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.random(n) + 0.1, jnp.float32)
    smask, fmask = _forest.sample_masks(
        jax.random.PRNGKey(1), n, d, ntrees, 0.8, 0.9
    )
    trees, _ = _forest.build_forest(binned, g, h, smask, fmask, cfg)
    ref_out = _tree.predict_forest(trees, binned, D)
    out = predict_forest_pallas(trees, binned, D)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_out), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("tile_n", [128, 256, 512])
def test_predict_kernel_tiling_invariance(tile_n):
    rng = np.random.default_rng(9)
    cfg = _TreeConfig(max_depth=3, num_bins=16)
    n, d = 700, 8
    binned = jnp.asarray(rng.integers(0, 16, (n, d)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.ones(n, jnp.float32)
    smask, fmask = _forest.sample_masks(jax.random.PRNGKey(2), n, d, 4, 1.0, 1.0)
    trees, _ = _forest.build_forest(binned, g, h, smask, fmask, cfg)
    ref_out = _tree.predict_forest(trees, binned, 3)
    out = predict_forest_pallas(trees, binned, 3, tile_n=tile_n)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_out), rtol=1e-5, atol=1e-6
    )
