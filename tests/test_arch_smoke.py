"""Per-architecture smoke tests (deliverable f): reduced same-family variant,
one forward + one train step on CPU, asserting output shapes and finiteness;
plus decode-vs-forward consistency per family (exact in f32)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model, ssm, train


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.num_frames, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 3
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    B, S = 2, 32
    batch = _batch(cfg, B, S)

    state = train.init_train_state(jax.random.PRNGKey(0), cfg)
    logits, aux = model.forward(
        state.params, batch["tokens"], cfg,
        patch_embeds=batch.get("patch_embeds"), frames=batch.get("frames"),
    )
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = jax.jit(train.make_train_step(cfg))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
        for a, b in zip(
            jax.tree.leaves(state.params), jax.tree.leaves(new_state.params)
        )
    )
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_decreases(arch):
    """Two steps on the same batch must reduce the loss (optimizer sanity)."""
    cfg = get_smoke_config(arch)
    batch = _batch(cfg, 2, 16)
    state = train.init_train_state(jax.random.PRNGKey(1), cfg)
    step = jax.jit(train.make_train_step(cfg, peak_lr=1e-3, warmup=0))
    state, m0 = step(state, batch)
    for _ in range(4):
        state, m1 = step(state, batch)
    assert float(m1["ce"]) < float(m0["ce"])


@pytest.mark.parametrize(
    "arch", ["smollm-135m", "gemma2-2b", "zamba2-7b", "rwkv6-7b",
             "whisper-large-v3", "mixtral-8x22b"]
)
def test_smoke_decode_matches_forward_f32(arch):
    """Decode path == training forward, token by token (f32 exact)."""
    cfg = dataclasses.replace(get_smoke_config(arch), compute_dtype="float32")
    rng = np.random.default_rng(3)
    B, S = 2, 16
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    stubs = {}
    if cfg.frontend == "audio_stub":
        stubs["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.num_frames, cfg.d_model)), jnp.float32
        )
    full, _ = model.forward(params, toks, cfg, **stubs)
    cache = model.init_cache(cfg, B, S)
    if cfg.encoder is not None:
        cache = model.fill_cross_cache(
            params, cache, model.encode(params, stubs["frames"], cfg), cfg
        )
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg))
    worst = 0.0
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t : t + 1], jnp.int32(t))
        worst = max(worst, float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert worst < 1e-3, worst


def test_mamba_chunked_matches_reference():
    cfg = get_smoke_config("zamba2-7b")
    p = ssm.init_mamba(jax.random.PRNGKey(3), cfg, cfg.d_model)
    u = jnp.asarray(np.random.default_rng(1).normal(size=(2, 64, cfg.d_model)) * 0.5,
                    jnp.float32)
    a = ssm.mamba_forward(p, u, cfg, cfg.d_model)
    b = ssm.mamba_reference(p, u, cfg, cfg.d_model)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_rwkv_chunked_matches_reference():
    cfg = get_smoke_config("rwkv6-7b")
    p = ssm.init_rwkv(jax.random.PRNGKey(4), cfg, cfg.d_model)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 64, cfg.d_model)) * 0.5,
                    jnp.float32)
    a = ssm.rwkv_forward(p, x, cfg, cfg.d_model)
    b = ssm.rwkv_reference(p, x, cfg, cfg.d_model)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_assignment(arch):
    """The full configs carry the EXACT assigned dimensions (lowered only via
    ShapeDtypeStruct in the dry-run, never allocated here)."""
    cfg = get_config(arch)
    expected = {
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, (got, expected)
    assert cfg.source  # citation present
    if arch == "zamba2-7b":
        assert cfg.ssm.d_state == 64
    if arch == "granite-moe-3b-a800m":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (40, 8)
    if arch == "mixtral-8x22b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (8, 2)
        assert cfg.window == 4096
    if arch == "whisper-large-v3":
        assert cfg.encoder.num_layers == 32


def test_checkpoint_roundtrip():
    from repro import checkpoint

    cfg = get_smoke_config("smollm-135m")
    state = train.init_train_state(jax.random.PRNGKey(5), cfg)
    import tempfile, os

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        checkpoint.save_pytree(path, state.params)
        loaded = checkpoint.load_pytree(path, state.params)
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_dense_matches_ragged_at_ample_capacity():
    """The dense-capacity dispatch (EXPERIMENTS §Perf pair 1) is numerically
    identical to ragged_dot when nothing overflows capacity."""
    import dataclasses as dc

    from repro.models import moe

    base = get_smoke_config("granite-moe-3b-a800m")
    cfg_r = dc.replace(base, compute_dtype="float32")
    cfg_d = dc.replace(
        base, compute_dtype="float32",
        moe=dc.replace(base.moe, impl="dense", capacity_factor=8.0),
    )
    p = moe.init_moe(jax.random.PRNGKey(0), cfg_r, base.d_model, base.d_ff)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(2, 32, base.d_model)) * 0.3,
        jnp.float32,
    )
    a, aux_a = moe.moe_ffn(p, x, cfg_r)
    b, aux_b = moe.moe_ffn_dense(p, x, cfg_d)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert float(aux_a) == pytest.approx(float(aux_b))


def test_sliding_window_decode_matches_forward():
    """Windowed ring-buffer decode == full-forward with window mask, even for
    positions beyond the window (gemma2/mixtral local layers)."""
    cfg = dataclasses.replace(
        get_smoke_config("mixtral-8x22b"), compute_dtype="float32", window=8
    )
    rng = np.random.default_rng(5)
    B, S = 2, 24  # 3x the window
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full, _ = model.forward(params, toks, cfg)
    cache = model.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg))
    worst = 0.0
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t : t + 1], jnp.int32(t))
        worst = max(worst, float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert worst < 1e-3, worst


def test_vocab_padding_granite_moe():
    """49155 is not 256-aligned; vocab_padded must be and logits use it."""
    cfg = get_config("granite-moe-3b-a800m")
    assert cfg.vocab == 49155 and cfg.vocab_padded == 49408
    assert cfg.vocab_padded % 256 == 0
