"""Communication-efficiency subsystem (federation/compress.py, DESIGN.md §5).

Single-device coverage of the codec, the GOSS masks, the wire model and the
measured-bytes reconciliation (on a 1-party mesh the full shard_map +
transport path runs on one CPU device); the multi-party strict/tolerance
equivalence checks live in federation/selftest.py (subprocess, forced
devices) via tests/test_federation.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, forest, losses, split
from repro.core.types import FedGBFConfig, TreeConfig
from repro.federation import compress, protocol, vfl


# ---------------------------------------------------------------------------
# Quantization codec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8, 16])
@pytest.mark.parametrize("stochastic", [True, False])
def test_quantize_roundtrip_error_bound(bits, stochastic):
    """|dequantize(quantize(x)) - x| <= scale per element (one rounding step),
    and exact zeros survive exactly (scale-1 guard)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 6, 16, 2)) * 100.0, jnp.float32)
    x = x.at[0, 0].set(0.0)  # an all-zero (node, feature) slice
    q, scale = compress.quantize_stats(x, bits, jax.random.PRNGKey(1), stochastic)
    assert q.dtype == (jnp.int8 if bits == 8 else jnp.int16)
    assert scale.shape == (4, 6, 2)
    deq = compress.dequantize_stats(q, scale)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    bound = np.asarray(scale)[:, :, None, :] * (1.0 if stochastic else 0.5)
    assert (err <= bound + 1e-6).all()
    np.testing.assert_array_equal(np.asarray(deq[0, 0]), 0.0)


def test_quantize_stochastic_is_unbiased():
    """Stochastic rounding is unbiased: averaging many independent roundings
    of the same value converges to the value."""
    x = jnp.full((1, 1, 8, 1), 3.1415926, jnp.float32)
    outs = []
    for s in range(200):
        q, scale = compress.quantize_stats(x, 8, jax.random.PRNGKey(s), True)
        outs.append(np.asarray(compress.dequantize_stats(q, scale)))
    mean = np.stack(outs).mean()
    # one rounding step is ~scale = 3.14/127 ~ 0.025; the mean over 200
    # draws must sit well inside it
    assert abs(mean - 3.1415926) < 0.005


def test_transport_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        compress.TransportSpec(kind="zstd")
    with pytest.raises(ValueError, match="bits"):
        compress.TransportSpec(kind="quantized", bits=4)
    with pytest.raises(ValueError, match="k >= 1"):
        compress.TransportSpec(kind="topk", k=0)
    assert compress.Q8.tag == "q8" and compress.Q16.tag == "q16"
    assert compress.TOPK.tag == "topk" and compress.RAW.tag == "raw"


def test_transport_aggregation_mismatch_rejected():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = TreeConfig(max_depth=2, num_bins=8)
    with pytest.raises(ValueError, match="does not apply"):
        vfl.make_vfl_backend(mesh, cfg, aggregation="histogram",
                             transport=compress.TOPK)
    with pytest.raises(ValueError, match="does not apply"):
        vfl.make_vfl_backend(mesh, cfg, aggregation="argmax",
                             transport=compress.Q8)


def test_named_backend_rejects_conflicting_transport_kwarg():
    """The registry name encodes the transport; a conflicting explicit
    transport= must error rather than silently ship a different format."""
    from repro.core import backend as backend_mod

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = TreeConfig(max_depth=2, num_bins=8)
    with pytest.raises(ValueError, match="encodes transport"):
        backend_mod.get_backend("vfl-histogram-q8", mesh=mesh, tree=cfg,
                                transport=compress.Q16)
    # explicit None defers to the name; explicit on the plain name works
    bk = backend_mod.get_backend("vfl-histogram-q8", mesh=mesh, tree=cfg,
                                 transport=None)
    assert bk.descriptor.transport == "q8"
    bk = backend_mod.get_backend("vfl-histogram", mesh=mesh, tree=cfg,
                                 transport=compress.Q8)
    assert bk.descriptor.transport == "q8"


# ---------------------------------------------------------------------------
# Transport correctness on a 1-party mesh (full shard_map path, one device)
# ---------------------------------------------------------------------------
def _toy_forest_inputs(n=600, d=4, num_bins=16, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    binned, _ = binning.fit_bin(x, num_bins)
    g, h = losses.grad_hess("logistic", y, jnp.zeros(n))
    smask, fmask = forest.sample_masks(jax.random.PRNGKey(7), n, d, 3, 0.8, 1.0)
    return binned, g, h, smask, fmask


def test_topk_bit_identical_to_centralized():
    from repro.compat import use_mesh

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = TreeConfig(max_depth=3, num_bins=16)
    binned, g, h, smask, fmask = _toy_forest_inputs()
    trees_c, _ = forest.build_forest(binned, g, h, smask, fmask, cfg)
    bk = vfl.make_vfl_backend(mesh, cfg, aggregation="argmax",
                              transport=compress.TOPK)
    with use_mesh(mesh):
        trees_f, _ = bk.build_forest(binned, g, h, smask, fmask, cfg)
    np.testing.assert_array_equal(np.asarray(trees_c.feature),
                                  np.asarray(trees_f.feature))
    np.testing.assert_array_equal(np.asarray(trees_c.threshold),
                                  np.asarray(trees_f.threshold))


def test_quantized_backend_close_to_centralized():
    from repro.compat import use_mesh

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = TreeConfig(max_depth=3, num_bins=16)
    binned, g, h, smask, fmask = _toy_forest_inputs()
    trees_c, pred_c = forest.build_forest(binned, g, h, smask, fmask, cfg)
    bk = vfl.make_vfl_backend(mesh, cfg, aggregation="histogram",
                              transport=compress.Q16)
    with use_mesh(mesh):
        trees_f, pred_f = bk.build_forest(binned, g, h, smask, fmask, cfg)
    # int16 quantization at toy scale: identical structure, close leaves
    np.testing.assert_array_equal(np.asarray(trees_c.feature),
                                  np.asarray(trees_f.feature))
    np.testing.assert_allclose(np.asarray(pred_c), np.asarray(pred_f),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Measured bytes == predicted wire model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("aggregation,transport", [
    ("histogram", None),
    ("histogram", compress.Q8),
    ("histogram", compress.Q16),
    ("argmax", None),
    ("argmax", compress.TOPK),
])
def test_probe_matches_wire_model(aggregation, transport):
    """Every collective's actual traced payload == the per-party wire-model
    formula, byte for byte (1-party mesh; multi-party in selftest.py)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = TreeConfig(max_depth=3, num_bins=16)  # hist_subtraction default ON
    n, d = 500, 4
    per_tree, grad = compress.probe_tree_cost(
        mesh, cfg, aggregation=aggregation, transport=transport,
        n_samples=n, num_features=d,
    )
    wire = protocol.wire_party_tree_cost(n, d, cfg.num_bins, cfg.max_depth,
                                         aggregation, transport,
                                         cfg.hist_subtraction)
    expected = {k: v for k, v in wire.items() if v and k != "grad_broadcast"}
    assert per_tree == expected
    assert grad == n * 2 * 4


def test_ledger_reconciles_and_breaks_down():
    cfg = FedGBFConfig(rounds=3, n_trees_max=4, n_trees_min=2,
                       rho_id_min=0.2, rho_id_max=0.5)
    spec = protocol.ProtocolSpec(n_samples=400, party_dims=(3, 3),
                                 num_bins=16, max_depth=3)
    ledger = protocol.ProtocolLedger(spec=spec, cfg=cfg)
    per_tree = protocol.wire_party_tree_cost(400, 3, 16, 3, "histogram", None)
    per_tree = {k: v for k, v in per_tree.items() if v}
    ledger.record_run(per_tree, grad_per_round=400 * 2 * 4)
    assert ledger.matches()
    rec = ledger.reconcile()
    assert rec["total"]["measured"] == rec["total"]["predicted"] > 0
    # a deliberate mismatch is caught
    ledger.record_measured("histograms", 1)
    assert not ledger.matches()
    # per-mode totals let benchmarks diff aggregation modes directly
    bd = ledger.breakdown()
    assert set(bd["modes"]) == {"histogram", "histogram+sub", "argmax"}
    assert bd["modes"]["histogram"] > bd["modes"]["argmax"]
    # the subtraction pipeline's histogram-phase cut is visible in the
    # breakdown: 7 -> 4 node-histograms per depth-3 tree, exactly 1.75x
    hp = bd["hist_phase_by_mode"]
    assert hp["histogram"] / hp["histogram+sub"] == 7 / 4
    assert bd["modes"]["histogram"] > bd["modes"]["histogram+sub"]
    # and the paper-world Paillier model rides along
    assert bd["predicted_paillier"]["total"] > bd["modes"]["histogram"]


def test_wire_model_quantized_reduction_factor():
    """The q8 histogram-phase formula yields the >= 4x reduction the
    acceptance demands (5.33x at B = 32, channel scales included)."""
    raw = protocol.wire_party_tree_cost(1000, 8, 32, 3, "histogram", None)
    q8 = protocol.wire_party_tree_cost(1000, 8, 32, 3, "histogram", compress.Q8)
    assert raw["histograms"] / q8["histograms"] >= 4.0
    q16 = protocol.wire_party_tree_cost(1000, 8, 32, 3, "histogram", compress.Q16)
    assert raw["histograms"] / q16["histograms"] >= 2.0


def test_wire_model_compaction_active_width():
    """Frontier compaction (DESIGN.md §9): the wire model ships the static
    live-slot budget per level, not the 2^level frontier — at depth 5 with
    budget 4 the direct pipeline drops 31 -> 15 node-histograms per tree
    and the subtraction pipeline 16 -> 12 (left children at PARENT active
    width), composing in one expression."""
    full = protocol.wire_party_tree_cost(1000, 8, 32, 5, "histogram", None,
                                         hist_subtraction=False)
    comp = protocol.wire_party_tree_cost(1000, 8, 32, 5, "histogram", None,
                                         hist_subtraction=False,
                                         max_active_nodes=4)
    assert full["histograms"] / comp["histograms"] == 31 / 15
    sub = protocol.wire_party_tree_cost(1000, 8, 32, 5, "histogram", None,
                                        hist_subtraction=True)
    sub_comp = protocol.wire_party_tree_cost(1000, 8, 32, 5, "histogram",
                                             None, hist_subtraction=True,
                                             max_active_nodes=4)
    assert sub["histograms"] / sub_comp["histograms"] == 16 / 12
    # per-level profile: full root, parent-width left children, budget cap
    levels = protocol.wire_hist_level_bytes(8, 32, 5, None, True, 4)
    per_node = 32 * 3 * 4 * 8
    assert levels == [1 * per_node, 1 * per_node, 2 * per_node,
                      4 * per_node, 4 * per_node]


# ---------------------------------------------------------------------------
# GOSS masks
# ---------------------------------------------------------------------------
def test_goss_counts_edges():
    assert forest.goss_counts(100, 0.3, 0.5) == (15, 15)
    n_top, n_rand = forest.goss_counts(100, 0.01, 0.5)  # tiny budget
    assert n_top == 0 and n_rand == 1
    n_top, n_rand = forest.goss_counts(100, 1.0, 1.0)   # degenerate top-heavy
    assert n_top <= 99 and n_rand >= 1 and n_top + n_rand <= 100


def test_goss_masks_counts_weights_and_top_set():
    rng = np.random.default_rng(2)
    n, d = 500, 6
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    n_top, n_rand = forest.goss_counts(n, 0.3, 0.5)
    smask, fmask = forest.goss_masks(
        jax.random.PRNGKey(3), g, d, 4, n_top, n_rand, d_keep=4
    )
    sm = np.asarray(smask)
    amp = (n - n_top) / n_rand
    order = np.argsort(-np.abs(np.asarray(g)))
    for t in range(4):
        kept = sm[t] > 0
        assert (sm[t] == 1.0).sum() == n_top
        assert kept.sum() == n_top + n_rand
        np.testing.assert_allclose(sm[t][kept & (sm[t] != 1.0)], amp, rtol=1e-6)
    # the top-|g| set is deterministic and shared by all trees
    assert (sm[:, order[:n_top]] == 1.0).all()
    assert np.asarray(fmask).sum(axis=1).tolist() == [4] * 4


def test_goss_prefix_stable_and_fmask_matches_uniform():
    """fold_in key discipline: any subset of tree slots draws exactly the
    masks a full draw produces, and the feature masks equal the uniform
    path's draw for the same keys (same (sample, feature) key split)."""
    rng = np.random.default_rng(4)
    n, d = 300, 5
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    key = jax.random.PRNGKey(11)
    s5, f5 = forest.goss_masks(key, g, d, 5, 40, 50, d_keep=3)
    s2, f2 = forest.goss_masks(key, g, d, 2, 40, 50, d_keep=3)
    np.testing.assert_array_equal(np.asarray(s5[:2]), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(f5[:2]), np.asarray(f2))
    _, f_uniform = forest.sample_masks_counts(key, n, d, 5, 90, 3)
    np.testing.assert_array_equal(np.asarray(f5), np.asarray(f_uniform))


def test_goss_histogram_sums_unbiased():
    """The amplified weights keep the masked (g, h, count) sums unbiased:
    averaging over many keys recovers the full-data sums."""
    rng = np.random.default_rng(5)
    n = 400
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    n_top, n_rand = forest.goss_counts(n, 0.4, 0.5)
    keys = forest.fold_in_keys(jax.random.PRNGKey(0), jnp.arange(256))
    smask, _ = forest.goss_masks_from_keys(keys, g, 2, n_top, n_rand, 2)
    est = np.asarray(smask * g[None, :]).sum(axis=1)
    full = float(jnp.sum(g))
    assert abs(est.mean() - full) < 4 * est.std() / 16 + 1e-3
