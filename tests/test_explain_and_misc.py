"""Explainability utilities + remaining substrate coverage (metrics oracle,
token pipeline, serving loop, runtime-model algebra)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting, explain, metrics, runtime_model
from repro.core.types import FedGBFConfig, TreeConfig
from repro.data import synthetic, tabular, tokens


def _tiny_model(n=800, d=6, rounds=4):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    # only features 0 and 1 carry signal
    y = ((x[:, 0] + 0.5 * x[:, 1] + rng.normal(0, 0.3, n)) > 0).astype(np.float32)
    cfg = FedGBFConfig(rounds=rounds, n_trees_max=3, n_trees_min=3,
                       rho_id_min=0.8, rho_id_max=0.8,
                       tree=TreeConfig(max_depth=3, num_bins=16))
    model, _ = boosting.train_fedgbf(
        jnp.asarray(x), jnp.asarray(y), cfg, jax.random.PRNGKey(0)
    )
    return model, d


def test_feature_importance_finds_signal():
    model, d = _tiny_model()
    imp = explain.feature_importance(model, d)
    assert imp.shape == (d,)
    assert imp.sum() == pytest.approx(1.0)
    # the two informative features dominate
    assert imp[0] + imp[1] > 0.6
    assert np.argmax(imp) in (0, 1)


def test_feature_importance_packed_parity():
    """The PackedEnsemble path (tree_scale-weighted) matches the per-round
    forests path to float tolerance, for both kinds — so checkpoint-loaded
    packed models are explainable without unpacking."""
    from repro.core.types import pack_ensemble

    model, d = _tiny_model()
    # plus a dynamic-schedule model: ragged rounds exercise per-round
    # tree_scale weights (lr / n_trees varies across the packed tree axis)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(600, 5)).astype(np.float32)
    y = ((x[:, 0] + rng.normal(0, 0.3, 600)) > 0).astype(np.float32)
    dyn_cfg = FedGBFConfig(rounds=4, n_trees_max=5, n_trees_min=2,
                           rho_id_min=0.4, rho_id_max=0.8,
                           tree=TreeConfig(max_depth=3, num_bins=16))
    dyn_model, _ = boosting.train_fedgbf(
        jnp.asarray(x), jnp.asarray(y), dyn_cfg, jax.random.PRNGKey(2))
    for m, dd in ((model, d), (dyn_model, 5)):
        pe = pack_ensemble(m)
        for kind in ("gain", "count"):
            ref = explain.feature_importance(m, dd, kind)
            packed = explain.feature_importance(pe, dd, kind)
            np.testing.assert_allclose(packed, ref, rtol=1e-5, atol=1e-8)


def test_feature_importance_packed_from_checkpoint(tmp_path):
    """End-to-end: a reloaded packed checkpoint explains like the original
    model (the serving-side use case the PackedEnsemble path exists for)."""
    from repro.checkpoint import io as ckpt_io

    model, d = _tiny_model(rounds=2)
    path = str(tmp_path / "ckpt")
    ckpt_io.save_ensemble(path, model)
    loaded = ckpt_io.load_ensemble(path)
    np.testing.assert_allclose(
        explain.feature_importance(loaded, d),
        explain.feature_importance(model, d),
        rtol=1e-5, atol=1e-8,
    )
    part = tabular.partition_from_dims([2, 4])
    pi = explain.party_importance(loaded, part)
    assert sum(pi.values()) == pytest.approx(1.0)


def test_party_importance_partitions_to_one():
    model, d = _tiny_model()
    part = tabular.partition_from_dims([2, 4])
    pi = explain.party_importance(model, part)
    assert set(pi) == {"party_0", "party_1"}
    assert sum(pi.values()) == pytest.approx(1.0)
    assert pi["party_0"] > 0.5  # signal features live in party 0's slice


def test_dump_tree_renders():
    model, _ = _tiny_model(rounds=1)
    text = explain.dump_tree(model, 0, 0)
    assert "leaf[" in text and ("if f" in text or "pass-through" in text)


def test_auc_against_bruteforce():
    rng = np.random.default_rng(1)
    y = jnp.asarray(rng.integers(0, 2, 200), jnp.float32)
    s = jnp.asarray(rng.normal(size=200), jnp.float32)
    # brute-force pairwise AUC
    yn, sn = np.asarray(y), np.asarray(s)
    pos, neg = sn[yn == 1], sn[yn == 0]
    wins = (pos[:, None] > neg[None, :]).sum() + 0.5 * (
        pos[:, None] == neg[None, :]
    ).sum()
    expected = wins / (len(pos) * len(neg))
    assert float(metrics.auc(y, s)) == pytest.approx(expected, abs=1e-5)


def test_f1_and_accuracy_edges():
    y = jnp.asarray([1, 1, 0, 0], jnp.float32)
    p = jnp.asarray([0.9, 0.2, 0.8, 0.1], jnp.float32)
    assert float(metrics.accuracy(y, p)) == pytest.approx(0.5)
    # tp=1 fp=1 fn=1 -> f1 = 2/(2+1+1)
    assert float(metrics.f1_score(y, p)) == pytest.approx(0.5)


def test_token_pipeline_shapes_and_determinism():
    it = tokens.batches(vocab=512, batch_size=4, seq_len=64, seed=3,
                        num_batches=2)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 64) and b1["labels"].shape == (4, 64)
    assert b1["tokens"].max() < 512 and b1["tokens"].min() >= 0
    # next-token alignment
    it2 = tokens.batches(vocab=512, batch_size=4, seq_len=64, seed=3,
                         num_batches=1)
    b1b = next(it2)
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])


def test_serve_generate_loop():
    from repro.configs import get_smoke_config
    from repro.launch.serve import generate
    from repro.models import model as model_mod

    cfg = get_smoke_config("smollm-135m")
    params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)), jnp.int32
    )
    out = generate(params, cfg, prompts, gen_len=6)
    assert out.shape == (2, 14)
    assert int(out.max()) < cfg.vocab and int(out.min()) >= 0


def test_runtime_model_degenerate_equals_secureboost():
    """FedGBF with 1 tree/round and alpha=1 must cost exactly T_S."""
    cfg = FedGBFConfig(rounds=13, n_trees_max=1, n_trees_min=1,
                       rho_id_min=1.0, rho_id_max=1.0)
    est = runtime_model.estimate_fedgbf_runtime(cfg, t_unit_s=2.0, t0_s=5.0)
    ts = runtime_model.estimate_secureboost_runtime(13, 2.0, t0_s=5.0)
    assert est.lower_s == pytest.approx(ts)
    assert est.upper_s == pytest.approx(ts)


def test_runtime_model_paper_ratio():
    """The paper's §4.3 headline: ideal-parallel FedGBF ~22-26% of T_S."""
    cfg = boosting.dynamic_fedgbf_config(rounds=20)
    est = runtime_model.estimate_fedgbf_runtime(cfg, t_unit_s=1.0)
    ts = runtime_model.estimate_secureboost_runtime(20, 1.0)
    ratio = est.lower_s / ts
    assert 0.20 <= ratio <= 0.28, ratio
    # worst case still cheaper than SecureBoost. Pure schedule arithmetic
    # gives ~18% saving; the paper reports 6-9% because its estimates carry
    # the measured setup offset T_0 and FATE-side rounding of the schedules.
    assert est.upper_s < ts
    assert 0.10 <= 1 - est.upper_s / ts <= 0.25


def test_vertical_partition_roundtrip():
    part = tabular.partition_from_dims([5, 5])
    assert part.num_parties == 2 and part.num_features == 10
    for f in range(10):
        p = part.owner_of(f)
        assert part.columns(p).start <= f < part.columns(p).stop
