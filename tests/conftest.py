"""Shared fixtures. NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benches must see the real single CPU device; multi-device tests
(federation, dry-run) shell out to subprocess entry points that set
XLA_FLAGS themselves (see tests/test_federation.py, tests/test_dryrun.py)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_credit():
    """A small credit-like dataset shared across core tests."""
    from repro.data import synthetic

    return synthetic.load("default_credit_card", n=4000)
