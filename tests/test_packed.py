"""PackedEnsemble inference path + TreeBackend registry coverage.

The load-bearing guarantee: ``PackedEnsemble`` prediction is bit-for-bit
equal to the legacy per-round loop — including dynamic schedules where
rounds have different n_trees — so the packed path can replace the loop
everywhere (boosting.predict, validation eval, serving) without any
numerical drift.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as backend_mod
from repro.core import boosting
from repro.core.types import (
    FedGBFConfig,
    PackedEnsemble,
    TreeConfig,
    pack_ensemble,
    unpack_ensemble,
)


def _train(loss: str, dynamic: bool, rounds: int = 5, n: int = 700, d: int = 7,
           seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    signal = x[:, 0] - 0.7 * x[:, 1] + rng.normal(0, 0.4, n)
    y = (signal > 0).astype(np.float32) if loss == "logistic" else signal
    if dynamic:  # 5 -> 2 trees across rounds: ragged per-round tree counts
        cfg = FedGBFConfig(rounds=rounds, loss=loss, n_trees_max=5,
                           n_trees_min=2, rho_id_min=0.3, rho_id_max=0.7,
                           tree=TreeConfig(max_depth=3, num_bins=16))
    else:
        cfg = FedGBFConfig(rounds=rounds, loss=loss, n_trees_max=3,
                           n_trees_min=3, rho_id_min=0.8, rho_id_max=0.8,
                           tree=TreeConfig(max_depth=2, num_bins=16))
    model, _ = boosting.train_fedgbf(
        jnp.asarray(x), jnp.asarray(y), cfg, jax.random.PRNGKey(seed)
    )
    x_test = jnp.asarray(rng.normal(size=(311, d)), jnp.float32)
    return model, x_test


@pytest.mark.parametrize("loss", ["logistic", "squared"])
@pytest.mark.parametrize("dynamic", [False, True])
def test_packed_predict_bitwise_equals_loop(loss, dynamic):
    """Satellite guarantee: packed == legacy loop, bit for bit."""
    model, x_test = _train(loss, dynamic)
    loop = boosting.predict(model, x_test, impl="loop")
    packed = boosting.predict(model, x_test, impl="packed")
    np.testing.assert_array_equal(np.asarray(loop), np.asarray(packed))
    # and through an explicitly packed model object
    pe = pack_ensemble(model)
    np.testing.assert_array_equal(
        np.asarray(loop), np.asarray(boosting.predict(pe, x_test))
    )


@pytest.mark.parametrize("impl", ["weighted", "pallas"])
def test_packed_fast_combiners_match(impl):
    """The single-pass scale combiner and the Pallas kernel agree to fp tol."""
    model, x_test = _train("logistic", dynamic=True)
    ref = boosting.predict(model, x_test, impl="packed")
    out = boosting.predict(model, x_test, impl=impl)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6
    )


def test_pack_unpack_roundtrip_lossless():
    model, _ = _train("squared", dynamic=True)
    pe = pack_ensemble(model)
    assert pe.total_trees == model.total_trees and pe.rounds == model.rounds
    # ragged rounds recorded in the offsets
    sizes = [pe.round_offsets[r + 1] - pe.round_offsets[r]
             for r in range(pe.rounds)]
    assert len(set(sizes)) > 1
    back = unpack_ensemble(pe)
    for f_orig, f_back in zip(model.forests, back.forests):
        for a, b in zip(f_orig, f_back):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_is_a_jittable_pytree():
    model, x_test = _train("logistic", dynamic=False, rounds=3)
    pe = pack_ensemble(model)
    leaves, treedef = jax.tree.flatten(pe)
    assert len(leaves) == 6  # arrays only; static aux carries the rest
    from repro.core import binning, tree as tree_mod

    fn = jax.jit(lambda p, x: tree_mod.predict_packed(
        p, binning.bin_data(x, p.bin_edges)))
    out = fn(pe, x_test)
    # under jit XLA may fuse the combiner arithmetic (1-ulp reassociation),
    # so the jitted program is compared at tight tolerance; the bit-for-bit
    # guarantee is for the un-jitted path boosting.predict uses.
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(boosting.predict(model, x_test, impl="loop")),
        rtol=1e-6, atol=1e-7,
    )


def test_ensemble_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import io as ckpt_io

    model, x_test = _train("logistic", dynamic=True, rounds=4)
    path = str(tmp_path / "ckpt")
    ckpt_io.save_ensemble(path, model)  # accepts the unpacked model too
    loaded = ckpt_io.load_ensemble(path)
    assert isinstance(loaded, PackedEnsemble)
    assert loaded.round_offsets == pack_ensemble(model).round_offsets
    assert loaded.loss == model.loss
    np.testing.assert_array_equal(
        np.asarray(boosting.predict(model, x_test, impl="loop")),
        np.asarray(boosting.predict(loaded, x_test)),
    )


def test_serve_stream_matches_direct_predict():
    """The serving microbatch loop (with ragged last-batch padding) scores
    exactly like a direct full-batch packed predict."""
    from repro.launch.serve_fedgbf import score_stream

    model, x_test = _train("logistic", dynamic=True, rounds=4)
    pe = pack_ensemble(model)
    x_np = np.asarray(x_test)  # 311 rows: 2 full batches of 128 + ragged 55
    scores, sm = score_stream(pe, x_np, batch_size=128, impl="packed")
    direct = jax.nn.sigmoid(boosting.predict(pe, x_test))
    np.testing.assert_allclose(scores, np.asarray(direct), rtol=1e-6, atol=1e-7)
    assert sm.batches.value == 3 and sm.latency.count == 3
    assert sm.rows.value == 311 and sm.padded_rows.value == 3 * 128 - 311


# ---------------------------------------------------------------------------
# TreeBackend registry
# ---------------------------------------------------------------------------
def test_backend_registry_names():
    names = backend_mod.available_backends()
    for expected in ("local", "local-pallas", "vfl-histogram", "vfl-argmax"):
        assert expected in names
    with pytest.raises(ValueError, match="unknown backend"):
        backend_mod.get_backend("no-such-backend")
    with pytest.raises(ValueError, match="mesh"):
        backend_mod.get_backend("vfl-histogram")  # vfl names need a mesh


def test_named_backend_matches_default():
    """train_fedgbf(backend="local") == train_fedgbf() bit for bit."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(400, 5)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, 400), jnp.float32)
    cfg = FedGBFConfig(rounds=3, n_trees_max=2, n_trees_min=2,
                       tree=TreeConfig(max_depth=2, num_bins=8))
    m_default, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(1))
    m_named, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(1),
                                       backend="local")
    for f1, f2 in zip(m_default.forests, m_named.forests):
        for a, b in zip(f1, f2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_local_pallas_backend_builds_identical_trees():
    """The Pallas histogram backend is lossless vs segment-sum (interpret
    mode on CPU): same split structure, same predictions."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(512, 6)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, 512), jnp.float32)
    cfg = FedGBFConfig(rounds=2, n_trees_max=2, n_trees_min=2,
                       tree=TreeConfig(max_depth=2, num_bins=16))
    m_seg, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(2))
    m_pal, _ = boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(2),
                                     backend="local-pallas")
    for f1, f2 in zip(m_seg.forests, m_pal.forests):
        np.testing.assert_array_equal(np.asarray(f1.feature), np.asarray(f2.feature))
        np.testing.assert_array_equal(np.asarray(f1.threshold), np.asarray(f2.threshold))
        np.testing.assert_allclose(np.asarray(f1.leaf_weight),
                                   np.asarray(f2.leaf_weight),
                                   rtol=1e-5, atol=1e-6)


def test_backend_descriptor_metadata():
    bk = backend_mod.get_backend("local-pallas")
    assert bk.name == "local-pallas"
    assert bk.descriptor.histogram_impl == "pallas"
    assert not bk.descriptor.is_federated
