"""Fault-tolerant federation runtime (DESIGN.md §13).

Covers the three pillars end-to-end:

* checkpoint hardening — atomic writes, sha256 sidecar verification,
  clear errors on truncated or bit-flipped files;
* chaos transport — deterministic fault plans, checksum detection, and
  bit-identity of the ``-chaos`` twins (zero-fault AND faulty) via the
  selftest's multi-device subprocess slice;
* bit-identical segment resume — a killed-and-resumed training run must
  produce a byte-identical PackedEnsemble and matching history metrics;
* party-dropout degradation — the round mask equals the masked-candidate
  oracle and the runtime schedule is deterministic.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core import boosting
from repro.core.types import pack_ensemble
from repro.federation import chaos as chaos_mod
from repro.federation import runtime

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _toy(n=256, d=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=d)
    y = (x @ w + 0.3 * rng.normal(size=n) > 0).astype(np.float32)
    return x, y


def _train(x, y, cfg, engine="scan", **kw):
    return boosting.train_fedgbf(x, y, cfg, jax.random.PRNGKey(42),
                                 engine=engine, verbose=False, **kw)


def _packed_bytes(model) -> bytes:
    from repro.core.types import PackedEnsemble

    packed = (model if isinstance(model, PackedEnsemble)
              else pack_ensemble(model))
    return b"".join(np.ascontiguousarray(np.asarray(l)).tobytes()
                    for l in jax.tree.leaves(packed))


# ---------------------------------------------------------------------------
# checkpoint hardening
# ---------------------------------------------------------------------------

def test_checkpoint_truncated_npz_raises(tmp_path):
    x, y = _toy()
    cfg = boosting.secureboost_config(rounds=2)
    model, _ = _train(x, y, cfg)
    path = str(tmp_path / "ck")
    ckpt_io.save_ensemble(path, model)
    npz = path + ".npz"
    data = open(npz, "rb").read()
    with open(npz, "wb") as f:  # torn write: half the payload
        f.write(data[: len(data) // 2])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        ckpt_io.load_ensemble(path)


def test_checkpoint_bit_flip_detected(tmp_path):
    x, y = _toy()
    cfg = boosting.secureboost_config(rounds=2)
    model, _ = _train(x, y, cfg)
    path = str(tmp_path / "ck")
    ckpt_io.save_ensemble(path, model)
    npz = path + ".npz"
    with open(npz, "r+b") as f:
        f.seek(100)
        b = f.read(1)
        f.seek(100)
        f.write(bytes([b[0] ^ 0x01]))  # single bit flip
    with pytest.raises(ValueError, match="sha256"):
        ckpt_io.load_ensemble(path)


def test_checkpoint_roundtrip_and_train_state(tmp_path):
    x, y = _toy()
    cfg = boosting.secureboost_config(rounds=3)
    model, hist = _train(x, y, cfg)
    path = str(tmp_path / "state")
    ckpt_io.save_train_state(path, model, margin=hist.final_margin,
                             completed_rounds=3, fingerprint="fp-1")
    state = ckpt_io.load_train_state(path)
    assert state["completed_rounds"] == 3
    assert state["config_fingerprint"] == "fp-1"
    np.testing.assert_array_equal(state["margin"], hist.final_margin)
    assert _packed_bytes(state["packed"]) == _packed_bytes(model)


def test_payload_checksum_detects_bit_flip():
    x = np.linspace(-3, 3, 64, dtype=np.float32).reshape(4, 16)
    base = int(chaos_mod.payload_checksum(np.asarray(x)))
    for rand in (0, 137, 999_999_937):
        flipped = np.asarray(chaos_mod._flip_one_bit(np.asarray(x), rand))
        assert int(chaos_mod.payload_checksum(flipped)) != base
    zeroed = np.zeros_like(x)
    assert int(chaos_mod.payload_checksum(zeroed)) != base


def test_chaos_plan_deterministic():
    spec = chaos_mod.ChaosSpec(drop=0.2, corrupt=0.1, dup=0.1, seed=9)
    plans = [[chaos_mod.plan_for_slot(spec, s) for s in range(20)]
             for _ in range(2)]
    assert plans[0] == plans[1]
    assert any(fails for fails, _ in plans[0])  # faults actually drawn
    zero = chaos_mod.ChaosSpec()
    assert zero.zero_fault
    assert all(chaos_mod.plan_for_slot(zero, s) == ([], "clean")
               for s in range(20))
    with pytest.raises(ValueError):
        chaos_mod.ChaosSpec(drop=0.7, corrupt=0.5)
    with pytest.raises(ValueError):
        chaos_mod.ChaosSpec(drop=-0.1)


# ---------------------------------------------------------------------------
# chaos transport bit-identity + faulty reconciliation (multi-device slice)
# ---------------------------------------------------------------------------

def test_chaos_selftest_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.federation.selftest", "--chaos"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL CHAOS SELF-TESTS PASSED" in out.stdout


# ---------------------------------------------------------------------------
# bit-identical segment resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["loop", "scan"])
def test_resume_equals_uninterrupted(engine, tmp_path):
    x, y = _toy(n=200, d=8, seed=1)
    xv, yv = _toy(n=80, d=8, seed=2)
    cfg = boosting.secureboost_config(rounds=7, learning_rate=0.3)

    full_model, full_hist = _train(x, y, cfg, engine=engine,
                                   x_valid=xv, y_valid=yv, eval_every=2)

    # "kill" after round 3: checkpoint the carry through checkpoint.io
    m1, h1 = _train(x, y, cfg, engine=engine, x_valid=xv, y_valid=yv,
                    eval_every=2, stop_round=3)
    path = str(tmp_path / "seg")
    ckpt_io.save_train_state(path, m1, margin=h1.final_margin,
                             completed_rounds=3, fingerprint="fp",
                             margin_valid=h1.final_margin_valid)
    state = ckpt_io.load_train_state(path)
    assert state["completed_rounds"] == 3

    # resume from the persisted carry
    m2, h2 = _train(x, y, cfg, engine=engine, x_valid=xv, y_valid=yv,
                    eval_every=2, start_round=3,
                    init_margin=state["margin"],
                    init_margin_valid=state["margin_valid"])

    from repro.core.types import unpack_ensemble

    prefix = unpack_ensemble(state["packed"])
    stitched = boosting.EnsembleModel(
        forests=prefix.forests + m2.forests,
        learning_rate=m1.learning_rate, base_score=m1.base_score,
        bin_edges=m1.bin_edges, loss=m1.loss, max_depth=m1.max_depth,
    )
    # byte-identical PackedEnsemble
    assert _packed_bytes(stitched) == _packed_bytes(full_model)
    # history metrics of the stitched run match the uninterrupted run
    assert h1.rounds + h2.rounds == full_hist.rounds
    assert h1.train + h2.train == full_hist.train
    assert h1.valid + h2.valid == full_hist.valid
    np.testing.assert_array_equal(h2.final_margin, full_hist.final_margin)


def test_resume_argument_validation():
    x, y = _toy(n=64)
    cfg = boosting.secureboost_config(rounds=4)
    with pytest.raises(ValueError, match="start_round"):
        _train(x, y, cfg, start_round=2)  # resume without a margin carry
    with pytest.raises(ValueError, match="init_margin"):
        _train(x, y, cfg, init_margin=np.zeros(64, np.float32))
    with pytest.raises(ValueError, match="round window"):
        _train(x, y, cfg, stop_round=9)


# ---------------------------------------------------------------------------
# party-dropout degradation
# ---------------------------------------------------------------------------

def test_dropout_schedule_deterministic_and_masks():
    pol = runtime.RetryPolicy(max_retries=2)
    s1 = runtime.dropout_schedule(0.5, 10, 4, seed=3, policy=pol)
    s2 = runtime.dropout_schedule(0.5, 10, 4, seed=3, policy=pol)
    np.testing.assert_array_equal(s1.degraded, s2.degraded)
    np.testing.assert_array_equal(s1.retries, s2.retries)
    assert s1.backoff_s == s2.backoff_s
    # degraded <=> all 1 + max_retries attempts failed
    assert (s1.retries[s1.degraded] == pol.max_retries).all()
    mask = runtime.degradation_masks(s1.degraded, d=8, num_parties=4)
    assert mask is not None and mask.shape == (10, 8)
    for m in range(10):
        for p in range(4):
            cols = mask[m, p * 2:(p + 1) * 2]
            assert cols.all() != s1.degraded[m, p] or not cols.any()
    # zero-dropout schedule lowers to None (pre-§13 path untouched)
    clean = runtime.dropout_schedule(0.0, 10, 4, seed=3, policy=pol)
    assert runtime.degradation_masks(clean.degraded, 8, 4) is None


def test_degradation_equals_masked_candidate_oracle():
    """A degraded round is bit-identical to a run whose candidate masks
    never contained the degraded party's columns (single-device oracle;
    the federated twin of this assertion runs in the --chaos selftest)."""
    x, y = _toy(n=220, d=8, seed=5)
    cfg = boosting.secureboost_config(rounds=4)
    sched = runtime.dropout_schedule(
        0.6, cfg.rounds, 4, seed=11, policy=runtime.RetryPolicy(max_retries=0))
    mask = runtime.degradation_masks(sched.degraded, 8, 4)
    assert mask is not None
    m_scan, _ = _train(x, y, cfg, engine="scan", round_feature_mask=mask)
    m_loop, _ = _train(x, y, cfg, engine="loop", round_feature_mask=mask)
    assert _packed_bytes(m_scan) == _packed_bytes(m_loop)
    packed = pack_ensemble(m_scan)
    for r in range(packed.rounds):
        trees_r = packed.round_trees(r)
        feats = np.asarray(trees_r.feature)
        gains = np.asarray(trees_r.gain)
        banned = np.nonzero(~mask[r])[0]
        assert not (np.isin(feats, banned) & (gains > 0)).any()


def test_retry_policy_backoff():
    pol = runtime.RetryPolicy(max_retries=4, base_delay_s=0.1, max_delay_s=0.5)
    assert pol.backoff(0) == pytest.approx(0.1)
    assert pol.backoff(1) == pytest.approx(0.2)
    assert pol.backoff(3) == pytest.approx(0.5)  # capped
    with pytest.raises(ValueError):
        runtime.RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        runtime.dropout_schedule(1.0, 5, 2)


# ---------------------------------------------------------------------------
# serving hardening
# ---------------------------------------------------------------------------

def test_serve_rejects_inf_rows_and_hot_reload(tmp_path):
    from repro.launch import serve_fedgbf

    x, y = _toy(n=300, d=6, seed=7)
    cfg = boosting.secureboost_config(rounds=2)
    model, _ = _train(x, y, cfg)
    packed = pack_ensemble(model)

    req = x[:64].copy()
    req[3, 2] = np.inf
    req[10, 0] = -np.inf
    req[20, 1] = np.nan  # NaN is a missing value, NOT a rejection
    scores, sm = serve_fedgbf.score_stream(packed, req, batch_size=32)
    assert sm.rows_rejected.value == 2
    assert np.isnan(scores[3]) and np.isnan(scores[10])
    assert np.isfinite(scores[20])
    good = np.ones(64, bool)
    good[[3, 10]] = False
    assert np.isfinite(scores[good]).all()

    # hot reload: corrupt candidate refused, previous model keeps serving
    ok_path = str(tmp_path / "ok")
    ckpt_io.save_ensemble(ok_path, packed)
    bad_path = str(tmp_path / "bad")
    ckpt_io.save_ensemble(bad_path, packed)
    with open(bad_path + ".npz", "r+b") as f:
        f.seek(64)
        b = f.read(1)
        f.seek(64)
        f.write(bytes([b[0] ^ 0xFF]))
    slot = serve_fedgbf.ModelSlot(packed, metrics=sm)
    assert not slot.try_reload(bad_path)
    assert sm.reload_failures.value == 1
    assert slot.packed is packed  # previous ensemble still serving
    assert slot.try_reload(ok_path)
    assert sm.reloads.value == 1
    rendered = sm.render()
    assert "fedgbf_serve_rows_rejected_total 2" in rendered
    assert "fedgbf_serve_reload_failures_total 1" in rendered
